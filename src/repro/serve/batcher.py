"""Continuous batching over shape-bucketed executables.

The serving pattern ALTIS-era fixed-shape loops cannot measure: bursty
arrivals of *heterogeneous* request sizes, coalesced into batches before
they reach the device. Every function here consumes a mixed-shape
:class:`~repro.serve.loadgen.Schedule` (each request tagged with a shape
bucket label) plus a table of precompiled zero-arg executables,
``calls[bucket][width]`` — one vmapped program per (shape bucket, batch
width), built by the engine through the ordinary compile caches.

Four dispatch policies, lowest to highest coalescing:

- :func:`serve_mixed_loop` — synchronize after every request (width 1);
  the no-concurrency floor every batching speedup is measured against.
- :func:`serve_mixed_lanes` — width-1 dispatch through a
  :class:`~repro.serve.lanes.LaneSet`: host/device overlap but no
  coalescing, the HyperQ-style middle ground.
- :func:`serve_fixed_batched` — a fixed-width vmap per bucket that waits
  for a full batch (the ``batched`` dispatch mode ``serve/lanes.py``
  promised occupancy numbers for); only the end-of-stream flush pads.
- :func:`serve_dynamic` — the continuous batcher: per-bucket queues,
  dispatched into the *largest* power-of-two width that fits under a
  latency budget. A batch goes out when its queue can fill ``max_batch``
  or when its oldest request has waited ``budget_s``; a partial batch is
  padded up to the smallest width that holds it.

Padding is **measured, not hidden**: every dispatched batch is recorded
as a :class:`BatchExecution` with its width (slots the program computes)
and fill (slots carrying real requests), and :class:`BatchReport`
aggregates them into ``occupancy`` (filled / total slots) and
``padding_waste`` (padded / total slots == 1 - occupancy). Latencies are
stamped from each request's *scheduled arrival*, so time spent waiting in
a coalescing queue counts toward latency — the batcher's budget knob
trades exactly that wait against device efficiency.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable, Mapping, Sequence

import jax

from repro.serve.lanes import Completion, LaneSet, lane_depth
from repro.serve.loadgen import Request, Schedule

__all__ = [
    "BatchExecution",
    "BatchReport",
    "bucket_widths",
    "serve_mixed_loop",
    "serve_mixed_lanes",
    "serve_fixed_batched",
    "serve_dynamic",
]

# Poll interval while waiting for arrivals / in-flight batches: long
# enough not to burn a core spinning, short enough (100 us) to be noise
# against the multi-ms latency budgets this path measures.
_POLL_S = 1e-4


@dataclasses.dataclass(frozen=True)
class BatchExecution:
    """One dispatched device program: ``width`` slots computed, ``filled``
    of them carrying real requests (the rest are padding). ``cause`` says
    *why* the batch went out — ``full`` (the queue could fill the largest
    width), ``expired`` (the oldest request hit the latency budget), or
    ``flush`` (end of stream) — so budget expiries are countable in the
    trace, not inferred from fill ratios."""

    bucket: str
    width: int
    filled: int
    t_dispatch: float
    t_done: float
    cause: str = "full"

    def __post_init__(self) -> None:
        if not 1 <= self.filled <= self.width:
            raise ValueError(
                f"batch fill must be in [1, width={self.width}], "
                f"got {self.filled}"
            )


@dataclasses.dataclass(frozen=True)
class BatchReport:
    """Everything one serving run dispatched, with the padding accounted."""

    completions: tuple[Completion, ...]
    batches: tuple[BatchExecution, ...]

    @property
    def total_slots(self) -> int:
        return sum(b.width for b in self.batches)

    @property
    def filled_slots(self) -> int:
        return sum(b.filled for b in self.batches)

    @property
    def occupancy(self) -> float:
        """Filled / total dispatched slots (1.0 = no padding ever)."""
        total = self.total_slots
        return self.filled_slots / total if total else 0.0

    @property
    def padding_waste(self) -> float:
        """Padded / total dispatched slots (== 1 - occupancy)."""
        total = self.total_slots
        return (total - self.filled_slots) / total if total else 0.0

    @property
    def mean_width(self) -> float:
        return self.total_slots / len(self.batches) if self.batches else 0.0


def bucket_widths(dispatch: str, max_batch: int) -> tuple[int, ...]:
    """The batch widths a dispatch policy needs compiled per bucket:
    powers of two up to ``max_batch`` for the dynamic batcher (its pad
    targets), just ``max_batch`` for the fixed-width mode, width 1 for
    the uncoalesced policies."""
    if dispatch == "dynamic":
        widths = [1]
        while widths[-1] * 2 <= max_batch:
            widths.append(widths[-1] * 2)
        if widths[-1] != max_batch:
            widths.append(max_batch)  # non-power-of-two edge stays reachable
        return tuple(widths)
    if dispatch == "batched":
        return (max_batch,)
    return (1,)


CallTable = Mapping[str, Mapping[int, Callable[[], Any]]]


def _call(calls: CallTable, bucket: str, width: int) -> Any:
    try:
        return calls[bucket][width]()
    except KeyError:
        raise KeyError(
            f"no executable for bucket={bucket!r} width={width}; "
            f"have {sorted((b, w) for b in calls for w in calls[b])}"
        ) from None


def serve_mixed_loop(calls: CallTable, schedule: Schedule) -> BatchReport:
    """``loop`` dispatch over a mixed-shape schedule: wait for each
    request's scheduled arrival, run its bucket's width-1 program,
    synchronize, repeat. Every batch is width 1 and fully occupied, so
    occupancy is 1.0 by construction — the floor the batcher's
    amortization is measured against."""
    completions: list[Completion] = []
    batches: list[BatchExecution] = []
    t0 = time.perf_counter()
    for req in schedule:
        target = t0 + req.arrival_s
        delay = target - time.perf_counter()
        if delay > 0:
            time.sleep(delay)
        t_dispatch = time.perf_counter()
        jax.block_until_ready(_call(calls, req.bucket, 1))
        t_done = time.perf_counter()
        completions.append(
            Completion(
                index=req.index, lane=0, t_submit=target, t_done=t_done,
                warmup=req.warmup, bucket=req.bucket,
            )
        )
        batches.append(
            BatchExecution(
                bucket=req.bucket, width=1, filled=1,
                t_dispatch=t_dispatch, t_done=t_done,
            )
        )
    return BatchReport(tuple(completions), tuple(batches))


def serve_mixed_lanes(
    calls: CallTable,
    schedule: Schedule,
    *,
    n_lanes: int,
    concurrency: int = 32,
) -> BatchReport:
    """``lanes`` dispatch over a mixed-shape schedule: each request's
    width-1 program goes into the least-loaded dispatch lane at its
    scheduled arrival (the :func:`~repro.serve.lanes.run_open_loop`
    policy, with the call chosen per request bucket). Overlap without
    coalescing: width-1 batches, occupancy 1.0."""
    lanes = LaneSet(n_lanes, lane_depth(concurrency, n_lanes))
    completions: list[Completion] = []
    batches: list[BatchExecution] = []
    t0 = time.perf_counter()
    for req in schedule:
        target = t0 + req.arrival_s
        delay = target - time.perf_counter()
        if delay > 0:
            time.sleep(delay)
        t_dispatch = time.perf_counter()
        completions.extend(lanes.submit(_call(calls, req.bucket, 1), req, target))
        completions.extend(lanes.poll())
        batches.append(
            BatchExecution(
                bucket=req.bucket, width=1, filled=1,
                t_dispatch=t_dispatch, t_done=t_dispatch,
            )
        )
    completions.extend(lanes.drain())
    return BatchReport(tuple(completions), tuple(batches))


class _InflightBatches:
    """FIFO window of dispatched batches, capped by in-flight *requests*
    (padding slots do not count against the cap — they are waste, not
    work the client asked for)."""

    def __init__(self, max_inflight_requests: int) -> None:
        self.cap = max(1, max_inflight_requests)
        self._inflight: deque[
            tuple[list[Request], str, int, float, str, Any]
        ] = deque()

    @property
    def inflight_requests(self) -> int:
        return sum(len(members) for members, *_ in self._inflight)

    def add(
        self, members: list[Request], bucket: str, width: int,
        t_dispatch: float, cause: str, out: Any,
    ) -> None:
        self._inflight.append((members, bucket, width, t_dispatch, cause, out))

    def poll(self, t0: float) -> tuple[list[Completion], list[BatchExecution]]:
        done_c: list[Completion] = []
        done_b: list[BatchExecution] = []
        while self._inflight and _batch_ready(self._inflight[0][5]):
            c, b = self._finish(t0, *self._inflight.popleft())
            done_c.extend(c)
            done_b.append(b)
        return done_c, done_b

    def pop_oldest(self, t0: float) -> tuple[list[Completion], list[BatchExecution]]:
        if not self._inflight:
            return [], []
        c, b = self._finish(t0, *self._inflight.popleft())
        return c, [b]

    def drain(self, t0: float) -> tuple[list[Completion], list[BatchExecution]]:
        done_c: list[Completion] = []
        done_b: list[BatchExecution] = []
        while self._inflight:
            c, b = self._finish(t0, *self._inflight.popleft())
            done_c.extend(c)
            done_b.append(b)
        return done_c, done_b

    def _finish(
        self, t0: float, members: list[Request], bucket: str, width: int,
        t_dispatch: float, cause: str, out: Any,
    ) -> tuple[list[Completion], BatchExecution]:
        jax.block_until_ready(out)
        t_done = time.perf_counter()
        completions = [
            Completion(
                index=req.index, lane=0, t_submit=t0 + req.arrival_s,
                t_done=t_done, warmup=req.warmup, bucket=bucket,
            )
            for req in members
        ]
        batch = BatchExecution(
            bucket=bucket, width=width, filled=len(members),
            t_dispatch=t_dispatch, t_done=t_done, cause=cause,
        )
        return completions, batch


def _batch_ready(out: Any) -> bool:
    return all(
        getattr(leaf, "is_ready", lambda: True)()
        for leaf in jax.tree_util.tree_leaves(out)
    )


def _coalescing_serve(
    calls: CallTable,
    schedule: Schedule,
    *,
    widths_by_bucket: Mapping[str, Sequence[int]],
    budget_s: float,
    concurrency: int,
) -> BatchReport:
    """The shared batched/dynamic core: per-bucket FIFO queues, dispatch
    when a queue can fill its largest width or its oldest request has
    waited ``budget_s`` (or the stream ended — the flush), pad a partial
    batch up to the smallest compiled width that holds it."""
    queues: dict[str, deque[Request]] = {b: deque() for b in widths_by_bucket}
    inflight = _InflightBatches(concurrency)
    completions: list[Completion] = []
    batches: list[BatchExecution] = []
    requests = schedule.requests
    i = 0
    t0 = time.perf_counter()

    def harvest(pairs: tuple[list[Completion], list[BatchExecution]]) -> None:
        completions.extend(pairs[0])
        batches.extend(pairs[1])

    def dispatch(bucket: str, cause: str) -> None:
        widths = widths_by_bucket[bucket]
        q = queues[bucket]
        take = min(len(q), max(widths))
        width = min(w for w in widths if w >= take)
        members = [q.popleft() for _ in range(take)]
        # Retire old batches until this one fits the in-flight window. A
        # batch wider than the whole cap dispatches alone once the window
        # is empty (the cap bounds concurrency, it cannot shrink a batch).
        while inflight.inflight_requests and (
            inflight.inflight_requests + take > inflight.cap
        ):
            harvest(inflight.pop_oldest(t0))
        t_dispatch = time.perf_counter()
        inflight.add(
            members, bucket, width, t_dispatch, cause,
            _call(calls, bucket, width),
        )

    while i < len(requests) or any(queues.values()) or inflight.inflight_requests:
        now = time.perf_counter()
        while i < len(requests) and t0 + requests[i].arrival_s <= now:
            req = requests[i]
            if req.bucket not in queues:
                raise KeyError(
                    f"request {req.index} has bucket {req.bucket!r} with no "
                    f"compiled executables; have {sorted(queues)}"
                )
            queues[req.bucket].append(req)
            i += 1
        harvest(inflight.poll(t0))
        stream_done = i >= len(requests)
        dispatched = False
        for bucket, q in queues.items():
            if not q:
                continue
            full = len(q) >= max(widths_by_bucket[bucket])
            expired = now - (t0 + q[0].arrival_s) >= budget_s
            if full or expired or stream_done:
                dispatch(
                    bucket,
                    "full" if full else ("expired" if expired else "flush"),
                )
                dispatched = True
        if dispatched:
            continue
        # Nothing ready: sleep until the next arrival or the oldest
        # queue deadline, in short slices so in-flight polls stay live.
        next_arrival = (
            t0 + requests[i].arrival_s if i < len(requests) else float("inf")
        )
        oldest = min(
            (t0 + q[0].arrival_s + budget_s for q in queues.values() if q),
            default=float("inf"),
        )
        wake = min(next_arrival, oldest)
        delay = wake - time.perf_counter()
        if delay > 0:
            time.sleep(min(delay, _POLL_S) if inflight.inflight_requests else min(delay, 0.01))
    harvest(inflight.drain(t0))
    return BatchReport(tuple(completions), tuple(batches))


def serve_fixed_batched(
    calls: CallTable,
    schedule: Schedule,
    *,
    batch: int,
    concurrency: int = 32,
) -> BatchReport:
    """``batched`` dispatch: one fixed-width vmap per bucket that waits
    for a full batch before dispatching — occupancy over concurrency, the
    ``serve/lanes.py`` docstring's third mode, now with its occupancy
    actually reported. Only the end-of-stream flush dispatches a padded
    partial batch, and that padding shows up in ``padding_waste``."""
    if batch < 1:
        raise ValueError(f"batch must be >= 1, got {batch}")
    widths = {b: (batch,) for b in calls}
    return _coalescing_serve(
        calls, schedule,
        widths_by_bucket=widths,
        budget_s=float("inf"),
        concurrency=concurrency,
    )


def serve_dynamic(
    calls: CallTable,
    schedule: Schedule,
    *,
    budget_s: float,
    concurrency: int = 32,
) -> BatchReport:
    """Continuous batching: coalesce queued requests of one bucket into
    the largest compiled width available, but never hold a request past
    ``budget_s`` — when the oldest queued request's wait hits the budget,
    the batch goes out at whatever fill it has, padded up to the smallest
    width that holds it. The budget is the latency/efficiency dial:
    0 degenerates to eager width-1 dispatch, infinity to fixed-width
    batching."""
    if budget_s < 0:
        raise ValueError(f"budget_s must be >= 0, got {budget_s}")
    widths = {b: tuple(sorted(calls[b])) for b in calls}
    return _coalescing_serve(
        calls, schedule,
        widths_by_bucket=widths,
        budget_s=budget_s,
        concurrency=concurrency,
    )
