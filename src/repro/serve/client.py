"""Thread-per-lane serving client — host-side issue concurrency.

The single-threaded client (``serve.lanes.run_open_loop`` /
``run_closed_loop``) dispatches every lane from one host thread, so lane
concurrency is serialized at the client: the device may expose N work
queues, but requests enter them one ``call()`` at a time, and host-side
contention between lanes is invisible by construction. The Milabench
serving methodology and the K80→A100 asynchronous-transfer study both
show the client's issue architecture changes what the benchmark measures
— so the threaded client makes it a first-class axis.

Here each :class:`~repro.serve.lanes.DispatchLane` gets its *own issuing
thread*:

- **open loop** (:func:`run_open_loop_threaded`): each thread walks its
  lane's deterministic sub-schedule (``loadgen.open_loop_lane_schedules``
  — seeded child RNG streams whose merge is Poisson at the target QPS),
  sleeping until each scheduled arrival and recording latency from it, so
  queueing delay counts exactly as in the single-threaded convention.
- **closed loop** (:func:`run_closed_loop_threaded`): each thread keeps
  its own lane's window full until the shared deadline.

Completions funnel through a lock-guarded :class:`CompletionSink`; per
lane, the client accounts *dispatch overhead* — the host time spent
inside ``call()`` enqueueing work, which JAX's async dispatch returns
from before the device finishes — so host contention between issuing
threads shows up as a measured number (:class:`LaneReport`), not a
silent skew. A worker that raises stops its lane only; the first error
is re-raised after the join so the engine's fault isolation sees it.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Callable, Sequence

# The client axis is declared next to ServeSpec's validation (one source
# of truth for "which clients exist"); re-exported here for serve users.
from repro.core.plan import SERVE_CLIENTS
from repro.obs import current_tracer
from repro.serve.lanes import Completion, DispatchLane, lane_depth
from repro.serve.loadgen import Request, Schedule

__all__ = [
    "SERVE_CLIENTS",
    "CompletionSink",
    "LaneReport",
    "ClientResult",
    "run_open_loop_threaded",
    "run_closed_loop_threaded",
]


class CompletionSink:
    """Thread-safe completion collector shared by all lane workers.

    Workers buffer completions in a thread-local list and flush it here
    once, when their lane is drained — the lock sits outside the issue
    hot loop, so the sink never adds cross-lane synchronization to the
    per-request host costs the client exists to measure."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._items: list[Completion] = []

    def add(self, completions: Sequence[Completion]) -> None:
        if completions:
            with self._lock:
                self._items.extend(completions)

    def harvest(self) -> list[Completion]:
        """Everything collected so far (call after joining the workers)."""
        with self._lock:
            return list(self._items)


@dataclasses.dataclass(frozen=True)
class LaneReport:
    """Per-lane client-side accounting for one threaded serve."""

    lane: int
    requests: int  # requests this lane's thread issued
    dispatch_overhead_us: float  # mean host time inside call() per request
    achieved_qps: float  # non-warmup completions per active second


@dataclasses.dataclass(frozen=True)
class ClientResult:
    """What a threaded client run produced: the merged completion list
    plus per-lane issue accounting."""

    completions: tuple[Completion, ...]
    lane_reports: tuple[LaneReport, ...]

    @property
    def dispatch_overhead_us(self) -> float:
        """Mean host dispatch time per request across all lanes."""
        n = sum(r.requests for r in self.lane_reports)
        if n == 0:
            return 0.0
        return (
            sum(r.dispatch_overhead_us * r.requests for r in self.lane_reports)
            / n
        )

    @property
    def lane_qps(self) -> tuple[float, ...]:
        return tuple(r.achieved_qps for r in self.lane_reports)


@dataclasses.dataclass
class _LaneTally:
    """Mutable per-lane accounting a worker fills as it issues."""

    requests: int = 0
    dispatch_s: float = 0.0


def _run_workers(
    workers: Sequence[Callable[[], None]],
) -> None:
    """Run one thread per worker; re-raise the first worker error after
    every thread has joined (no half-drained lanes left behind)."""
    errors: list[BaseException] = []
    lock = threading.Lock()

    def guarded(fn: Callable[[], None]) -> Callable[[], None]:
        def run() -> None:
            try:
                fn()
            except BaseException as e:  # noqa: BLE001 — re-raised in parent
                with lock:
                    errors.append(e)

        return run

    threads = [
        threading.Thread(target=guarded(fn), name=f"serve-lane-{i}", daemon=True)
        for i, fn in enumerate(workers)
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    if errors:
        raise errors[0]


def run_open_loop_threaded(
    call: Callable[[], Any],
    lane_schedules: Sequence[Schedule],
    *,
    concurrency: int = 32,
) -> ClientResult:
    """Open-loop serving with one issuing thread per lane.

    Each thread paces its own sub-schedule (``open_loop_lane_schedules``)
    against a shared start time; latency is recorded from the scheduled
    arrival, the standard open-loop convention. ``concurrency`` splits
    into per-lane window depths, as in the single-threaded client.
    """
    n_lanes = len(lane_schedules)
    if n_lanes < 1:
        raise ValueError("run_open_loop_threaded needs at least one lane schedule")
    depth = lane_depth(concurrency, n_lanes)
    sink = CompletionSink()
    tallies = [_LaneTally() for _ in range(n_lanes)]
    start = threading.Barrier(n_lanes)
    t0: list[float] = []

    def worker(lane_index: int) -> Callable[[], None]:
        lane = DispatchLane(lane_index, depth)
        schedule = lane_schedules[lane_index]
        tally = tallies[lane_index]

        def run() -> None:
            # All lanes leave the barrier together; the first one through
            # stamps the shared schedule origin.
            start.wait()
            if not t0:
                t0.append(time.perf_counter())
            origin = t0[0]
            done: list[Completion] = []  # lane-local; flushed once
            try:
                # One span per lane thread, recorded on the thread that
                # actually issued — the Chrome trace's tid attribution
                # for the threaded client comes from here.
                with current_tracer().span(
                    "serve.lane",
                    track="serve",
                    tid=f"lane {lane_index}",
                    lane=lane_index,
                ):
                    for req in schedule:
                        target = origin + req.arrival_s
                        delay = target - time.perf_counter()
                        if delay > 0:
                            time.sleep(delay)
                        d0 = time.perf_counter()
                        out = call()
                        tally.dispatch_s += time.perf_counter() - d0
                        tally.requests += 1
                        done.extend(lane.submit(out, req, target))
                        done.extend(lane.poll())
                    done.extend(lane.drain())
            finally:
                sink.add(done)

        return run

    _run_workers([worker(i) for i in range(n_lanes)])
    return _finalize(sink, tallies)


def run_closed_loop_threaded(
    call: Callable[[], Any],
    *,
    concurrency: int,
    n_lanes: int,
    duration_s: float,
    warmup: int = 0,
    max_requests: int | None = None,
) -> ClientResult:
    """Closed-loop serving with one issuing thread per lane.

    Each thread keeps its own lane's window (depth ``concurrency //
    n_lanes``) full until ``duration_s`` elapses. Request indices are
    striped (lane k issues k, k+N, k+2N, ...) so they stay globally
    unique without cross-thread coordination; each lane marks its first
    ``ceil(warmup / n_lanes)`` requests as warmup, covering at least the
    requested pipeline-fill exclusion. ``max_requests`` is an exact total
    cap (as in the single-threaded client): it is pre-split across lanes,
    the first ``max_requests % n_lanes`` lanes taking one extra request.
    """
    if n_lanes < 1:
        raise ValueError(f"n_lanes must be >= 1, got {n_lanes}")
    depth = lane_depth(concurrency, n_lanes)
    per_lane_warmup = -(-warmup // n_lanes)  # ceil
    per_lane_cap = [None] * n_lanes
    if max_requests is not None:
        per_lane_cap = [
            max_requests // n_lanes + (1 if k < max_requests % n_lanes else 0)
            for k in range(n_lanes)
        ]
    sink = CompletionSink()
    tallies = [_LaneTally() for _ in range(n_lanes)]
    start = threading.Barrier(n_lanes)

    def worker(lane_index: int) -> Callable[[], None]:
        lane = DispatchLane(lane_index, depth)
        tally = tallies[lane_index]
        cap = per_lane_cap[lane_index]

        def run() -> None:
            start.wait()
            deadline = time.perf_counter() + duration_s
            i = 0
            done: list[Completion] = []  # lane-local; flushed once
            try:
                with current_tracer().span(
                    "serve.lane",
                    track="serve",
                    tid=f"lane {lane_index}",
                    lane=lane_index,
                ):
                    while time.perf_counter() < deadline:
                        if cap is not None and i >= cap:
                            break
                        req = Request(
                            index=lane_index + i * n_lanes,
                            arrival_s=0.0,
                            warmup=i < per_lane_warmup,
                        )
                        t_submit = time.perf_counter()
                        d0 = t_submit
                        out = call()
                        tally.dispatch_s += time.perf_counter() - d0
                        tally.requests += 1
                        done.extend(lane.submit(out, req, t_submit))
                        done.extend(lane.poll())
                        i += 1
                    done.extend(lane.drain())
            finally:
                sink.add(done)

        return run

    _run_workers([worker(i) for i in range(n_lanes)])
    return _finalize(sink, tallies)


def _finalize(sink: CompletionSink, tallies: Sequence[_LaneTally]) -> ClientResult:
    # Per-lane QPS comes from the same helper the record column uses, so
    # LaneReport.achieved_qps and the row's lane_qps cannot drift apart.
    from repro.serve.latency import lane_qps_from_completions

    completions = sink.harvest()
    completions.sort(key=lambda c: c.t_done)
    qps = lane_qps_from_completions(completions, n_lanes=len(tallies))
    reports = tuple(
        LaneReport(
            lane=lane,
            requests=tally.requests,
            dispatch_overhead_us=(
                tally.dispatch_s / tally.requests * 1e6
                if tally.requests
                else 0.0
            ),
            achieved_qps=qps[lane],
        )
        for lane, tally in enumerate(tallies)
    )
    return ClientResult(completions=tuple(completions), lane_reports=reports)
