"""Deterministic load generation for the serving subsystem.

Two canonical load models (the Milabench / serving-benchmark split):

- **open loop** (:func:`open_loop_schedule`): requests arrive on a Poisson
  process at a target QPS, independent of completions — the model that
  exposes queueing under overload. Interarrival gaps are drawn from a
  seeded ``numpy`` generator, so a schedule is *fully deterministic* for a
  fixed ``(qps, duration_s, seed)`` triple and reproducible across
  processes and platforms. The result is a :class:`Schedule`, which also
  carries a ``truncated`` flag: a schedule cut short at ``max_requests``
  offered *less* than the target QPS, and downstream statistics must say
  so rather than report the full target as the offered load.
- **per-lane open loop** (:func:`open_loop_lane_schedules`): the threaded
  client's variant — N independent Poisson streams at ``qps / N`` each,
  drawn from child RNGs spawned off one seed (``numpy`` ``SeedSequence``
  spawning, so lane k's stream is deterministic and independent of how
  the other lanes draw). The superposition of independent Poisson
  processes is Poisson at the summed rate, so the *merged* arrival
  process still offers the target QPS while each lane owns a stream it
  can issue without cross-thread coordination. Request indices and the
  warmup prefix are assigned in merged arrival order, so statistics see
  the same request stream a single-threaded client would.
- **closed loop** (:func:`closed_loop_schedule`): a fixed number of
  always-pending requests; the runner (``serve.lanes``) issues the next
  one the moment a slot frees, so arrival times are execution-driven and
  the schedule is just an indexed request list.

Warmup exclusion: the first ``warmup`` requests of either schedule are
flagged ``warmup=True``; latency statistics (``serve.latency``) drop them,
mirroring ``harness.time_fn``'s warmup iterations.

Mixed-shape traffic rides on top of either generator: :func:`sample_mix`
assigns every request a shape-bucket label drawn from a weighted
distribution with its *own* seeded stream (the arrival offsets are
untouched, so adding a mix never perturbs the arrival process), and
:func:`save_trace` / :func:`load_trace` persist the resulting
arrival+shape stream as replayable JSONL — two runs that load one trace
serve the identical request sequence, whatever their dispatch policy.
"""

from __future__ import annotations

import dataclasses
import heapq
import json
import os
from typing import Iterator, Mapping, Sequence, overload

import numpy as np

__all__ = [
    "Request",
    "Schedule",
    "open_loop_schedule",
    "open_loop_lane_schedules",
    "merge_schedules",
    "closed_loop_schedule",
    "sample_mix",
    "save_trace",
    "load_trace",
]

# Entropy appended to the plan seed for the shape-mix stream, so bucket
# draws are deterministic per seed yet independent of the arrival draws
# (the arrival stream is identical with and without a mix).
_MIX_STREAM = 0x5AAB


@dataclasses.dataclass(frozen=True)
class Request:
    """One generated request: arrival offset seconds from serve start
    (0.0 for closed-loop, where issue time is execution-driven), plus the
    shape-bucket label its inputs are drawn from (None = the single
    measure-stage shape)."""

    index: int
    arrival_s: float = 0.0
    warmup: bool = False
    bucket: str | None = None


@dataclasses.dataclass(frozen=True)
class Schedule(Sequence):
    """An ordered request stream plus the facts needed to interpret it:
    the per-stream offered QPS and whether generation was cut short at
    ``max_requests`` (``truncated=True`` means the stream offered *less*
    than ``offered_qps`` over the nominal duration)."""

    requests: tuple[Request, ...]
    offered_qps: float | None = None
    truncated: bool = False

    def __len__(self) -> int:
        return len(self.requests)

    def __iter__(self) -> Iterator[Request]:
        return iter(self.requests)

    @overload
    def __getitem__(self, i: int) -> Request: ...

    @overload
    def __getitem__(self, i: slice) -> tuple[Request, ...]: ...

    def __getitem__(self, i):
        return self.requests[i]


def open_loop_schedule(
    *,
    qps: float,
    duration_s: float,
    seed: int = 0,
    warmup: int = 0,
    max_requests: int = 100_000,
) -> Schedule:
    """Poisson arrivals at ``qps`` for ``duration_s`` seconds.

    Deterministic for a fixed seed: the same triple always yields the same
    arrival offsets. ``max_requests`` bounds pathological qps*duration
    products (the schedule is materialized up front); hitting the bound
    sets ``truncated`` on the returned :class:`Schedule` so the run is
    reported as offering less than the target, not silently mislabeled.
    """
    _validate_open_loop(qps, duration_s)
    rng = np.random.default_rng(seed)
    arrivals = _poisson_arrivals(rng, qps, duration_s, max_requests)
    if not arrivals:
        # A duration too small for even one Poisson arrival is a valid
        # (if degenerate) request: return an explicitly empty schedule
        # rather than letting downstream stats raise a confusing error.
        # Empty is never "truncated" — nothing was cut short.
        return Schedule(requests=(), offered_qps=qps, truncated=False)
    requests = tuple(
        Request(index=i, arrival_s=t, warmup=i < warmup)
        for i, t in enumerate(arrivals)
    )
    return Schedule(
        requests=requests,
        offered_qps=qps,
        truncated=len(arrivals) >= max_requests,
    )


def open_loop_lane_schedules(
    *,
    qps: float,
    duration_s: float,
    n_lanes: int,
    seed: int = 0,
    warmup: int = 0,
    max_requests: int = 100_000,
) -> tuple[Schedule, ...]:
    """Split one open-loop load into ``n_lanes`` independent sub-streams.

    Lane k draws its own Poisson process at ``qps / n_lanes`` from a child
    RNG spawned off ``seed`` (``SeedSequence(seed).spawn``), so the merged
    arrival process is Poisson at the target QPS, each lane's stream is
    reproducible in isolation, and no thread ever coordinates with another
    to find its next arrival. Global request indices and the ``warmup``
    prefix are assigned in merged arrival order; ``max_requests`` caps the
    *merged* request count, and every lane's ``truncated`` flag reflects
    the merged truncation (the offered load is a property of the whole
    client, not one lane).
    """
    if n_lanes < 1:
        raise ValueError(f"n_lanes must be >= 1, got {n_lanes}")
    _validate_open_loop(qps, duration_s)
    lane_rate = qps / n_lanes
    rngs = [
        np.random.default_rng(child)
        for child in np.random.SeedSequence(seed).spawn(n_lanes)
    ]
    # Lazy heap-merge of the per-lane streams: each lane draws its next
    # gap only when its previous arrival is consumed, so hitting
    # ``max_requests`` materializes at most that many arrivals — the cap
    # keeps bounding pathological qps*duration products, per lane count.
    # Ties pop by lane index, deterministically. A lane's arrival
    # sequence is the same cumulative sum either way, so the streams are
    # identical to eager generation, just cut at the merged cap.
    heap: list[tuple[float, int]] = []
    for lane, rng in enumerate(rngs):
        t = float(rng.exponential(1.0 / lane_rate))
        if t < duration_s:
            heap.append((t, lane))
    heapq.heapify(heap)
    merged: list[tuple[float, int]] = []
    truncated = False
    while heap:
        if len(merged) >= max_requests:
            truncated = True  # more arrivals would have fit the duration
            break
        t, lane = heapq.heappop(heap)
        merged.append((t, lane))
        t_next = t + float(rngs[lane].exponential(1.0 / lane_rate))
        if t_next < duration_s:
            heapq.heappush(heap, (t_next, lane))
    per_lane: list[list[Request]] = [[] for _ in range(n_lanes)]
    for index, (t, lane) in enumerate(merged):
        per_lane[lane].append(
            Request(index=index, arrival_s=t, warmup=index < warmup)
        )
    return tuple(
        Schedule(
            requests=tuple(reqs),
            offered_qps=lane_rate,
            truncated=truncated,
        )
        for reqs in per_lane
    )


def merge_schedules(schedules: Sequence[Schedule]) -> Schedule:
    """The merged arrival stream of several sub-schedules, in arrival
    order — what the device sees when every lane issues its own stream.
    Offered QPS sums; truncation is sticky."""
    if not schedules:
        raise ValueError("merge_schedules needs at least one schedule")
    requests = tuple(
        sorted(
            (r for s in schedules for r in s.requests),
            key=lambda r: (r.arrival_s, r.index),
        )
    )
    offered = [s.offered_qps for s in schedules if s.offered_qps is not None]
    return Schedule(
        requests=requests,
        offered_qps=sum(offered) if offered else None,
        truncated=any(s.truncated for s in schedules),
    )


def closed_loop_schedule(n_requests: int, *, warmup: int = 0) -> tuple[Request, ...]:
    """``n_requests`` always-pending requests (arrival_s=0)."""
    if n_requests < 1:
        raise ValueError(f"n_requests must be >= 1, got {n_requests}")
    return tuple(
        Request(index=i, arrival_s=0.0, warmup=i < warmup)
        for i in range(n_requests)
    )


def sample_mix(
    schedule: Schedule,
    mix: Sequence[tuple[str, float]] | Mapping[str, float],
    *,
    seed: int = 0,
) -> Schedule:
    """Assign every request a shape-bucket label drawn from ``mix``.

    ``mix`` maps bucket label -> weight (weights need not sum to 1; they
    are normalized). Draws come from a dedicated stream seeded by
    ``(seed, _MIX_STREAM)`` — deterministic per seed, and independent of
    the arrival draws, so the arrival offsets of ``schedule`` are
    returned untouched. Bucket *order* matters for reproducibility:
    mappings are sorted by label first.
    """
    if isinstance(mix, Mapping):
        entries = sorted(mix.items())
    else:
        entries = list(mix)
    if not entries:
        raise ValueError("sample_mix needs at least one bucket")
    labels = [label for label, _ in entries]
    weights = np.array([w for _, w in entries], dtype=np.float64)
    if not (weights > 0).all():
        raise ValueError(f"mix weights must be > 0, got {entries}")
    rng = np.random.default_rng(np.random.SeedSequence([seed, _MIX_STREAM]))
    picks = rng.choice(len(labels), size=len(schedule), p=weights / weights.sum())
    requests = tuple(
        dataclasses.replace(req, bucket=labels[int(pick)])
        for req, pick in zip(schedule.requests, picks)
    )
    return dataclasses.replace(schedule, requests=requests)


def save_trace(schedule: Schedule, path: str) -> None:
    """Persist a schedule as a replayable JSONL trace.

    Line 1 is a header object (``offered_qps``, ``truncated``, request
    count); every following line is one request (index / arrival_s /
    bucket / warmup). The format is append-only JSONL so traces diff and
    stream like the report files do.
    """
    parent = os.path.dirname(path)
    if parent:
        os.makedirs(parent, exist_ok=True)
    with open(path, "w", encoding="utf-8") as fh:
        header = {
            "kind": "serve-trace",
            "offered_qps": schedule.offered_qps,
            "truncated": schedule.truncated,
            "requests": len(schedule),
        }
        fh.write(json.dumps(header) + "\n")
        for req in schedule:
            fh.write(
                json.dumps(
                    {
                        "index": req.index,
                        "arrival_s": req.arrival_s,
                        "bucket": req.bucket,
                        "warmup": req.warmup,
                    }
                )
                + "\n"
            )


def load_trace(path: str) -> Schedule:
    """Load a trace saved by :func:`save_trace` back into a
    :class:`Schedule` (bucket labels and warmup flags included) — the
    replay is exact, so loop/lanes/batcher runs over one trace serve the
    identical request stream."""
    with open(path, encoding="utf-8") as fh:
        lines = [line for line in fh if line.strip()]
    if not lines:
        raise ValueError(f"trace {path!r} is empty")
    header = json.loads(lines[0])
    if header.get("kind") != "serve-trace":
        raise ValueError(
            f"trace {path!r} does not look like a serve trace "
            f"(header kind={header.get('kind')!r})"
        )
    requests = tuple(
        Request(
            index=rec["index"],
            arrival_s=rec["arrival_s"],
            warmup=bool(rec.get("warmup", False)),
            bucket=rec.get("bucket"),
        )
        for rec in map(json.loads, lines[1:])
    )
    declared = header.get("requests")
    if declared is not None and declared != len(requests):
        raise ValueError(
            f"trace {path!r} is truncated on disk: header says "
            f"{declared} requests, file has {len(requests)}"
        )
    return Schedule(
        requests=requests,
        offered_qps=header.get("offered_qps"),
        truncated=bool(header.get("truncated", False)),
    )


def _validate_open_loop(qps: float, duration_s: float) -> None:
    if qps <= 0:
        raise ValueError(f"open-loop qps must be > 0, got {qps}")
    if duration_s <= 0:
        raise ValueError(f"duration_s must be > 0, got {duration_s}")


def _poisson_arrivals(
    rng: np.random.Generator, qps: float, duration_s: float, max_requests: int
) -> list[float]:
    out: list[float] = []
    t = 0.0
    while len(out) < max_requests:
        t += float(rng.exponential(1.0 / qps))
        if t >= duration_s:
            break
        out.append(t)
    return out
