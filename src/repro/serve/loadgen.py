"""Deterministic load generation for the serving subsystem.

Two canonical load models (the Milabench / serving-benchmark split):

- **open loop** (:func:`open_loop_schedule`): requests arrive on a Poisson
  process at a target QPS, independent of completions — the model that
  exposes queueing under overload. Interarrival gaps are drawn from a
  seeded ``numpy`` generator, so a schedule is *fully deterministic* for a
  fixed ``(qps, duration_s, seed)`` triple and reproducible across
  processes and platforms.
- **closed loop** (:func:`closed_loop_schedule`): a fixed number of
  always-pending requests; the runner (``serve.lanes``) issues the next
  one the moment a slot frees, so arrival times are execution-driven and
  the schedule is just an indexed request list.

Warmup exclusion: the first ``warmup`` requests of either schedule are
flagged ``warmup=True``; latency statistics (``serve.latency``) drop them,
mirroring ``harness.time_fn``'s warmup iterations.
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["Request", "open_loop_schedule", "closed_loop_schedule"]


@dataclasses.dataclass(frozen=True)
class Request:
    """One generated request: arrival offset seconds from serve start
    (0.0 for closed-loop, where issue time is execution-driven)."""

    index: int
    arrival_s: float = 0.0
    warmup: bool = False


def open_loop_schedule(
    *,
    qps: float,
    duration_s: float,
    seed: int = 0,
    warmup: int = 0,
    max_requests: int = 100_000,
) -> tuple[Request, ...]:
    """Poisson arrivals at ``qps`` for ``duration_s`` seconds.

    Deterministic for a fixed seed: the same triple always yields the same
    arrival offsets. ``max_requests`` bounds pathological qps*duration
    products (the schedule is materialized up front).
    """
    if qps <= 0:
        raise ValueError(f"open-loop qps must be > 0, got {qps}")
    if duration_s <= 0:
        raise ValueError(f"duration_s must be > 0, got {duration_s}")
    rng = np.random.default_rng(seed)
    out: list[Request] = []
    t = 0.0
    while len(out) < max_requests:
        t += float(rng.exponential(1.0 / qps))
        if t >= duration_s:
            break
        out.append(Request(index=len(out), arrival_s=t, warmup=len(out) < warmup))
    return tuple(out)


def closed_loop_schedule(n_requests: int, *, warmup: int = 0) -> tuple[Request, ...]:
    """``n_requests`` always-pending requests (arrival_s=0)."""
    if n_requests < 1:
        raise ValueError(f"n_requests must be >= 1, got {n_requests}")
    return tuple(
        Request(index=i, arrival_s=0.0, warmup=i < warmup)
        for i in range(n_requests)
    )
