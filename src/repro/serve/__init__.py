"""Concurrent-dispatch serving subsystem (paper §V-B generalized suite-wide).

ALTIS argues modern GPU runtimes are defined by their concurrency features
— HyperQ work queues, asynchronous streams, kernel co-location — and the
original suite only ever measured workloads in isolation. This package
turns any registered workload (or a co-located pair) into a *served*
workload under generated load:

- :mod:`repro.serve.lanes` — N dispatch lanes exploiting JAX async
  dispatch; each lane is an ordered window of in-flight device
  computations that blocks only on its own oldest result (the HyperQ
  work-queue analogue), with ``loop`` / ``lanes`` / ``batched`` dispatch
  modes generalizing the old feat_hyperq split.
- :mod:`repro.serve.loadgen` — deterministic seeded load generation:
  open-loop Poisson arrivals at a target QPS and closed-loop issue at a
  fixed concurrency, with warmup exclusion.
- :mod:`repro.serve.latency` — per-request latency capture folded into
  p50/p95/p99/max percentiles, achieved QPS, and goodput.
- :mod:`repro.serve.interference` — co-locate workload pairs across split
  lanes and report the slowdown-vs-isolated matrix.

The engine (``core/engine.py``) drives all of this as a ``serve`` stage
after ``measure``, reusing the compile cache's executables — serving never
recompiles what measuring already compiled.
"""

from repro.serve.lanes import (
    DISPATCH_MODES,
    Completion,
    DispatchLane,
    LaneSet,
    run_closed_loop,
    run_open_loop,
    serve_loop,
)
from repro.serve.latency import LatencyStats, stats_from_completions
from repro.serve.loadgen import Request, closed_loop_schedule, open_loop_schedule
from repro.serve.interference import ColocationResult, colocate_closed_loop

__all__ = [
    "DISPATCH_MODES",
    "Completion",
    "DispatchLane",
    "LaneSet",
    "run_closed_loop",
    "run_open_loop",
    "serve_loop",
    "LatencyStats",
    "stats_from_completions",
    "Request",
    "closed_loop_schedule",
    "open_loop_schedule",
    "ColocationResult",
    "colocate_closed_loop",
]
