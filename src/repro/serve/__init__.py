"""Concurrent-dispatch serving subsystem (paper §V-B generalized suite-wide).

ALTIS argues modern GPU runtimes are defined by their concurrency features
— HyperQ work queues, asynchronous streams, kernel co-location — and the
original suite only ever measured workloads in isolation. This package
turns any registered workload (or a co-located pair) into a *served*
workload under generated load:

- :mod:`repro.serve.lanes` — N dispatch lanes exploiting JAX async
  dispatch; each lane is an ordered window of in-flight device
  computations that blocks only on its own oldest result (the HyperQ
  work-queue analogue), with ``loop`` / ``lanes`` / ``batched`` dispatch
  modes generalizing the old feat_hyperq split.
- :mod:`repro.serve.loadgen` — deterministic seeded load generation:
  open-loop Poisson arrivals at a target QPS (with an explicit
  ``truncated`` flag when the schedule hits its request cap) and
  closed-loop issue at a fixed concurrency, with warmup exclusion;
  ``open_loop_lane_schedules`` splits one load into per-lane Poisson
  sub-streams via seeded child RNGs whose merge still offers the target
  QPS.
- :mod:`repro.serve.client` — the host issue architectures: the
  single-threaded client lives in ``lanes``; the thread-per-lane client
  (``run_open_loop_threaded`` / ``run_closed_loop_threaded``) issues each
  lane from its own thread through a thread-safe completion sink, with
  per-lane dispatch-overhead accounting so host contention is measured.
- :mod:`repro.serve.latency` — per-request latency capture folded into
  p50/p95/p99/max percentiles, achieved QPS, goodput under an optional
  SLO, per-lane achieved QPS, and the truncation honesty flag.
- :mod:`repro.serve.interference` — co-locate workload pairs across split
  lanes and report the slowdown-vs-isolated matrix.
- :mod:`repro.serve.batcher` — continuous batching over mixed-shape
  traffic: per-bucket request queues coalesced into shape-bucketed
  vmapped executables under a latency budget, with batch occupancy and
  padding waste measured per dispatched batch (plus the uncoalesced
  ``loop`` / ``lanes`` / fixed-``batched`` policies over the same mixed
  schedule, for comparison at identical offered load).

The engine (``core/engine.py``) drives all of this as a ``serve`` stage
after ``measure``, reusing the compile cache's executables — serving never
recompiles what measuring already compiled, whichever client issues it.
Mixed-shape serving precompiles one executable per (shape bucket, batch
width) through the same caches, so warm runs restore every bucket with
zero XLA compiles.
"""

from repro.serve.client import (
    SERVE_CLIENTS,
    ClientResult,
    CompletionSink,
    LaneReport,
    run_closed_loop_threaded,
    run_open_loop_threaded,
)
from repro.serve.lanes import (
    DISPATCH_MODES,
    Completion,
    DispatchLane,
    LaneSet,
    run_closed_loop,
    run_open_loop,
    serve_loop,
)
from repro.serve.latency import BucketStats, LatencyStats, stats_from_completions
from repro.serve.loadgen import (
    Request,
    Schedule,
    closed_loop_schedule,
    load_trace,
    merge_schedules,
    open_loop_lane_schedules,
    open_loop_schedule,
    sample_mix,
    save_trace,
)
from repro.serve.interference import ColocationResult, colocate_closed_loop
from repro.serve.batcher import (
    BatchExecution,
    BatchReport,
    bucket_widths,
    serve_dynamic,
    serve_fixed_batched,
    serve_mixed_lanes,
    serve_mixed_loop,
)

__all__ = [
    "DISPATCH_MODES",
    "SERVE_CLIENTS",
    "Completion",
    "DispatchLane",
    "LaneSet",
    "run_closed_loop",
    "run_open_loop",
    "serve_loop",
    "ClientResult",
    "CompletionSink",
    "LaneReport",
    "run_closed_loop_threaded",
    "run_open_loop_threaded",
    "LatencyStats",
    "stats_from_completions",
    "Request",
    "Schedule",
    "closed_loop_schedule",
    "merge_schedules",
    "open_loop_lane_schedules",
    "open_loop_schedule",
    "ColocationResult",
    "colocate_closed_loop",
    "BucketStats",
    "sample_mix",
    "save_trace",
    "load_trace",
    "BatchExecution",
    "BatchReport",
    "bucket_widths",
    "serve_mixed_loop",
    "serve_mixed_lanes",
    "serve_fixed_batched",
    "serve_dynamic",
]
