"""Dispatch lanes — the HyperQ work-queue analogue on JAX's async runtime.

The paper's §V-B HyperQ study launches N kernels on N CUDA streams and
watches speedup saturate near the 32 hardware work queues. JAX has no user
streams, but its dispatch is asynchronous: a jitted call enqueues device
work and returns immediately, so a host thread can keep many computations
in flight and synchronize late. A :class:`DispatchLane` models one work
queue as an ordered window of in-flight results; submitting to a full lane
blocks on that lane's *own oldest* result only, so the other lanes keep
draining independently — which is exactly what distinguishes N shallow
queues from one deep one.

Three dispatch modes generalize the old ``feat_hyperq`` serial-loop-vs-
batched split:

- ``loop``   — synchronize after every call (:func:`serve_loop`); the
  no-concurrency baseline every speedup is measured against. With
  ``window=K`` it becomes the *windowed* floor: dispatch K requests back
  to back, synchronize once on all of them — the same
  amortize-the-sync move as ``harness.time_fn``'s windowed timing mode,
  so the gap between the two floors is the measured per-request
  dispatch + sync overhead of serial dispatch.
- ``lanes``  — N lanes × depth-D windows (:func:`run_closed_loop` /
  :func:`run_open_loop`); host dispatch overlaps device execution.
- ``batched``— N instances fused into one program via ``vmap``
  (:func:`batched_call`, re-exported from ``core.features``); occupancy
  rather than dispatch concurrency.

All timestamps are ``time.perf_counter`` seconds; completion times are
observed either by a non-blocking ready poll (``is_ready``) or at the
blocking harvest, whichever comes first.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable, Iterable

import jax

from repro.core.features import concurrent_instances as batched_call  # noqa: F401
from repro.obs import current_tracer
from repro.serve.loadgen import Request

__all__ = [
    "DISPATCH_MODES",
    "Completion",
    "DispatchLane",
    "LaneSet",
    "serve_loop",
    "run_closed_loop",
    "run_open_loop",
    "batched_call",
]

DISPATCH_MODES = ("loop", "lanes", "batched")


@dataclasses.dataclass(frozen=True)
class Completion:
    """One served request as observed by the dispatch loop."""

    index: int
    lane: int
    t_submit: float  # perf_counter seconds (scheduled arrival for open loop)
    t_done: float
    warmup: bool
    bucket: str | None = None  # shape bucket served (None = single-shape)

    @property
    def latency_us(self) -> float:
        return (self.t_done - self.t_submit) * 1e6


def _is_ready(out: Any) -> bool:
    # no_jit workloads may return host objects with no is_ready; treat
    # anything non-pollable as ready (its submit already did the work).
    return all(
        getattr(leaf, "is_ready", lambda: True)()
        for leaf in jax.tree_util.tree_leaves(out)
    )


class DispatchLane:
    """One work queue: an ordered window of up to ``depth`` in-flight
    computations. FIFO — only a ready *prefix* can ever be harvested."""

    def __init__(self, index: int, depth: int = 4) -> None:
        if depth < 1:
            raise ValueError(f"lane depth must be >= 1, got {depth}")
        self.index = index
        self.depth = depth
        self._inflight: deque[tuple[Request, float, Any]] = deque()

    def __len__(self) -> int:
        return len(self._inflight)

    @property
    def full(self) -> bool:
        return len(self._inflight) >= self.depth

    def submit(self, out: Any, request: Request, t_submit: float) -> list[Completion]:
        """Enqueue an already-dispatched computation; when the lane is at
        depth, first block on — and return — this lane's oldest result."""
        done = []
        if self.full:
            # The blocked-submit wall time is the lane-stall signal the
            # obs layer counts; guarded so the disabled cost is one
            # attribute read, with no timestamps taken.
            tracer = current_tracer()
            if tracer.enabled:
                b0 = time.perf_counter()
                done.append(self._finish(*self._inflight.popleft()))
                tracer.counters.inc(
                    "lane.submit_block_us", (time.perf_counter() - b0) * 1e6
                )
                tracer.counters.inc("lane.submit_blocks")
            else:
                done.append(self._finish(*self._inflight.popleft()))
        self._inflight.append((request, t_submit, out))
        return done

    def poll(self) -> list[Completion]:
        """Harvest ready results without blocking."""
        done = []
        while self._inflight and _is_ready(self._inflight[0][2]):
            done.append(self._finish(*self._inflight.popleft()))
        return done

    def oldest_t_submit(self) -> float:
        """Submit time of this lane's head (inf when empty)."""
        return self._inflight[0][1] if self._inflight else float("inf")

    def pop_oldest(self) -> list[Completion]:
        """Block on — and return — this lane's head, if any."""
        if not self._inflight:
            return []
        return [self._finish(*self._inflight.popleft())]

    def drain(self) -> list[Completion]:
        """Block on everything still in flight, oldest first."""
        done = []
        while self._inflight:
            done.append(self._finish(*self._inflight.popleft()))
        return done

    def _finish(self, request: Request, t_submit: float, out: Any) -> Completion:
        jax.block_until_ready(out)
        return Completion(
            index=request.index,
            lane=self.index,
            t_submit=t_submit,
            t_done=time.perf_counter(),
            warmup=request.warmup,
            bucket=request.bucket,
        )


class LaneSet:
    """N dispatch lanes with least-loaded (round-robin tiebreak) submission."""

    def __init__(self, n_lanes: int, depth: int = 4) -> None:
        if n_lanes < 1:
            raise ValueError(f"n_lanes must be >= 1, got {n_lanes}")
        self.lanes = [DispatchLane(i, depth) for i in range(n_lanes)]
        self._rr = 0

    @property
    def in_flight(self) -> int:
        return sum(len(lane) for lane in self.lanes)

    @property
    def capacity(self) -> int:
        return sum(lane.depth for lane in self.lanes)

    def submit(self, out: Any, request: Request, t_submit: float) -> list[Completion]:
        n = len(self.lanes)
        lane = min(
            self.lanes, key=lambda l: (len(l), (l.index - self._rr) % n)
        )
        self._rr = (lane.index + 1) % n
        return lane.submit(out, request, t_submit)

    def poll(self) -> list[Completion]:
        return [c for lane in self.lanes for c in lane.poll()]

    def oldest_t_submit(self) -> float:
        return min(lane.oldest_t_submit() for lane in self.lanes)

    def pop_oldest(self) -> list[Completion]:
        """Block on the globally oldest in-flight head across lanes."""
        lane = min(self.lanes, key=DispatchLane.oldest_t_submit)
        return lane.pop_oldest()

    def drain(self) -> list[Completion]:
        """Harvest everything, interleaving across lanes: ready results
        first (prompt timestamps), then block on the globally oldest head
        — never fully draining one lane while another's finished results
        sit unstamped (that would charge lane 0's drain time to lane 1's
        latencies)."""
        done = []
        while self.in_flight:
            ready = self.poll()
            done.extend(ready if ready else self.pop_oldest())
        return done


def lane_depth(concurrency: int, n_lanes: int) -> int:
    """Per-lane window depth giving a total in-flight cap of ~concurrency."""
    return max(1, concurrency // max(n_lanes, 1))


def serve_loop(
    call: Callable[[], Any],
    requests: Iterable[Request],
    *,
    window: int = 1,
) -> list[Completion]:
    """``loop`` dispatch: synchronize after every call (no concurrency).

    ``window=K`` dispatches K requests back to back and synchronizes once
    on **all** of them (blocking only on the last could under-measure if
    the runtime completes computations out of order). Requests in a
    window share the window's completion stamp, so per-request latency
    becomes window-granular — use windowed loops for *throughput* floors
    (the per-call quotient), sync loops for latency floors.
    """
    if window < 1:
        raise ValueError(f"window must be >= 1, got {window}")
    out: list[Completion] = []
    if window == 1:
        for req in requests:
            t0 = time.perf_counter()
            jax.block_until_ready(call())
            out.append(
                Completion(
                    index=req.index,
                    lane=0,
                    t_submit=t0,
                    t_done=time.perf_counter(),
                    warmup=req.warmup,
                    bucket=req.bucket,
                )
            )
        return out
    pending: list[tuple[Request, float, Any]] = []

    def flush() -> None:
        if not pending:
            return
        jax.block_until_ready([p[2] for p in pending])
        t_done = time.perf_counter()
        out.extend(
            Completion(
                index=req.index, lane=0, t_submit=t0, t_done=t_done,
                warmup=req.warmup, bucket=req.bucket,
            )
            for req, t0, _ in pending
        )
        pending.clear()

    for req in requests:
        pending.append((req, time.perf_counter(), call()))
        if len(pending) >= window:
            flush()
    flush()
    return out


def run_closed_loop(
    call: Callable[[], Any],
    *,
    concurrency: int,
    n_lanes: int,
    duration_s: float,
    warmup: int = 0,
    max_requests: int | None = None,
) -> list[Completion]:
    """Closed-loop serving: keep ``concurrency`` requests in flight across
    ``n_lanes`` lanes until ``duration_s`` elapses (or ``max_requests``).

    The next request is issued as soon as the least-loaded lane has a free
    slot; a full lane blocks on its own oldest result, which *is* the slot
    freeing up. The first ``warmup`` requests are marked for exclusion.
    """
    lanes = LaneSet(n_lanes, lane_depth(concurrency, n_lanes))
    completions: list[Completion] = []
    deadline = time.perf_counter() + duration_s
    index = 0
    while time.perf_counter() < deadline:
        if max_requests is not None and index >= max_requests:
            break
        req = Request(index=index, arrival_s=0.0, warmup=index < warmup)
        t_submit = time.perf_counter()
        completions.extend(lanes.submit(call(), req, t_submit))
        completions.extend(lanes.poll())
        index += 1
    completions.extend(lanes.drain())
    return completions


def run_open_loop(
    call: Callable[[], Any],
    schedule: Iterable[Request],
    *,
    n_lanes: int,
    concurrency: int = 32,
) -> list[Completion]:
    """Open-loop serving: dispatch each request at its scheduled arrival.

    Pacing is best-effort — a dispatch that falls behind is *recorded from
    its scheduled arrival*, so queueing delay counts toward latency (the
    standard open-loop convention; closed-loop measurement hides it).
    ``concurrency`` caps total in-flight work so an overloaded run degrades
    by queueing on lanes rather than exhausting memory.
    """
    lanes = LaneSet(n_lanes, lane_depth(concurrency, n_lanes))
    completions: list[Completion] = []
    t0 = time.perf_counter()
    for req in schedule:
        target = t0 + req.arrival_s
        delay = target - time.perf_counter()
        if delay > 0:
            time.sleep(delay)
        completions.extend(lanes.submit(call(), req, target))
        completions.extend(lanes.poll())
    completions.extend(lanes.drain())
    return completions
