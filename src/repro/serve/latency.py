"""Per-request latency capture → serving statistics.

Folds a list of :class:`~repro.serve.lanes.Completion` into the numbers a
serving benchmark reports: latency percentiles (p50/p95/p99/max over
non-warmup requests), achieved QPS (completions per measured second), and
goodput (completions under an optional latency SLO per measured second —
without an SLO every completed request is good, so goodput == achieved).

The measured window starts at the first non-warmup submission and ends at
the last completion, so pipeline fill (warmup) neither inflates latency
nor deflates throughput.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.serve.lanes import Completion

__all__ = ["LatencyStats", "stats_from_completions"]


@dataclasses.dataclass(frozen=True)
class LatencyStats:
    """Serving statistics over one run's non-warmup completions."""

    requests: int  # measured (non-warmup) completions
    warmup_requests: int
    p50_us: float
    p95_us: float
    p99_us: float
    max_us: float
    achieved_qps: float
    goodput_qps: float  # completions under the SLO per second (== achieved without one)
    offered_qps: float | None = None  # open-loop target; None for closed loop

    def derived(self) -> str:
        """The compact ``k=v;...`` form figure drivers put in CSV rows."""
        offered = f";offered_qps={self.offered_qps:.1f}" if self.offered_qps else ""
        return (
            f"requests={self.requests};p50_us={self.p50_us:.1f};"
            f"p95_us={self.p95_us:.1f};p99_us={self.p99_us:.1f};"
            f"qps={self.achieved_qps:.1f}{offered}"
        )


def stats_from_completions(
    completions: Sequence[Completion],
    *,
    offered_qps: float | None = None,
    slo_us: float | None = None,
) -> LatencyStats:
    measured = [c for c in completions if not c.warmup]
    warmup = len(completions) - len(measured)
    if not measured:
        raise ValueError(
            f"no measured completions ({warmup} warmup-only); "
            "serve longer or lower the warmup count"
        )
    lat = np.array([c.latency_us for c in measured], dtype=np.float64)
    window_s = max(
        max(c.t_done for c in measured) - min(c.t_submit for c in measured),
        1e-9,
    )
    good = len(measured) if slo_us is None else int((lat <= slo_us).sum())
    return LatencyStats(
        requests=len(measured),
        warmup_requests=warmup,
        p50_us=float(np.percentile(lat, 50)),
        p95_us=float(np.percentile(lat, 95)),
        p99_us=float(np.percentile(lat, 99)),
        max_us=float(lat.max()),
        achieved_qps=len(measured) / window_s,
        goodput_qps=good / window_s,
        offered_qps=offered_qps,
    )
