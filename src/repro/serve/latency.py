"""Per-request latency capture → serving statistics.

Folds a list of :class:`~repro.serve.lanes.Completion` into the numbers a
serving benchmark reports: latency percentiles (p50/p95/p99/max over
non-warmup requests), achieved QPS (completions per measured second), and
goodput (completions under an optional latency SLO per measured second —
a request at exactly the SLO counts as good; without an SLO every
completed request is good, so goodput == achieved).

The measured window starts at the first non-warmup submission and ends at
the last completion, so pipeline fill (warmup) neither inflates latency
nor deflates throughput.

Honesty flags travel with the stats: ``truncated`` marks an open-loop run
whose schedule hit its request cap and therefore offered *less* than
``offered_qps``; ``dispatch_overhead_us`` / ``lane_qps`` carry the
client-side issue accounting (host time per dispatch, per-lane achieved
QPS) so host contention between lanes is a reported number.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.serve.lanes import Completion

__all__ = [
    "LatencyStats",
    "BucketStats",
    "stats_from_completions",
    "lane_qps_from_completions",
]


@dataclasses.dataclass(frozen=True)
class BucketStats:
    """Latency percentiles for one shape bucket's measured completions."""

    requests: int
    p50_us: float
    p95_us: float
    p99_us: float


@dataclasses.dataclass(frozen=True)
class LatencyStats:
    """Serving statistics over one run's non-warmup completions."""

    requests: int  # measured (non-warmup) completions
    warmup_requests: int
    p50_us: float
    p95_us: float
    p99_us: float
    max_us: float
    achieved_qps: float
    goodput_qps: float  # completions under the SLO per second (== achieved without one)
    offered_qps: float | None = None  # open-loop target; None for closed loop
    slo_us: float | None = None  # the SLO goodput was measured against
    truncated: bool = False  # schedule hit its cap: offered < offered_qps
    dispatch_overhead_us: float | None = None  # mean host time per dispatch
    lane_qps: tuple[float, ...] | None = None  # per-lane achieved QPS
    # Mixed-shape serving (serve.batcher): per-bucket latency percentiles
    # keyed by bucket label, batch occupancy (filled / dispatched slots),
    # and padding waste (padded / dispatched slots). None outside the
    # bucketed paths.
    bucket_stats: tuple[tuple[str, "BucketStats"], ...] | None = None
    batch_occupancy: float | None = None
    padding_waste: float | None = None
    n_batches: int | None = None

    def derived(self) -> str:
        """The compact ``k=v;...`` form figure drivers put in CSV rows.

        ``offered_qps`` is emitted whenever it was set (``is not None`` —
        a 0.0 target must not vanish), ``goodput_qps`` whenever an SLO
        was in force, and ``truncated=1`` marks runs whose offered load
        fell short of the target.
        """
        parts = [
            f"requests={self.requests}",
            f"p50_us={self.p50_us:.1f}",
            f"p95_us={self.p95_us:.1f}",
            f"p99_us={self.p99_us:.1f}",
            f"qps={self.achieved_qps:.1f}",
        ]
        if self.offered_qps is not None:
            parts.append(f"offered_qps={self.offered_qps:.1f}")
        if self.slo_us is not None:
            parts.append(f"goodput_qps={self.goodput_qps:.1f}")
        if self.truncated:
            parts.append("truncated=1")
        if self.batch_occupancy is not None:
            parts.append(f"occupancy={self.batch_occupancy:.3f}")
        if self.padding_waste is not None:
            parts.append(f"padding_waste={self.padding_waste:.3f}")
        return ";".join(parts)


def stats_from_completions(
    completions: Sequence[Completion],
    *,
    offered_qps: float | None = None,
    slo_us: float | None = None,
    truncated: bool = False,
    dispatch_overhead_us: float | None = None,
    n_lanes: int | None = None,
    batch_occupancy: float | None = None,
    padding_waste: float | None = None,
    n_batches: int | None = None,
) -> LatencyStats:
    if not completions:
        raise ValueError(
            "no completions at all: the schedule was empty (duration too "
            "short for any arrival at this qps); raise --duration or --qps"
        )
    measured = [c for c in completions if not c.warmup]
    warmup = len(completions) - len(measured)
    if not measured:
        raise ValueError(
            f"no measured completions ({warmup} warmup-only); "
            "serve longer or lower the warmup count"
        )
    lat = np.array([c.latency_us for c in measured], dtype=np.float64)
    window_s = max(
        max(c.t_done for c in measured) - min(c.t_submit for c in measured),
        1e-9,
    )
    good = len(measured) if slo_us is None else int((lat <= slo_us).sum())
    by_bucket: dict[str, list[float]] = {}
    for c in measured:
        if c.bucket is not None:
            by_bucket.setdefault(c.bucket, []).append(c.latency_us)
    bucket_stats = (
        tuple(
            (
                label,
                BucketStats(
                    requests=len(lats),
                    p50_us=float(np.percentile(lats, 50)),
                    p95_us=float(np.percentile(lats, 95)),
                    p99_us=float(np.percentile(lats, 99)),
                ),
            )
            for label, lats in sorted(by_bucket.items())
        )
        if by_bucket
        else None
    )
    return LatencyStats(
        requests=len(measured),
        warmup_requests=warmup,
        p50_us=float(np.percentile(lat, 50)),
        p95_us=float(np.percentile(lat, 95)),
        p99_us=float(np.percentile(lat, 99)),
        max_us=float(lat.max()),
        achieved_qps=len(measured) / window_s,
        goodput_qps=good / window_s,
        offered_qps=offered_qps,
        slo_us=slo_us,
        truncated=truncated,
        dispatch_overhead_us=dispatch_overhead_us,
        lane_qps=lane_qps_from_completions(completions, n_lanes=n_lanes),
        bucket_stats=bucket_stats,
        batch_occupancy=batch_occupancy,
        padding_waste=padding_waste,
        n_batches=n_batches,
    )


def lane_qps_from_completions(
    completions: Sequence[Completion], *, n_lanes: int | None = None
) -> tuple[float, ...]:
    """Per-lane achieved QPS over each lane's own active window, indexed
    by lane — the column that shows whether lanes pulled equal weight or
    one issuing path starved the rest. A lane with no measured
    completions reads 0.0 (a starved lane is the finding, not a gap in
    the data); pass ``n_lanes`` to fix the length, else it spans the
    highest lane observed."""
    measured = [c for c in completions if not c.warmup]
    by_lane: dict[int, list[Completion]] = {}
    for c in measured:
        by_lane.setdefault(c.lane, []).append(c)
    count = (
        n_lanes if n_lanes is not None else max(by_lane, default=-1) + 1
    )
    out = []
    for lane in range(count):
        comps = by_lane.get(lane)
        if not comps:
            out.append(0.0)
            continue
        window = max(
            max(c.t_done for c in comps) - min(c.t_submit for c in comps),
            1e-9,
        )
        out.append(len(comps) / window)
    return tuple(out)
