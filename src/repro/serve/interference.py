"""Co-location interference — the HyperQ §V-B experiment, suite-wide.

The paper co-locates kernels on one GPU and measures how shared work
queues degrade each tenant. The analogue here: two workloads served
*concurrently* through disjoint halves of a lane set, all dispatching onto
the same device(s). Device time is the shared resource; each tenant's
slowdown is its co-located latency over its isolated latency under the
same per-tenant load:

    slowdown(w) = p50_colocated(w) / p50_isolated(w)      (>= ~1.0)

:func:`colocate_closed_loop` runs the co-located measurement itself;
:func:`measure_colocation` wraps it with the two isolated baselines and
returns a :class:`ColocationResult` — this is what the engine's serve
stage calls under ``ServeSpec.colocate`` (and what
``benchmarks/fig_concurrency.py`` reaches through ``run_suite``).
:func:`interference_matrix` maps a set of already-compiled callables to
the full pairwise slowdown matrix, for programmatic sweeps wider than the
one-pair-per-plan CLI surface.

Dispatch is single-threaded (tenants alternate submissions round-robin),
matching how every other measurement in this suite drives the device; the
concurrency being measured is on-device overlap, not host threading.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Mapping, Sequence

from repro.serve.lanes import Completion, LaneSet, lane_depth
from repro.serve.latency import LatencyStats, stats_from_completions
from repro.serve.loadgen import Request

__all__ = [
    "ColocationResult",
    "colocate_closed_loop",
    "measure_colocation",
    "interference_matrix",
]


@dataclasses.dataclass(frozen=True)
class ColocationResult:
    """Isolated vs co-located serving statistics for one workload pair."""

    names: tuple[str, ...]
    isolated: Mapping[str, LatencyStats]
    colocated: Mapping[str, LatencyStats]

    def slowdown(self, name: str) -> float:
        base = self.isolated[name].p50_us
        return self.colocated[name].p50_us / base if base > 0 else 0.0

    def slowdowns(self) -> dict[str, float]:
        return {name: self.slowdown(name) for name in self.names}


def colocate_closed_loop(
    calls: Mapping[str, Callable[[], object]],
    *,
    concurrency: int,
    n_lanes: int,
    duration_s: float,
    warmup: int = 0,
) -> dict[str, list[Completion]]:
    """Serve every tenant closed-loop at once, splitting the lane set.

    Each of the K tenants gets ``n_lanes // K`` lanes (min 1) and
    ``concurrency // K`` in-flight slots (min 1), so total pressure on the
    device matches a single-tenant run at the same ServeSpec — the
    difference in latency is the interference.
    """
    if not calls:
        raise ValueError("colocate_closed_loop needs at least one tenant")
    k = len(calls)
    per_lanes = max(1, n_lanes // k)
    per_depth = lane_depth(max(1, concurrency // k), per_lanes)
    tenants = {
        name: (call, LaneSet(per_lanes, per_depth))
        for name, call in calls.items()
    }
    completions: dict[str, list[Completion]] = {name: [] for name in calls}
    counters = {name: 0 for name in calls}
    deadline = time.perf_counter() + duration_s
    while time.perf_counter() < deadline:
        for name, (call, lanes) in tenants.items():
            i = counters[name]
            req = Request(index=i, arrival_s=0.0, warmup=i < warmup)
            t_submit = time.perf_counter()
            completions[name].extend(lanes.submit(call(), req, t_submit))
            completions[name].extend(lanes.poll())
            counters[name] = i + 1
    # Final drain interleaves across tenants: draining A to empty before
    # touching B would stamp B's long-finished results with A's drain
    # time, inflating B's tail and skewing the slowdown ratio.
    lanesets = {name: lanes for name, (_, lanes) in tenants.items()}
    while any(ls.in_flight for ls in lanesets.values()):
        progressed = False
        for name, ls in lanesets.items():
            got = ls.poll()
            if got:
                completions[name].extend(got)
                progressed = True
        if not progressed:
            name, ls = min(
                ((n, l) for n, l in lanesets.items() if l.in_flight),
                key=lambda nl: nl[1].oldest_t_submit(),
            )
            completions[name].extend(ls.pop_oldest())
    return completions


def measure_colocation(
    calls: Mapping[str, Callable[[], object]],
    *,
    concurrency: int,
    n_lanes: int,
    duration_s: float,
    warmup: int = 0,
    slo_us: float | None = None,
) -> ColocationResult:
    """Isolated baselines (same per-tenant lanes/slots as the co-located
    run, so the only variable is the neighbour) + the co-located run.
    An SLO applies to both measurements, so each tenant's goodput is
    comparable across isolation and co-location."""
    k = len(calls)
    per_lanes = max(1, n_lanes // k)
    per_conc = max(1, concurrency // k)
    from repro.serve.lanes import run_closed_loop

    isolated = {
        name: stats_from_completions(
            run_closed_loop(
                call,
                concurrency=per_conc,
                n_lanes=per_lanes,
                duration_s=duration_s,
                warmup=warmup,
            ),
            slo_us=slo_us,
        )
        for name, call in calls.items()
    }
    together = colocate_closed_loop(
        calls,
        concurrency=concurrency,
        n_lanes=n_lanes,
        duration_s=duration_s,
        warmup=warmup,
    )
    colocated = {
        name: stats_from_completions(comps, slo_us=slo_us)
        for name, comps in together.items()
    }
    return ColocationResult(
        names=tuple(calls), isolated=isolated, colocated=colocated
    )


def interference_matrix(
    calls: Mapping[str, Callable[[], object]],
    *,
    concurrency: int,
    n_lanes: int,
    duration_s: float,
    warmup: int = 0,
    slo_us: float | None = None,
    pairs: Sequence[tuple[str, str]] | None = None,
) -> dict[tuple[str, str], ColocationResult]:
    """Pairwise co-location over ``calls`` (all unordered pairs by
    default) — the suite-wide slowdown-vs-isolated matrix."""
    names = list(calls)
    if pairs is None:
        pairs = [
            (a, b) for i, a in enumerate(names) for b in names[i + 1:]
        ]
    out: dict[tuple[str, str], ColocationResult] = {}
    for a, b in pairs:
        out[(a, b)] = measure_colocation(
            {a: calls[a], b: calls[b]},
            concurrency=concurrency,
            n_lanes=n_lanes,
            duration_s=duration_s,
            warmup=warmup,
            slo_us=slo_us,
        )
    return out
