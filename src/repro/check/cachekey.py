"""cache-key: every axis that reaches lowering joins both cache keys.

A plan/placement axis that changes what gets compiled but is missing
from the cache key makes the warm path serve a stale executable as fresh
data — the worst failure mode a benchmarking suite has, because the
numbers still look plausible. This rule makes "added an axis, forgot the
key" a CI failure:

* every non-``self`` parameter of ``Engine._cache_key`` and
  ``Engine._bucket_key`` must be referenced inside the function (a
  parameter that does not reach the key is an axis that was plumbed in
  and then dropped);
* every field of the ``Placement`` dataclass (parsed from ``plan.py``)
  must appear as ``placement.<field>`` in *both* key builders;
* the two builders' key tuples must have the same arity — they describe
  the same executable identity, so one growing without the other means a
  new axis joined only one of them;
* every ``*.disk_cache.<load/store/...>`` call site must pass a key that
  was produced by ``_cache_key``/``_bucket_key`` (or arrived as a
  parameter named ``key``), never an ad-hoc tuple;
* ``HloDiskCache._path`` must hash ``repr(key)`` of the whole key —
  subscripting the key there would silently drop axes from the digest.
"""

from __future__ import annotations

import ast

from repro.check.core import Context, Finding, checker, dotted_name

RULE = "cache-key"

_ENGINE_FILE = "src/repro/core/engine.py"
_PLAN_FILE = "src/repro/core/plan.py"
_HLOCACHE_FILE = "src/repro/core/hlocache.py"

_KEY_BUILDERS = ("_cache_key", "_bucket_key")
_DISK_CACHE_METHODS = {"load", "store", "note_skip", "load_tuned", "store_tuned"}


def _finding(file: str, line: int, message: str) -> Finding:
    return Finding(rule=RULE, severity="error", file=file, line=line, message=message)


def _placement_fields(ctx: Context) -> set[str]:
    tree = ctx.tree(_PLAN_FILE)
    if tree is None:
        return set()
    for node in tree.body:
        if isinstance(node, ast.ClassDef) and node.name == "Placement":
            return {
                stmt.target.id
                for stmt in node.body
                if isinstance(stmt, ast.AnnAssign)
                and isinstance(stmt.target, ast.Name)
            }
    return set()


def _find_methods(tree: ast.Module, names: tuple[str, ...]) -> dict[str, ast.FunctionDef]:
    out: dict[str, ast.FunctionDef] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.FunctionDef) and node.name in names:
            out[node.name] = node
    return out


def _check_builder(
    fn: ast.FunctionDef, placement_fields: set[str]
) -> tuple[list[Finding], int]:
    """Findings for one key-builder, plus the arity of its key tuple."""
    findings: list[Finding] = []

    params = [
        a.arg
        for a in fn.args.posonlyargs + fn.args.args + fn.args.kwonlyargs
        if a.arg != "self"
    ]
    used_names = {
        n.id for n in ast.walk(fn) if isinstance(n, ast.Name)
    }
    for p in params:
        if p not in used_names:
            findings.append(
                _finding(
                    _ENGINE_FILE,
                    fn.lineno,
                    f"{fn.name}() parameter {p!r} never reaches the key — "
                    "an axis was plumbed in and then dropped",
                )
            )

    attrs = {
        d
        for n in ast.walk(fn)
        if isinstance(n, ast.Attribute) and (d := dotted_name(n)) is not None
    }
    for field in sorted(placement_fields):
        if f"placement.{field}" not in attrs:
            findings.append(
                _finding(
                    _ENGINE_FILE,
                    fn.lineno,
                    f"{fn.name}() omits Placement.{field} — every Placement "
                    "axis must join the cache key",
                )
            )

    arity = max(
        (len(n.elts) for n in ast.walk(fn) if isinstance(n, ast.Tuple)),
        default=0,
    )
    return findings, arity


def _own_nodes(fn: ast.FunctionDef):
    """Nodes of a function body excluding nested function subtrees
    (nested defs are scanned separately, inheriting captured names)."""
    stack: list[ast.AST] = list(fn.body)
    while stack:
        node = stack.pop()
        yield node
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            stack.extend(ast.iter_child_nodes(node))


def _check_disk_cache_sites(tree: ast.Module) -> list[Finding]:
    """Every disk_cache call's key argument must come from a key builder
    or a parameter literally named ``key``/``base_key``. Closures see the
    enclosing function's key bindings (captured names)."""
    findings: list[Finding] = []

    def scan_fn(fn: ast.FunctionDef, inherited: frozenset[str]) -> None:
        key_vars = set(inherited)
        key_vars.update(
            a.arg
            for a in fn.args.posonlyargs + fn.args.args + fn.args.kwonlyargs
            if a.arg in ("key", "base_key")
        )
        for node in _own_nodes(fn):
            if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
                callee = dotted_name(node.value.func) or ""
                if callee.split(".")[-1] in _KEY_BUILDERS:
                    key_vars.update(
                        t.id for t in node.targets if isinstance(t, ast.Name)
                    )
        for node in _own_nodes(fn):
            if not isinstance(node, ast.Call):
                continue
            callee = dotted_name(node.func)
            if callee is None:
                continue
            parts = callee.split(".")
            if len(parts) < 3 or parts[-2] != "disk_cache":
                continue
            if parts[-1] not in _DISK_CACHE_METHODS:
                continue
            if not node.args:
                continue
            first = node.args[0]
            ok = isinstance(first, ast.Name) and first.id in key_vars
            if not ok:
                findings.append(
                    _finding(
                        _ENGINE_FILE,
                        node.lineno,
                        f"disk_cache.{parts[-1]}() key must be bound from "
                        "_cache_key()/_bucket_key(), not built ad hoc — "
                        "ad-hoc keys drift from the compile-cache key",
                    )
                )
        for node in _own_nodes(fn):
            if isinstance(node, ast.FunctionDef):
                scan_fn(node, frozenset(key_vars))

    for node in tree.body:
        if isinstance(node, ast.FunctionDef):
            scan_fn(node, frozenset())
        elif isinstance(node, ast.ClassDef):
            for item in node.body:
                if isinstance(item, ast.FunctionDef):
                    scan_fn(item, frozenset())
    return findings


def _check_hlocache_path(ctx: Context) -> list[Finding]:
    findings: list[Finding] = []
    tree = ctx.tree(_HLOCACHE_FILE)
    if tree is None:
        return findings
    fn = _find_methods(tree, ("_path",)).get("_path")
    if fn is None:
        return findings
    key_params = {
        a.arg
        for a in fn.args.posonlyargs + fn.args.args + fn.args.kwonlyargs
        if a.arg != "self"
    }
    has_repr_of_key = any(
        isinstance(n, ast.Call)
        and isinstance(n.func, ast.Name)
        and n.func.id == "repr"
        and n.args
        and isinstance(n.args[0], ast.Name)
        and n.args[0].id in key_params
        for n in ast.walk(fn)
    )
    if not has_repr_of_key:
        findings.append(
            _finding(
                _HLOCACHE_FILE,
                fn.lineno,
                "HloDiskCache._path must digest repr(key) of the whole key "
                "tuple so every axis reaches the on-disk path",
            )
        )
    for n in ast.walk(fn):
        if (
            isinstance(n, ast.Subscript)
            and isinstance(n.value, ast.Name)
            and n.value.id in key_params
        ):
            findings.append(
                _finding(
                    _HLOCACHE_FILE,
                    n.lineno,
                    "HloDiskCache._path must not subscript the key — "
                    "selecting elements drops axes from the digest",
                )
            )
    return findings


@checker(
    RULE,
    "every ExecutionPlan/Placement axis joins both the compile-cache and "
    "HLO-disk-cache keys; disk-cache call sites use builder-produced keys",
)
def check_cache_key(ctx: Context) -> list[Finding]:
    findings: list[Finding] = []
    tree = ctx.tree(_ENGINE_FILE)
    if tree is not None:
        placement_fields = _placement_fields(ctx)
        builders = _find_methods(tree, _KEY_BUILDERS)
        arities: dict[str, int] = {}
        for name in _KEY_BUILDERS:
            fn = builders.get(name)
            if fn is None:
                findings.append(
                    _finding(
                        _ENGINE_FILE,
                        1,
                        f"engine.py must define {name}() — it is the single "
                        "source of executable identity",
                    )
                )
                continue
            fn_findings, arity = _check_builder(fn, placement_fields)
            findings.extend(fn_findings)
            arities[name] = arity
        if len(arities) == len(_KEY_BUILDERS):
            a, b = (arities[n] for n in _KEY_BUILDERS)
            if a != b:
                findings.append(
                    _finding(
                        _ENGINE_FILE,
                        builders[_KEY_BUILDERS[1]].lineno,
                        f"_cache_key builds a {a}-axis key but _bucket_key "
                        f"builds {b} — a new axis joined only one of them",
                    )
                )
        findings.extend(_check_disk_cache_sites(tree))
    findings.extend(_check_hlocache_path(ctx))
    return findings
