"""concurrency: lock-owning classes mutate their containers under the lock.

Scope: classes under ``serve/``, ``obs/`` and ``dist/`` whose ``__init__`` creates a
``threading.Lock``/``RLock``. For those classes, the containers also
created in ``__init__`` (list/dict/set/deque literals or constructors)
are treated as lock-guarded shared state: any mutation of them from a
method — assignment, augmented assignment, subscript store, or a mutator
call like ``.append``/``.update`` — must be lexically inside a
``with self.<lock>:`` block.

Classes without a lock attribute are skipped on purpose: single-owner
helpers (``_LaneTally``, ``_InflightBatches``) are thread-confined by
design, and flagging them would teach people to sprinkle locks that the
dispatch loop never needed. Reads are also unflagged — the rule exists
to catch torn writes, and read-side tolerance is a per-call-site
judgment the suppression comment can record.
"""

from __future__ import annotations

import ast

from repro.check.core import Context, Finding, checker, dotted_name

RULE = "concurrency"

_SCOPES = ("src/repro/serve", "src/repro/obs", "src/repro/dist")

_CONTAINER_CTORS = {"list", "dict", "set", "deque", "defaultdict", "OrderedDict"}
_MUTATORS = {
    "append",
    "appendleft",
    "extend",
    "extendleft",
    "insert",
    "remove",
    "pop",
    "popleft",
    "popitem",
    "clear",
    "update",
    "add",
    "discard",
    "setdefault",
}


def _finding(file: str, line: int, message: str) -> Finding:
    return Finding(rule=RULE, severity="error", file=file, line=line, message=message)


def _self_attr(node: ast.AST) -> str | None:
    """``x`` for ``self.x`` (plain attribute on the name ``self``)."""
    if (
        isinstance(node, ast.Attribute)
        and isinstance(node.value, ast.Name)
        and node.value.id == "self"
    ):
        return node.attr
    return None


def _is_lock_ctor(value: ast.expr) -> bool:
    if not isinstance(value, ast.Call):
        return False
    callee = dotted_name(value.func) or ""
    return callee.split(".")[-1] in ("Lock", "RLock")


def _is_container_init(value: ast.expr) -> bool:
    if isinstance(value, (ast.List, ast.Dict, ast.Set)):
        return True
    if isinstance(value, ast.Call):
        callee = dotted_name(value.func) or ""
        return callee.split(".")[-1] in _CONTAINER_CTORS
    return False


def _init_assignments(init: ast.FunctionDef):
    """Yield (attr-name, value-expr) for every self.x = ... in __init__."""
    for node in ast.walk(init):
        if isinstance(node, ast.Assign):
            for t in node.targets:
                attr = _self_attr(t)
                if attr is not None:
                    yield attr, node.value
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            attr = _self_attr(node.target)
            if attr is not None:
                yield attr, node.value


def _is_lock_with(node: ast.With, locks: set[str]) -> bool:
    for item in node.items:
        attr = _self_attr(item.context_expr)
        if attr in locks:
            return True
    return False


def _mutation(node: ast.AST, guarded_attrs: set[str]) -> tuple[int, str] | None:
    """(line, description) when this node mutates a guarded attribute."""
    if isinstance(node, (ast.Assign, ast.AugAssign)):
        targets = node.targets if isinstance(node, ast.Assign) else [node.target]
        for t in targets:
            attr = _self_attr(t)
            if attr in guarded_attrs:
                return node.lineno, f"assignment to self.{attr}"
            if isinstance(t, ast.Subscript):
                attr = _self_attr(t.value)
                if attr in guarded_attrs:
                    return node.lineno, f"item store into self.{attr}[...]"
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute):
        if node.func.attr in _MUTATORS:
            attr = _self_attr(node.func.value)
            if attr in guarded_attrs:
                return node.lineno, f"self.{attr}.{node.func.attr}()"
    if isinstance(node, ast.Delete):
        for t in node.targets:
            if isinstance(t, ast.Subscript):
                attr = _self_attr(t.value)
                if attr in guarded_attrs:
                    return node.lineno, f"del self.{attr}[...]"
    return None


def _scan_method(
    rel: str,
    node: ast.AST,
    locked: bool,
    locks: set[str],
    guarded_attrs: set[str],
    findings: list[Finding],
) -> None:
    for child in ast.iter_child_nodes(node):
        child_locked = locked
        if isinstance(child, ast.With) and _is_lock_with(child, locks):
            child_locked = True
        if not locked:
            hit = _mutation(child, guarded_attrs)
            if hit is not None:
                line, what = hit
                lock_name = sorted(locks)[0]
                findings.append(
                    _finding(
                        rel,
                        line,
                        f"{what} outside `with self.{lock_name}:` — this "
                        "attribute is initialised alongside a lock and is "
                        "shared across threads",
                    )
                )
        _scan_method(rel, child, child_locked, locks, guarded_attrs, findings)


def _check_class(rel: str, classdef: ast.ClassDef) -> list[Finding]:
    findings: list[Finding] = []
    init = next(
        (
            n
            for n in classdef.body
            if isinstance(n, ast.FunctionDef) and n.name == "__init__"
        ),
        None,
    )
    if init is None:
        return findings

    locks: set[str] = set()
    guarded_attrs: set[str] = set()
    for attr, value in _init_assignments(init):
        if _is_lock_ctor(value):
            locks.add(attr)
        elif _is_container_init(value):
            guarded_attrs.add(attr)
    if not locks or not guarded_attrs:
        return findings

    for method in classdef.body:
        if not isinstance(method, ast.FunctionDef) or method.name == "__init__":
            continue
        _scan_method(rel, method, False, locks, guarded_attrs, findings)
    return findings


@checker(
    RULE,
    "in serve/ and obs/, containers owned by a lock-carrying class are "
    "only mutated under that lock",
)
def check_concurrency(ctx: Context) -> list[Finding]:
    findings: list[Finding] = []
    for scope in _SCOPES:
        for rel in ctx.iter_py(scope):
            tree = ctx.tree(rel)
            if tree is None:
                continue
            for node in ast.walk(tree):
                if isinstance(node, ast.ClassDef):
                    findings.extend(_check_class(rel, node))
    return findings
