"""CLI for the static contract checker: ``python -m repro.check``.

Runs on a plain Python install — the checker only parses source with the
stdlib ``ast`` module and never imports the code it inspects, so the CI
lint job needs neither JAX nor NumPy.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from repro.check.core import all_checkers, run_checks
from repro.check.schema import update_fingerprint

_EPILOG = """\
rules (see --list-rules for one-line summaries):
  workload-contract   bench registrations vs kernels.PALLAS_OPS
  cache-key           plan/placement axes join both cache keys
  stage-discipline    _timed_stage coverage + zero-overhead hot loops
  schema-drift        BenchmarkRecord shape vs committed fingerprint
  concurrency         lock-owning serve/obs/dist classes mutate under the lock
  dist-proto          every dist/proto.py message registered + round-trips

suppressing a finding:
  put `# repro-check: ignore[<rule>]` on the flagged line or the line
  above it (comma-separate several rules; `*` matches any rule), e.g.

      self._items.append(x)  # repro-check: ignore[concurrency]

after an intentional schema change:
  bump results.SCHEMA_VERSION, then run
  `python -m repro.check --update-schema-fingerprint` and commit the
  regenerated src/repro/check/schema_fingerprint.json.
"""


def _default_root() -> Path:
    # src/repro/check/__main__.py -> repo root is three levels above src/.
    return Path(__file__).resolve().parents[3]


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.check",
        description="Static contract checker for the repro suite.",
        epilog=_EPILOG,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    parser.add_argument(
        "--root",
        type=Path,
        default=_default_root(),
        help="repo root to check (default: this checkout)",
    )
    parser.add_argument(
        "--rules",
        help="comma-separated subset of rule ids to run (default: all)",
    )
    parser.add_argument(
        "--json",
        action="store_true",
        help="emit findings as JSON on stdout",
    )
    parser.add_argument(
        "--list-rules",
        action="store_true",
        help="list registered rules and exit",
    )
    parser.add_argument(
        "--update-schema-fingerprint",
        action="store_true",
        help="rewrite src/repro/check/schema_fingerprint.json from the "
        "live results.py and exit",
    )
    args = parser.parse_args(argv)

    if args.list_rules:
        for c in all_checkers():
            print(f"{c.rule:20s} {c.description}")
        return 0

    if args.update_schema_fingerprint:
        path = update_fingerprint(args.root)
        print(f"wrote {path}")
        return 0

    rules = None
    if args.rules:
        rules = [r.strip() for r in args.rules.split(",") if r.strip()]
    try:
        findings = run_checks(args.root, rules=rules)
    except KeyError as exc:
        print(f"error: {exc.args[0]}", file=sys.stderr)
        return 2

    if args.json:
        print(
            json.dumps(
                {
                    "root": str(args.root),
                    "rules": sorted(rules) if rules else [c.rule for c in all_checkers()],
                    "findings": [f.to_dict() for f in findings],
                    "count": len(findings),
                },
                indent=2,
            )
        )
    else:
        for f in findings:
            print(f.format())
        n = len(findings)
        label = "finding" if n == 1 else "findings"
        print(f"repro.check: {n} {label}")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
