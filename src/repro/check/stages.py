"""stage-discipline: stage timing and the zero-overhead hot-path contract.

Two sub-checks:

* every ``self._stage_*`` call in ``engine.py`` happens under a
  ``with self._timed_stage(...)`` block — or inside another ``_stage_*``
  method, whose own caller already opened the span. A bare stage call
  produces a benchmark record whose ``stage_timings_us`` silently omits
  real work, which skews the overhead accounting the tracing layer
  reports;
* the designated hot loops (windowed timer, lane drain, batcher flush)
  contain no tracer/log/print calls except under an ``if ...enabled:``
  guard — the PR 8 zero-overhead contract, made static. The guarded
  pattern in ``DispatchLane.submit`` is the canonical form.
"""

from __future__ import annotations

import ast

from repro.check.core import Context, Finding, checker, dotted_name

RULE = "stage-discipline"

_ENGINE_FILE = "src/repro/core/engine.py"

# (file, class-or-None, function) triples naming the hot loops whose inner
# bodies must stay instrumentation-free. These are the code paths that run
# once per timed sample / request / batch — any unguarded tracer or log
# call there is measured as benchmark time.
_HOT_LOOPS: tuple[tuple[str, str | None, str], ...] = (
    ("src/repro/core/harness.py", None, "time_fn"),
    ("src/repro/serve/lanes.py", "DispatchLane", "submit"),
    ("src/repro/serve/lanes.py", "DispatchLane", "poll"),
    ("src/repro/serve/lanes.py", "DispatchLane", "drain"),
    ("src/repro/serve/lanes.py", "DispatchLane", "_finish"),
    ("src/repro/serve/lanes.py", None, "serve_loop"),
    ("src/repro/serve/batcher.py", None, "_coalescing_serve"),
    ("src/repro/serve/batcher.py", None, "serve_mixed_loop"),
    ("src/repro/serve/batcher.py", None, "serve_mixed_lanes"),
    ("src/repro/serve/batcher.py", "_InflightBatches", "poll"),
    ("src/repro/serve/batcher.py", "_InflightBatches", "_finish"),
)


def _finding(file: str, line: int, message: str) -> Finding:
    return Finding(rule=RULE, severity="error", file=file, line=line, message=message)


# --- stage calls must be timed -------------------------------------------


def _is_timed_stage_with(node: ast.With) -> bool:
    for item in node.items:
        expr = item.context_expr
        if isinstance(expr, ast.Call):
            callee = dotted_name(expr.func) or ""
            if callee.split(".")[-1] == "_timed_stage":
                return True
    return False


def _scan_for_stage_calls(
    node: ast.AST, timed: bool, findings: list[Finding]
) -> None:
    """Walk statements carrying a "we are under _timed_stage" flag."""
    for child in ast.iter_child_nodes(node):
        if isinstance(child, ast.FunctionDef):
            # Nested defs get their own scan; being lexically inside a
            # with-block does not mean the *call* happens there.
            _scan_for_stage_calls(child, False, findings)
            continue
        child_timed = timed
        if isinstance(child, ast.With) and _is_timed_stage_with(child):
            child_timed = True
        if isinstance(child, ast.Call):
            callee = dotted_name(child.func) or ""
            last = callee.split(".")[-1]
            if last.startswith("_stage_") and not timed:
                findings.append(
                    _finding(
                        _ENGINE_FILE,
                        child.lineno,
                        f"{last}() called outside a _timed_stage span — the "
                        "record's stage_timings_us will omit this work",
                    )
                )
        _scan_for_stage_calls(child, child_timed, findings)


def _check_engine_stages(ctx: Context) -> list[Finding]:
    findings: list[Finding] = []
    tree = ctx.tree(_ENGINE_FILE)
    if tree is None:
        return findings
    for node in ast.walk(tree):
        if not isinstance(node, ast.FunctionDef):
            continue
        # A _stage_* method runs entirely inside its caller's span, so its
        # own nested stage calls (e.g. _stage_tune -> _stage_compile) are
        # already timed.
        inside_stage = node.name.startswith("_stage_")
        _scan_for_stage_calls(node, inside_stage, findings)
    return findings


# --- hot loops must stay instrumentation-free ----------------------------


def _is_enabled_guard(test: ast.expr) -> bool:
    """True for any test that consults a tracer `.enabled` flag
    (`if tracer.enabled:`, `if t.enabled and ...:`, `if not t.enabled:`)."""
    return any(
        isinstance(n, ast.Attribute) and n.attr == "enabled"
        for n in ast.walk(test)
    )


def _instrumentation_call(call: ast.Call) -> str | None:
    callee = dotted_name(call.func)
    if callee is None:
        return None
    if callee == "print":
        return "print()"
    parts = callee.split(".")
    if parts[0] in ("logging", "logger", "log"):
        return f"{callee}()"
    if "counters" in parts[:-1]:
        return f"{callee}()"
    if parts[-1] in ("span", "event"):
        return f"{callee}()"
    return None


def _scan_hot_body(
    rel: str, node: ast.AST, guarded: bool, findings: list[Finding]
) -> None:
    for child in ast.iter_child_nodes(node):
        child_guarded = guarded
        if isinstance(child, ast.If) and _is_enabled_guard(child.test):
            # Body runs only when tracing is on; orelse stays hot.
            _scan_hot_body(rel, child.test, guarded, findings)
            for stmt in child.body:
                _scan_hot_body(rel, stmt, True, findings)
            for stmt in child.orelse:
                _scan_hot_body(rel, stmt, guarded, findings)
            continue
        if isinstance(child, ast.Call) and not guarded:
            label = _instrumentation_call(child)
            if label is not None:
                findings.append(
                    _finding(
                        rel,
                        child.lineno,
                        f"hot loop calls {label} without an "
                        "`if tracer.enabled:` guard — this cost lands "
                        "inside the timed region (PR 8 contract)",
                    )
                )
        _scan_hot_body(rel, child, child_guarded, findings)


def _find_hot_fn(
    tree: ast.Module, cls: str | None, name: str
) -> ast.FunctionDef | None:
    if cls is None:
        scope: list[ast.stmt] = tree.body
    else:
        classdef = next(
            (
                n
                for n in tree.body
                if isinstance(n, ast.ClassDef) and n.name == cls
            ),
            None,
        )
        if classdef is None:
            return None
        scope = classdef.body
    return next(
        (
            n
            for n in scope
            if isinstance(n, ast.FunctionDef) and n.name == name
        ),
        None,
    )


@checker(
    RULE,
    "engine stage calls go through _timed_stage; designated hot loops have "
    "no unguarded tracer/log/print calls",
)
def check_stage_discipline(ctx: Context) -> list[Finding]:
    findings = _check_engine_stages(ctx)
    for rel, cls, name in _HOT_LOOPS:
        tree = ctx.tree(rel)
        if tree is None:
            continue
        fn = _find_hot_fn(tree, cls, name)
        if fn is None:
            continue
        _scan_hot_body(rel, fn, False, findings)
    return findings
