"""Static contract checker for the repro suite (``python -m repro.check``).

Parses the tree with the stdlib ``ast`` module — no imports of the
checked code, no third-party dependencies — and enforces the invariants
the suite's correctness rests on: workload/kernel registration contracts,
cache-key completeness, stage-timing discipline, record-schema stability,
and lock discipline in the serving/observability layers.
"""

from repro.check.core import Checker, Context, Finding, all_checkers, run_checks

__all__ = ["Checker", "Context", "Finding", "all_checkers", "run_checks"]
