"""Checker infrastructure: findings, the rule registry, suppressions.

A *checker* is a function ``(Context) -> list[Finding]`` registered under
a rule id with :func:`checker`. The :class:`Context` gives checkers
cached source text and parsed ASTs for files under one repo root, so the
whole run parses each file at most once and never imports the code it
inspects (a checker must work in an environment without JAX).

Suppression
-----------

A finding is suppressed by a comment on the flagged line or the line
directly above it::

    self._items.append(x)  # repro-check: ignore[concurrency]
    # repro-check: ignore[stage-discipline] -- covered by the outer span
    entry = self._stage_compile(...)

The bracket takes a comma-separated list of rule ids, or ``*`` for any
rule. Suppressions are per-line and per-rule by design: a blanket file
opt-out would defeat the point of the checker.

Checkers *skip* (emit nothing) when the file a rule targets does not
exist under the root — that is what lets the seeded-violation fixtures in
``tests/test_check.py`` stay minimal. The live repo always has every
target, and ``tests/test_check.py`` asserts it is check-clean.
"""

from __future__ import annotations

import ast
import dataclasses
import re
from pathlib import Path
from typing import Callable, Iterable

__all__ = [
    "Finding",
    "Context",
    "Checker",
    "checker",
    "all_checkers",
    "run_checks",
    "dotted_name",
]

_SUPPRESS_RE = re.compile(r"#\s*repro-check:\s*ignore\[([^\]]*)\]")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One contract violation: where it is and what the contract says."""

    rule: str
    severity: str  # "error" (gates CI) — the field exists for future tiers
    file: str  # repo-root-relative posix path
    line: int
    message: str

    def format(self) -> str:
        return f"{self.file}:{self.line}: {self.severity}: [{self.rule}] {self.message}"

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


class Context:
    """Parsed-source access for checkers, rooted at one repo checkout."""

    def __init__(self, root: str | Path) -> None:
        self.root = Path(root)
        self._sources: dict[str, str | None] = {}
        self._trees: dict[str, ast.Module | None] = {}
        self._suppressions: dict[str, dict[int, set[str]]] = {}

    def source(self, rel: str) -> str | None:
        """File text for a root-relative path, or None when absent."""
        if rel not in self._sources:
            path = self.root / rel
            try:
                self._sources[rel] = path.read_text()
            except OSError:
                self._sources[rel] = None
        return self._sources[rel]

    def tree(self, rel: str) -> ast.Module | None:
        """Parsed AST, or None when the file is absent or unparseable
        (a syntax error is a louder failure than any contract finding —
        the tier-1 suite and CI both catch it on import)."""
        if rel not in self._trees:
            text = self.source(rel)
            if text is None:
                self._trees[rel] = None
            else:
                try:
                    self._trees[rel] = ast.parse(text, filename=rel)
                except SyntaxError:
                    self._trees[rel] = None
        return self._trees[rel]

    def iter_py(self, rel_dir: str) -> list[str]:
        """Sorted root-relative paths of every .py file under a directory
        (empty when the directory does not exist)."""
        base = self.root / rel_dir
        if not base.is_dir():
            return []
        return sorted(
            p.relative_to(self.root).as_posix() for p in base.rglob("*.py")
        )

    def suppressions(self, rel: str) -> dict[int, set[str]]:
        """line number -> rule ids suppressed on that line."""
        if rel not in self._suppressions:
            out: dict[int, set[str]] = {}
            text = self.source(rel)
            if text is not None:
                for i, line in enumerate(text.splitlines(), start=1):
                    m = _SUPPRESS_RE.search(line)
                    if m:
                        out[i] = {
                            r.strip() for r in m.group(1).split(",") if r.strip()
                        }
            self._suppressions[rel] = out
        return self._suppressions[rel]

    def suppressed(self, finding: Finding) -> bool:
        sup = self.suppressions(finding.file)
        for line in (finding.line, finding.line - 1):
            rules = sup.get(line)
            if rules and (finding.rule in rules or "*" in rules):
                return True
        return False


@dataclasses.dataclass(frozen=True)
class Checker:
    rule: str
    description: str
    fn: Callable[[Context], list[Finding]]


_CHECKERS: dict[str, Checker] = {}


def checker(rule: str, description: str):
    """Register a checker function under a rule id."""

    def register(fn: Callable[[Context], list[Finding]]):
        if rule in _CHECKERS:
            raise ValueError(f"duplicate checker rule id: {rule!r}")
        _CHECKERS[rule] = Checker(rule=rule, description=description, fn=fn)
        return fn

    return register


def all_checkers() -> list[Checker]:
    _load_rules()
    return [_CHECKERS[r] for r in sorted(_CHECKERS)]


def _load_rules() -> None:
    # Rule modules self-register on import, like the bench registry.
    from repro.check import (  # noqa: F401
        cachekey,
        concurrency,
        contracts,
        distproto,
        schema,
        stages,
    )


def run_checks(
    root: str | Path, rules: Iterable[str] | None = None
) -> list[Finding]:
    """Run (a subset of) the registered checkers against one repo root;
    returns unsuppressed findings sorted by (file, line, rule)."""
    _load_rules()
    wanted = set(rules) if rules is not None else None
    if wanted is not None:
        unknown = wanted - set(_CHECKERS)
        if unknown:
            raise KeyError(
                f"unknown rule(s) {sorted(unknown)}; "
                f"known: {sorted(_CHECKERS)}"
            )
    ctx = Context(root)
    findings: list[Finding] = []
    for rule in sorted(_CHECKERS):
        if wanted is not None and rule not in wanted:
            continue
        findings.extend(_CHECKERS[rule].fn(ctx))
    findings = [f for f in findings if not ctx.suppressed(f)]
    findings.sort(key=lambda f: (f.file, f.line, f.rule, f.message))
    return findings


def dotted_name(node: ast.AST) -> str | None:
    """``a.b.c`` for a Name/Attribute chain (None for anything else —
    calls, subscripts, literals inside the chain)."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None
