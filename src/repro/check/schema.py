"""schema-drift: record shape, csv header, and SCHEMA_VERSION move together.

``BenchmarkRecord`` is the on-disk interchange format — downstream
plotting and the warm-cache comparisons in CI both parse it. This rule
pins the record shape to a committed fingerprint
(``src/repro/check/schema_fingerprint.json``):

* changing the record/metadata fields or the csv header without bumping
  ``SCHEMA_VERSION`` fails (old result files would be misread as new);
* bumping the version (or changing shape with a bump) fails with a
  "regenerate the fingerprint" message — run
  ``python -m repro.check --update-schema-fingerprint`` and commit the
  diff, which makes every schema change reviewable in one file;
* the csv header may only name real record fields.
"""

from __future__ import annotations

import ast
import json
from pathlib import Path

from repro.check.core import Context, Finding, checker

RULE = "schema-drift"

_RESULTS_FILE = "src/repro/core/results.py"
FINGERPRINT_FILE = "src/repro/check/schema_fingerprint.json"


def _finding(file: str, line: int, message: str) -> Finding:
    return Finding(rule=RULE, severity="error", file=file, line=line, message=message)


def _class_fields(tree: ast.Module, name: str) -> list[str]:
    for node in tree.body:
        if isinstance(node, ast.ClassDef) and node.name == name:
            return [
                stmt.target.id
                for stmt in node.body
                if isinstance(stmt, ast.AnnAssign)
                and isinstance(stmt.target, ast.Name)
            ]
    return []


def compute_schema(ctx: Context) -> dict | None:
    """The live schema shape as a JSON-ready dict, or None when
    results.py is absent/unparseable."""
    tree = ctx.tree(_RESULTS_FILE)
    if tree is None:
        return None

    version: int | None = None
    csv_header: str | None = None
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign):
            for t in node.targets:
                if (
                    isinstance(t, ast.Name)
                    and t.id == "SCHEMA_VERSION"
                    and isinstance(node.value, ast.Constant)
                    and type(node.value.value) is int
                ):
                    version = node.value.value
        if isinstance(node, ast.FunctionDef) and node.name == "csv_header":
            for ret in ast.walk(node):
                if (
                    isinstance(ret, ast.Return)
                    and isinstance(ret.value, ast.Constant)
                    and isinstance(ret.value.value, str)
                ):
                    csv_header = ret.value.value

    return {
        "schema_version": version,
        "record_fields": _class_fields(tree, "BenchmarkRecord"),
        "metadata_fields": _class_fields(tree, "RunMetadata"),
        "csv_header": csv_header,
    }


def update_fingerprint(root: str | Path) -> Path:
    """Write the committed fingerprint from the live results.py."""
    ctx = Context(root)
    schema = compute_schema(ctx)
    if schema is None:
        raise FileNotFoundError(f"{_RESULTS_FILE} not found under {root}")
    path = Path(root) / FINGERPRINT_FILE
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(schema, indent=2, sort_keys=True) + "\n")
    return path


@checker(
    RULE,
    "BenchmarkRecord fields, csv_header(), and SCHEMA_VERSION match the "
    "committed fingerprint; shape changes require a version bump",
)
def check_schema_drift(ctx: Context) -> list[Finding]:
    findings: list[Finding] = []
    schema = compute_schema(ctx)
    if schema is None:
        return findings

    if schema["schema_version"] is None:
        findings.append(
            _finding(
                _RESULTS_FILE,
                1,
                "results.py must define SCHEMA_VERSION as an int literal",
            )
        )
    if not schema["record_fields"]:
        findings.append(
            _finding(_RESULTS_FILE, 1, "BenchmarkRecord defines no fields")
        )
    if schema["csv_header"] is None:
        findings.append(
            _finding(
                _RESULTS_FILE,
                1,
                "csv_header() must return a string literal",
            )
        )
    else:
        bogus = [
            col
            for col in schema["csv_header"].split(",")
            if col not in schema["record_fields"]
        ]
        for col in bogus:
            findings.append(
                _finding(
                    _RESULTS_FILE,
                    1,
                    f"csv_header() names {col!r}, which is not a "
                    "BenchmarkRecord field",
                )
            )

    raw = ctx.source(FINGERPRINT_FILE)
    if raw is None:
        findings.append(
            _finding(
                FINGERPRINT_FILE,
                1,
                "committed schema fingerprint is missing — run "
                "`python -m repro.check --update-schema-fingerprint` "
                "and commit it",
            )
        )
        return findings
    try:
        committed = json.loads(raw)
    except ValueError:
        findings.append(
            _finding(FINGERPRINT_FILE, 1, "schema fingerprint is not valid JSON")
        )
        return findings

    if committed == schema:
        return findings

    if committed.get("schema_version") == schema["schema_version"]:
        findings.append(
            _finding(
                _RESULTS_FILE,
                1,
                "record shape changed without a SCHEMA_VERSION bump — old "
                "result files would be misread as current; bump "
                "SCHEMA_VERSION, then regenerate the fingerprint with "
                "`python -m repro.check --update-schema-fingerprint`",
            )
        )
    else:
        findings.append(
            _finding(
                FINGERPRINT_FILE,
                1,
                f"SCHEMA_VERSION is now {schema['schema_version']} but the "
                f"fingerprint records {committed.get('schema_version')} — "
                "regenerate with "
                "`python -m repro.check --update-schema-fingerprint` and "
                "commit the diff",
            )
        )
    return findings
