"""dist-proto: every wire message round-trips through encode/decode.

An unregistered message dataclass in ``dist/proto.py`` encodes fine and
then dies on the *other* side of the socket as "unknown message type" —
in a subprocess, under load, with the traceback buried in a client's
stderr tempfile. This rule makes that a CI failure instead:

* ``MESSAGE_TYPES`` must be a module-level dict **literal** (constant
  string tags → class names) so it can be read statically — computed
  registries hide exactly the drift this rule exists to catch;
* every dataclass defined in ``proto.py`` must be registered exactly
  once, every registered name must be a dataclass defined there, and no
  tag may repeat;
* ``proto.py`` must import only from a stdlib allowlist (no jax, no
  repro internals) — the wire format must be loadable by a bare client
  process before any heavy import succeeds, and it is what lets this
  rule *execute* the module safely;
* each registered message type must actually round-trip: the rule execs
  the module source in an isolated namespace (no package import, works
  in an environment without JAX), builds a dummy instance per class from
  its field annotations, and asserts ``decode(encode(msg)) == msg``.
  This catches JSON-hostile field types (tuples come back as lists,
  bytes don't encode) at check time, not mid-benchmark.
"""

from __future__ import annotations

import ast

from repro.check.core import Context, Finding, checker

RULE = "dist-proto"

_PROTO_FILE = "src/repro/dist/proto.py"

# Modules proto.py may import: pure-stdlib, no accelerator stack. The
# exec-based round-trip below is only safe while this holds.
_ALLOWED_IMPORTS = {
    "__future__",
    "dataclasses",
    "json",
    "socket",
    "struct",
    "typing",
}

# Annotation base type -> JSON-stable dummy value. Every value here must
# survive json.dumps/json.loads unchanged, or the round-trip assertion
# would fail for reasons that are this table's fault, not the protocol's.
_DUMMIES = {
    "int": 7,
    "float": 1.25,
    "str": "x",
    "bool": True,
    "dict": {"k": 1},
    "list": [1, 2],
}


def _finding(line: int, message: str) -> Finding:
    return Finding(
        rule=RULE, severity="error", file=_PROTO_FILE, line=line, message=message
    )


def _dataclass_defs(tree: ast.Module) -> dict[str, ast.ClassDef]:
    out: dict[str, ast.ClassDef] = {}
    for node in tree.body:
        if isinstance(node, ast.ClassDef) and any(
            "dataclass" in ast.dump(d) for d in node.decorator_list
        ):
            out[node.name] = node
    return out


def _registry_literal(tree: ast.Module) -> tuple[ast.Dict | None, int]:
    """The MESSAGE_TYPES dict literal and its line, or (None, line)."""
    for node in tree.body:
        if (
            isinstance(node, ast.Assign)
            and any(
                isinstance(t, ast.Name) and t.id == "MESSAGE_TYPES"
                for t in node.targets
            )
        ):
            if isinstance(node.value, ast.Dict):
                return node.value, node.lineno
            return None, node.lineno
    return None, 1


def _check_imports(tree: ast.Module) -> list[Finding]:
    findings = []
    for node in ast.walk(tree):
        mods = []
        if isinstance(node, ast.Import):
            mods = [(a.name, node.lineno) for a in node.names]
        elif isinstance(node, ast.ImportFrom):
            mods = [(node.module or "", node.lineno)]
        for mod, line in mods:
            if mod.split(".")[0] not in _ALLOWED_IMPORTS:
                findings.append(
                    _finding(
                        line,
                        f"proto.py imports {mod!r} — the wire format must "
                        "stay pure-stdlib so bare client processes (and "
                        "this rule's exec) can load it without JAX",
                    )
                )
    return findings


def _dummy_instance(cls, errors: list[str]):
    """Build cls with a JSON-stable dummy per field, or record why not."""
    import dataclasses

    kwargs = {}
    for f in dataclasses.fields(cls):
        base = str(f.type).split("|")[0].strip()
        if base not in _DUMMIES:
            errors.append(
                f"{cls.__name__}.{f.name} has annotation {f.type!r} with no "
                "dummy mapping — extend _DUMMIES (and make sure the type is "
                "JSON-stable) when adding new wire field types"
            )
            return None
        kwargs[f.name] = _DUMMIES[base]
    return cls(**kwargs)


def _check_roundtrips(ctx: Context, line: int) -> list[Finding]:
    source = ctx.source(_PROTO_FILE)
    if source is None:
        return []
    import sys
    import types

    # A real (temporary) module entry: the dataclass decorator resolves
    # the defining module through sys.modules, so a bare dict won't do.
    mod = types.ModuleType("_repro_check_distproto_exec")
    sys.modules[mod.__name__] = mod
    try:
        exec(compile(source, _PROTO_FILE, "exec"), mod.__dict__)
    except Exception as e:  # noqa: BLE001 - any load failure is the finding
        return [_finding(line, f"proto.py failed to execute in isolation: {e}")]
    finally:
        sys.modules.pop(mod.__name__, None)
    namespace = mod.__dict__
    registry = namespace.get("MESSAGE_TYPES")
    encode, decode = namespace.get("encode"), namespace.get("decode")
    if not isinstance(registry, dict) or encode is None or decode is None:
        return [
            _finding(line, "proto.py must define MESSAGE_TYPES, encode, decode")
        ]
    findings = []
    header = namespace.get("_HEADER")
    for tag, cls in sorted(registry.items()):
        errors: list[str] = []
        msg = _dummy_instance(cls, errors)
        for err in errors:
            findings.append(_finding(line, err))
        if msg is None:
            continue
        try:
            frame = encode(msg)
            back = decode(frame[header.size :])
        except Exception as e:  # noqa: BLE001
            findings.append(
                _finding(line, f"{tag!r} does not survive encode/decode: {e}")
            )
            continue
        if back != msg:
            findings.append(
                _finding(
                    line,
                    f"{tag!r} round-trip changed the message: sent "
                    f"{msg!r}, got back {back!r} — a field type is not "
                    "JSON-stable (tuples become lists, keys become str)",
                )
            )
    return findings


@checker(
    RULE,
    "every dist/proto.py message dataclass is registered exactly once in "
    "the MESSAGE_TYPES literal and round-trips decode(encode(msg)) == msg",
)
def check_dist_proto(ctx: Context) -> list[Finding]:
    tree = ctx.tree(_PROTO_FILE)
    if tree is None:
        return []
    findings: list[Finding] = []
    findings.extend(_check_imports(tree))

    classes = _dataclass_defs(tree)
    # Exception classes are dataclass-free; anything decorated is a message.
    registry, reg_line = _registry_literal(tree)
    if registry is None:
        findings.append(
            _finding(
                reg_line,
                "MESSAGE_TYPES must be a dict literal of tag -> class so "
                "registration is statically checkable",
            )
        )
        return findings

    tags: list[str] = []
    registered: list[str] = []
    for key, value in zip(registry.keys, registry.values):
        if not (isinstance(key, ast.Constant) and isinstance(key.value, str)):
            findings.append(
                _finding(reg_line, "MESSAGE_TYPES keys must be string literals")
            )
            continue
        tags.append(key.value)
        if not isinstance(value, ast.Name):
            findings.append(
                _finding(
                    reg_line,
                    f"MESSAGE_TYPES[{key.value!r}] must name a class directly",
                )
            )
            continue
        registered.append(value.id)
        if value.id not in classes:
            findings.append(
                _finding(
                    reg_line,
                    f"MESSAGE_TYPES[{key.value!r}] = {value.id} is not a "
                    "dataclass defined in proto.py",
                )
            )

    for tag in sorted({t for t in tags if tags.count(t) > 1}):
        findings.append(
            _finding(reg_line, f"duplicate tag {tag!r} in MESSAGE_TYPES")
        )
    for name in sorted({n for n in registered if registered.count(n) > 1}):
        findings.append(
            _finding(
                reg_line,
                f"{name} registered under more than one tag — one message "
                "type must have one wire identity",
            )
        )
    for name, node in sorted(classes.items()):
        if name not in registered:
            findings.append(
                _finding(
                    node.lineno,
                    f"dataclass {name} is not registered in MESSAGE_TYPES — "
                    "it would encode but never decode on the peer",
                )
            )

    if not findings:
        findings.extend(_check_roundtrips(ctx, reg_line))
    return findings
