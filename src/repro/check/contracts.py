"""workload-contract: bench registrations match the kernel registry.

Three sub-checks, all static:

* every ``Workload(...)`` construction under the bench levels passes
  ``batch_dims=`` explicitly — ``batch_dims=None`` is the documented
  opt-out from vmap batching, but *omitting* the kwarg means the author
  never decided, which is exactly the drift this rule exists to catch;
* every string that can flow into a ``pallas_kernel=`` kwarg is a key of
  ``kernels.PALLAS_OPS``;
* every module registered in ``PALLAS_OPS`` defines a top-level
  ``tune_space()`` whose returns are literal tuples/lists of dicts with
  string keys and positive-int values (the shape ``_stage_tune`` and the
  autotune cache assume; ``({},)`` is the documented "nothing to tune"
  form).
"""

from __future__ import annotations

import ast

from repro.check.core import Context, Finding, checker

RULE = "workload-contract"

_BENCH_DIRS = (
    "src/repro/bench/level0",
    "src/repro/bench/level1",
    "src/repro/bench/level2",
    "src/repro/bench/dnn",
)
_OPS_FILE = "src/repro/kernels/ops.py"


def _finding(file: str, line: int, message: str) -> Finding:
    return Finding(rule=RULE, severity="error", file=file, line=line, message=message)


def _pallas_ops(ctx: Context) -> tuple[dict[str, str], list[Finding]]:
    """PALLAS_OPS as {op name: kernel module rel path}, plus findings for
    malformed registry entries. Empty dict when ops.py is absent."""
    findings: list[Finding] = []
    tree = ctx.tree(_OPS_FILE)
    if tree is None:
        return {}, findings

    # Map import aliases ("_matmul_mod") back to module files.
    alias_to_rel: dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom) and node.module:
            for alias in node.names:
                bound = alias.asname or alias.name
                mod_path = node.module.replace(".", "/")
                alias_to_rel[bound] = f"src/{mod_path}/{alias.name}.py"

    ops: dict[str, str] = {}
    dict_node: ast.Dict | None = None
    for node in tree.body:
        targets = []
        if isinstance(node, ast.Assign):
            targets = node.targets
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            targets = [node.target]
        for t in targets:
            if isinstance(t, ast.Name) and t.id == "PALLAS_OPS":
                value = node.value
                if isinstance(value, ast.Dict):
                    dict_node = value
                else:
                    findings.append(
                        _finding(
                            _OPS_FILE,
                            node.lineno,
                            "PALLAS_OPS must be a dict literal so the op "
                            "registry stays statically checkable",
                        )
                    )
    if dict_node is None:
        return ops, findings

    for k, v in zip(dict_node.keys, dict_node.values):
        if not (isinstance(k, ast.Constant) and isinstance(k.value, str)):
            findings.append(
                _finding(
                    _OPS_FILE,
                    (k or v).lineno,
                    "PALLAS_OPS keys must be string literals",
                )
            )
            continue
        rel = alias_to_rel.get(v.id) if isinstance(v, ast.Name) else None
        if rel is None:
            findings.append(
                _finding(
                    _OPS_FILE,
                    v.lineno,
                    f"PALLAS_OPS[{k.value!r}] must be a module imported at "
                    "the top of ops.py so the checker can resolve it",
                )
            )
            continue
        ops[k.value] = rel
    return ops, findings


def _check_tune_space(ctx: Context, op: str, rel: str) -> list[Finding]:
    findings: list[Finding] = []
    tree = ctx.tree(rel)
    if tree is None:
        findings.append(
            _finding(
                _OPS_FILE,
                1,
                f"PALLAS_OPS[{op!r}] points at {rel}, which does not exist",
            )
        )
        return findings

    fn = next(
        (
            n
            for n in tree.body
            if isinstance(n, ast.FunctionDef) and n.name == "tune_space"
        ),
        None,
    )
    if fn is None:
        findings.append(
            _finding(
                rel,
                1,
                f"kernel module for PALLAS_OPS[{op!r}] must define a "
                "top-level tune_space()",
            )
        )
        return findings

    returns = [n for n in ast.walk(fn) if isinstance(n, ast.Return)]
    if not returns:
        findings.append(
            _finding(rel, fn.lineno, "tune_space() never returns a value")
        )
    for ret in returns:
        findings.extend(_check_space_literal(rel, ret))
    return findings


def _check_space_literal(rel: str, ret: ast.Return) -> list[Finding]:
    value = ret.value
    if not isinstance(value, (ast.Tuple, ast.List)):
        return [
            _finding(
                rel,
                ret.lineno,
                "tune_space() must return a literal tuple/list of dicts "
                "(the autotune cache persists it verbatim)",
            )
        ]
    findings: list[Finding] = []
    if not value.elts:
        findings.append(
            _finding(
                rel,
                ret.lineno,
                "tune_space() must return at least one candidate "
                "(use ({},) when there is nothing to tune)",
            )
        )
    for elt in value.elts:
        if not isinstance(elt, ast.Dict):
            findings.append(
                _finding(
                    rel,
                    elt.lineno,
                    "tune_space() candidates must be dict literals",
                )
            )
            continue
        for k, v in zip(elt.keys, elt.values):
            if not (isinstance(k, ast.Constant) and isinstance(k.value, str)):
                findings.append(
                    _finding(
                        rel,
                        elt.lineno,
                        "tune_space() candidate keys must be string literals",
                    )
                )
            ok = (
                isinstance(v, ast.Constant)
                and type(v.value) is int
                and v.value > 0
            )
            if not ok:
                findings.append(
                    _finding(
                        rel,
                        v.lineno,
                        "tune_space() candidate values must be positive "
                        "int literals",
                    )
                )
    return findings


def _kwarg(call: ast.Call, name: str) -> ast.keyword | None:
    for kw in call.keywords:
        if kw.arg == name:
            return kw
    return None


def _has_splat(call: ast.Call) -> bool:
    return any(kw.arg is None for kw in call.keywords)


def _kernel_names(node: ast.expr):
    """String constants a pallas_kernel= value can evaluate to. Recurses
    into conditional *branches* only — strings in the test (e.g.
    ``"matmul" if impl == "im2col" else None``) are not kernel names.
    Non-literal expressions yield nothing: unanalyzable is not a finding."""
    if isinstance(node, ast.Constant):
        if isinstance(node.value, str):
            yield node
    elif isinstance(node, ast.IfExp):
        yield from _kernel_names(node.body)
        yield from _kernel_names(node.orelse)


def _check_bench_file(ctx: Context, rel: str, ops: dict[str, str]) -> list[Finding]:
    findings: list[Finding] = []
    tree = ctx.tree(rel)
    if tree is None:
        return findings
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        # batch_dims must be an explicit decision on every direct Workload
        # construction (helpers like dnn_workload() forward it, so the
        # Workload() call inside the helper is the enforcement point).
        if (
            isinstance(node.func, ast.Name)
            and node.func.id == "Workload"
            and _kwarg(node, "batch_dims") is None
            and not _has_splat(node)
        ):
            findings.append(
                _finding(
                    rel,
                    node.lineno,
                    "Workload() must pass batch_dims explicitly "
                    "(batch_dims=None is the opt-out from vmap batching)",
                )
            )
        # pallas_kernel= is checked on ANY call — bench modules routinely
        # pass it through construction helpers rather than Workload().
        kw = _kwarg(node, "pallas_kernel")
        if kw is not None and ops:
            for const in _kernel_names(kw.value):
                if const.value not in ops:
                    findings.append(
                        _finding(
                            rel,
                            const.lineno,
                            f"pallas_kernel={const.value!r} is not a key of "
                            f"kernels.PALLAS_OPS {sorted(ops)}",
                        )
                    )
    return findings


@checker(
    RULE,
    "bench Workload registrations declare batch_dims and name real, "
    "well-formed PALLAS_OPS kernels",
)
def check_workload_contract(ctx: Context) -> list[Finding]:
    findings: list[Finding] = []
    ops, op_findings = _pallas_ops(ctx)
    findings.extend(op_findings)
    for op, rel in sorted(ops.items()):
        findings.extend(_check_tune_space(ctx, op, rel))
    for bench_dir in _BENCH_DIRS:
        for rel in ctx.iter_py(bench_dir):
            findings.extend(_check_bench_file(ctx, rel, ops))
    return findings
