# Pallas TPU kernels for the compute hot-spots the paper benchmarks:
# GEMM/MaxFlops (matmul), attention (flash_attention — also the model zoo's
# training-time attention on TPU), DNN softmax/LRN/avgpool, the SRAD stencil
# (cooperative-groups analogue: fused vs split), prefix scan (Where), and
# bitonic key-value sort (Sort). Each <name>.py is a pl.pallas_call with
# explicit BlockSpec VMEM tiling; ref.py holds the pure-jnp oracles; ops.py
# is the public dispatch layer (pallas-on-TPU / interpret / oracle).

from repro.kernels import ops, ref  # noqa: F401
