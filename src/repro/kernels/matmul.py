"""Blocked MXU matmul kernel (the GEMM benchmark + Connected/RNN layers).

TPU adaptation of the paper's cuBLAS GEMM benchmark: HBM→VMEM tiling with an
fp32 VMEM accumulator. Grid is (M/bm, N/bn, K/bk) with K innermost — TPU
executes the grid sequentially per core, so the accumulator scratch persists
across the K steps of one (i, j) tile ("arbitrary" dimension semantics).
Block sizes default to 128/256 multiples so the MXU (128×128 systolic array)
sees hardware-aligned operands.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["matmul_pallas", "tune_space"]


def tune_space() -> tuple[dict, ...]:
    """Autotune candidates (first entry = the kernel's defaults).

    Oversized blocks are safe: the wrapper clamps each block to the actual
    dim (``min(block, dim)``) and pads, so one space serves every preset.
    """
    return (
        {"block_m": 128, "block_n": 128, "block_k": 128},
        {"block_m": 256, "block_n": 128, "block_k": 128},
        {"block_m": 128, "block_n": 256, "block_k": 128},
        {"block_m": 128, "block_n": 128, "block_k": 256},
        {"block_m": 256, "block_n": 256, "block_k": 128},
    )


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "block_k", "interpret")
)
def matmul_pallas(
    a: jax.Array,  # (M, K)
    b: jax.Array,  # (K, N)
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    M, K = a.shape
    K2, N = b.shape
    assert K == K2, (a.shape, b.shape)
    bm, bn, bk = min(block_m, M), min(block_n, N), min(block_k, K)
    pm, pn, pk = (-M) % bm, (-N) % bn, (-K) % bk
    if pm or pk:
        a = jnp.pad(a, ((0, pm), (0, pk)))
    if pk or pn:
        b = jnp.pad(b, ((0, pk), (0, pn)))
    Mp, Kp = a.shape
    Np = b.shape[1]
    k_steps = Kp // bk
    out = pl.pallas_call(
        functools.partial(_matmul_kernel, k_steps=k_steps),
        grid=(Mp // bm, Np // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, b)
    return out[:M, :N]
