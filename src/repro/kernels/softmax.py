"""Row-softmax kernel (DNN Softmax benchmark, paper eq. 2).

Two internal passes over column chunks held in VMEM: pass 1 accumulates the
running max and sum-of-exponentials (online softmax, numerically safe for
long rows); pass 2 writes the normalized values. Rows are tiled over the
grid; columns are chunked inside the kernel so arbitrarily wide class
dimensions never exceed the VMEM block.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["softmax_pallas", "tune_space"]


def tune_space() -> tuple[dict, ...]:
    """Autotune candidates (first entry = the kernel's defaults)."""
    return (
        {"block_rows": 256, "block_cols": 512},
        {"block_rows": 128, "block_cols": 512},
        {"block_rows": 512, "block_cols": 256},
        {"block_rows": 256, "block_cols": 1024},
    )

_NEG_INF = -1e30


def _softmax_kernel(x_ref, o_ref, *, block_c: int, c_valid: int):
    br, cp = x_ref.shape
    n_blocks = cp // block_c

    def stat_body(j, carry):
        m, l = carry
        blk = x_ref[:, pl.dslice(j * block_c, block_c)].astype(jnp.float32)
        col = j * block_c + jax.lax.broadcasted_iota(jnp.int32, (1, block_c), 1)
        blk = jnp.where(col < c_valid, blk, _NEG_INF)
        m_new = jnp.maximum(m, jnp.max(blk, axis=-1, keepdims=True))
        l_new = l * jnp.exp(m - m_new) + jnp.sum(
            jnp.exp(blk - m_new), axis=-1, keepdims=True
        )
        return m_new, l_new

    init = (
        jnp.full((br, 1), _NEG_INF, jnp.float32),
        jnp.zeros((br, 1), jnp.float32),
    )
    m, l = jax.lax.fori_loop(0, n_blocks, stat_body, init)
    inv = 1.0 / jnp.maximum(l, 1e-30)

    def write_body(j, _):
        blk = x_ref[:, pl.dslice(j * block_c, block_c)].astype(jnp.float32)
        o_ref[:, pl.dslice(j * block_c, block_c)] = (
            jnp.exp(blk - m) * inv
        ).astype(o_ref.dtype)
        return 0

    jax.lax.fori_loop(0, n_blocks, write_body, 0)


@functools.partial(
    jax.jit, static_argnames=("block_rows", "block_cols", "interpret")
)
def softmax_pallas(
    x: jax.Array,  # (..., C) — flattened to (R, C)
    *,
    block_rows: int = 256,
    block_cols: int = 512,
    interpret: bool = False,
) -> jax.Array:
    orig_shape = x.shape
    C = orig_shape[-1]
    x2 = x.reshape(-1, C)
    R = x2.shape[0]
    br = min(block_rows, R)
    bc = min(block_cols, C)
    pr, pc = (-R) % br, (-C) % bc
    if pr or pc:
        x2 = jnp.pad(x2, ((0, pr), (0, pc)))
    Rp, Cp = x2.shape
    out = pl.pallas_call(
        functools.partial(_softmax_kernel, block_c=bc, c_valid=C),
        grid=(Rp // br,),
        in_specs=[pl.BlockSpec((br, Cp), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, Cp), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Rp, Cp), x.dtype),
        interpret=interpret,
    )(x2)
    return out[:R, :C].reshape(orig_shape)
