"""SRAD diffusion stencil — the Cooperative-Groups analogue (DESIGN.md §2).

The paper adds grid-wide sync (cooperative groups) to SRAD because its two
phases — (1) diffusion-coefficient from 4-neighbour gradients, (2) divergence
update — must be separated by a global barrier. On TPU there is no grid sync
because there is no grid-wide parallel execution to synchronize; the analogue
of "one kernel with an internal barrier" vs "two kernel launches" is **one
fused kernel holding the image in VMEM across both phases** vs **two
`pallas_call`s with an HBM round-trip between them**. ``srad_step_fused`` and
``srad_step_split`` implement exactly that pair; the feature benchmark
measures the round-trip cost the paper's cooperative kernel avoids.

Both variants operate on a whole image per block (the cooperative-kernel
regime of the paper: its CG version is limited to ≤256², ours to what fits
VMEM — 1024² fp32 = 4 MiB, comfortably inside 128 MiB).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["srad_step_fused", "srad_step_split", "tune_space"]


def tune_space() -> tuple[dict, ...]:
    """No block parameters: the stencil runs as one whole-image block."""
    return ({},)


def _gradients(img):
    north = jnp.concatenate([img[:1], img[:-1]], axis=0)
    south = jnp.concatenate([img[1:], img[-1:]], axis=0)
    west = jnp.concatenate([img[:, :1], img[:, :-1]], axis=1)
    east = jnp.concatenate([img[:, 1:], img[:, -1:]], axis=1)
    return north - img, south - img, west - img, east - img


def _coeff(img, dN, dS, dW, dE, q0sqr):
    g2 = (dN * dN + dS * dS + dW * dW + dE * dE) / (img * img)
    l = (dN + dS + dW + dE) / img
    num = 0.5 * g2 - 0.0625 * l * l
    den = 1.0 + 0.25 * l
    qsqr = num / (den * den)
    c = 1.0 / (1.0 + (qsqr - q0sqr) / (q0sqr * (1.0 + q0sqr)))
    return jnp.clip(c, 0.0, 1.0)


def _divergence_update(img, c, dN, dS, dW, dE, lam):
    cS = jnp.concatenate([c[1:], c[-1:]], axis=0)
    cE = jnp.concatenate([c[:, 1:], c[:, -1:]], axis=1)
    div = c * dN + cS * dS + c * dW + cE * dE
    return img + 0.25 * lam * div


def _fused_kernel(img_ref, o_ref, *, lam: float, q0sqr: float):
    img = img_ref[...].astype(jnp.float32)
    dN, dS, dW, dE = _gradients(img)
    c = _coeff(img, dN, dS, dW, dE, q0sqr)
    # "Grid sync" point: on GPU this is grid.sync(); here phase 2 simply
    # continues on VMEM-resident values — no HBM round-trip.
    o_ref[...] = _divergence_update(img, c, dN, dS, dW, dE, lam).astype(o_ref.dtype)


def _phase1_kernel(img_ref, c_ref, *, q0sqr: float):
    img = img_ref[...].astype(jnp.float32)
    dN, dS, dW, dE = _gradients(img)
    c_ref[...] = _coeff(img, dN, dS, dW, dE, q0sqr).astype(c_ref.dtype)


def _phase2_kernel(img_ref, c_ref, o_ref, *, lam: float):
    img = img_ref[...].astype(jnp.float32)
    c = c_ref[...].astype(jnp.float32)
    dN, dS, dW, dE = _gradients(img)  # recomputed, as in Rodinia's srad_v1
    o_ref[...] = _divergence_update(img, c, dN, dS, dW, dE, lam).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("lam", "q0sqr", "interpret"))
def srad_step_fused(
    img: jax.Array, *, lam: float = 0.5, q0sqr: float = 0.05, interpret: bool = False
) -> jax.Array:
    h, w = img.shape
    return pl.pallas_call(
        functools.partial(_fused_kernel, lam=lam, q0sqr=q0sqr),
        out_shape=jax.ShapeDtypeStruct((h, w), img.dtype),
        interpret=interpret,
    )(img)


@functools.partial(jax.jit, static_argnames=("lam", "q0sqr", "interpret"))
def srad_step_split(
    img: jax.Array, *, lam: float = 0.5, q0sqr: float = 0.05, interpret: bool = False
) -> jax.Array:
    h, w = img.shape
    c = pl.pallas_call(
        functools.partial(_phase1_kernel, q0sqr=q0sqr),
        out_shape=jax.ShapeDtypeStruct((h, w), jnp.float32),
        interpret=interpret,
    )(img)
    return pl.pallas_call(
        functools.partial(_phase2_kernel, lam=lam),
        out_shape=jax.ShapeDtypeStruct((h, w), img.dtype),
        interpret=interpret,
    )(img, c)
