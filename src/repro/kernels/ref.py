"""Pure-jnp oracles for every Pallas kernel in this package.

Each ``*_ref`` is the semantic ground truth: numerically straightforward,
un-tiled, fp32-accumulating jnp code. Kernel tests sweep shapes/dtypes and
``assert_allclose`` the Pallas output against these.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "matmul_ref",
    "attention_ref",
    "softmax_ref",
    "lrn_ref",
    "avgpool_ref",
    "srad_step_ref",
    "prefix_scan_ref",
    "sort_kv_ref",
]


def matmul_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """C = A @ B with fp32 accumulation, cast back to A's dtype."""
    out = jnp.dot(a, b, preferred_element_type=jnp.float32)
    return out.astype(a.dtype)


def attention_ref(
    q: jax.Array,  # (B, Hq, T, D)
    k: jax.Array,  # (B, Hkv, S, D)
    v: jax.Array,  # (B, Hkv, S, D)
    *,
    causal: bool = False,
    window: int | None = None,
    scale: float | None = None,
) -> jax.Array:
    """Dense (materialized-scores) GQA attention oracle.

    Queries occupy the *last* T positions of the S-long key timeline
    (``offset = S - T``), which covers prefill (T == S) and cached decode
    (T << S). ``window`` is sliding-window attention: query at absolute
    position p attends to keys in (p - window, p].
    """
    B, Hq, T, D = q.shape
    Hkv = k.shape[1]
    assert Hq % Hkv == 0, (Hq, Hkv)
    group = Hq // Hkv
    if scale is None:
        scale = D**-0.5
    kx = jnp.repeat(k, group, axis=1)  # (B, Hq, S, D)
    vx = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhtd,bhsd->bhts", q.astype(jnp.float32), kx.astype(jnp.float32))
    s *= scale
    S = k.shape[2]
    offset = S - T
    q_pos = jnp.arange(T)[:, None] + offset  # absolute positions
    k_pos = jnp.arange(S)[None, :]
    mask = jnp.ones((T, S), dtype=bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    # Fully-masked rows produce NaN from softmax(-inf row); define as zeros.
    p = jnp.where(jnp.any(mask, axis=-1)[None, None, :, None], p, 0.0)
    out = jnp.einsum("bhts,bhsd->bhtd", p, vx.astype(jnp.float32))
    return out.astype(q.dtype)


def softmax_ref(x: jax.Array) -> jax.Array:
    """Row softmax over the last axis, fp32 internally."""
    return jax.nn.softmax(x.astype(jnp.float32), axis=-1).astype(x.dtype)


def lrn_ref(
    x: jax.Array,  # (N, C, H, W)
    *,
    size: int = 5,
    alpha: float = 1e-4,
    beta: float = 0.75,
    k: float = 2.0,
) -> jax.Array:
    """AlexNet local response normalization across channels (paper eq. 3)."""
    xf = x.astype(jnp.float32)
    sq = xf * xf
    half = size // 2
    C = x.shape[1]
    padded = jnp.pad(sq, ((0, 0), (half, half), (0, 0), (0, 0)))
    win = sum(padded[:, i : i + C] for i in range(size))
    return (xf / jnp.power(k + alpha * win, beta)).astype(x.dtype)


def avgpool_ref(x: jax.Array, *, ksize: int = 2) -> jax.Array:
    """Non-overlapping (stride == ksize) average pooling on (N, C, H, W)."""
    n, c, h, w = x.shape
    assert h % ksize == 0 and w % ksize == 0, (h, w, ksize)
    xf = x.astype(jnp.float32)
    out = xf.reshape(n, c, h // ksize, ksize, w // ksize, ksize).mean(axis=(3, 5))
    return out.astype(x.dtype)


def _srad_coeff(img: jax.Array, q0sqr: jax.Array):
    """Phase 1: diffusion coefficient from 4-neighbour gradients (Rodinia)."""
    # Replicated (clamped) boundary neighbours.
    north = jnp.concatenate([img[:1], img[:-1]], axis=0)
    south = jnp.concatenate([img[1:], img[-1:]], axis=0)
    west = jnp.concatenate([img[:, :1], img[:, :-1]], axis=1)
    east = jnp.concatenate([img[:, 1:], img[:, -1:]], axis=1)
    dN, dS, dW, dE = north - img, south - img, west - img, east - img
    g2 = (dN * dN + dS * dS + dW * dW + dE * dE) / (img * img)
    l = (dN + dS + dW + dE) / img
    num = 0.5 * g2 - 0.0625 * l * l
    den = 1.0 + 0.25 * l
    qsqr = num / (den * den)
    c = 1.0 / (1.0 + (qsqr - q0sqr) / (q0sqr * (1.0 + q0sqr)))
    return jnp.clip(c, 0.0, 1.0), (dN, dS, dW, dE)


def srad_step_ref(img: jax.Array, *, lam: float = 0.5, q0sqr: float = 0.05) -> jax.Array:
    """One SRAD diffusion step (phases 1+2) on a 2-D fp32 image."""
    imgf = img.astype(jnp.float32)
    c, (dN, dS, dW, dE) = _srad_coeff(imgf, jnp.float32(q0sqr))
    cS = jnp.concatenate([c[1:], c[-1:]], axis=0)  # c at south neighbour
    cE = jnp.concatenate([c[:, 1:], c[:, -1:]], axis=1)  # c at east neighbour
    div = c * dN + cS * dS + c * dW + cE * dE
    return (imgf + 0.25 * lam * div).astype(img.dtype)


def prefix_scan_ref(x: jax.Array) -> jax.Array:
    """Inclusive prefix sum along the last axis, fp32 accumulation."""
    return jnp.cumsum(x.astype(jnp.float32), axis=-1).astype(x.dtype)


def sort_kv_ref(keys: jax.Array, values: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Ascending key sort carrying values (the paper's key-value Sort)."""
    order = jnp.argsort(keys, axis=-1, stable=True)
    return jnp.take_along_axis(keys, order, axis=-1), jnp.take_along_axis(
        values, order, axis=-1
    )
