"""In-VMEM bitonic key-value sort kernel (the Sort benchmark).

TPU adaptation of the paper's radix sort (Satish et al.): radix sort's
per-digit histogram + scatter is gather/scatter-heavy, which the TPU's
vector unit punishes. A bitonic network is branch-free and expressible with
**reshape-swap compare-exchange** — partner elements at XOR-distance ``j``
are adjacent blocks of size ``j`` after reshaping to (n/2j, 2, j), so every
stage is pure vector min/max/select with zero gathers. O(n log² n) work
trades for full lane utilization; rows are sorted independently (grid over
row tiles), and the ops.py wrapper merges multi-block arrays.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["bitonic_sort_pallas", "tune_space"]


def tune_space() -> tuple[dict, ...]:
    """No block parameters: the network shape is fixed by N (single entry)."""
    return ({},)


def _stage(keys, vals, j: int, dir_up_vec):
    """One compare-exchange stage at XOR distance j (vector-only)."""
    n = keys.shape[-1]
    # Partner at idx ^ j == swap adjacent j-blocks.
    kp = keys.reshape(-1, 2, j)[:, ::-1, :].reshape(n)
    vp = vals.reshape(-1, 2, j)[:, ::-1, :].reshape(n)
    idx = jax.lax.iota(jnp.int32, n)
    is_low = (idx & j) == 0  # this element is the smaller index of its pair
    # Ascending region: low index keeps min. Descending: low keeps max.
    # Strict comparisons per side — on equal keys BOTH sides keep their own
    # element (otherwise one (key, value) pair is duplicated and its partner
    # dropped; caught by the hypothesis permutation property).
    take_min = jnp.logical_xor(is_low, ~dir_up_vec)
    swap = jnp.where(take_min, keys > kp, keys < kp)
    keys_new = jnp.where(swap, kp, keys)
    vals_new = jnp.where(swap, vp, vals)
    return keys_new, vals_new


def _bitonic_kernel(k_ref, v_ref, ko_ref, vo_ref, *, n: int):
    keys = k_ref[0]
    vals = v_ref[0]
    idx = jax.lax.iota(jnp.int32, n)
    k = 2
    while k <= n:
        dir_up_vec = (idx & k) == 0  # ascending iff bit k of index is 0
        j = k // 2
        while j >= 1:
            keys, vals = _stage(keys, vals, j, dir_up_vec)
            j //= 2
        k *= 2
    ko_ref[0] = keys
    vo_ref[0] = vals


@functools.partial(jax.jit, static_argnames=("interpret",))
def bitonic_sort_pallas(
    keys: jax.Array,  # (N,) — N padded to a power of two by the wrapper
    values: jax.Array,  # (N,)
    *,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    (N,) = keys.shape
    assert N & (N - 1) == 0, f"bitonic sort needs a power-of-two length, got {N}"
    assert values.shape == (N,)
    ko, vo = pl.pallas_call(
        functools.partial(_bitonic_kernel, n=N),
        out_shape=(
            jax.ShapeDtypeStruct((1, N), keys.dtype),
            jax.ShapeDtypeStruct((1, N), values.dtype),
        ),
        interpret=interpret,
    )(keys[None], values[None])
    return ko[0], vo[0]
