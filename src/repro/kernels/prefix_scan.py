"""Blocked inclusive prefix-sum kernel (substrate of the Where benchmark).

TPU adaptation of the GPU scan: GPUs do block-local scans + a spine scan +
a fixup pass because blocks run concurrently. A TPU core walks the grid
**sequentially**, so the cross-block carry is just an SMEM scalar that
persists across grid steps — one pass, no spine, no fixup. The block-local
scan is a vectorized ``jnp.cumsum`` in VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["prefix_scan_pallas", "tune_space"]


def tune_space() -> tuple[dict, ...]:
    """Autotune candidates (first entry = the kernel's defaults)."""
    return ({"block_n": 2048}, {"block_n": 1024}, {"block_n": 4096})


def _scan_kernel(x_ref, o_ref, carry_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        carry_ref[0] = 0.0

    block = x_ref[...].astype(jnp.float32)  # (1, bn)
    local = jnp.cumsum(block, axis=-1)
    o_ref[...] = (local + carry_ref[0]).astype(o_ref.dtype)
    carry_ref[0] = carry_ref[0] + jnp.sum(block)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def prefix_scan_pallas(
    x: jax.Array,  # (N,)
    *,
    block_n: int = 2048,
    interpret: bool = False,
) -> jax.Array:
    (N,) = x.shape
    bn = min(block_n, N)
    pn = (-N) % bn
    x2 = jnp.pad(x, (0, pn))[None, :]  # zeros don't perturb the running sum
    Np = x2.shape[1]
    out = pl.pallas_call(
        _scan_kernel,
        grid=(Np // bn,),
        in_specs=[pl.BlockSpec((1, bn), lambda i: (0, i))],
        out_specs=pl.BlockSpec((1, bn), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, Np), x.dtype),
        scratch_shapes=[pltpu.SMEM((1,), jnp.float32)],
        interpret=interpret,
    )(x2)
    return out[0, :N]
