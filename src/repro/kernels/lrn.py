"""Local Response Normalization kernel (paper eq. 3, AlexNet-style).

TPU adaptation: the GPU implementation walks the channel window per thread;
here the cross-channel windowed sum-of-squares is a **banded-matrix matmul on
the MXU** — ``win = Band @ sq`` where ``Band[i, j] = 1`` iff ``|i - j| <=
size // 2``. Channels are small (≤ ~2k), so the band matrix lives in VMEM and
the windowed reduction becomes dense systolic work instead of a gather loop —
a textbook case of rethinking a CUDA neighbourhood loop for systolic compute.
Spatial positions are tiled over the grid.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["lrn_pallas", "tune_space"]


def tune_space() -> tuple[dict, ...]:
    """Autotune candidates (first entry = the kernel's defaults)."""
    return ({"block_s": 512}, {"block_s": 256}, {"block_s": 1024})


def _lrn_kernel(x_ref, band_ref, o_ref, *, alpha: float, beta: float, k: float):
    x = x_ref[0].astype(jnp.float32)  # (C, bs)
    band = band_ref[...].astype(jnp.float32)  # (C, C)
    win = jnp.dot(band, x * x, preferred_element_type=jnp.float32)
    denom = jnp.exp(beta * jnp.log(k + alpha * win))
    o_ref[0] = (x / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("size", "alpha", "beta", "k", "block_s", "interpret")
)
def lrn_pallas(
    x: jax.Array,  # (N, C, H, W)
    *,
    size: int = 5,
    alpha: float = 1e-4,
    beta: float = 0.75,
    k: float = 2.0,
    block_s: int = 512,
    interpret: bool = False,
) -> jax.Array:
    N, C, H, W = x.shape
    S = H * W
    x2 = x.reshape(N, C, S)
    bs = min(block_s, S)
    ps = (-S) % bs
    if ps:
        x2 = jnp.pad(x2, ((0, 0), (0, 0), (0, ps)))
    Sp = x2.shape[-1]
    half = size // 2
    ch = jnp.arange(C)
    band = (jnp.abs(ch[:, None] - ch[None, :]) <= half).astype(x.dtype)
    out = pl.pallas_call(
        functools.partial(_lrn_kernel, alpha=alpha, beta=beta, k=k),
        grid=(N, Sp // bs),
        in_specs=[
            pl.BlockSpec((1, C, bs), lambda n, s: (n, 0, s)),
            pl.BlockSpec((C, C), lambda n, s: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, C, bs), lambda n, s: (n, 0, s)),
        out_shape=jax.ShapeDtypeStruct((N, C, Sp), x.dtype),
        interpret=interpret,
    )(x2, band)
    return out[:, :, :S].reshape(N, C, H, W)
