"""Public jit'd entry points for the kernel layer.

Each op dispatches between the Pallas TPU kernel and the pure-jnp oracle:

- on TPU backends the Pallas kernel runs compiled,
- on CPU (this container) the kernel runs in ``interpret=True`` mode when
  invoked directly (tests/benchmarks), while *model/dry-run* code paths use
  the jnp reference implementation so XLA:CPU can lower the 512-device SPMD
  programs (Pallas interpret inside a 512-way pjit is neither representative
  nor compilable in reasonable time — DESIGN.md §8).

``mode`` overrides: "pallas" forces the kernel (interpret on non-TPU),
"ref" forces the oracle, "auto" picks pallas-on-TPU / ref-otherwise.
"""

from __future__ import annotations

from typing import Literal

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.avgpool import avgpool_pallas
from repro.kernels.bitonic_sort import bitonic_sort_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.lrn import lrn_pallas
from repro.kernels.matmul import matmul_pallas
from repro.kernels.prefix_scan import prefix_scan_pallas
from repro.kernels.softmax import softmax_pallas
from repro.kernels.srad_stencil import srad_step_fused, srad_step_split

__all__ = [
    "matmul",
    "attention",
    "softmax",
    "lrn",
    "avgpool",
    "srad_step",
    "prefix_scan",
    "sort_kv",
    "on_tpu",
]

Mode = Literal["auto", "pallas", "ref"]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _use_pallas(mode: Mode) -> tuple[bool, bool]:
    """-> (use_pallas, interpret)."""
    if mode == "ref":
        return False, False
    if mode == "pallas":
        return True, not on_tpu()
    return on_tpu(), False


def matmul(a, b, *, mode: Mode = "auto", **blocks):
    use, interp = _use_pallas(mode)
    if use:
        return matmul_pallas(a, b, interpret=interp, **blocks)
    return _ref.matmul_ref(a, b)


def attention(
    q,
    k,
    v,
    *,
    causal: bool = False,
    window: int | None = None,
    scale: float | None = None,
    mode: Mode = "auto",
    **blocks,
):
    use, interp = _use_pallas(mode)
    if use:
        return flash_attention_pallas(
            q, k, v, causal=causal, window=window, scale=scale,
            interpret=interp, **blocks,
        )
    return _ref.attention_ref(q, k, v, causal=causal, window=window, scale=scale)


def softmax(x, *, mode: Mode = "auto", **blocks):
    use, interp = _use_pallas(mode)
    if use:
        return softmax_pallas(x, interpret=interp, **blocks)
    return _ref.softmax_ref(x)


def lrn(x, *, size=5, alpha=1e-4, beta=0.75, k=2.0, mode: Mode = "auto", **blocks):
    use, interp = _use_pallas(mode)
    if use:
        return lrn_pallas(
            x, size=size, alpha=alpha, beta=beta, k=k, interpret=interp, **blocks
        )
    return _ref.lrn_ref(x, size=size, alpha=alpha, beta=beta, k=k)


def avgpool(x, *, ksize=2, mode: Mode = "auto", **blocks):
    use, interp = _use_pallas(mode)
    if use:
        return avgpool_pallas(x, ksize=ksize, interpret=interp, **blocks)
    return _ref.avgpool_ref(x, ksize=ksize)


def srad_step(
    img, *, lam=0.5, q0sqr=0.05, fused: bool = True, mode: Mode = "auto"
):
    use, interp = _use_pallas(mode)
    if use:
        fn = srad_step_fused if fused else srad_step_split
        return fn(img, lam=lam, q0sqr=q0sqr, interpret=interp)
    return _ref.srad_step_ref(img, lam=lam, q0sqr=q0sqr)


def prefix_scan(x, *, mode: Mode = "auto", **blocks):
    use, interp = _use_pallas(mode)
    if use:
        return prefix_scan_pallas(x, interpret=interp, **blocks)
    return _ref.prefix_scan_ref(x)


def sort_kv(keys, values, *, mode: Mode = "auto"):
    use, interp = _use_pallas(mode)
    if use:
        (n,) = keys.shape
        n_pow2 = 1 << (n - 1).bit_length()
        if n_pow2 != n:
            pad = n_pow2 - n
            maxval = (
                jnp.iinfo(keys.dtype).max
                if jnp.issubdtype(keys.dtype, jnp.integer)
                else jnp.inf
            )
            keys = jnp.pad(keys, (0, pad), constant_values=maxval)
            values = jnp.pad(values, (0, pad))
        ko, vo = bitonic_sort_pallas(keys, values, interpret=interp)
        return ko[:n], vo[:n]
    return _ref.sort_kv_ref(keys, values)
