"""Public jit'd entry points for the kernel layer.

Each op dispatches between the Pallas TPU kernel and the pure-jnp oracle:

- on TPU backends the Pallas kernel runs compiled,
- on CPU (this container) the kernel runs in ``interpret=True`` mode when
  invoked directly (tests/benchmarks), while *model/dry-run* code paths use
  the jnp reference implementation so XLA:CPU can lower the 512-device SPMD
  programs (Pallas interpret inside a 512-way pjit is neither representative
  nor compilable in reasonable time — DESIGN.md §8).

``mode`` overrides: "pallas" forces the kernel (interpret on non-TPU),
"ref" forces the oracle, "auto" picks pallas-on-TPU / ref-otherwise.

The engine's ``impl`` axis routes through this same dispatch rather than a
parallel code path: ``force_impl(mode, op, **params)`` sets a context-local
override consulted whenever an op is called with ``mode="auto"`` (an explicit
call-site ``mode=`` always wins). The engine enters this context around
``jit(fn).lower(...)`` so the choice is baked into the traced program — the
bench functions themselves never change. ``params`` are merged under the
call-site blocks, and only for the named op, which is how ``_stage_tune``'s
winning block config reaches the kernel.

``tune_space(op)`` exposes each kernel module's exported autotune candidates
(``PALLAS_OPS`` maps op name -> kernel module) for the engine's tune stage.
"""

from __future__ import annotations

import contextlib
import contextvars
from typing import Literal

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels import (
    avgpool as _avgpool_mod,
    bitonic_sort as _bitonic_mod,
    flash_attention as _flash_mod,
    lrn as _lrn_mod,
    matmul as _matmul_mod,
    prefix_scan as _scan_mod,
    softmax as _softmax_mod,
    srad_stencil as _srad_mod,
)
from repro.kernels.avgpool import avgpool_pallas
from repro.kernels.bitonic_sort import bitonic_sort_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.lrn import lrn_pallas
from repro.kernels.matmul import matmul_pallas
from repro.kernels.prefix_scan import prefix_scan_pallas
from repro.kernels.softmax import softmax_pallas
from repro.kernels.srad_stencil import srad_step_fused, srad_step_split

__all__ = [
    "matmul",
    "attention",
    "softmax",
    "lrn",
    "avgpool",
    "srad_step",
    "prefix_scan",
    "sort_kv",
    "on_tpu",
    "force_impl",
    "tune_space",
    "PALLAS_OPS",
]

Mode = Literal["auto", "pallas", "ref"]

# op name -> kernel module exporting tune_space(). These names are what a
# Workload's ``pallas_kernel`` field refers to (registry.py impl contract).
PALLAS_OPS = {
    "matmul": _matmul_mod,
    "attention": _flash_mod,
    "softmax": _softmax_mod,
    "lrn": _lrn_mod,
    "avgpool": _avgpool_mod,
    "srad_step": _srad_mod,
    "prefix_scan": _scan_mod,
    "sort_kv": _bitonic_mod,
}

# (mode, op-or-None, params) set by force_impl; consulted only for mode="auto"
# call sites so an explicit mode= argument keeps absolute priority.
_FORCED: contextvars.ContextVar = contextvars.ContextVar(
    "repro_forced_impl", default=None
)


@contextlib.contextmanager
def force_impl(mode: Mode, op: str | None = None, **params):
    """Context-locally override ``mode="auto"`` dispatch for the kernel ops.

    ``op=None`` applies to every op; otherwise ``params`` (tuned block sizes)
    are merged only into calls of the named op. Must wrap *tracing* (jit
    lower / first call), not execution — dispatch happens at trace time.
    """
    if mode not in ("auto", "pallas", "ref"):
        raise ValueError(f"force_impl mode must be auto|pallas|ref, got {mode!r}")
    token = _FORCED.set((mode, op, dict(params)))
    try:
        yield
    finally:
        _FORCED.reset(token)


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _use_pallas(mode: Mode) -> tuple[bool, bool]:
    """-> (use_pallas, interpret)."""
    if mode == "ref":
        return False, False
    if mode == "pallas":
        return True, not on_tpu()
    return on_tpu(), False


def _resolve(op: str, mode: Mode, blocks: dict) -> tuple[bool, bool, dict]:
    """Apply any force_impl override -> (use_pallas, interpret, blocks)."""
    forced = _FORCED.get()
    if mode == "auto" and forced is not None:
        mode, f_op, f_params = forced
        if f_params and (f_op is None or f_op == op):
            blocks = {**f_params, **blocks}
    use, interp = _use_pallas(mode)
    return use, interp, blocks


def tune_space(op: str) -> tuple[dict, ...]:
    """The autotune candidates for ``op`` (first entry = kernel defaults)."""
    try:
        module = PALLAS_OPS[op]
    except KeyError:
        raise KeyError(
            f"unknown pallas op {op!r}; known: {sorted(PALLAS_OPS)}"
        ) from None
    return module.tune_space()


def matmul(a, b, *, mode: Mode = "auto", **blocks):
    use, interp, blocks = _resolve("matmul", mode, blocks)
    if use:
        return matmul_pallas(a, b, interpret=interp, **blocks)
    return _ref.matmul_ref(a, b)


def attention(
    q,
    k,
    v,
    *,
    causal: bool = False,
    window: int | None = None,
    scale: float | None = None,
    mode: Mode = "auto",
    **blocks,
):
    use, interp, blocks = _resolve("attention", mode, blocks)
    if use:
        return flash_attention_pallas(
            q, k, v, causal=causal, window=window, scale=scale,
            interpret=interp, **blocks,
        )
    return _ref.attention_ref(q, k, v, causal=causal, window=window, scale=scale)


def softmax(x, *, mode: Mode = "auto", **blocks):
    use, interp, blocks = _resolve("softmax", mode, blocks)
    if use:
        return softmax_pallas(x, interpret=interp, **blocks)
    return _ref.softmax_ref(x)


def lrn(x, *, size=5, alpha=1e-4, beta=0.75, k=2.0, mode: Mode = "auto", **blocks):
    use, interp, blocks = _resolve("lrn", mode, blocks)
    if use:
        return lrn_pallas(
            x, size=size, alpha=alpha, beta=beta, k=k, interpret=interp, **blocks
        )
    return _ref.lrn_ref(x, size=size, alpha=alpha, beta=beta, k=k)


def avgpool(x, *, ksize=2, mode: Mode = "auto", **blocks):
    use, interp, blocks = _resolve("avgpool", mode, blocks)
    if use:
        return avgpool_pallas(x, ksize=ksize, interpret=interp, **blocks)
    return _ref.avgpool_ref(x, ksize=ksize)


def srad_step(
    img, *, lam=0.5, q0sqr=0.05, fused: bool = True, mode: Mode = "auto"
):
    use, interp, _ = _resolve("srad_step", mode, {})
    if use:
        fn = srad_step_fused if fused else srad_step_split
        return fn(img, lam=lam, q0sqr=q0sqr, interpret=interp)
    return _ref.srad_step_ref(img, lam=lam, q0sqr=q0sqr)


def prefix_scan(x, *, mode: Mode = "auto", **blocks):
    use, interp, blocks = _resolve("prefix_scan", mode, blocks)
    if use:
        return prefix_scan_pallas(x, interpret=interp, **blocks)
    return _ref.prefix_scan_ref(x)


def sort_kv(keys, values, *, mode: Mode = "auto"):
    use, interp, _ = _resolve("sort_kv", mode, {})
    if use:
        (n,) = keys.shape
        n_pow2 = 1 << (n - 1).bit_length()
        if n_pow2 != n:
            pad = n_pow2 - n
            maxval = (
                jnp.iinfo(keys.dtype).max
                if jnp.issubdtype(keys.dtype, jnp.integer)
                else jnp.inf
            )
            keys = jnp.pad(keys, (0, pad), constant_values=maxval)
            values = jnp.pad(values, (0, pad))
        ko, vo = bitonic_sort_pallas(keys, values, interpret=interp)
        return ko[:n], vo[:n]
    return _ref.sort_kv_ref(keys, values)
