"""Flash (online-softmax) attention kernel with GQA + causal + sliding window.

TPU adaptation notes (DESIGN.md §2):

- GQA is expressed through the **BlockSpec index map** — the kv block for
  query head ``h`` is head ``h // group``; kv heads are never materialized
  per-query-head in HBM (the wrapper-level ``jnp.repeat`` of the oracle is
  exactly what this avoids).
- The online-softmax running (m, l, acc) state lives in VMEM registers inside
  a ``fori_loop`` over key blocks; the loop *trip count is dynamic* per query
  block: causal masking bounds the top, sliding-window masking bounds the
  bottom, so SWA decode does O(window) work per token — this is what makes
  ``long_500k`` sub-quadratic for mixtral-style archs.
- Queries occupy the last ``t_valid`` positions of the ``s_valid``-long key
  timeline (offset = s_valid - t_valid), covering prefill and cached decode.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["flash_attention_pallas", "tune_space"]


def tune_space() -> tuple[dict, ...]:
    """Autotune candidates (first entry = the kernel's defaults)."""
    return (
        {"block_q": 128, "block_k": 128},
        {"block_q": 256, "block_k": 128},
        {"block_q": 128, "block_k": 256},
    )

_NEG_INF = -1e30


def _flash_kernel(
    q_ref,  # (1, 1, bq, D)
    k_ref,  # (1, 1, Sp, D)
    v_ref,  # (1, 1, Sp, D)
    o_ref,  # (1, 1, bq, D)
    *,
    block_k: int,
    s_valid: int,
    t_valid: int,
    causal: bool,
    window: int | None,
    scale: float,
    num_k_blocks: int,
):
    bq = q_ref.shape[2]
    d = q_ref.shape[3]
    qi = pl.program_id(2)
    offset = s_valid - t_valid  # absolute position of query row 0
    q_pos = offset + qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, 1), 0)

    q = q_ref[0, 0].astype(jnp.float32) * scale  # (bq, D)

    # Dynamic trip bounds: causal upper bound, sliding-window lower bound.
    if causal:
        last_q = offset + qi * bq + bq - 1
        hi = jnp.minimum((last_q // block_k) + 1, num_k_blocks)
    else:
        hi = num_k_blocks
    if window is not None:
        first_q = offset + qi * bq
        lo = jnp.maximum((first_q - window + 1) // block_k, 0)
    else:
        lo = 0

    def body(j, carry):
        m, l, acc = carry
        k = k_ref[0, 0, pl.dslice(j * block_k, block_k), :].astype(jnp.float32)
        v = v_ref[0, 0, pl.dslice(j * block_k, block_k), :].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (bq, bk)
        k_pos = j * block_k + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)
        mask = k_pos < s_valid
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, _NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_new = acc * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        return m_new, l_new, acc_new

    init = (
        jnp.full((bq, 1), _NEG_INF, jnp.float32),
        jnp.zeros((bq, 1), jnp.float32),
        jnp.zeros((bq, d), jnp.float32),
    )
    m, l, acc = jax.lax.fori_loop(lo, hi, body, init)
    out = acc / jnp.maximum(l, 1e-30)
    o_ref[0, 0] = out.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "block_q", "block_k", "interpret"),
)
def flash_attention_pallas(
    q: jax.Array,  # (B, Hq, T, D)
    k: jax.Array,  # (B, Hkv, S, D)
    v: jax.Array,  # (B, Hkv, S, D)
    *,
    causal: bool = False,
    window: int | None = None,
    scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, Hq, T, D = q.shape
    _, Hkv, S, _ = k.shape
    assert Hq % Hkv == 0, (Hq, Hkv)
    group = Hq // Hkv
    if scale is None:
        scale = float(D) ** -0.5

    bq = min(block_q, T)
    bk = min(block_k, S)
    pt, ps = (-T) % bq, (-S) % bk
    if pt:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pt), (0, 0)))
    if ps:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, ps), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, ps), (0, 0)))
    Tp, Sp = T + pt, S + ps

    kernel = functools.partial(
        _flash_kernel,
        block_k=bk,
        s_valid=S,
        t_valid=T,
        causal=causal,
        window=window,
        scale=scale,
        num_k_blocks=Sp // bk,
    )
    out = pl.pallas_call(
        kernel,
        grid=(B, Hq, Tp // bq),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, Sp, D), lambda b, h, i, g=group: (b, h // g, 0, 0)),
            pl.BlockSpec((1, 1, Sp, D), lambda b, h, i, g=group: (b, h // g, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, i: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Tp, D), q.dtype),
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :T]
