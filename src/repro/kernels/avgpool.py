"""Average-pooling kernel (DNN Pooling benchmark, non-overlapping window).

The paper benchmarks cuDNN's average pool; its common configuration (and the
one the paper describes) is stride == kernel size. On TPU that case is a pure
reshape-reduce in VMEM — no halo exchange — so one kernel invocation handles
a (channels-block × full spatial extent) tile. Overlapping windows fall back
to ``lax.reduce_window`` in ops.py (documented).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["avgpool_pallas", "tune_space"]


def tune_space() -> tuple[dict, ...]:
    """Autotune candidates (first entry = the kernel's defaults)."""
    return ({"block_c": 8}, {"block_c": 16}, {"block_c": 32})


def _avgpool_kernel(x_ref, o_ref, *, ksize: int):
    _, bc, h, w = x_ref.shape
    x = x_ref[0].astype(jnp.float32)  # (bc, H, W)
    pooled = x.reshape(bc, h // ksize, ksize, w // ksize, ksize).mean(axis=(2, 4))
    o_ref[0] = pooled.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("ksize", "block_c", "interpret"))
def avgpool_pallas(
    x: jax.Array,  # (N, C, H, W)
    *,
    ksize: int = 2,
    block_c: int = 8,
    interpret: bool = False,
) -> jax.Array:
    N, C, H, W = x.shape
    assert H % ksize == 0 and W % ksize == 0, (H, W, ksize)
    bc = min(block_c, C)
    pc = (-C) % bc
    if pc:
        x = jnp.pad(x, ((0, 0), (0, pc), (0, 0), (0, 0)))
    Cp = x.shape[1]
    out = pl.pallas_call(
        functools.partial(_avgpool_kernel, ksize=ksize),
        grid=(N, Cp // bc),
        in_specs=[pl.BlockSpec((1, bc, H, W), lambda n, c: (n, c, 0, 0))],
        out_specs=pl.BlockSpec(
            (1, bc, H // ksize, W // ksize), lambda n, c: (n, c, 0, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((N, Cp, H // ksize, W // ksize), x.dtype),
        interpret=interpret,
    )(x)
    return out[:, :C]
