"""Host→device prefetch pipeline — the training-loop Unified-Memory analogue.

A background thread materializes batch ``step+depth`` while the device runs
step ``step``; ``jax.device_put`` is asynchronous, so transfer overlaps
compute exactly like ``cudaMemPrefetchAsync`` overlaps kernels (§V-B). With
a mesh, batches are placed sharded (batch axis over the data axes) so no
device ever holds the global batch.
"""

from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator

import jax

__all__ = ["Prefetch"]


class Prefetch:
    def __init__(
        self,
        batch_at: Callable[[int], dict],
        *,
        start_step: int = 0,
        depth: int = 2,
        sharding=None,
    ):
        self._batch_at = batch_at
        self._sharding = sharding
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self) -> None:
        step = self._step
        while not self._stop.is_set():
            batch = self._batch_at(step)
            if self._sharding is not None:
                batch = jax.device_put(batch, self._sharding)
            else:
                batch = jax.device_put(batch)
            # Block until the consumer drains — backpressure caps host memory.
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator[tuple[int, dict]]:
        while True:
            yield self._q.get()

    def close(self) -> None:
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
