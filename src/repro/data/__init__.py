# Data substrate: deterministic stateless synthetic token streams (exactly
# resumable from a step index — the checkpoint stores only the cursor) and a
# double-buffered host→device prefetch pipeline (the Unified-Memory
# prefetch analogue at the training-loop level).

from repro.data.synthetic import SyntheticLM, SyntheticEmbeds  # noqa: F401
from repro.data.pipeline import Prefetch  # noqa: F401
