"""Deterministic synthetic data: stateless, step-indexed, resumable.

Batches are a pure function of (seed, step), so checkpoint/restore needs
only the integer cursor and elastic re-meshing re-partitions the same global
batch — no data-loader state machine to snapshot. Token streams are
low-entropy Markov-ish mixtures (next-token structure exists, so training
loss visibly decreases in the examples — a pure-uniform stream would pin the
loss at log V).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["SyntheticLM", "SyntheticEmbeds"]


@dataclasses.dataclass(frozen=True)
class SyntheticLM:
    """Next-token-prediction batches: {"tokens", "labels"}."""

    vocab: int
    batch: int
    seq: int
    seed: int = 0

    def batch_at(self, step: int) -> dict:
        key = jax.random.fold_in(jax.random.key(self.seed), step)
        k1, k2 = jax.random.split(key)
        # Structured stream: x_{t+1} = (a·x_t + b + noise) mod V on a small
        # effective alphabet, so the mapping is learnable.
        v_eff = min(self.vocab, 257)
        a = 31
        x0 = jax.random.randint(k1, (self.batch,), 0, v_eff)
        noise = (jax.random.uniform(k2, (self.batch, self.seq + 1)) < 0.1).astype(
            jnp.int32
        )

        def stepf(x, n):
            nxt = (a * x + 7 + n) % v_eff
            return nxt, nxt

        _, xs = jax.lax.scan(stepf, x0, jnp.swapaxes(noise, 0, 1))
        toks = jnp.swapaxes(xs, 0, 1)  # (B, T+1)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


@dataclasses.dataclass(frozen=True)
class SyntheticEmbeds:
    """Frontend-stub batches for [audio]/[vlm] archs: {"embeds", "labels"}
    (+ 3-component "positions" when mrope=True)."""

    d_model: int
    vocab: int
    batch: int
    seq: int
    mrope: bool = False
    seed: int = 0

    def batch_at(self, step: int) -> dict:
        key = jax.random.fold_in(jax.random.key(self.seed), step)
        k1, k2 = jax.random.split(key)
        embeds = jax.random.normal(k1, (self.batch, self.seq, self.d_model), jnp.float32)
        labels = jax.random.randint(k2, (self.batch, self.seq), 0, self.vocab)
        out = {"embeds": embeds, "labels": labels}
        if self.mrope:
            pos = jnp.broadcast_to(
                jnp.arange(self.seq)[None, :, None], (self.batch, self.seq, 3)
            )
            out["positions"] = pos
        return out
