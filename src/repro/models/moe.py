"""Mixture-of-Experts FFN with GShard-style grouped one-hot dispatch.

TPU/GSPMD adaptation (DESIGN.md §5): MegaBlocks-style sparse grouped GEMM is
a GPU-kernel mechanism; the GSPMD-native expression is the GShard einsum
dispatch — tokens are split into groups of ``moe_group_size``, each group
routes its tokens into per-expert capacity buffers with a one-hot dispatch
tensor, expert FFNs run as batched einsums over the expert axis (shardable
as EP), and a combine einsum scatters results back. Dispatch overhead is
O(group_size) per token (≈5% of active FLOPs at group 1024 for
mixtral-scale FFNs — quantified in EXPERIMENTS.md §Roofline).

Top-k routing with softmax-renormalized weights over the selected experts
(Mixtral's scheme); tokens over capacity are dropped (standard GShard
behaviour — tests use full capacity so the oracle comparison is exact).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.layers import dtype_of, init_dense

__all__ = ["init_moe", "apply_moe", "moe_oracle"]


def init_moe(key, cfg: ArchConfig) -> dict:
    """Expert weights; with ``moe_split`` > 1 they are stored pre-sliced as
    (E·split, d, ff/split) virtual experts (see split_moe_params)."""
    dt = dtype_of(cfg)
    kr, kg, ku, kd = jax.random.split(key, 4)
    E, d, ff = cfg.n_experts, cfg.d_model, cfg.d_ff
    sp = cfg.moe_split
    assert ff % sp == 0, (ff, sp)
    Ev, ffv = E * sp, ff // sp

    def stack(k, din, dout, scale=None):
        return jnp.stack(
            [init_dense(kk, din, dout, dt, scale) for kk in jax.random.split(k, Ev)]
        )

    return {
        "router": init_dense(kr, d, E, jnp.float32),
        "w_gate": stack(kg, d, ffv),
        "w_up": stack(ku, d, ffv),
        "w_down": stack(kd, ffv, d, scale=0.02 / (2 * cfg.n_layers) ** 0.5),
    }


def split_moe_params(p: dict, split: int) -> dict:
    """Re-slice unsplit expert params (E, d, ff) → (E·split, d, ff/split).

    Virtual experts [e·split .. e·split+split) are the ff-slices of real
    expert e; SwiGLU is elementwise over ff and w_down sums over ff, so the
    slice outputs add exactly to the unsplit output (tested)."""
    E, d, ff = p["w_gate"].shape
    ffv = ff // split

    def col(w):  # (E, d, ff) -> (E*split, d, ffv)
        return (
            w.reshape(E, d, split, ffv).transpose(0, 2, 1, 3).reshape(E * split, d, ffv)
        )

    def row(w):  # (E, ff, d) -> (E*split, ffv, d)
        return w.reshape(E, split, ffv, d).reshape(E * split, ffv, d)

    return {
        "router": p["router"],
        "w_gate": col(p["w_gate"]),
        "w_up": col(p["w_up"]),
        "w_down": row(p["w_down"]),
    }


def _route(logits: jax.Array, top_k: int):
    """logits (N, E) -> combine weights (N, E) with top-k renormalized."""
    weights = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top_vals, _ = jax.lax.top_k(weights, top_k)
    thresh = top_vals[..., -1:]
    selected = weights >= thresh
    w = jnp.where(selected, weights, 0.0)
    return w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)


def apply_moe(p: dict, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    """x (B, T, d) -> (B, T, d)."""
    B, T, d = x.shape
    N = B * T
    E, k = cfg.n_experts, cfg.top_k
    g = min(cfg.moe_group_size, N)
    assert N % g == 0, (N, g)
    G = N // g
    cap = max(1, int(round(k * g * cfg.capacity_factor / E)))

    xg = x.reshape(G, g, d)
    logits = xg.astype(jnp.float32) @ p["router"]  # (G, g, E)
    combine_w = _route(logits.reshape(N, E), k).reshape(G, g, E)
    if cfg.moe_split > 1:
        # Virtual ff-slice experts: every selected token goes to all slices
        # of its expert with the same combine weight (slice outputs add).
        combine_w = jnp.repeat(combine_w, cfg.moe_split, axis=-1)

    # Position of each token inside its expert's capacity buffer.
    sel = combine_w > 0
    pos = jnp.cumsum(sel.astype(jnp.int32), axis=1) - 1  # (G, g, E[v])
    keep = sel & (pos < cap)
    # dispatch (G, g, E, cap): one-hot over the capacity slot.
    disp = keep[..., None] & (
        pos[..., None] == jnp.arange(cap)[None, None, None, :]
    )
    disp_f = disp.astype(x.dtype)
    comb_f = (combine_w[..., None] * disp).astype(x.dtype)

    xin = jnp.einsum("gsec,gsd->gecd", disp_f, xg)  # (G, E, cap, d)
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xin, p["w_gate"]))
    h = h * jnp.einsum("gecd,edf->gecf", xin, p["w_up"])
    out = jnp.einsum("gecf,efd->gecd", h, p["w_down"])
    y = jnp.einsum("gsec,gecd->gsd", comb_f, out)
    return y.reshape(B, T, d)


def moe_oracle(p: dict, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    """Per-token dense oracle (no capacity drops) for tests."""
    B, T, d = x.shape
    xf = x.reshape(-1, d)
    w = _route(xf.astype(jnp.float32) @ p["router"], cfg.top_k)  # (N, E)
    outs = []
    for e in range(cfg.n_experts):
        h = jax.nn.silu(xf @ p["w_gate"][e]) * (xf @ p["w_up"][e])
        outs.append(h @ p["w_down"][e])
    y = sum(w[:, e : e + 1].astype(x.dtype) * outs[e] for e in range(cfg.n_experts))
    return y.reshape(B, T, d)
