"""Core transformer layers: RMSNorm, RoPE/M-RoPE, GQA attention, SwiGLU.

Pure-functional style: every layer is an ``init_*(key, cfg) -> params-dict``
plus an ``apply`` function. Parameters are plain nested dicts of arrays so
they pytree-map cleanly onto sharding rules (runtime/sharding.py) and
checkpoints.

Numerics policy: parameters and activations in ``cfg.dtype`` (bf16 for the
production configs), normalization statistics / softmax / attention
accumulation in fp32.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig

__all__ = [
    "dtype_of",
    "rms_norm",
    "init_dense",
    "init_attention",
    "apply_attention",
    "init_mlp",
    "apply_mlp",
    "rope_angles",
    "apply_rope",
]


def dtype_of(cfg: ArchConfig):
    return jnp.dtype(cfg.dtype)


def init_dense(key, d_in: int, d_out: int, dtype, scale: float | None = None):
    scale = 0.02 if scale is None else scale
    return (scale * jax.random.truncated_normal(key, -2, 2, (d_in, d_out))).astype(dtype)


def rms_norm(x: jax.Array, gamma: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    scale = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (xf * scale).astype(x.dtype) * gamma


# ---------------------------------------------------------------------------
# Rotary embeddings (RoPE + Qwen2-VL M-RoPE).
# ---------------------------------------------------------------------------


def rope_angles(cfg: ArchConfig, positions: jax.Array) -> tuple[jax.Array, jax.Array]:
    """cos/sin tables for (possibly multimodal) positions.

    ``positions``: (B, T) int for plain RoPE, or (B, T, 3) for M-RoPE where
    the trailing axis is (temporal, height, width) position ids. M-RoPE
    assigns each rotary frequency pair to one of the three sections
    (Qwen2-VL §3.1); for text, all three ids are equal, making M-RoPE
    degenerate to RoPE — checked in tests.
    Returns cos/sin of shape (B, T, head_dim/2), fp32.
    """
    half = cfg.head_dim // 2
    freqs = cfg.rope_theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    if positions.ndim == 2:
        pos = positions[..., None].astype(jnp.float32)  # (B, T, 1)
        angles = pos * freqs  # (B, T, half)
    else:
        # Normalize the (t, h, w) section lengths to the actual half size
        # (static python — sections are config constants).
        s0, s1, s2 = cfg.mrope_sections
        tot = s0 + s1 + s2
        n0, n1 = (s0 * half) // tot, (s1 * half) // tot
        sec_id = jnp.concatenate(
            [
                jnp.full((n0,), 0),
                jnp.full((n1,), 1),
                jnp.full((half - n0 - n1,), 2),
            ]
        )  # (half,) -> which position component drives each frequency
        pos = jnp.take_along_axis(
            positions.astype(jnp.float32),
            jnp.broadcast_to(sec_id, positions.shape[:2] + (half,)).astype(jnp.int32),
            axis=-1,
        )  # (B, T, half)
        angles = pos * freqs
    return jnp.cos(angles), jnp.sin(angles)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B, T, n_heads, head_dim); llama-style half rotation."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[:, :, None, :].astype(jnp.float32)
    s = sin[:, :, None, :].astype(jnp.float32)
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([x1f * c - x2f * s, x2f * c + x1f * s], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# GQA attention.
# ---------------------------------------------------------------------------


def init_attention(key, cfg: ArchConfig) -> dict:
    dt = dtype_of(cfg)
    d, hd = cfg.d_model, cfg.head_dim
    kq, kk, kv, ko, kb = jax.random.split(key, 5)
    p = {
        "wq": init_dense(kq, d, cfg.n_heads * hd, dt),
        "wk": init_dense(kk, d, cfg.n_kv_heads * hd, dt),
        "wv": init_dense(kv, d, cfg.n_kv_heads * hd, dt),
        "wo": init_dense(ko, cfg.n_heads * hd, d, dt, scale=0.02 / (2 * cfg.n_layers) ** 0.5),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * hd,), dt)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * hd,), dt)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * hd,), dt)
    return p


def project_qkv(p: dict, cfg: ArchConfig, x: jax.Array, positions: jax.Array):
    """x (B, T, d) -> q (B, T, H, hd), k/v (B, T, KV, hd), RoPE applied."""
    B, T, _ = x.shape
    hd = cfg.head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, T, cfg.n_heads, hd)
    k = k.reshape(B, T, cfg.n_kv_heads, hd)
    v = v.reshape(B, T, cfg.n_kv_heads, hd)
    if cfg.rope != "none":
        cos, sin = rope_angles(cfg, positions)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    return q, k, v


def sdpa(
    q: jax.Array,  # (B, T, H, hd)
    k: jax.Array,  # (B, S, KV, hd)
    v: jax.Array,  # (B, S, KV, hd)
    *,
    causal: bool,
    window: int | None,
    kv_len: jax.Array | None = None,
    chunk: int = 0,
    score_dtype=jnp.float32,
    unroll_inner: bool = False,
) -> jax.Array:
    """Scaled dot-product GQA attention. Queries sit at the *end* of the key
    timeline; ``kv_len`` masks a partially-filled cache.

    Perf knobs (EXPERIMENTS.md §Perf):
    - ``chunk > 0``: online-softmax over KV blocks via ``lax.scan`` — the
      flash-attention recurrence in pure XLA. Never materializes the (T, S)
      score matrix; the per-step working set is (T, chunk). This is the
      memory-term optimization that brings 32k prefill under the HBM budget.
    - ``score_dtype``: accumulation dtype of the QKᵀ matmul (bf16 halves
      score-buffer traffic on the dense path at ~1e-2 logit error).
    """
    B, T, H, hd = q.shape
    S, KV = k.shape[1], k.shape[2]
    group = H // KV
    scale = hd**-0.5
    qf = (q.astype(jnp.float32) * scale).astype(score_dtype).reshape(B, T, KV, group, hd)
    q_pos = jnp.arange(T)[:, None] + (S if kv_len is None else kv_len) - T

    def mask_for(k_pos):
        m = jnp.ones((T, k_pos.shape[-1]), bool)
        if causal:
            m &= k_pos <= q_pos
        if window is not None:
            m &= k_pos > q_pos - window
        if kv_len is not None:
            m &= k_pos < kv_len
        return m

    if chunk and S % chunk == 0 and S > chunk:
        n_chunks = S // chunk
        kc = k.astype(score_dtype).reshape(B, n_chunks, chunk, KV, hd)
        vc = v.astype(jnp.float32).reshape(B, n_chunks, chunk, KV, hd)

        def body(carry, inp):
            m_run, l_run, acc = carry
            kj, vj, j = inp
            s = jnp.einsum("btkgh,bskh->bkgts", qf, kj).astype(jnp.float32)
            k_pos = j * chunk + jnp.arange(chunk)[None, :]
            m = mask_for(k_pos)
            s = jnp.where(m[None, None, None], s, -1e30)
            m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m_run - m_new)
            l_new = l_run * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum("bkgts,bskh->bkgth", p, vj)
            return (m_new, l_new, acc), None

        init = (
            jnp.full((B, KV, group, T), -1e30, jnp.float32),
            jnp.zeros((B, KV, group, T), jnp.float32),
            jnp.zeros((B, KV, group, T, hd), jnp.float32),
        )
        ks = jnp.swapaxes(kc, 0, 1)  # (n_chunks, B, chunk, KV, hd)
        vs = jnp.swapaxes(vc, 0, 1)
        (m_run, l_run, acc), _ = jax.lax.scan(
            body, init, (ks, vs, jnp.arange(n_chunks)), unroll=unroll_inner
        )
        out = acc / jnp.maximum(l_run, 1e-30)[..., None]
        out = jnp.moveaxis(out, -2, 1)  # (B, T, KV, group, hd)
        return out.reshape(B, T, H, hd).astype(q.dtype)

    kf = k.astype(score_dtype)
    s = jnp.einsum("btkgh,bskh->bkgts", qf, kf).astype(jnp.float32)
    k_pos = jnp.arange(S)[None, :]
    s = jnp.where(mask_for(k_pos)[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgts,bskh->btkgh", p, v.astype(jnp.float32))
    return out.reshape(B, T, H, hd).astype(q.dtype)


def apply_attention(
    p: dict,
    cfg: ArchConfig,
    x: jax.Array,
    positions: jax.Array,
    *,
    k_cache: jax.Array | None = None,
    v_cache: jax.Array | None = None,
    kv_len: jax.Array | None = None,
):
    """Full-sequence path (training/prefill): returns (out, (k, v)).

    With ``k_cache/v_cache`` (decode): attends over the cache; returns out.
    """
    B, T, _ = x.shape
    q, k, v = project_qkv(p, cfg, x, positions)
    opts = dict(
        chunk=cfg.attn_chunk,
        score_dtype=jnp.dtype(cfg.score_dtype),
        unroll_inner=cfg.unroll_inner,
    )
    if k_cache is not None:
        out = sdpa(
            q, k_cache, v_cache, causal=cfg.causal, window=cfg.window,
            kv_len=kv_len, **opts,
        )
        new_kv = (k, v)
    else:
        out = sdpa(q, k, v, causal=cfg.causal, window=cfg.window, **opts)
        new_kv = (k, v)
    out = out.reshape(B, T, cfg.n_heads * cfg.head_dim)
    return out @ p["wo"], new_kv


# ---------------------------------------------------------------------------
# SwiGLU MLP.
# ---------------------------------------------------------------------------


def init_mlp(key, cfg: ArchConfig) -> dict:
    dt = dtype_of(cfg)
    kg, ku, kd = jax.random.split(key, 3)
    return {
        "w_gate": init_dense(kg, cfg.d_model, cfg.d_ff, dt),
        "w_up": init_dense(ku, cfg.d_model, cfg.d_ff, dt),
        "w_down": init_dense(
            kd, cfg.d_ff, cfg.d_model, dt, scale=0.02 / (2 * cfg.n_layers) ** 0.5
        ),
    }


def apply_mlp(p: dict, x: jax.Array) -> jax.Array:
    return (jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]
