"""Architecture configuration for the model zoo.

One ``ArchConfig`` describes any of the ten assigned architectures (dense /
MoE / SSM / hybrid / audio-encoder / VLM) plus the reduced smoke variants.
The layer stack is expressed as a repeating *period* of block kinds
(``block_period``), which is also the scan unit (DESIGN.md §5): dense models
have period ``("attn", "mlp")``-fused blocks; jamba has a period of 8 mixed
mamba/attention layers with MoE on alternating layers; xLSTM alternates
mLSTM/sLSTM blocks.
"""

from __future__ import annotations

import dataclasses
from typing import Literal

Family = Literal["dense", "moe", "ssm", "hybrid", "audio", "vlm"]
BlockKind = Literal[
    "attn_mlp",  # attention + dense SwiGLU MLP
    "attn_moe",  # attention + MoE FFN
    "mamba_mlp",  # Mamba mixer + dense MLP
    "mamba_moe",  # Mamba mixer + MoE FFN
    "mamba",  # Mamba mixer only (no FFN)
    "mlstm",  # xLSTM matrix-memory block (self-contained)
    "slstm",  # xLSTM scalar-memory block (self-contained)
]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 128
    # Attention flavour
    qkv_bias: bool = False
    window: int | None = None  # sliding-window attention width
    causal: bool = True  # False => bidirectional encoder
    rope: Literal["rope", "mrope", "none"] = "rope"
    rope_theta: float = 1e4
    mrope_sections: tuple[int, int, int] = (16, 24, 24)  # t/h/w pairs (half-dim)
    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_every: int = 1  # MoE FFN on layers where (layer % moe_every == moe_offset)
    moe_offset: int = 0
    capacity_factor: float = 1.25
    moe_group_size: int = 1024
    # Expert slicing (§Perf mixtral iteration): split each expert's SwiGLU
    # into `moe_split` ff-slices = E·moe_split virtual experts. SwiGLU sums
    # over d_ff, so slices add exactly; 8 experts × split 2 = 16 virtual
    # experts divide a 16-way model axis → clean EP instead of ff-row-
    # parallel partial-sum all-reduces.
    moe_split: int = 1
    # SSM (Mamba)
    ssm_state: int = 16
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_dt_rank: int = 0  # 0 => ceil(d_model / 16)
    # Hybrid layout (jamba): one attention layer per `attn_period` layers
    attn_period: int = 0  # 0 => pure-attention stack
    attn_offset: int = 0
    # xLSTM
    xlstm_heads: int = 4
    # Chunkwise-parallel mLSTM (§Perf xlstm iteration): process the sequence
    # in chunks of this length — matrix-memory state traffic drops by the
    # chunk length; intra-chunk work becomes an attention-like (L×L) block.
    # 0 = sequential scan.
    xlstm_chunk: int = 0
    # Performance knobs (beyond-paper optimizations; EXPERIMENTS.md §Perf)
    attn_chunk: int = 0  # >0: chunked online-softmax attention (KV blocks)
    score_dtype: str = "float32"  # attention score matmul accumulation dtype
    unroll_inner: bool = False  # unroll inner chunk scans (cost-analysis mode)
    # I/O
    input_mode: Literal["tokens", "embeds"] = "tokens"
    encoder_only: bool = False
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    # Numerics
    dtype: str = "bfloat16"
    # Notes carried into DESIGN/EXPERIMENTS tables
    notes: str = ""

    @property
    def dt_rank(self) -> int:
        return self.ssm_dt_rank or -(-self.d_model // 16)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    # ---- layer stack -----------------------------------------------------
    def block_kinds(self) -> tuple[BlockKind, ...]:
        """Kind of every layer, length n_layers."""
        kinds: list[BlockKind] = []
        for i in range(self.n_layers):
            moe = self.n_experts > 0 and (i % self.moe_every == self.moe_offset)
            if self.family == "ssm":
                kinds.append("mlstm" if i % 2 == 0 else "slstm")
            elif self.attn_period > 0:  # hybrid
                if i % self.attn_period == self.attn_offset:
                    kinds.append("attn_moe" if moe else "attn_mlp")
                else:
                    kinds.append("mamba_moe" if moe else "mamba_mlp")
            else:
                kinds.append("attn_moe" if moe else "attn_mlp")
        return tuple(kinds)

    def block_period(self) -> tuple[BlockKind, ...]:
        """Smallest repeating unit of the stack (the scan body)."""
        kinds = self.block_kinds()
        for p in range(1, len(kinds) + 1):
            if len(kinds) % p == 0 and kinds == kinds[:p] * (len(kinds) // p):
                return kinds[:p]
        return kinds

    @property
    def n_periods(self) -> int:
        return self.n_layers // len(self.block_period())

    # ---- parameter counting (for MODEL_FLOPS = 6·N·D) ---------------------
    def param_counts(self) -> dict[str, float]:
        """Analytic parameter counts: total and active-per-token."""
        d, ff, hd = self.d_model, self.d_ff, self.head_dim
        attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d
        if self.qkv_bias:
            attn += (self.n_heads + 2 * self.n_kv_heads) * hd
        mlp = 3 * d * ff
        moe_total = self.n_experts * mlp + d * self.n_experts
        moe_active = self.top_k * mlp + d * self.n_experts
        di, ds, dtr = self.d_inner, self.ssm_state, self.dt_rank
        mamba = (
            d * 2 * di  # in_proj
            + di * self.ssm_conv + di  # conv
            + di * (dtr + 2 * ds)  # x_proj
            + dtr * di + di  # dt_proj
            + di * ds + di  # A_log, D
            + di * d  # out_proj
        )
        dh = d // self.xlstm_heads
        mlstm = d * 2 * d + 2 * d * self.ssm_conv + 3 * (2 * d) * (2 * d) // 1 + 2 * d * d  # approx
        slstm = d * 4 * d + self.xlstm_heads * dh * dh * 4 + d * (4 * d // 3) * 2
        total = 0.0
        active = 0.0
        for kind in self.block_kinds():
            if kind.startswith("attn"):
                total += attn
                active += attn
            if kind.startswith("mamba"):
                total += mamba
                active += mamba
            if kind.endswith("_moe"):
                total += moe_total
                active += moe_active
            elif kind.endswith("_mlp"):
                total += mlp
                active += mlp
            if kind == "mlstm":
                total += mlstm
                active += mlstm
            if kind == "slstm":
                total += slstm
                active += slstm
            total += 2 * d  # norms
            active += 2 * d
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        total += emb + d
        active += emb + d
        return {"total": total, "active": active}

    def validate(self) -> None:
        assert self.n_heads * self.head_dim > 0
        assert self.n_heads % self.n_kv_heads == 0, (self.n_heads, self.n_kv_heads)
        if self.n_experts:
            assert 0 < self.top_k <= self.n_experts
        if self.family == "ssm":
            assert self.n_layers % 2 == 0, "xLSTM alternates mLSTM/sLSTM pairs"
        assert self.n_layers % len(self.block_period()) == 0
