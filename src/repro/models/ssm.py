"""Recurrent mixers: Mamba (S6) and the xLSTM pair (mLSTM / sLSTM).

All three share one execution pattern chosen for TPU memory sanity
(DESIGN.md §5): projections run in parallel over the sequence; only the
recurrence itself is a ``lax.scan`` over time whose body *recomputes* the
per-step outer products from O(d)-sized inputs — the (T, B, d_inner, d_state)
transition tensors are never materialized, so scan-saved residuals stay
O(T·B·d) and the backward pass reconstructs transitions locally (the same
trade selective-scan kernels make on GPU).

Each mixer exposes:
- ``init_*(key, cfg)``
- ``apply_*(p, cfg, x)``            — full sequence, returns (y, final_state)
- ``step_*(p, cfg, x_t, state)``    — one decode step, returns (y_t, state)
- ``init_state_*(cfg, batch)``      — zero state for decode
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.layers import dtype_of, init_dense, rms_norm

__all__ = [
    "init_mamba", "apply_mamba", "step_mamba", "init_state_mamba",
    "init_mlstm", "apply_mlstm", "step_mlstm", "init_state_mlstm",
    "init_slstm", "apply_slstm", "step_slstm", "init_state_slstm",
]


# ---------------------------------------------------------------------------
# Mamba (S6 selective SSM).
# ---------------------------------------------------------------------------


def init_mamba(key, cfg: ArchConfig) -> dict:
    dt = dtype_of(cfg)
    d, di, ds, dtr, ck = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dt_rank, cfg.ssm_conv
    keys = jax.random.split(key, 6)
    # S4/Mamba A initialization: A_i,s = -(s+1).
    a = jnp.tile(jnp.arange(1, ds + 1, dtype=jnp.float32)[None, :], (di, 1))
    return {
        "in_proj": init_dense(keys[0], d, 2 * di, dt),
        "conv_w": (0.1 * jax.random.normal(keys[1], (ck, di))).astype(dt),
        "conv_b": jnp.zeros((di,), dt),
        "x_proj": init_dense(keys[2], di, dtr + 2 * ds, dt),
        "dt_w": init_dense(keys[3], dtr, di, dt),
        "dt_b": jnp.log(jnp.expm1(0.01)) * jnp.ones((di,), jnp.float32),  # dt≈0.01
        "A_log": jnp.log(a),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": init_dense(
            keys[4], di, d, dt, scale=0.02 / (2 * cfg.n_layers) ** 0.5
        ),
    }


def _mamba_conv_full(p, x):  # x (B, T, di) -> causal depthwise conv
    ck = p["conv_w"].shape[0]
    xp = jnp.pad(x, ((0, 0), (ck - 1, 0), (0, 0)))
    out = sum(xp[:, i : i + x.shape[1]] * p["conv_w"][i] for i in range(ck))
    return out + p["conv_b"]


def _mamba_scan_inputs(p, cfg, xc):
    """xc (B, T, di) conv output -> (delta, Bt, Ct) for the recurrence."""
    proj = xc @ p["x_proj"]  # (B, T, dtr + 2 ds)
    dtr, ds = cfg.dt_rank, cfg.ssm_state
    d_raw, Bt, Ct = jnp.split(proj, [dtr, dtr + ds], axis=-1)
    delta = jax.nn.softplus(
        (d_raw @ p["dt_w"]).astype(jnp.float32) + p["dt_b"]
    )  # (B, T, di)
    return delta, Bt.astype(jnp.float32), Ct.astype(jnp.float32)


def _mamba_step(p, h, inputs):
    """One recurrence step. h (B, di, ds) fp32."""
    xc_t, delta_t, B_t, C_t = inputs  # (B,di) (B,di) (B,ds) (B,ds)
    A = -jnp.exp(p["A_log"])  # (di, ds)
    a = jnp.exp(delta_t[:, :, None] * A[None])  # (B, di, ds)
    b = delta_t[:, :, None] * B_t[:, None, :] * xc_t.astype(jnp.float32)[:, :, None]
    h = a * h + b
    y = jnp.einsum("bis,bs->bi", h, C_t) + p["D"] * xc_t.astype(jnp.float32)
    return h, y


def init_state_mamba(cfg: ArchConfig, batch: int) -> dict:
    di, ds, ck = cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
    return {
        "h": jnp.zeros((batch, di, ds), jnp.float32),
        "conv": jnp.zeros((batch, ck - 1, di), dtype_of(cfg)),
    }


def apply_mamba(p: dict, cfg: ArchConfig, x: jax.Array):
    """x (B, T, d) -> (y (B, T, d), final_state)."""
    B, T, _ = x.shape
    xz = x @ p["in_proj"]
    x1, z = jnp.split(xz, 2, axis=-1)  # (B, T, di)
    xc = jax.nn.silu(_mamba_conv_full(p, x1))
    delta, Bt, Ct = _mamba_scan_inputs(p, cfg, xc)

    def body(h, inp):
        h, y = _mamba_step(p, h, inp)
        return h, y

    h0 = jnp.zeros((B, cfg.d_inner, cfg.ssm_state), jnp.float32)
    scan_in = (
        jnp.swapaxes(xc, 0, 1),
        jnp.swapaxes(delta, 0, 1),
        jnp.swapaxes(Bt, 0, 1),
        jnp.swapaxes(Ct, 0, 1),
    )
    h_final, ys = jax.lax.scan(body, h0, scan_in)
    y = jnp.swapaxes(ys, 0, 1).astype(x.dtype)  # (B, T, di)
    out = (y * jax.nn.silu(z)) @ p["out_proj"]
    ck = cfg.ssm_conv
    tail = x1[:, -(ck - 1) :, :] if T >= ck - 1 else jnp.pad(
        x1, ((0, 0), (ck - 1 - T, 0), (0, 0))
    )
    return out, {"h": h_final, "conv": tail}


def step_mamba(p: dict, cfg: ArchConfig, x_t: jax.Array, state: dict):
    """x_t (B, d), state from init_state/prefill -> (y_t (B, d), state)."""
    xz = x_t @ p["in_proj"]
    x1, z = jnp.split(xz, 2, axis=-1)  # (B, di)
    window = jnp.concatenate([state["conv"], x1[:, None, :]], axis=1)  # (B, ck, di)
    xc = jax.nn.silu(
        jnp.einsum("bki,ki->bi", window.astype(jnp.float32), p["conv_w"].astype(jnp.float32))
        + p["conv_b"].astype(jnp.float32)
    ).astype(x_t.dtype)
    proj = xc @ p["x_proj"]
    dtr, ds = cfg.dt_rank, cfg.ssm_state
    d_raw, B_t, C_t = jnp.split(proj, [dtr, dtr + ds], axis=-1)
    delta = jax.nn.softplus((d_raw @ p["dt_w"]).astype(jnp.float32) + p["dt_b"])
    h, y = _mamba_step(
        p, state["h"], (xc, delta, B_t.astype(jnp.float32), C_t.astype(jnp.float32))
    )
    out = (y.astype(x_t.dtype) * jax.nn.silu(z)) @ p["out_proj"]
    return out, {"h": h, "conv": window[:, 1:]}


# ---------------------------------------------------------------------------
# mLSTM (xLSTM matrix-memory block) — self-contained block with ×2 up-proj.
# ---------------------------------------------------------------------------


def init_mlstm(key, cfg: ArchConfig) -> dict:
    dt = dtype_of(cfg)
    d = cfg.d_model
    du = 2 * d
    H = cfg.xlstm_heads
    keys = jax.random.split(key, 8)
    return {
        "ln": jnp.ones((d,), dt),
        "w_up": init_dense(keys[0], d, 2 * du, dt),
        "conv_w": (0.1 * jax.random.normal(keys[1], (cfg.ssm_conv, du))).astype(dt),
        "conv_b": jnp.zeros((du,), dt),
        "wq": init_dense(keys[2], du, du, dt),
        "wk": init_dense(keys[3], du, du, dt),
        "wv": init_dense(keys[4], du, du, dt),
        "w_gates": init_dense(keys[5], du, 2 * H, jnp.float32),
        "b_gates": jnp.concatenate(
            [jnp.zeros((H,)), jnp.linspace(3.0, 6.0, H)]  # forget bias high
        ),
        "gn": jnp.ones((du,), dt),
        "w_down": init_dense(
            keys[6], du, d, dt, scale=0.02 / (2 * cfg.n_layers) ** 0.5
        ),
    }


def _mlstm_step(C, n, m, q, k, v, i_raw, f_raw):
    """Stabilized exponential-gating matrix-memory update (xLSTM eq. 19-27).

    C (B,H,dk,dv), n (B,H,dk), m (B,H); q/k/v (B,H,dh); i_raw/f_raw (B,H).
    """
    m_new = jnp.maximum(f_raw + m, i_raw)
    i = jnp.exp(i_raw - m_new)
    f = jnp.exp(f_raw + m - m_new)
    C = f[..., None, None] * C + i[..., None, None] * (
        k[..., :, None] * v[..., None, :]
    )
    n = f[..., None] * n + i[..., None] * k
    num = jnp.einsum("bhkv,bhk->bhv", C, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, q)), 1.0)
    return C, n, m_new, num / den[..., None]


def init_state_mlstm(cfg: ArchConfig, batch: int) -> dict:
    du = 2 * cfg.d_model
    H = cfg.xlstm_heads
    dh = du // H
    return {
        "C": jnp.zeros((batch, H, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, H, dh), jnp.float32),
        "m": jnp.zeros((batch, H), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, du), dtype_of(cfg)),
    }


def _mlstm_parallel_inputs(p, cfg, xm):
    """xm (B, T, du) -> per-step q,k,v,i,f (fp32 gates)."""
    H = cfg.xlstm_heads
    du = xm.shape[-1]
    dh = du // H
    xc = jax.nn.silu(_mamba_conv_full({"conv_w": p["conv_w"], "conv_b": p["conv_b"]}, xm))
    B, T, _ = xm.shape
    q = (xc @ p["wq"]).reshape(B, T, H, dh).astype(jnp.float32) * dh**-0.5
    k = (xc @ p["wk"]).reshape(B, T, H, dh).astype(jnp.float32) * dh**-0.5
    v = (xm @ p["wv"]).reshape(B, T, H, dh).astype(jnp.float32)
    gates = xc.astype(jnp.float32) @ p["w_gates"] + p["b_gates"]
    i_raw, f_raw = jnp.split(gates, 2, axis=-1)  # (B, T, H)
    f_raw = jax.nn.log_sigmoid(f_raw)  # f = sigmoid in log space
    return q, k, v, i_raw, f_raw, xc


def _group_norm_heads(h, gamma, H):
    """Per-head group normalization of (B, T, du) or (B, du)."""
    shp = h.shape
    hh = h.reshape(*shp[:-1], H, shp[-1] // H).astype(jnp.float32)
    mu = hh.mean(-1, keepdims=True)
    var = hh.var(-1, keepdims=True)
    out = (hh - mu) * jax.lax.rsqrt(var + 1e-5)
    return out.reshape(shp).astype(gamma.dtype) * gamma


def _mlstm_chunk_body(carry, inp):
    """One chunk of the chunkwise-parallel stabilized mLSTM.

    Derivation (EXPERIMENTS.md §Perf, xlstm iteration 3): with per-step log
    decays f̃ and log inputs ĩ, define within a chunk of length L

        B_j = Σ_{r≤j} f̃_r,      a_k = ĩ_k − B_k,
        M_j = max(m_prev, cummax_{k≤j} a_k)       (the running stabilizer),

    then the sequential recurrence is exactly

        h_j ∝ e^{m_prev−M_j}·C_prev q_j + Σ_{k≤j} e^{a_k−M_j}(k_k·q_j) v_k,
        n_j = e^{m_prev−M_j}·n_prev + Σ_{k≤j} e^{a_k−M_j} k_k,
        C_new = e^{m_prev−M_L} C_prev + Σ_k e^{a_k−M_L} k_k v_kᵀ,
        m_new = B_L + M_L.

    All exponents are ≤ 0 (stable); the state is touched once per chunk, so
    HBM traffic on the (dh × dh) matrix memory drops by L×.
    """
    C, n, m = carry
    q, k, v, i_raw, f_raw = inp  # (B, L, H, dh) / gates (B, L, H)
    B_cum = jnp.cumsum(f_raw, axis=1)  # (B, L, H)
    a = i_raw - B_cum
    M = jnp.maximum(m[:, None], jax.lax.cummax(a, axis=1))  # (B, L, H)
    inter = jnp.exp(m[:, None] - M)  # (B, L, H)

    # inter-chunk contribution from the carried state
    num = inter[..., None] * jnp.einsum("bhkv,blhk->blhv", C, q)
    n_j = inter[..., None] * n[:, None] + 0.0

    # intra-chunk attention-like block (causal within the chunk)
    s = jnp.einsum("blhd,bmhd->bhlm", q, k)  # (B, H, L, L)
    w = jnp.exp(
        jnp.moveaxis(a, -1, 1)[:, :, None, :] - jnp.moveaxis(M, -1, 1)[:, :, :, None]
    )  # w[j, k] = e^{a_k - M_j}
    L = q.shape[1]
    causal = jnp.tril(jnp.ones((L, L), bool))
    w = jnp.where(causal[None, None], w, 0.0)
    num = num + jnp.einsum("bhlm,bmhv->blhv", s * w, v)
    n_j = n_j + jnp.einsum("bhlm,bmhd->blhd", w, k)

    den = jnp.maximum(jnp.abs(jnp.einsum("blhd,blhd->blh", n_j, q)), 1.0)
    h = num / den[..., None]

    # carry update (state touched once per chunk)
    scale_prev = jnp.exp(m - M[:, -1])  # (B, H)
    wL = jnp.exp(a - M[:, -1][:, None])  # (B, L, H)
    C_new = scale_prev[..., None, None] * C + jnp.einsum(
        "blhk,blhv->bhkv", wL[..., None] * k, v
    )
    n_new = scale_prev[..., None] * n + jnp.einsum("blh,blhd->bhd", wL, k)
    m_new = B_cum[:, -1] + M[:, -1]
    return (C_new, n_new, m_new), h


def apply_mlstm(p: dict, cfg: ArchConfig, x: jax.Array):
    B, T, d = x.shape
    H = cfg.xlstm_heads
    xn = rms_norm(x, p["ln"], cfg.norm_eps)
    up = xn @ p["w_up"]
    xm, z = jnp.split(up, 2, axis=-1)  # (B, T, du)
    q, k, v, i_raw, f_raw, _ = _mlstm_parallel_inputs(p, cfg, xm)

    du = xm.shape[-1]
    dh = du // H
    init = (
        jnp.zeros((B, H, dh, dh), jnp.float32),
        jnp.zeros((B, H, dh), jnp.float32),
        jnp.zeros((B, H), jnp.float32),
    )
    L = cfg.xlstm_chunk
    if L and T % L == 0 and T > L:
        nc = T // L
        chunked = lambda arr: jnp.swapaxes(
            arr.reshape(B, nc, L, *arr.shape[2:]), 0, 1
        )
        (C, n, m), hs = jax.lax.scan(
            _mlstm_chunk_body,
            init,
            (chunked(q), chunked(k), chunked(v), chunked(i_raw), chunked(f_raw)),
        )
        h = jnp.moveaxis(hs, 0, 1).reshape(B, T, du).astype(x.dtype)
    else:
        def body(carry, inp):
            C, n, m = carry
            qt, kt, vt, it, ft = inp
            C, n, m, h = _mlstm_step(C, n, m, qt, kt, vt, it, ft)
            return (C, n, m), h

        sw = lambda a: jnp.swapaxes(a, 0, 1)
        (C, n, m), hs = jax.lax.scan(
            body, init, (sw(q), sw(k), sw(v), sw(i_raw), sw(f_raw))
        )
        h = jnp.swapaxes(hs, 0, 1).reshape(B, T, du).astype(x.dtype)
    h = _group_norm_heads(h, p["gn"], H)
    out = (h * jax.nn.silu(z)) @ p["w_down"]
    ck = cfg.ssm_conv
    tail = xm[:, -(ck - 1) :, :] if T >= ck - 1 else jnp.pad(
        xm, ((0, 0), (ck - 1 - T, 0), (0, 0))
    )
    return x + out, {"C": C, "n": n, "m": m, "conv": tail}


def step_mlstm(p: dict, cfg: ArchConfig, x_t: jax.Array, state: dict):
    B, d = x_t.shape
    H = cfg.xlstm_heads
    xn = rms_norm(x_t, p["ln"], cfg.norm_eps)
    up = xn @ p["w_up"]
    xm, z = jnp.split(up, 2, axis=-1)  # (B, du)
    du = xm.shape[-1]
    dh = du // H
    window = jnp.concatenate([state["conv"], xm[:, None, :]], axis=1)
    xc = jax.nn.silu(
        jnp.einsum("bki,ki->bi", window.astype(jnp.float32), p["conv_w"].astype(jnp.float32))
        + p["conv_b"].astype(jnp.float32)
    ).astype(x_t.dtype)
    q = (xc @ p["wq"]).reshape(B, H, dh).astype(jnp.float32) * dh**-0.5
    k = (xc @ p["wk"]).reshape(B, H, dh).astype(jnp.float32) * dh**-0.5
    v = (xm @ p["wv"]).reshape(B, H, dh).astype(jnp.float32)
    gates = xc.astype(jnp.float32) @ p["w_gates"] + p["b_gates"]
    i_raw, f_raw = jnp.split(gates, 2, axis=-1)
    f_raw = jax.nn.log_sigmoid(f_raw)
    C, n, m, h = _mlstm_step(state["C"], state["n"], state["m"], q, k, v, i_raw, f_raw)
    h = _group_norm_heads(h.reshape(B, du).astype(x_t.dtype), p["gn"], H)
    out = (h * jax.nn.silu(z)) @ p["w_down"]
    return x_t + out, {"C": C, "n": n, "m": m, "conv": window[:, 1:]}


# ---------------------------------------------------------------------------
# sLSTM (xLSTM scalar-memory block with per-head recurrence).
# ---------------------------------------------------------------------------


def init_slstm(key, cfg: ArchConfig) -> dict:
    dt = dtype_of(cfg)
    d = cfg.d_model
    H = cfg.xlstm_heads
    dh = d // H
    keys = jax.random.split(key, 8)
    # xLSTM's 4/3 post-up-projection, rounded up to 128 for MXU alignment
    # (and 16-way TP divisibility) — matches production xLSTM packings.
    dff = -(-(4 * d) // (3 * 128)) * 128

    def rec(k):  # block-diagonal per-head recurrent matrix
        return (0.02 * jax.random.normal(k, (H, dh, dh))).astype(jnp.float32)

    return {
        "ln": jnp.ones((d,), dt),
        "w_x": init_dense(keys[0], d, 4 * d, dt),  # z, i, f, o stacked
        "r_z": rec(keys[1]),
        "r_i": rec(keys[2]),
        "r_f": rec(keys[3]),
        "r_o": rec(keys[4]),
        "b": jnp.concatenate(
            [jnp.zeros((2 * d,)), jnp.full((d,), 3.0), jnp.zeros((d,))]
        ),  # forget bias high
        "gn": jnp.ones((d,), dt),
        "w_ff1": init_dense(keys[5], d, dff, dt),
        "w_ff2": init_dense(keys[6], dff, d, dt, scale=0.02 / (2 * cfg.n_layers) ** 0.5),
    }


def init_state_slstm(cfg: ArchConfig, batch: int) -> dict:
    d = cfg.d_model
    return {
        "c": jnp.zeros((batch, d), jnp.float32),
        "n": jnp.ones((batch, d), jnp.float32),
        "m": jnp.zeros((batch, d), jnp.float32),
        "h": jnp.zeros((batch, d), jnp.float32),
    }


def _slstm_cell(p, cfg, state, x_proj):
    """x_proj (B, 4d) = x @ w_x + b. Returns (state, h_out)."""
    H = cfg.xlstm_heads
    d = cfg.d_model
    dh = d // H
    c, n, m, h = state["c"], state["n"], state["m"], state["h"]
    B = c.shape[0]

    def rmul(r, hvec):  # (H,dh,dh) x (B,d) block-diag matvec
        return jnp.einsum("bhd,hde->bhe", hvec.reshape(B, H, dh), r).reshape(B, d)

    zx, ix, fx, ox = jnp.split(x_proj.astype(jnp.float32), 4, axis=-1)
    z = jnp.tanh(zx + rmul(p["r_z"], h))
    i_raw = ix + rmul(p["r_i"], h)
    f_raw = jax.nn.log_sigmoid(fx + rmul(p["r_f"], h))
    o = jax.nn.sigmoid(ox + rmul(p["r_o"], h))
    m_new = jnp.maximum(f_raw + m, i_raw)
    i = jnp.exp(i_raw - m_new)
    f = jnp.exp(f_raw + m - m_new)
    c = f * c + i * z
    n = f * n + i
    h_new = o * c / jnp.maximum(n, 1e-6)
    return {"c": c, "n": n, "m": m_new, "h": h_new}, h_new


def apply_slstm(p: dict, cfg: ArchConfig, x: jax.Array):
    B, T, d = x.shape
    xn = rms_norm(x, p["ln"], cfg.norm_eps)
    xp = xn @ p["w_x"] + p["b"].astype(xn.dtype)  # (B, T, 4d)

    def body(state, xt):
        state, h = _slstm_cell(p, cfg, state, xt)
        return state, h

    state, hs = jax.lax.scan(body, init_state_slstm(cfg, B), jnp.swapaxes(xp, 0, 1))
    h = jnp.swapaxes(hs, 0, 1).astype(x.dtype)  # (B, T, d)
    h = _group_norm_heads(h, p["gn"], cfg.xlstm_heads)
    h = x + h
    ff = jax.nn.gelu(h @ p["w_ff1"]) @ p["w_ff2"]
    return h + ff, state


def step_slstm(p: dict, cfg: ArchConfig, x_t: jax.Array, state: dict):
    xn = rms_norm(x_t, p["ln"], cfg.norm_eps)
    xp = xn @ p["w_x"] + p["b"].astype(xn.dtype)
    state, h = _slstm_cell(p, cfg, state, xp)
    h = _group_norm_heads(h.astype(x_t.dtype), p["gn"], cfg.xlstm_heads)
    h = x_t + h
    ff = jax.nn.gelu(h @ p["w_ff1"]) @ p["w_ff2"]
    return h + ff, state
