"""The unified model: any ArchConfig → init / forward / loss / prefill / decode.

Structure (DESIGN.md §5):

- The layer stack is a ``lax.scan`` over *periods* (the smallest repeating
  unit of block kinds — 1 for dense, 2 for xLSTM, 8 for jamba), so compiled
  HLO size is O(period), not O(depth), and the remat policy wraps the scan
  body.
- Parameters are a tuple over period positions of per-kind dicts, with every
  leaf stacked over periods (leading dim ``n_periods``).
- Decode caches mirror the parameter structure: attention positions carry
  (k, v) ring/linear buffers, mamba positions carry (h, conv), xLSTM
  positions carry their cell states. ``lax.scan`` threads (params, cache)
  together and emits the updated cache as scan outputs.
- ``shard_activation`` is an injection point: the launch layer passes a
  function applying ``with_sharding_constraint`` to the residual stream
  (batch over data axes; sequence over model for SP) without the model
  depending on any mesh.
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models import ssm
from repro.models.config import ArchConfig
from repro.models.layers import (
    apply_attention,
    apply_mlp,
    dtype_of,
    init_attention,
    init_dense,
    init_mlp,
    rms_norm,
)
from repro.models.moe import apply_moe, init_moe

__all__ = ["Model"]

ShardFn = Callable[[jax.Array, str], jax.Array]


def _identity_shard(x: jax.Array, name: str) -> jax.Array:
    return x


# ---------------------------------------------------------------------------
# Per-kind block init / full-sequence apply / single-step apply.
# ---------------------------------------------------------------------------


def _init_block(kind: str, key, cfg: ArchConfig) -> dict:
    dt = dtype_of(cfg)
    k1, k2, k3 = jax.random.split(key, 3)
    if kind in ("attn_mlp", "attn_moe"):
        ffn = init_moe(k2, cfg) if kind == "attn_moe" else init_mlp(k2, cfg)
        return {
            "ln1": jnp.ones((cfg.d_model,), dt),
            "mixer": init_attention(k1, cfg),
            "ln2": jnp.ones((cfg.d_model,), dt),
            "ffn": ffn,
        }
    if kind in ("mamba_mlp", "mamba_moe", "mamba"):
        out = {
            "ln1": jnp.ones((cfg.d_model,), dt),
            "mixer": ssm.init_mamba(k1, cfg),
        }
        if kind != "mamba":
            out["ln2"] = jnp.ones((cfg.d_model,), dt)
            out["ffn"] = init_moe(k2, cfg) if kind == "mamba_moe" else init_mlp(k2, cfg)
        return out
    if kind == "mlstm":
        return ssm.init_mlstm(k1, cfg)
    if kind == "slstm":
        return ssm.init_slstm(k1, cfg)
    raise ValueError(kind)


def _cache_len(cfg: ArchConfig, max_len: int) -> int:
    return min(cfg.window, max_len) if cfg.window else max_len


def _init_block_cache(kind: str, cfg: ArchConfig, batch: int, max_len: int):
    dt = dtype_of(cfg)
    if kind.startswith("attn"):
        s = _cache_len(cfg, max_len)
        return {
            "k": jnp.zeros((batch, s, cfg.n_kv_heads, cfg.head_dim), dt),
            "v": jnp.zeros((batch, s, cfg.n_kv_heads, cfg.head_dim), dt),
        }
    if kind.startswith("mamba"):
        return ssm.init_state_mamba(cfg, batch)
    if kind == "mlstm":
        return ssm.init_state_mlstm(cfg, batch)
    if kind == "slstm":
        return ssm.init_state_slstm(cfg, batch)
    raise ValueError(kind)


def _apply_block_full(
    kind: str,
    p: dict,
    cfg: ArchConfig,
    x: jax.Array,
    positions: jax.Array,
    shard: ShardFn,
    max_len: int,
):
    """Full-sequence block application. Returns (x, cache_entry)."""
    if kind.startswith("attn"):
        h, (k, v) = apply_attention(
            p["mixer"], cfg, rms_norm(x, p["ln1"], cfg.norm_eps), positions
        )
        x = shard(x + h, "residual")
        ffn_in = rms_norm(x, p["ln2"], cfg.norm_eps)
        if kind == "attn_moe":
            ffn = apply_moe(p["ffn"], cfg, shard(ffn_in, "moe_in"))
        else:
            ffn = apply_mlp(p["ffn"], ffn_in)
        x = shard(x + ffn, "residual")
        cache = _kv_to_cache(cfg, k, v, max_len)
        return x, cache
    if kind.startswith("mamba"):
        h, state = ssm.apply_mamba(p["mixer"], cfg, rms_norm(x, p["ln1"], cfg.norm_eps))
        x = shard(x + h, "residual")
        if kind != "mamba":
            ffn_in = rms_norm(x, p["ln2"], cfg.norm_eps)
            if kind == "mamba_moe":
                ffn = apply_moe(p["ffn"], cfg, shard(ffn_in, "moe_in"))
            else:
                ffn = apply_mlp(p["ffn"], ffn_in)
            x = shard(x + ffn, "residual")
        return x, state
    if kind == "mlstm":
        x, state = ssm.apply_mlstm(p, cfg, x)
        return shard(x, "residual"), state
    if kind == "slstm":
        x, state = ssm.apply_slstm(p, cfg, x)
        return shard(x, "residual"), state
    raise ValueError(kind)


def _kv_to_cache(cfg: ArchConfig, k: jax.Array, v: jax.Array, max_len: int):
    """Pack prefill K/V (B, T, KV, hd) into the decode cache layout.

    Token at absolute position p lives at slot p (linear cache) or p % W
    (sliding-window ring buffer) — decode continues the same convention.
    """
    B, T, KV, hd = k.shape
    s = _cache_len(cfg, max_len)
    if cfg.window and T >= s:
        last_k, last_v = k[:, -s:], v[:, -s:]
        pos = jnp.arange(T - s, T) % s
        ck = jnp.zeros((B, s, KV, hd), k.dtype).at[:, pos].set(last_k)
        cv = jnp.zeros((B, s, KV, hd), v.dtype).at[:, pos].set(last_v)
        return {"k": ck, "v": cv}
    pad = s - min(T, s)
    t = min(T, s)
    ck = jnp.pad(k[:, :t], ((0, 0), (0, pad), (0, 0), (0, 0)))
    cv = jnp.pad(v[:, :t], ((0, 0), (0, pad), (0, 0), (0, 0)))
    return {"k": ck, "v": cv}


def _apply_block_step(
    kind: str,
    p: dict,
    cfg: ArchConfig,
    x_t: jax.Array,
    cache: dict,
    pos: jax.Array,
    positions_t: jax.Array,
):
    """Single-token block application. x_t (B, d). Returns (x_t, cache)."""
    if kind.startswith("attn"):
        from repro.models.layers import project_qkv, sdpa

        B, d = x_t.shape
        xn = rms_norm(x_t, p["ln1"], cfg.norm_eps)[:, None, :]  # (B, 1, d)
        q, k, v = project_qkv(p["mixer"], cfg, xn, positions_t)
        s = cache["k"].shape[1]
        slot = pos % s
        ck = jax.lax.dynamic_update_index_in_dim(cache["k"], k[:, 0], slot, axis=1)
        cv = jax.lax.dynamic_update_index_in_dim(cache["v"], v[:, 0], slot, axis=1)
        kv_len = jnp.minimum(pos + 1, s)
        # Ring/linear cache: every stored key is a valid past token; mask
        # only unfilled slots (order-independence of attention lets the ring
        # rotation stand — RoPE was applied at absolute positions).
        out = sdpa(q, ck, cv, causal=False, window=None, kv_len=kv_len)
        h = out.reshape(B, cfg.n_heads * cfg.head_dim) @ p["mixer"]["wo"]
        x_t = x_t + h
        ffn_in = rms_norm(x_t, p["ln2"], cfg.norm_eps)
        if kind == "attn_moe":
            ffn = apply_moe(p["ffn"], cfg, ffn_in[:, None, :])[:, 0]
        else:
            ffn = apply_mlp(p["ffn"], ffn_in)
        return x_t + ffn, {"k": ck, "v": cv}
    if kind.startswith("mamba"):
        h, state = ssm.step_mamba(
            p["mixer"], cfg, rms_norm(x_t, p["ln1"], cfg.norm_eps), cache
        )
        x_t = x_t + h
        if kind != "mamba":
            ffn_in = rms_norm(x_t, p["ln2"], cfg.norm_eps)
            if kind == "mamba_moe":
                ffn = apply_moe(p["ffn"], cfg, ffn_in[:, None, :])[:, 0]
            else:
                ffn = apply_mlp(p["ffn"], ffn_in)
            x_t = x_t + ffn
        return x_t, state
    if kind == "mlstm":
        return ssm.step_mlstm(p, cfg, x_t, cache)
    if kind == "slstm":
        return ssm.step_slstm(p, cfg, x_t, cache)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# The model.
# ---------------------------------------------------------------------------


class Model:
    def __init__(
        self,
        cfg: ArchConfig,
        *,
        shard_activation: ShardFn | None = None,
        remat: bool = True,
        scan_unroll: bool = False,
    ):
        cfg.validate()
        self.cfg = cfg
        self.period = cfg.block_period()
        self.shard = shard_activation or _identity_shard
        self.remat = remat
        # scan_unroll=True unrolls the layer scan — used by the dry-run's
        # cost-analysis pair (XLA counts while bodies once; an unrolled pair
        # at depth 1/2 periods yields the exact per-period cost delta).
        self.scan_unroll = scan_unroll

    # ---- parameters -------------------------------------------------------
    def init(self, key) -> dict:
        cfg = self.cfg
        dt = dtype_of(cfg)
        n_posns = len(self.period)
        keys = jax.random.split(key, cfg.n_layers + 3)
        periods = []
        for n in range(cfg.n_periods):
            periods.append(
                tuple(
                    _init_block(kind, keys[n * n_posns + i], cfg)
                    for i, kind in enumerate(self.period)
                )
            )
        blocks = jax.tree.map(lambda *xs: jnp.stack(xs), *periods)
        params: dict[str, Any] = {
            "blocks": blocks,
            "ln_f": jnp.ones((cfg.d_model,), dt),
        }
        params["embed"] = init_dense(keys[-1], cfg.vocab, cfg.d_model, dt)
        if not cfg.tie_embeddings:
            params["unembed"] = init_dense(keys[-2], cfg.d_model, cfg.vocab, dt)
        return params

    # ---- shared pieces ----------------------------------------------------
    def _embed_in(self, params, batch) -> tuple[jax.Array, jax.Array]:
        cfg = self.cfg
        if cfg.input_mode == "embeds":
            x = batch["embeds"].astype(dtype_of(cfg))
        else:
            x = jnp.take(params["embed"], batch["tokens"], axis=0)
        B, T = x.shape[:2]
        if "positions" in batch:
            positions = batch["positions"]
        else:
            positions = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
            if cfg.rope == "mrope":
                positions = jnp.broadcast_to(positions[..., None], (B, T, 3))
        return self.shard(x, "embed"), positions

    def _unembed(self, params, x: jax.Array) -> jax.Array:
        w = params["embed"].T if self.cfg.tie_embeddings else params["unembed"]
        logits = x.astype(jnp.float32) @ w.astype(jnp.float32)
        return self.shard(logits, "logits")

    # ---- training / encoder forward ----------------------------------------
    def forward(self, params, batch) -> jax.Array:
        cfg = self.cfg
        x, positions = self._embed_in(params, batch)
        T = x.shape[1]

        def period_body(x, period_params):
            for i, kind in enumerate(self.period):
                x, _ = _apply_block_full(
                    kind, period_params[i], cfg, x, positions, self.shard, T
                )
            return x, None

        body = jax.checkpoint(period_body) if self.remat else period_body
        x, _ = jax.lax.scan(body, x, params["blocks"], unroll=self.scan_unroll)
        x = rms_norm(x, params["ln_f"], cfg.norm_eps)
        return self._unembed(params, x)

    def loss_fn(self, params, batch) -> tuple[jax.Array, dict]:
        logits = self.forward(params, batch)  # (B, T, V) fp32
        labels = batch["labels"]
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
        nll = logz - gold
        mask = batch.get("loss_mask", jnp.ones_like(nll))
        loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
        return loss, {"loss": loss, "tokens": jnp.sum(mask)}

    # ---- serving ------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int):
        cfg = self.cfg
        one_period = tuple(
            _init_block_cache(kind, cfg, batch, max_len) for kind in self.period
        )
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (cfg.n_periods,) + x.shape),
            one_period,
        )

    def prefill(self, params, batch, max_len: int):
        """Run the prompt; returns (cache, logits (B, T, V))."""
        cfg = self.cfg
        x, positions = self._embed_in(params, batch)
        T = x.shape[1]

        def period_body(x, period_params):
            entries = []
            for i, kind in enumerate(self.period):
                x, entry = _apply_block_full(
                    kind, period_params[i], cfg, x, positions, self.shard, max_len
                )
                entries.append(entry)
            return x, tuple(entries)

        x, cache = jax.lax.scan(
            period_body, x, params["blocks"], unroll=self.scan_unroll
        )
        x = rms_norm(x, params["ln_f"], cfg.norm_eps)
        return cache, self._unembed(params, x)

    def decode_step(self, params, cache, tokens: jax.Array, pos: jax.Array):
        """One token step. tokens (B,) int32, pos scalar absolute position.
        Returns (logits (B, V), new cache)."""
        cfg = self.cfg
        x_t = jnp.take(params["embed"], tokens, axis=0)  # (B, d)
        B = x_t.shape[0]
        positions_t = jnp.broadcast_to(pos[None, None], (B, 1))
        if cfg.rope == "mrope":
            positions_t = jnp.broadcast_to(positions_t[..., None], (B, 1, 3))

        def period_body(x_t, inp):
            period_params, period_cache = inp
            new_entries = []
            for i, kind in enumerate(self.period):
                x_t, entry = _apply_block_step(
                    kind, period_params[i], cfg, x_t, period_cache[i], pos, positions_t
                )
                new_entries.append(entry)
            return x_t, tuple(new_entries)

        x_t, new_cache = jax.lax.scan(
            period_body, x_t, (params["blocks"], cache), unroll=self.scan_unroll
        )
        x_t = rms_norm(x_t, params["ln_f"], cfg.norm_eps)
        logits = self._unembed(params, x_t)
        return logits, new_cache
