# LM-family model zoo: a single functional Model (models/model.py) driven by
# ArchConfig (models/config.py) covering dense GQA transformers, MoE
# (GShard-dispatch), Mamba/xLSTM recurrent mixers, the Jamba hybrid layout,
# encoder-only audio backbones, and the Qwen2-VL M-RoPE VLM backbone.

from repro.models.config import ArchConfig  # noqa: F401
from repro.models.model import Model  # noqa: F401
