"""Level 2: Mandelbrot — the Dynamic-Parallelism benchmark.

Two implementations, exactly the paper's pair (§V-B):

- ``escape_time``: flat per-pixel iteration (the baseline the paper measures
  without Dynamic Parallelism) — a vectorized ``while_loop`` over the whole
  image; every pixel iterates until escape or max_iter.
- ``mariani_silver``: the adaptive algorithm the paper enables with Dynamic
  Parallelism. TPU adaptation (DESIGN.md §2): instead of child-kernel
  launches, the image is tiled; a cheap *border* pass classifies each tile
  (the Mariani–Silver invariant: if the border of a region lies entirely in
  the set, the whole region is in the set); interior tiles are filled
  without iteration and only mixed tiles run the per-pixel loop via
  ``lax.map`` + ``cond``. The work saved — interior pixels never iterate to
  max_iter — is the same work Dynamic Parallelism saves on GPU.

Validation: both versions agree exactly.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.presets import geometric_presets
from repro.core.registry import BenchmarkSpec, Workload, register


def _pixel_grid(n: int, center=(-0.6, 0.0), extent=2.6):
    xs = jnp.linspace(center[0] - extent / 2, center[0] + extent / 2, n)
    ys = jnp.linspace(center[1] - extent / 2, center[1] + extent / 2, n)
    return xs[None, :] + 1j * ys[:, None]


def _iterate(c: jax.Array, max_iter: int) -> jax.Array:
    """Escape-time counts for an arbitrary-shape complex block."""

    def cond(state):
        z, k, n = state
        return jnp.any(jnp.abs(z) <= 2.0) & (k < max_iter)

    def body(state):
        z, k, n = state
        active = jnp.abs(z) <= 2.0
        z = jnp.where(active, z * z + c, z)
        n = jnp.where(active, n + 1, n)
        return z, k + 1, n

    _, _, n = jax.lax.while_loop(
        cond, body, (jnp.zeros_like(c), jnp.int32(0), jnp.zeros(c.shape, jnp.int32))
    )
    return n


def escape_time(c: jax.Array, max_iter: int) -> jax.Array:
    return _iterate(c, max_iter)


def mariani_silver(c: jax.Array, max_iter: int, tile: int = 32) -> jax.Array:
    n = c.shape[0]
    assert n % tile == 0
    t = n // tile
    tiles = c.reshape(t, tile, t, tile).transpose(0, 2, 1, 3).reshape(-1, tile, tile)

    # Border classification: all four edges of a tile.
    border = jnp.concatenate(
        [tiles[:, 0, :], tiles[:, -1, :], tiles[:, :, 0], tiles[:, :, -1]], axis=1
    )
    border_n = _iterate(border, max_iter)
    uniform_interior = jnp.all(border_n == max_iter, axis=1)

    def per_tile(args):
        tc, is_interior = args
        return jax.lax.cond(
            is_interior,
            lambda tc: jnp.full((tile, tile), max_iter, jnp.int32),
            lambda tc: _iterate(tc, max_iter),
            tc,
        )

    out_tiles = jax.lax.map(per_tile, (tiles, uniform_interior))
    return (
        out_tiles.reshape(t, t, tile, tile).transpose(0, 2, 1, 3).reshape(n, n)
    )


def _make(n: int, max_iter: int, adaptive: bool) -> Workload:
    def make_inputs(seed: int):
        del seed  # the fractal view is fixed; determinism is the point
        return (_pixel_grid(n),)

    fn = (
        functools.partial(mariani_silver, max_iter=max_iter)
        if adaptive
        else functools.partial(escape_time, max_iter=max_iter)
    )

    def validate(out, args):
        import numpy as np

        (c,) = args
        want = escape_time(c, max_iter)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(want))

    return Workload(
        name=f"mandelbrot.{'ms' if adaptive else 'flat'}.{n}px.i{max_iter}",
        fn=fn,
        make_inputs=make_inputs,
        flops=float(n * n * max_iter * 10),  # upper bound (flat version)
        bytes_moved=float(n * n * 12),
        validate=validate,
        # Flat escape-time is per-pixel independent: shard image rows (the
        # while_loop's global any() is one scalar psum per iteration).
        # Mariani-Silver opts out — its tiling reshapes span both axes.
        batch_dims=None if adaptive else (0,),
    )


for _adaptive in (False, True):
    register(
        BenchmarkSpec(
            name=f"mandelbrot_{'ms' if _adaptive else 'flat'}",
            level=2,
            dwarf=None,
            domain="Numerical analysis",
            cuda_feature="Dynamic Parallelism" if _adaptive else None,
            tpu_feature="tile-adaptive refinement (feat_dynamic_parallelism)"
            if _adaptive
            else None,
            presets=geometric_presets(
                {"n": 128, "max_iter": 64, "adaptive": _adaptive},
                scale_keys={"n": 2.0, "max_iter": 2.0},
                round_to=32,
            ),
            build=lambda n, max_iter, adaptive: _make(n, max_iter, adaptive),
        )
    )
