"""Level 2: ParticleFilter — Bayesian object tracking (medical imaging).

Sequential importance resampling: propagate a particle cloud with process
noise, weight by a Gaussian likelihood against noisy measurements, and
**systematically resample** — the GPU version's scatter-heavy step, which on
TPU becomes prefix-sum (our scan idiom) + vectorized ``searchsorted``.
Validation: the state estimate tracks the true trajectory within noise
bounds.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.presets import geometric_presets
from repro.core.registry import BenchmarkSpec, Workload, register

PROC_STD = 0.25
MEAS_STD = 0.5


def make_trajectory(steps: int, seed: int):
    key = jax.random.key(seed ^ 0x5EED)
    kv, km = jax.random.split(key)
    vel = jax.random.normal(kv, (2,)) * 0.5 + 1.0
    t = jnp.arange(steps, dtype=jnp.float32)[:, None]
    truth = t * vel[None, :]  # constant-velocity ground truth
    meas = truth + MEAS_STD * jax.random.normal(km, (steps, 2))
    return truth, meas


def particle_filter(meas: jax.Array, n_particles: int, key: jax.Array) -> jax.Array:
    """Returns the (steps, 2) posterior-mean track."""

    def step(carry, inp):
        particles, key = carry
        z, = inp
        key, kp, kr = jax.random.split(key, 3)
        # Propagate: random-walk-with-drift process model.
        particles = particles + 1.0 + PROC_STD * jax.random.normal(kp, particles.shape)
        # Weight.
        d2 = jnp.sum((particles - z[None]) ** 2, axis=1)
        logw = -0.5 * d2 / MEAS_STD**2
        w = jax.nn.softmax(logw)
        est = jnp.sum(w[:, None] * particles, axis=0)
        # Systematic resampling: prefix-sum + searchsorted.
        cdf = jnp.cumsum(w)
        u0 = jax.random.uniform(kr, ()) / n_particles
        u = u0 + jnp.arange(n_particles) / n_particles
        idx = jnp.searchsorted(cdf, u)
        particles = particles[jnp.clip(idx, 0, n_particles - 1)]
        return (particles, key), est

    k0, kinit = jax.random.split(key)
    particles0 = meas[0][None] + jax.random.normal(kinit, (n_particles, 2))
    (_, _), track = jax.lax.scan(step, (particles0, k0), (meas,))
    return track


def _make(n_particles: int, steps: int) -> Workload:
    def make_inputs(seed: int):
        _, meas = make_trajectory(steps, seed)
        return (meas, jax.random.key(seed))

    def fn(meas, key):
        return particle_filter(meas, n_particles, key)

    def validate(out, args):
        import numpy as np

        meas, _ = args
        track = np.asarray(out)
        # Skip burn-in; the posterior mean must beat raw-measurement error.
        err = np.abs(track[3:] - np.asarray(meas)[3:]).mean()
        assert err < 3 * MEAS_STD, f"filter diverged: mean err {err}"

    return Workload(
        name=f"particlefilter.p{n_particles}.s{steps}",
        fn=fn,
        make_inputs=make_inputs,
        flops=float(steps * n_particles * 30),
        bytes_moved=float(steps * n_particles * 2 * 4 * 4),
        validate=validate,
        # Opt out: systematic resampling gathers particles through a global
        # CDF every step; the cloud cannot be partitioned independently.
        batch_dims=None,
    )


register(
    BenchmarkSpec(
        name="particlefilter",
        level=2,
        dwarf="Structured grid",
        domain="Medical imaging",
        cuda_feature=None,
        tpu_feature="prefix-sum systematic resampling",
        presets=geometric_presets(
            {"n_particles": 1024, "steps": 16},
            scale_keys={"n_particles": 4.0},
            round_to=128,
        ),
        build=lambda n_particles, steps: _make(n_particles, steps),
    )
)
