"""Level 2: LavaMD — N-body particle potentials within a cutoff (chemistry).

Space is a 3-D lattice of boxes; each home box interacts with itself and its
26 neighbours (Rodinia's formulation). TPU adaptation: the GPU version walks
neighbour lists per thread-block; here the neighbour gather is a static
index array (boxes, 27) built on the host, and the pairwise kernel is a
dense (ppb × 27·ppb) distance/potential block per box, vmapped over boxes —
regular compute the MXU/VPU can saturate. Uses the Rodinia DP-potential form
u(r²)=exp(−2αr²)·q_i·q_j within cutoff.

Validation: brute-force all-pairs-with-cutoff oracle on small presets.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.presets import geometric_presets
from repro.core.registry import BenchmarkSpec, Workload, register

ALPHA = 0.5


def neighbour_table(nb: int) -> np.ndarray:
    """(nb³, 27) box indices; out-of-lattice neighbours point at the ghost
    box nb³ (zero-charge particles at infinity), so boundary boxes simply
    have fewer live neighbours — the paper's "fewer neighbors at the
    boundaries" case without duplicate counting."""
    idx = np.arange(nb**3).reshape(nb, nb, nb)
    padded = np.full((nb + 2, nb + 2, nb + 2), nb**3, dtype=np.int32)
    padded[1:-1, 1:-1, 1:-1] = idx
    out = np.empty((nb, nb, nb, 27), dtype=np.int32)
    n = 0
    for dx in (0, 1, 2):
        for dy in (0, 1, 2):
            for dz in (0, 1, 2):
                out[..., n] = padded[dx : dx + nb, dy : dy + nb, dz : dz + nb]
                n += 1
    return out.reshape(-1, 27)


def box_potentials(pos, charge, neigh, cutoff2: float):
    """pos (B, P, 3), charge (B, P), neigh (B, 27) -> potential (B, P).

    ``pos``/``charge`` include a trailing ghost box (index B-1 of the padded
    arrays) holding zero-charge particles at infinity."""
    ghost_pos = jnp.full((1,) + pos.shape[1:], 1e6, pos.dtype)
    ghost_q = jnp.zeros((1,) + charge.shape[1:], charge.dtype)
    pos = jnp.concatenate([pos, ghost_pos], axis=0)
    charge = jnp.concatenate([charge, ghost_q], axis=0)

    def one_box(b):
        home_pos = pos[b]  # (P, 3)
        home_q = charge[b]  # (P,)
        nb_pos = pos[neigh[b]].reshape(-1, 3)  # (27P, 3)
        nb_q = charge[neigh[b]].reshape(-1)
        d = home_pos[:, None, :] - nb_pos[None, :, :]
        r2 = jnp.sum(d * d, axis=-1)  # (P, 27P)
        u = jnp.exp(-2.0 * ALPHA * r2) * home_q[:, None] * nb_q[None, :]
        u = jnp.where((r2 <= cutoff2) & (r2 > 0.0), u, 0.0)  # exclude self
        return jnp.sum(u, axis=1)

    return jax.vmap(one_box)(jnp.arange(pos.shape[0] - 1))


def brute_force_oracle(pos: np.ndarray, charge: np.ndarray, cutoff2: float) -> np.ndarray:
    """All-pairs oracle over the flattened particle set (duplicate-box pairs
    excluded by cutoff geometry when box edge ≥ cutoff)."""
    flat_p = pos.reshape(-1, 3)
    flat_q = charge.reshape(-1)
    d = flat_p[:, None] - flat_p[None]
    r2 = (d * d).sum(-1)
    u = np.exp(-2.0 * ALPHA * r2) * flat_q[:, None] * flat_q[None]
    u[(r2 > cutoff2) | (r2 <= 0.0)] = 0.0
    return u.sum(1).reshape(charge.shape)


def _make(nb: int, ppb: int) -> Workload:
    cutoff2 = 1.0  # box edge is 1.0 → neighbours cover the cutoff sphere
    neigh = jnp.asarray(neighbour_table(nb))

    def make_inputs(seed: int):
        rng = np.random.default_rng(seed)
        boxes = nb**3
        # Particles uniformly inside their own unit box.
        corner = np.stack(
            np.meshgrid(*([np.arange(nb)] * 3), indexing="ij"), axis=-1
        ).reshape(-1, 1, 3)
        pos = corner + rng.uniform(0, 1, (boxes, ppb, 3))
        q = rng.uniform(0.5, 1.0, (boxes, ppb))
        return (
            jnp.asarray(pos, jnp.float32),
            jnp.asarray(q, jnp.float32),
        )

    def fn(pos, charge):
        return box_potentials(pos, charge, neigh, cutoff2)

    def validate(out, args):
        pos, charge = args
        if nb**3 * ppb > 4096:
            return  # oracle is O(n²); only check small presets
        want = brute_force_oracle(np.asarray(pos), np.asarray(charge), cutoff2)
        np.testing.assert_allclose(np.asarray(out), want, rtol=1e-4, atol=1e-5)

    boxes = nb**3
    pair_flops = 11.0
    return Workload(
        name=f"lavamd.nb{nb}.ppb{ppb}",
        fn=fn,
        make_inputs=make_inputs,
        flops=boxes * ppb * 27 * ppb * pair_flops,
        bytes_moved=float(boxes * ppb * 16 * 27),
        validate=validate,
        # Opt out: every home box gathers its 27 neighbour boxes, so a
        # box-sharded cloud exchanges most of its particles per call.
        batch_dims=None,
    )


register(
    BenchmarkSpec(
        name="lavamd",
        level=2,
        dwarf="N-body",
        domain="Computational chemistry",
        cuda_feature=None,
        tpu_feature="dense neighbour-block pair kernel",
        presets=geometric_presets(
            {"nb": 4, "ppb": 16}, scale_keys={"nb": 1.6, "ppb": 1.5}, round_to=2
        ),
        build=lambda nb, ppb: _make(nb, ppb),
    )
)
