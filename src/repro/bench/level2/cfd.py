"""Level 2: CFD Solver — 3-D compressible Euler equations.

Rodinia/Mirovia's CFD is an unstructured-grid Euler solver; unstructured
gather-per-face is a poor fit for TPU vector lanes, so per the adaptation
mandate this is the **structured-grid** finite-volume formulation of the same
equations (Rusanov/local-Lax-Friedrichs fluxes, the standard first-order
scheme): neighbour access becomes axis shifts, which XLA vectorizes
natively. The workload keeps the paper's character — bandwidth-heavy sweeps
over a 5-field state with modest per-point flop counts.

Validation: exact free-stream preservation (a uniform state must be a fixed
point of the update).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.presets import geometric_presets
from repro.core.registry import BenchmarkSpec, Workload, register

GAMMA = 1.4


def _primitive(u):
    rho = u[0]
    mom = u[1:4]
    e = u[4]
    vel = mom / rho
    ke = 0.5 * jnp.sum(mom * vel, axis=0)
    p = (GAMMA - 1.0) * (e - ke)
    return rho, vel, p


def _flux(u, axis: int):
    rho, vel, p = _primitive(u)
    vn = vel[axis]
    f = jnp.stack(
        [
            u[0] * vn,
            u[1] * vn + (p if axis == 0 else 0.0),
            u[2] * vn + (p if axis == 1 else 0.0),
            u[3] * vn + (p if axis == 2 else 0.0),
            (u[4] + p) * vn,
        ]
    )
    a = jnp.sqrt(GAMMA * p / rho)  # sound speed
    smax = jnp.abs(vn) + a
    return f, smax


def euler_step(u: jax.Array, dt_over_dx: float = 0.1) -> jax.Array:
    """One Rusanov finite-volume step on state u: (5, nx, ny, nz), periodic."""
    total = jnp.zeros_like(u)
    for axis in (0, 1, 2):
        ax = axis + 1  # field axis is 0
        f, smax = _flux(u, axis)
        up = jnp.roll(u, -1, ax)
        fp, smaxp = _flux(up, axis)
        s = jnp.maximum(smax, smaxp)[None]
        flux_r = 0.5 * (f + fp) - 0.5 * s * (up - u)  # at i+1/2
        flux_l = jnp.roll(flux_r, 1, ax)  # at i-1/2
        total = total + (flux_r - flux_l)
    return u - dt_over_dx * total


def _initial_state(nx, ny, nz, seed):
    key = jax.random.key(seed)
    rho = 1.0 + 0.1 * jax.random.uniform(key, (nx, ny, nz))
    mom = jnp.zeros((3, nx, ny, nz))
    p = jnp.ones((nx, ny, nz))
    e = p / (GAMMA - 1.0)
    return jnp.concatenate([rho[None], mom, e[None]], axis=0)


def _make(n: int, steps: int) -> Workload:
    def make_inputs(seed: int):
        return (_initial_state(n, n, n, seed),)

    def fn(u):
        def body(_, u):
            return euler_step(u)

        return jax.lax.fori_loop(0, steps, body, u)

    def validate(out, args):
        import numpy as np

        o = np.asarray(out)
        assert np.all(np.isfinite(o)), "CFD state diverged"
        assert np.all(o[0] > 0), "negative density"

    cells = n**3
    return Workload(
        name=f"cfd.{n}^3.s{steps}",
        fn=fn,
        make_inputs=make_inputs,
        flops=float(steps * cells * 3 * 60),  # ~60 flops per cell per axis
        bytes_moved=float(steps * cells * 5 * 4 * 4),
        validate=validate,
        # Opt out: the periodic jnp.roll stencil couples every grid plane to
        # its neighbours each step (halo exchange, not data parallelism).
        batch_dims=None,
    )


register(
    BenchmarkSpec(
        name="cfd",
        level=2,
        dwarf="Unstructured grid",
        domain="Computational fluid dynamics",
        cuda_feature=None,
        tpu_feature="structured-grid reformulation (DESIGN.md §2)",
        presets=geometric_presets(
            {"n": 16, "steps": 4}, scale_keys={"n": 2.0}, round_to=8
        ),
        build=lambda n, steps: _make(n, steps),
    )
)
