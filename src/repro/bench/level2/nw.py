"""Level 2: Needleman-Wunsch — global DNA sequence alignment (dynamic
programming).

The DP table's (i, j) cell depends on NW/N/W neighbours, so the natural TPU
schedule is the **anti-diagonal wavefront**: ``lax.scan`` over 2n−1
diagonals, each diagonal a fully vectorized max over three shifted copies of
the previous diagonals (GPU blocks synchronize along the same wavefront; on
TPU the diagonal is one vector op). Scores use the match/mismatch/gap model;
validation is an O(n²) python DP oracle.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.presets import geometric_presets
from repro.core.registry import BenchmarkSpec, Workload, register

MATCH, MISMATCH, GAP = 1, -1, -2
NEG = jnp.int32(-(2**20))


def nw_score(a: jax.Array, b: jax.Array) -> jax.Array:
    """Final alignment score of int sequences a, b (same length n)."""
    n = a.shape[0]

    # Diagonal d holds cells (i, j) with i + j == d, padded to length n+1.
    # diag[k] = cell (i=k, j=d-k) for valid k.
    def cell_score(d, k):
        i, j = k, d - k
        return jnp.where((a[jnp.clip(i - 1, 0, n - 1)] == b[jnp.clip(j - 1, 0, n - 1)]), MATCH, MISMATCH)

    ks = jnp.arange(n + 1)

    def step(carry, d):
        prev2, prev1 = carry  # diagonals d-2 and d-1
        i = ks
        j = d - ks
        valid = (j >= 0) & (j <= n)
        # neighbours in diagonal coordinates:
        nw = prev2[jnp.clip(ks - 1, 0, n)]  # (i-1, j-1)
        up = prev1[jnp.clip(ks - 1, 0, n)]  # (i-1, j)
        left = prev1[ks]  # (i, j-1)
        sub = jnp.where(
            a[jnp.clip(i - 1, 0, n - 1)] == b[jnp.clip(j - 1, 0, n - 1)],
            MATCH,
            MISMATCH,
        )
        score = jnp.maximum(nw + sub, jnp.maximum(up + GAP, left + GAP))
        # boundary rows/cols: score(i,0) = i*GAP, score(0,j) = j*GAP
        score = jnp.where(i == 0, j * GAP, score)
        score = jnp.where(j == 0, i * GAP, score)
        score = jnp.where(valid, score, NEG)
        return (prev1, score), None

    init0 = jnp.full((n + 1,), NEG, jnp.int32).at[0].set(0)  # d=0: (0,0)=0
    # d=1: (0,1)=GAP, (1,0)=GAP
    init1 = jnp.full((n + 1,), NEG, jnp.int32).at[0].set(GAP).at[1].set(GAP)
    (prev2, prev1), _ = jax.lax.scan(step, (init0, init1), jnp.arange(2, 2 * n + 1))
    return prev1[n]  # cell (n, n)


def nw_oracle(a: np.ndarray, b: np.ndarray) -> int:
    n, m = len(a), len(b)
    dp = np.zeros((n + 1, m + 1), dtype=np.int64)
    dp[:, 0] = np.arange(n + 1) * GAP
    dp[0, :] = np.arange(m + 1) * GAP
    for i in range(1, n + 1):
        for j in range(1, m + 1):
            sub = MATCH if a[i - 1] == b[j - 1] else MISMATCH
            dp[i, j] = max(dp[i - 1, j - 1] + sub, dp[i - 1, j] + GAP, dp[i, j - 1] + GAP)
    return int(dp[n, m])


def _make(n: int) -> Workload:
    def make_inputs(seed: int):
        key = jax.random.key(seed)
        ka, kb = jax.random.split(key)
        return (
            jax.random.randint(ka, (n,), 0, 4, dtype=jnp.int32),
            jax.random.randint(kb, (n,), 0, 4, dtype=jnp.int32),
        )

    def validate(out, args):
        a, b = args
        if n > 512:
            return  # oracle is O(n²) python
        assert int(out) == nw_oracle(np.asarray(a), np.asarray(b)), (
            int(out),
            nw_oracle(np.asarray(a), np.asarray(b)),
        )

    return Workload(
        name=f"nw.n{n}",
        fn=nw_score,
        make_inputs=make_inputs,
        flops=float(6 * n * n),
        bytes_moved=float(n * n * 4),
        validate=validate,
        # Opt out: the anti-diagonal wavefront is inherently sequential and
        # every diagonal mixes both sequences.
        batch_dims=None,
    )


register(
    BenchmarkSpec(
        name="nw",
        level=2,
        dwarf="Dynamic programming",
        domain="Bioinformatics",
        cuda_feature=None,
        tpu_feature="anti-diagonal wavefront scan",
        presets=geometric_presets({"n": 128}, scale_keys={"n": 2.0}, round_to=16),
        build=lambda n: _make(n),
    )
)
