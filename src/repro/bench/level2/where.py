"""Level 2: Where — relational selection (data analytics, MapReduce dwarf).

The paper's new data-analytics benchmark: map each record to 0/1 under a
predicate, prefix-sum the flags, and compact matching records to the output.
The prefix sum is the Pallas scan kernel (`repro.kernels.prefix_scan`); the
compaction writes via scatter to the scanned offsets — exactly the paper's
description of the filter. Output is fixed-capacity (records, padded) to
keep shapes static under jit; the match count is returned alongside.

Validation: equal to the boolean-mask filter.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.presets import geometric_presets
from repro.core.registry import BenchmarkSpec, Workload, register
from repro.kernels import ops


def where_select(records: jax.Array, lo: float, hi: float):
    """records (N, F); select rows with lo < records[:, 0] < hi."""
    n = records.shape[0]
    flags = ((records[:, 0] > lo) & (records[:, 0] < hi)).astype(jnp.float32)
    offsets = ops.prefix_scan(flags)  # inclusive scan
    count = offsets[-1].astype(jnp.int32)
    dest = (offsets - 1).astype(jnp.int32)  # exclusive position of each match
    dest = jnp.where(flags > 0, dest, n)  # park non-matches on a scratch row
    out = jnp.zeros((n + 1, records.shape[1]), records.dtype)
    out = out.at[dest].set(records)[:n]  # scratch row n sliced away
    valid = jnp.arange(n)[:, None] < count
    return jnp.where(valid, out, 0.0), count


def _make(n: int, fields: int) -> Workload:
    def make_inputs(seed: int):
        key = jax.random.key(seed)
        return (jax.random.uniform(key, (n, fields), jnp.float32),)

    def fn(records):
        return where_select(records, 0.25, 0.75)

    def validate(out, args):
        (records,) = args
        got, count = np.asarray(out[0]), int(out[1])
        r = np.asarray(records)
        mask = (r[:, 0] > 0.25) & (r[:, 0] < 0.75)
        want = r[mask]
        assert count == want.shape[0], (count, want.shape)
        np.testing.assert_allclose(got[:count], want, rtol=1e-6)
        assert np.all(got[count:] == 0.0)

    return Workload(
        name=f"where.n{n}.f{fields}",
        fn=fn,
        make_inputs=make_inputs,
        flops=float(3 * n),
        bytes_moved=float(n * fields * 4 * 2),
        validate=validate,
        # Opt out: the compaction scatters records to prefix-sum offsets
        # that depend on every earlier record (global scan, global writes).
        batch_dims=None,
        pallas_kernel="prefix_scan",
    )


register(
    BenchmarkSpec(
        name="where",
        level=2,
        dwarf="MapReduce",
        domain="Data Analytics",
        cuda_feature=None,
        tpu_feature="prefix-scan compaction (Pallas scan)",
        presets=geometric_presets(
            {"n": 1 << 12, "fields": 8}, scale_keys={"n": 8.0}, round_to=128
        ),
        build=lambda n, fields: _make(n, fields),
    )
)
