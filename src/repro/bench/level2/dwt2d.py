"""Level 2: GPUDWT — 2-D discrete wavelet transform (image compression).

Implements both transforms the paper measures: the integer **5/3** (lossless
JPEG2000) and floating **9/7** (lossy) wavelets, forward and inverse, via the
lifting scheme — separable row/column passes of shift-add lifting steps,
which map to pure vector ops on TPU. Validation: inverse(forward(x)) == x
(exact for 5/3 on integers, allclose for 9/7).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.presets import geometric_presets
from repro.core.registry import BenchmarkSpec, Workload, register

# CDF 9/7 lifting coefficients (JPEG2000).
_A1, _A2, _A3, _A4 = -1.586134342, -0.05298011854, 0.8829110762, 0.4435068522
_K = 1.149604398


def _lift_1d(x, mode: str, inverse: bool):
    """Lifting along the last axis (even length). Returns (lo, hi)."""
    even, odd = x[..., 0::2], x[..., 1::2]

    def predict(e, o, coef):
        e_next = jnp.concatenate([e[..., 1:], e[..., -1:]], axis=-1)
        return o + coef * (e + e_next)

    def update(e, o, coef):
        o_prev = jnp.concatenate([o[..., :1], o[..., :-1]], axis=-1)
        return e + coef * (o + o_prev)

    if mode == "53":
        if not inverse:
            d = predict(even, odd, -0.5)
            s = update(even, d, 0.25)
            return s, d
        s, d = even, odd
        e = update(s, d, -0.25)
        o = predict(e, d, 0.5)
        return e, o
    # 9/7
    if not inverse:
        d = predict(even, odd, _A1)
        s = update(even, d, _A2)
        d = predict(s, d, _A3)
        s = update(s, d, _A4)
        return s * _K, d / _K
    s, d = even / _K, odd * _K
    s = update(s, d, -_A4)
    d = predict(s, d, -_A3)
    s = update(s, d, -_A2)
    d = predict(s, d, -_A1)
    return s, d


def _interleave(lo, hi):
    out = jnp.stack([lo, hi], axis=-1)
    return out.reshape(*lo.shape[:-1], lo.shape[-1] * 2)


def dwt2d(x: jax.Array, mode: str = "97", inverse: bool = False) -> jax.Array:
    """One-level separable 2-D DWT. x: (..., H, W), H and W even."""
    if not inverse:
        lo, hi = _lift_1d(x, mode, False)  # rows
        x = jnp.concatenate([lo, hi], axis=-1)
        x = jnp.swapaxes(x, -1, -2)
        lo, hi = _lift_1d(x, mode, False)  # cols
        x = jnp.concatenate([lo, hi], axis=-1)
        return jnp.swapaxes(x, -1, -2)
    h = x.shape[-1] // 2
    x = jnp.swapaxes(x, -1, -2)
    x = _interleave(*_lift_1d_inv_pair(x, mode))
    x = jnp.swapaxes(x, -1, -2)
    x = _interleave(*_lift_1d_inv_pair(x, mode))
    return x


def _lift_1d_inv_pair(x, mode):
    h = x.shape[-1] // 2
    lo, hi = x[..., :h], x[..., h:]
    packed = _interleave(lo, hi)
    return _lift_1d(packed, mode, True)


def _make(n: int, mode: str) -> Workload:
    def make_inputs(seed: int):
        key = jax.random.key(seed)
        img = jax.random.uniform(key, (n, n), jnp.float32) * 255.0
        if mode == "53":
            img = jnp.round(img)
        return (img,)

    def fn(img):
        return dwt2d(img, mode=mode, inverse=False)

    def validate(out, args):
        import numpy as np

        (img,) = args
        rec = dwt2d(out, mode=mode, inverse=True)
        np.testing.assert_allclose(np.asarray(rec), np.asarray(img), rtol=1e-4, atol=1e-3)

    return Workload(
        name=f"dwt2d.{mode}.{n}x{n}",
        fn=fn,
        make_inputs=make_inputs,
        flops=float(n * n * (14 if mode == "97" else 5)),
        bytes_moved=float(n * n * 4 * 2),
        validate=validate,
        # Opt out: the column lifting pass mixes rows (the separable
        # transform touches both image axes), so neither dim is a batch dim.
        batch_dims=None,
    )


for _mode in ("53", "97"):
    register(
        BenchmarkSpec(
            name=f"dwt2d_{_mode}",
            level=2,
            dwarf="Spectral method",
            domain="Image processing",
            cuda_feature=None,
            tpu_feature="lifting scheme as vector shift-adds",
            presets=geometric_presets(
                {"n": 256, "mode": _mode}, scale_keys={"n": 2.0}, round_to=16
            ),
            build=lambda n, mode: _make(n, mode),
        )
    )
