"""Level 2: SRAD — speckle-reducing anisotropic diffusion (computer vision).

The Cooperative-Groups benchmark (§V-B). The suite workload iterates the
*fused* two-phase Pallas stencil (`repro.kernels.srad_stencil`); the feature
comparison fused-vs-split lives in ``benchmarks/feat_coop_groups.py``.
q0sqr follows Rodinia: speckle statistics of a homogeneous image region.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.presets import geometric_presets
from repro.core.registry import BenchmarkSpec, Workload, register
from repro.kernels import ops


def q0sqr_of(img: jax.Array) -> float:
    region = img[: max(8, img.shape[0] // 8), : max(8, img.shape[1] // 8)]
    mean = jnp.mean(region)
    var = jnp.var(region)
    return var / (mean * mean)


def srad_iterations(img: jax.Array, iters: int, lam: float, fused: bool) -> jax.Array:
    q0 = float(0.05)  # Rodinia default speckle scale for synthetic inputs

    def body(_, im):
        return ops.srad_step(im, lam=lam, q0sqr=q0, fused=fused)

    return jax.lax.fori_loop(0, iters, body, img)


def _make(n: int, iters: int, fused: bool = True) -> Workload:
    def make_inputs(seed: int):
        key = jax.random.key(seed)
        # Positive speckled image (exponential of Gaussian, as in Rodinia).
        return (jnp.exp(0.1 * jax.random.normal(key, (n, n), jnp.float32)),)

    def fn(img):
        return srad_iterations(img, iters, lam=0.5, fused=fused)

    def validate(out, args):
        import numpy as np

        (img,) = args
        o = np.asarray(out)
        assert np.all(np.isfinite(o)), "SRAD diverged"
        # Diffusion must reduce speckle variance.
        assert o.var() <= np.asarray(img).var() * 1.01

    return Workload(
        name=f"srad.{n}x{n}.i{iters}.{'fused' if fused else 'split'}",
        fn=fn,
        make_inputs=make_inputs,
        flops=float(iters * n * n * 40),
        bytes_moved=float(iters * n * n * 4 * (2 if fused else 4)),
        validate=validate,
        # Opt out: the diffusion stencil needs halos each iteration and the
        # q0 statistics couple the whole image.
        batch_dims=None,
        pallas_kernel="srad_step",
    )


register(
    BenchmarkSpec(
        name="srad",
        level=2,
        dwarf="Structured grid",
        domain="Computer vision",
        cuda_feature="Cooperative Groups",
        tpu_feature="fused two-phase stencil kernel (feat_coop_groups)",
        presets=geometric_presets(
            {"n": 64, "iters": 4, "fused": True}, scale_keys={"n": 2.0}, round_to=16
        ),
        build=lambda n, iters, fused: _make(n, iters, fused),
    )
)
