"""Level 2: KMeans — Lloyd iterations (data mining).

Assignment is a dense distance matmul (‖x−c‖² = ‖x‖² − 2x·cᵀ + ‖c‖², the
MXU-friendly expansion) + argmin; update is a one-hot matmul (segment mean
without scatters — TPU adaptation of the GPU's atomic accumulation).
Validation: inertia is non-increasing across iterations.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.presets import geometric_presets
from repro.core.registry import BenchmarkSpec, Workload, register


def kmeans_step(points: jax.Array, centers: jax.Array):
    """One Lloyd iteration. points (N, D), centers (K, D) -> (centers', inertia)."""
    x2 = jnp.sum(points * points, axis=1, keepdims=True)  # (N, 1)
    c2 = jnp.sum(centers * centers, axis=1)[None]  # (1, K)
    d2 = x2 - 2.0 * points @ centers.T + c2  # (N, K)
    assign = jnp.argmin(d2, axis=1)
    inertia = jnp.sum(jnp.min(d2, axis=1))
    onehot = jax.nn.one_hot(assign, centers.shape[0], dtype=points.dtype)  # (N, K)
    sums = onehot.T @ points  # (K, D)
    counts = jnp.sum(onehot, axis=0)[:, None]
    new_centers = jnp.where(counts > 0, sums / jnp.maximum(counts, 1.0), centers)
    return new_centers, inertia


def _make(n: int, d: int, k: int, iters: int) -> Workload:
    def make_inputs(seed: int):
        key = jax.random.key(seed)
        kp, kc = jax.random.split(key)
        pts = jax.random.normal(kp, (n, d), jnp.float32)
        ctr = pts[jax.random.choice(kc, n, (k,), replace=False)]
        return (pts, ctr)

    def fn(points, centers):
        def body(carry, _):
            centers, _ = carry
            new_centers, inertia = kmeans_step(points, centers)
            return (new_centers, inertia), inertia

        (centers, _), history = jax.lax.scan(
            body, (centers, jnp.float32(0)), None, length=iters
        )
        return centers, history

    def validate(out, args):
        import numpy as np

        _, history = out
        h = np.asarray(history)
        assert np.all(np.diff(h) <= 1e-2 * np.abs(h[:-1]) + 1e-3), (
            f"k-means inertia increased: {h}"
        )

    return Workload(
        name=f"kmeans.n{n}.d{d}.k{k}.i{iters}",
        fn=fn,
        make_inputs=make_inputs,
        flops=float(iters * (2.0 * n * d * k + 2.0 * n * k * d)),
        bytes_moved=float(iters * n * d * 4 * 2),
        validate=validate,
        # Classic data-parallel Lloyd: points shard over rows, centers
        # replicate; the one-hot segment sums reduce with a psum per iter.
        batch_dims=(0, None),
    )


register(
    BenchmarkSpec(
        name="kmeans",
        level=2,
        dwarf="Dense linear algebra",
        domain="Data mining",
        cuda_feature=None,
        tpu_feature="one-hot matmul segment reduce",
        presets=geometric_presets(
            {"n": 4096, "d": 16, "k": 16, "iters": 5},
            scale_keys={"n": 4.0, "d": 2.0},
            round_to=8,
        ),
        build=lambda n, d, k, iters: _make(n, d, k, iters),
    )
)
