# The Mirovia/Altis benchmark suite. Importing this package registers every
# benchmark with repro.core.registry (Table I). Levels:
#   0 — device microbenchmarks (BusSpeed*, DeviceMemory, MaxFlops)
#   1 — basic parallel algorithms (GUPS, BFS, GEMM, Pathfinder, Sort)
#   2 — application kernels (CFD, DWT2D, KMeans, LavaMD, Mandelbrot, NW,
#       ParticleFilter, SRAD, Where) + the DNN section (Activation, Pooling,
#       Batchnorm, Connected, Convolution, Dropout, RNN, Softmax, LRN).

from repro.bench.level0 import devicemem, hostbus, maxflops  # noqa: F401
from repro.bench.level1 import bfs, gemm, gups, pathfinder, sort  # noqa: F401
from repro.bench.level2 import (  # noqa: F401
    cfd,
    dwt2d,
    kmeans,
    lavamd,
    mandelbrot,
    nw,
    particlefilter,
    srad,
    where,
)
from repro.bench.dnn import (  # noqa: F401
    activation,
    batchnorm,
    connected,
    convolution,
    dropout,
    lrn,
    pooling,
    rnn,
    softmax,
)
