"""Level 0: BusSpeedDownload / BusSpeedReadback.

The paper measures PCIe in both directions over 1 kB–500 kB transfers. The
TPU analogue is the host↔HBM staging path (PCIe on real pods too); in JAX the
download direction is ``jax.device_put`` of a host buffer and readback is
``np.asarray`` of a device buffer. These are deliberately *not* jitted — the
transfer itself is the benchmark (``meta={'no_jit': True}``).
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.presets import geometric_presets
from repro.core.registry import BenchmarkSpec, Workload, register


def _make_download(nbytes: int) -> Workload:
    n = nbytes // 4

    def make_inputs(seed: int):
        rng = np.random.default_rng(seed)
        return (rng.standard_normal(n, dtype=np.float32),)

    def fn(host_array):
        return jax.device_put(host_array)

    return Workload(
        name=f"busspeeddownload.n{nbytes}",
        fn=fn,
        make_inputs=make_inputs,
        bytes_moved=float(nbytes),
        # Host-bus transfers time the staging path itself; there is no
        # device computation to data-parallelize.
        batch_dims=None,
        meta={"no_jit": True},
    )


def _make_readback(nbytes: int) -> Workload:
    n = nbytes // 4

    def make_inputs(seed: int):
        # Device-resident input; fn pulls it back to host.
        key = jax.random.key(seed)
        return (jax.block_until_ready(jax.random.normal(key, (n,), jnp.float32)),)

    def fn(dev_array):
        return np.asarray(dev_array)

    return Workload(
        name=f"busspeedreadback.n{nbytes}",
        fn=fn,
        make_inputs=make_inputs,
        bytes_moved=float(nbytes),
        batch_dims=None,  # see _make_download
        meta={"no_jit": True},
    )


_PRESETS = geometric_presets(
    {"nbytes": 1 << 10}, scale_keys={"nbytes": 16.0}, round_to=4
)  # 1 KiB .. 64 MiB

register(
    BenchmarkSpec(
        name="busspeeddownload",
        level=0,
        dwarf=None,
        domain=None,
        cuda_feature=None,
        tpu_feature="host staging (device_put)",
        presets=_PRESETS,
        build=lambda nbytes: _make_download(nbytes),
    )
)

register(
    BenchmarkSpec(
        name="busspeedreadback",
        level=0,
        dwarf=None,
        domain=None,
        cuda_feature=None,
        tpu_feature="host readback (np.asarray)",
        presets=_PRESETS,
        build=lambda nbytes: _make_readback(nbytes),
    )
)
