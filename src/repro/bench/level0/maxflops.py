"""Level 0: MaxFlops — peak achievable FLOP/s.

The paper's "Half Precision" MaxFlops maps to **bf16 on the MXU**: a chain of
dependent square matmuls (so nothing is elided) at MXU-aligned sizes. The
suite reports achieved GFLOP/s; the roofline pipeline compares it against
197 TFLOP/s on the target part. fp32 variant included (VPU/precision study,
the paper's "single precision" case).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.presets import geometric_presets
from repro.core.registry import BenchmarkSpec, Workload, register
from repro.kernels import ops


def _make(n: int, chain: int, dtype: str) -> Workload:
    dt = jnp.bfloat16 if dtype == "bf16" else jnp.float32

    def make_inputs(seed: int):
        key = jax.random.key(seed)
        ka, kb = jax.random.split(key)
        scale = 1.0 / (n**0.5)  # keep the chain numerically bounded
        return (
            (jax.random.normal(ka, (n, n), jnp.float32) * scale).astype(dt),
            (jax.random.normal(kb, (n, n), jnp.float32) * scale).astype(dt),
        )

    def fn(a, b):
        def body(_, acc):
            return ops.matmul(acc, b)

        return jax.lax.fori_loop(0, chain, body, a)

    return Workload(
        name=f"maxflops.{dtype}.n{n}x{chain}",
        fn=fn,
        make_inputs=make_inputs,
        flops=2.0 * n * n * n * chain,
        bytes_moved=2.0 * n * n * jnp.dtype(dt).itemsize,
        # Data-parallel over a's rows: every chain step is (rows, n) @ (n, n)
        # with b replicated, so shards never exchange data.
        batch_dims=(0, None),
        pallas_kernel="matmul",
    )


for _dtype in ("bf16", "f32"):
    register(
        BenchmarkSpec(
            name=f"maxflops_{_dtype}",
            level=0,
            dwarf=None,
            domain=None,
            cuda_feature="Half Precision" if _dtype == "bf16" else None,
            tpu_feature="MXU bf16 peak" if _dtype == "bf16" else "VPU fp32 peak",
            presets=geometric_presets(
                {"n": 256, "chain": 4, "dtype": _dtype},
                scale_keys={"n": 2.0},
                round_to=128,
            ),
            build=functools.partial(_make),
        )
    )
