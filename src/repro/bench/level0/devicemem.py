"""Level 0: DeviceMemory — device memory-hierarchy bandwidth.

The paper measures global/constant/shared memory. The TPU hierarchy is
HBM→VMEM→VREG; we expose three streams that pin each level:

- ``stream``: y = a·x + y over N elements (HBM-bound, 3 N·4 bytes),
- ``reduce``: sum(x) (HBM read-bound, N·4 bytes),
- ``vmem``:   a VMEM-resident tile iterated k times inside one kernel-sized
  jit region (the shared-memory analogue: traffic stays on-chip after the
  first load; reported bytes count only the HBM load).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.presets import geometric_presets
from repro.core.registry import BenchmarkSpec, Workload, register


def _inputs(n: int):
    def make_inputs(seed: int):
        key = jax.random.key(seed)
        kx, ky = jax.random.split(key)
        return (
            jax.random.normal(kx, (n,), jnp.float32),
            jax.random.normal(ky, (n,), jnp.float32),
        )

    return make_inputs


def _make(n: int, op: str) -> Workload:
    if op == "stream":

        def fn(x, y):
            return 1.0001 * x + y

        flops, nbytes = 2.0 * n, 12.0 * n
    elif op == "reduce":

        def fn(x, y):
            return jnp.sum(x)

        flops, nbytes = float(n), 4.0 * n
    elif op == "vmem":
        k = 64

        def fn(x, y):
            tile = x[: 128 * 128].reshape(128, 128)

            def body(_, t):
                return t * 0.999 + 0.001

            return jax.lax.fori_loop(0, k, body, tile)

        flops, nbytes = 2.0 * 128 * 128 * k, 4.0 * 128 * 128 * 2
    else:
        raise ValueError(op)
    return Workload(
        name=f"devicemem.{op}.n{n}",
        fn=fn,
        make_inputs=_inputs(n),
        flops=flops,
        bytes_moved=nbytes,
        # stream/reduce are data-parallel over the element dim (reduce's sum
        # becomes a per-shard partial + psum). vmem opts out: the benchmark
        # is one on-chip tile sliced from x — sharding the source vector
        # would just move the tile's bytes between devices.
        batch_dims=(0, 0) if op in ("stream", "reduce") else None,
    )


for _op in ("stream", "reduce", "vmem"):
    register(
        BenchmarkSpec(
            name=f"devicemem_{_op}",
            level=0,
            dwarf=None,
            domain=None,
            cuda_feature=None,
            tpu_feature=f"memory hierarchy: {_op}",
            presets=geometric_presets(
                {"n": 1 << 16, "op": _op}, scale_keys={"n": 8.0}, round_to=128
            ),
            build=lambda n, op: _make(n, op),
        )
    )
