"""Level 1: BFS — breadth-first search (the Unified-Memory benchmark).

Control-flow-intensive graph traversal. TPU adaptation: the GPU version is a
per-thread frontier queue; the JAX idiom is *frontier-parallel edge
relaxation* — each step scatters the frontier across all edges at once
(``dst.at[...].max``) inside a ``lax.while_loop`` that runs until the
frontier empties (data-dependent trip count, the paper's "irregular
execution path" point). The §V-B unified-memory study (staged vs prefetched
host graphs) lives in ``benchmarks/feat_unified_memory.py`` on top of this
workload.

Graphs are deterministic uniform-random digraphs (the paper generates random
graphs too, and notes the resulting speedup noise).
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.presets import geometric_presets
from repro.core.registry import BenchmarkSpec, Workload, register

UNREACHED = jnp.int32(2**30)


def make_random_graph(n_nodes: int, n_edges: int, seed: int) -> tuple[np.ndarray, np.ndarray]:
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n_nodes, n_edges, dtype=np.int32)
    dst = rng.integers(0, n_nodes, n_edges, dtype=np.int32)
    return src, dst


def bfs_host_reference(n_nodes: int, src: np.ndarray, dst: np.ndarray, root: int) -> np.ndarray:
    """Plain python BFS — the oracle for tests/validate."""
    adj: list[list[int]] = [[] for _ in range(n_nodes)]
    for s, d in zip(src.tolist(), dst.tolist()):
        adj[s].append(d)
    depth = np.full(n_nodes, int(UNREACHED), dtype=np.int64)
    depth[root] = 0
    frontier = [root]
    level = 0
    while frontier:
        level += 1
        nxt = []
        for u in frontier:
            for w in adj[u]:
                if depth[w] > level:
                    depth[w] = level
                    nxt.append(w)
        frontier = nxt
    return depth


def bfs_depths(n_nodes: int, src: jax.Array, dst: jax.Array, root: int) -> jax.Array:
    """Frontier-parallel BFS: returns per-node depth (UNREACHED if not)."""
    depth0 = jnp.full((n_nodes,), UNREACHED, jnp.int32).at[root].set(0)

    def cond(state):
        depth, frontier, level = state
        return jnp.any(frontier)

    def body(state):
        depth, frontier, level = state
        active = frontier[src]  # edges whose source is on the frontier
        # Relax: any touched node gets depth level+1 if currently deeper.
        touched = jnp.zeros((n_nodes,), jnp.bool_).at[dst].max(active)
        improved = touched & (depth > level + 1)
        depth = jnp.where(improved, level + 1, depth)
        return depth, improved, level + 1

    depth, _, _ = jax.lax.while_loop(
        cond, body, (depth0, jnp.zeros((n_nodes,), jnp.bool_).at[root].set(True), jnp.int32(0))
    )
    return depth


def _make(n_nodes: int, n_edges: int) -> Workload:
    def make_inputs(seed: int):
        src, dst = make_random_graph(n_nodes, n_edges, seed)
        return (jnp.asarray(src), jnp.asarray(dst))

    def fn(src, dst):
        return bfs_depths(n_nodes, src, dst, root=0)

    def validate(out, args):
        src, dst = args
        want = bfs_host_reference(n_nodes, np.asarray(src), np.asarray(dst), 0)
        got = np.asarray(out).astype(np.int64)
        np.testing.assert_array_equal(got, want)

    return Workload(
        name=f"bfs.n{n_nodes}.e{n_edges}",
        fn=fn,
        make_inputs=make_inputs,
        flops=2.0 * n_edges,  # per level bound; reported per-call
        bytes_moved=8.0 * n_edges,
        validate=validate,
        # Opt out: the frontier state spans the whole graph and every
        # relaxation scatters across it; sharded plans fall back to
        # replicate (the ISSUE's canonical non-batchable example).
        batch_dims=None,
    )


register(
    BenchmarkSpec(
        name="bfs",
        level=1,
        dwarf="Graph traversal",
        domain=None,
        cuda_feature="Unified Memory",
        tpu_feature="host staging vs prefetch (feat_unified_memory)",
        presets=geometric_presets(
            {"n_nodes": 1 << 10, "n_edges": 1 << 13},
            scale_keys={"n_nodes": 8.0, "n_edges": 8.0},
            round_to=64,
        ),
        build=lambda n_nodes, n_edges: _make(n_nodes, n_edges),
    )
)
