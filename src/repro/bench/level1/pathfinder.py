"""Level 1: Pathfinder — shortest path down a grid (the HyperQ benchmark).

Dynamic-programming row sweep: dist'[j] = w[i,j] + min(dist[j-1..j+1]).
Irregular parallelism comes from the data-dependent min selection per lane.
TPU adaptation of HyperQ (§V-B): instead of 32 hardware work queues, idle
compute is filled by *batching independent instances* — the feature benchmark
(`benchmarks/feat_hyperq.py`) vmaps 1..32 instances of this workload through
``repro.core.features.concurrent_instances`` and reports the speedup curve
the paper's Figure shows (saturating near full occupancy).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.presets import geometric_presets
from repro.core.registry import BenchmarkSpec, Workload, register


def pathfinder_min_path(grid: jax.Array) -> jax.Array:
    """Min path cost entering anywhere in row 0, moving down (rows, cols)."""

    def step(dist, row):
        left = jnp.concatenate([dist[:1], dist[:-1]])
        right = jnp.concatenate([dist[1:], dist[-1:]])
        return row + jnp.minimum(dist, jnp.minimum(left, right)), None

    dist, _ = jax.lax.scan(step, grid[0], grid[1:])
    return dist


def _make(rows: int, cols: int) -> Workload:
    def make_inputs(seed: int):
        key = jax.random.key(seed)
        return (jax.random.randint(key, (rows, cols), 0, 10).astype(jnp.int32),)

    def validate(out, args):
        (grid,) = args
        import numpy as np

        g = np.asarray(grid)
        dist = g[0].copy()
        for i in range(1, rows):
            left = np.concatenate([dist[:1], dist[:-1]])
            right = np.concatenate([dist[1:], dist[-1:]])
            dist = g[i] + np.minimum(dist, np.minimum(left, right))
        np.testing.assert_array_equal(np.asarray(out), dist)

    return Workload(
        name=f"pathfinder.{rows}x{cols}",
        fn=pathfinder_min_path,
        make_inputs=make_inputs,
        flops=4.0 * rows * cols,
        bytes_moved=4.0 * rows * cols,
        validate=validate,
        # Opt out: rows are the sequential scan axis and each step mixes
        # neighbouring cols (halo exchange per row if sharded).
        batch_dims=None,
    )


register(
    BenchmarkSpec(
        name="pathfinder",
        level=1,
        dwarf="Dynamic programming",
        domain=None,
        cuda_feature="HyperQ",
        tpu_feature="concurrent instances via vmap (feat_hyperq)",
        presets=geometric_presets(
            {"rows": 64, "cols": 1024},
            scale_keys={"rows": 2.0, "cols": 4.0},
            round_to=16,
        ),
        build=lambda rows, cols: _make(rows, cols),
    )
)
