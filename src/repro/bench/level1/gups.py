"""Level 1: GUPS — giga-updates per second (random memory access).

Random read-modify-write over a large table. TPU adaptation: GPU GUPS uses
atomics; the JAX idiom is ``table.at[idx].add(...)`` which XLA lowers to a
sorted scatter-add — the benchmark therefore stresses the scatter path (the
TPU's weak spot that SparseCore targets on newer parts; documented in
DESIGN.md). ``derived`` reports GUPS = updates / second.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.presets import geometric_presets
from repro.core.registry import BenchmarkSpec, Workload, register


def _make(table_n: int, updates: int) -> Workload:
    def make_inputs(seed: int):
        key = jax.random.key(seed)
        kt, ki, kv = jax.random.split(key, 3)
        return (
            jax.random.normal(kt, (table_n,), jnp.float32),
            jax.random.randint(ki, (updates,), 0, table_n),
            jax.random.normal(kv, (updates,), jnp.float32),
        )

    def fn(table, idx, vals):
        return table.at[idx].add(vals)

    def validate(out, args):
        table, idx, vals = args
        assert float(jnp.sum(out) - jnp.sum(table) - jnp.sum(vals)) < 1e-1

    return Workload(
        name=f"gups.t{table_n}.u{updates}",
        fn=fn,
        make_inputs=make_inputs,
        flops=float(updates),
        bytes_moved=12.0 * updates,  # idx read + table read + table write
        validate=validate,
        # Opt out: every update may touch any table row, so sharding the
        # table (or the updates against a replicated table) turns the
        # scatter into all-to-all traffic — not data parallelism.
        batch_dims=None,
        meta={"updates": updates},
    )


register(
    BenchmarkSpec(
        name="gups",
        level=1,
        dwarf=None,
        domain=None,
        cuda_feature=None,
        tpu_feature="scatter-add path",
        presets=geometric_presets(
            {"table_n": 1 << 16, "updates": 1 << 14},
            scale_keys={"table_n": 8.0, "updates": 8.0},
            round_to=128,
        ),
        build=lambda table_n, updates: _make(table_n, updates),
    )
)
