"""Level 1: General Matrix Multiply (dense linear algebra dwarf).

The paper's GEMM covers single/double precision with and without transposed
inputs. TPU adaptation: bf16 replaces fp16/fp64 as the second precision (the
MXU's native format; fp64 has no TPU unit), and the kernel is our Pallas
blocked matmul on TPU / XLA dot on CPU.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.presets import geometric_presets
from repro.core.registry import BenchmarkSpec, Workload, register
from repro.kernels import ops


def _make(n: int, dtype: str, transpose: str) -> Workload:
    dt = {"f32": jnp.float32, "bf16": jnp.bfloat16}[dtype]

    def make_inputs(seed: int):
        key = jax.random.key(seed)
        ka, kb = jax.random.split(key)
        a = jax.random.normal(ka, (n, n), jnp.float32).astype(dt)
        b = jax.random.normal(kb, (n, n), jnp.float32).astype(dt)
        return (a, b)

    def fn(a, b):
        if "t" in transpose[:1]:  # "tn"/"tt": transpose A
            a = a.T
        if transpose[1:] == "t":
            b = b.T
        return ops.matmul(a, b)

    # "nn" is data-parallel over a's rows (b replicated, output row-sharded,
    # no collectives). The transposed variants opt out: a.T turns a's leading
    # dim into the contraction dim, which is reduction- not data-parallelism.
    batch_dims = (0, None) if transpose == "nn" else None
    return Workload(
        name=f"gemm.{dtype}.{transpose}.n{n}",
        fn=fn,
        make_inputs=make_inputs,
        flops=2.0 * n**3,
        bytes_moved=3.0 * n * n * jnp.dtype(dt).itemsize,
        batch_dims=batch_dims,
        pallas_kernel="matmul",
    )


for _dtype in ("f32", "bf16"):
    for _tr in ("nn", "tn"):
        register(
            BenchmarkSpec(
                name=f"gemm_{_dtype}_{_tr}",
                level=1,
                dwarf="Dense linear algebra",
                domain=None,
                cuda_feature=None,
                tpu_feature="MXU blocked matmul (Pallas)",
                presets=geometric_presets(
                    {"n": 256, "dtype": _dtype, "transpose": _tr},
                    scale_keys={"n": 2.0},
                    round_to=128,
                ),
                build=lambda n, dtype, transpose: _make(n, dtype, transpose),
            )
        )
