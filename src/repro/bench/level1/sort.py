"""Level 1: Sort — key-value sort (radix sort in the paper).

TPU adaptation (DESIGN.md §2): radix sort's histogram+scatter inner loop is
gather/scatter-bound, hostile to the TPU vector unit; the kernel here is a
**bitonic network of reshape-swap compare-exchanges** (zero gathers, full
lane utilization) at O(n log² n) — `repro.kernels.bitonic_sort`. The suite
workload sorts uint keys carrying payload values, validated against
``jnp.argsort``.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.presets import geometric_presets
from repro.core.registry import BenchmarkSpec, Workload, register
from repro.kernels import ops


def _make(n: int) -> Workload:
    def make_inputs(seed: int):
        key = jax.random.key(seed)
        kk, kv = jax.random.split(key)
        keys = jax.random.randint(kk, (n,), 0, 1 << 30, dtype=jnp.int32)
        vals = jax.random.randint(kv, (n,), 0, 1 << 30, dtype=jnp.int32)
        return (keys, vals)

    def fn(keys, vals):
        return ops.sort_kv(keys, vals)

    def validate(out, args):
        keys, vals = args
        ko, vo = out
        ko, vo = np.asarray(ko), np.asarray(vo)
        assert np.all(np.diff(ko) >= 0), "keys not sorted"
        # Same multiset of (key, value) pairs.
        got = np.sort(np.stack([ko, vo]), axis=1)
        want = np.sort(np.stack([np.asarray(keys), np.asarray(vals)]), axis=1)
        np.testing.assert_array_equal(got, want)

    log2n = max(1, int(np.ceil(np.log2(n))))
    return Workload(
        name=f"sort.n{n}",
        fn=fn,
        make_inputs=make_inputs,
        flops=float(n * log2n * (log2n + 1) / 2),  # compare-exchanges
        bytes_moved=16.0 * n,
        validate=validate,
        # Opt out: bitonic stages compare-exchange across the full array
        # (global reshape-swaps), so there is no independent batch dim.
        batch_dims=None,
        pallas_kernel="sort_kv",
    )


register(
    BenchmarkSpec(
        name="sort",
        level=1,
        dwarf="Sorting",
        domain=None,
        cuda_feature=None,
        tpu_feature="bitonic reshape-swap network (Pallas)",
        presets=geometric_presets(
            {"n": 1 << 12}, scale_keys={"n": 8.0}, round_to=128
        ),
        build=lambda n: _make(n),
    )
)
