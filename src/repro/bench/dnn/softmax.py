"""DNN: Softmax — classifier output layer fwd/bwd (paper eq. 2)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.bench.dnn.common import dnn_workload
from repro.core.presets import geometric_presets
from repro.core.registry import DNN_DOMAIN, BenchmarkSpec, register
from repro.kernels import ops


def _make(batch: int, classes: int):
    def make_inputs(seed: int):
        return (
            5.0 * jax.random.normal(jax.random.key(seed), (batch, classes), jnp.float32),
        )

    def fn(x):
        return ops.softmax(x)

    def validate(out, args):
        import numpy as np

        o = np.asarray(out)
        np.testing.assert_allclose(o.sum(-1), 1.0, rtol=1e-5)
        assert np.all(o >= 0)

    numel = float(batch * classes)
    return dnn_workload(
        f"softmax.{batch}x{classes}",
        fn,
        make_inputs,
        flops=numel * 5,
        bytes_moved=numel * 8,
        validate=validate,
        pallas_kernel="softmax",
    )


register(
    BenchmarkSpec(
        name="softmax",
        level=2,
        dwarf="Unstructured Grid",
        domain=DNN_DOMAIN,
        cuda_feature=None,
        tpu_feature="online-softmax kernel (Pallas)",
        presets=geometric_presets(
            {"batch": 128, "classes": 1024},
            scale_keys={"batch": 4.0, "classes": 2.0},
            round_to=64,
        ),
        build=lambda batch, classes: _make(batch, classes),
    )
)
