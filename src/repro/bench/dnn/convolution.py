"""DNN: Convolution — 2-D conv fwd/bwd.

Two paths, both benchmarked:

- ``xla``: `lax.conv_general_dilated` (the cuDNN analogue — XLA's native
  convolution, which on TPU lowers to MXU convolutions),
- ``im2col``: explicit im2col + Pallas blocked matmul — the TPU-native
  expression of "convolution as GEMM" the paper's
  `maxwell_scudnn_128x128_relu_*` kernels embody on GPU; validated against
  the XLA path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.bench.dnn.common import dnn_workload
from repro.core.presets import geometric_presets
from repro.core.registry import DNN_DOMAIN, BenchmarkSpec, register
from repro.kernels import ops


def conv2d_xla(x, w):
    """x (N, C, H, W), w (O, C, KH, KW), VALID padding, stride 1."""
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="VALID",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )


def conv2d_im2col(x, w):
    n, c, h, wd = x.shape
    o, _, kh, kw = w.shape
    oh, ow = h - kh + 1, wd - kw + 1
    # Patches: (N, OH, OW, C*KH*KW) via static strided slices.
    cols = jnp.stack(
        [
            x[:, :, i : i + oh, j : j + ow]
            for i in range(kh)
            for j in range(kw)
        ],
        axis=2,
    )  # (N, C, KH*KW, OH, OW)
    cols = cols.reshape(n, c * kh * kw, oh * ow)
    wmat = w.reshape(o, c * kh * kw)
    out = jax.vmap(lambda col: ops.matmul(wmat, col))(cols)  # (N, O, OH*OW)
    return out.reshape(n, o, oh, ow)


def _make(n: int, c: int, hw: int, o: int, k: int, impl: str):
    def make_inputs(seed: int):
        key = jax.random.key(seed)
        kx, kw = jax.random.split(key)
        s = (c * k * k) ** -0.5
        return (
            jax.random.normal(kx, (n, c, hw, hw), jnp.float32),
            s * jax.random.normal(kw, (o, c, k, k), jnp.float32),
        )

    fn = conv2d_im2col if impl == "im2col" else conv2d_xla

    def validate(out, args):
        import numpy as np

        want = conv2d_xla(*args)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(want), rtol=2e-4, atol=2e-4
        )

    oh = hw - k + 1
    flops = 2.0 * n * o * c * k * k * oh * oh
    return dnn_workload(
        f"convolution.{impl}.{n}x{c}x{hw}.o{o}k{k}",
        fn,
        make_inputs,
        flops=flops,
        bytes_moved=4.0 * (n * c * hw * hw + o * c * k * k + n * o * oh * oh),
        validate=validate,
        # Only the im2col variant routes through the kernel layer; the xla
        # variant is lax.conv by definition (this spec's own `impl` preset
        # key is the conv algorithm, orthogonal to the plan's impl axis).
        pallas_kernel="matmul" if impl == "im2col" else None,
    )


for _impl in ("xla", "im2col"):
    register(
        BenchmarkSpec(
            name=f"convolution_{_impl}",
            level=2,
            dwarf="Dense linear algebra",
            domain=DNN_DOMAIN,
            cuda_feature=None,
            tpu_feature="conv-as-GEMM on MXU" if _impl == "im2col" else "XLA native conv",
            presets=geometric_presets(
                {"n": 4, "c": 16, "hw": 32, "o": 16, "k": 3, "impl": _impl},
                scale_keys={"n": 2.0, "c": 2.0, "o": 2.0},
                round_to=4,
            ),
            build=lambda n, c, hw, o, k, impl: _make(n, c, hw, o, k, impl),
        )
    )
