"""DNN: Dropout — stochastic regularization fwd/bwd (paper: dropout_fp/bp).

JAX's counter-based threefry PRNG generates the mask inside the kernel (no
mask tensor round-trip — the memory optimization cuDNN's dropout_fp does
with Philox on GPU).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.bench.dnn.common import dnn_workload
from repro.core.presets import geometric_presets
from repro.core.registry import DNN_DOMAIN, BenchmarkSpec, register

RATE = 0.5


def dropout(x, key):
    keep = jax.random.bernoulli(key, 1.0 - RATE, x.shape)
    return jnp.where(keep, x / (1.0 - RATE), 0.0)


def _make(n: int, d: int):
    shape = (n, d)

    def make_inputs(seed: int):
        key = jax.random.key(seed)
        kx, kd = jax.random.split(key)
        return (jax.random.normal(kx, shape, jnp.float32), kd)

    def validate(out, args):
        import numpy as np

        x, _ = args
        o, xv = np.asarray(out), np.asarray(x)
        kept = o != 0
        frac = kept.mean()
        assert abs(frac - (1 - RATE)) < 0.05, f"keep fraction {frac}"
        np.testing.assert_allclose(o[kept], xv[kept] / (1 - RATE), rtol=1e-6)

    numel = float(n * d)
    return dnn_workload(
        f"dropout.{n}x{d}",
        dropout,
        make_inputs,
        flops=numel * 2,
        bytes_moved=numel * 8,
        validate=validate,
        diff_argnums=(0,),
    )


register(
    BenchmarkSpec(
        name="dropout",
        level=2,
        dwarf="Unstructured Grid",
        domain=DNN_DOMAIN,
        cuda_feature=None,
        tpu_feature="in-kernel counter-based PRNG",
        presets=geometric_presets(
            {"n": 256, "d": 1024}, scale_keys={"n": 4.0, "d": 2.0}, round_to=64
        ),
        build=lambda n, d: _make(n, d),
    )
)
