"""DNN: Batchnorm — training-mode batch normalization fwd/bwd.

The paper identifies BN as memory-bound (low FP-unit utilization, few
eligible warps) vs convolution's compute-bound profile — our roofline terms
reproduce that classification (see benchmarks/table2_dnn_kernels.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.bench.dnn.common import dnn_workload
from repro.core.presets import geometric_presets
from repro.core.registry import DNN_DOMAIN, BenchmarkSpec, register

EPS = 1e-5


def batchnorm_train(x, gamma, beta):
    """NCHW batch norm over (N, H, W) per channel."""
    mean = jnp.mean(x, axis=(0, 2, 3), keepdims=True)
    var = jnp.var(x, axis=(0, 2, 3), keepdims=True)
    xhat = (x - mean) * jax.lax.rsqrt(var + EPS)
    return xhat * gamma[None, :, None, None] + beta[None, :, None, None]


def _make(n: int, c: int, hw: int):
    shape = (n, c, hw, hw)

    def make_inputs(seed: int):
        key = jax.random.key(seed)
        kx, kg, kb = jax.random.split(key, 3)
        return (
            jax.random.normal(kx, shape, jnp.float32),
            1.0 + 0.1 * jax.random.normal(kg, (c,), jnp.float32),
            0.1 * jax.random.normal(kb, (c,), jnp.float32),
        )

    def validate(out, args):
        import numpy as np

        x, gamma, beta = args
        o = np.asarray(out)
        # Normalized-then-affine: per-channel mean≈beta, std≈gamma.
        np.testing.assert_allclose(
            o.mean(axis=(0, 2, 3)), np.asarray(beta), atol=1e-4
        )
        np.testing.assert_allclose(
            o.std(axis=(0, 2, 3)), np.abs(np.asarray(gamma)), rtol=1e-3, atol=1e-4
        )

    numel = float(n * c * hw * hw)
    return dnn_workload(
        f"batchnorm.{n}x{c}x{hw}x{hw}",
        batchnorm_train,
        make_inputs,
        flops=numel * 8,
        bytes_moved=numel * 4 * 3,
        validate=validate,
    )


register(
    BenchmarkSpec(
        name="batchnorm",
        level=2,
        dwarf="Unstructured Grid",
        domain=DNN_DOMAIN,
        cuda_feature=None,
        tpu_feature=None,
        presets=geometric_presets(
            {"n": 8, "c": 16, "hw": 32}, scale_keys={"n": 2.0, "c": 2.0}, round_to=4
        ),
        build=lambda n, c, hw: _make(n, c, hw),
    )
)
