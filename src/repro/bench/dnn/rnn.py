"""DNN: RNN — LSTM layer fwd/bwd over a sequence (paper: LSTM via cuDNN).

One fused-gate LSTM (the 4-gate projection is a single matmul, the
`maxwell_sgemm_128x64_tn` of Table II) scanned over time with `lax.scan`.
The scan is also the structural template for the model zoo's recurrent
blocks (xLSTM sLSTM, Mamba decode).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.bench.dnn.common import dnn_workload
from repro.core.presets import geometric_presets
from repro.core.registry import DNN_DOMAIN, BenchmarkSpec, register


def lstm_forward(x, wx, wh, b):
    """x (B, T, D); wx (D, 4H); wh (H, 4H); b (4H,) -> outputs (B, T, H)."""
    B = x.shape[0]
    H = wh.shape[0]

    def cell(carry, xt):
        h, c = carry
        gates = xt @ wx + h @ wh + b[None]
        i, f, g, o = jnp.split(gates, 4, axis=-1)
        i, f, o = jax.nn.sigmoid(i), jax.nn.sigmoid(f), jax.nn.sigmoid(o)
        g = jnp.tanh(g)
        c = f * c + i * g
        h = o * jnp.tanh(c)
        return (h, c), h

    init = (jnp.zeros((B, H), x.dtype), jnp.zeros((B, H), x.dtype))
    _, hs = jax.lax.scan(cell, init, jnp.swapaxes(x, 0, 1))
    return jnp.swapaxes(hs, 0, 1)


def _make(batch: int, seq: int, d: int, h: int):
    def make_inputs(seed: int):
        key = jax.random.key(seed)
        kx, kwx, kwh, kb = jax.random.split(key, 4)
        return (
            jax.random.normal(kx, (batch, seq, d), jnp.float32),
            d**-0.5 * jax.random.normal(kwx, (d, 4 * h), jnp.float32),
            h**-0.5 * jax.random.normal(kwh, (h, 4 * h), jnp.float32),
            jnp.zeros((4 * h,), jnp.float32),
        )

    def validate(out, args):
        import numpy as np

        o = np.asarray(out)
        assert o.shape == (batch, seq, h)
        assert np.all(np.isfinite(o))
        assert np.all(np.abs(o) <= 1.0)  # h = o·tanh(c) is bounded

    flops = 2.0 * batch * seq * (d + h) * 4 * h
    return dnn_workload(
        f"rnn.lstm.b{batch}.t{seq}.d{d}.h{h}",
        lstm_forward,
        make_inputs,
        flops=flops,
        bytes_moved=4.0 * (batch * seq * (d + h) + (d + h) * 4 * h),
        validate=validate,
    )


register(
    BenchmarkSpec(
        name="rnn",
        level=2,
        dwarf="Dense linear algebra",
        domain=DNN_DOMAIN,
        cuda_feature=None,
        tpu_feature="fused-gate scan",
        presets=geometric_presets(
            {"batch": 16, "seq": 32, "d": 128, "h": 128},
            scale_keys={"batch": 2.0, "d": 2.0, "h": 2.0},
            round_to=32,
        ),
        build=lambda batch, seq, d, h: _make(batch, seq, d, h),
    )
)
