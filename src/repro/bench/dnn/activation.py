"""DNN: Activation — ReLU forward/backward (paper eq. 1)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.bench.dnn.common import dnn_workload
from repro.core.presets import geometric_presets
from repro.core.registry import DNN_DOMAIN, BenchmarkSpec, register


def _make(n: int, c: int, hw: int):
    shape = (n, c, hw, hw)

    def make_inputs(seed: int):
        return (jax.random.normal(jax.random.key(seed), shape, jnp.float32),)

    def fn(x):
        return jax.nn.relu(x)

    def validate(out, args):
        import numpy as np

        (x,) = args
        np.testing.assert_array_equal(np.asarray(out), np.maximum(np.asarray(x), 0))

    numel = float(jnp.prod(jnp.array(shape)))
    return dnn_workload(
        f"activation.relu.{n}x{c}x{hw}x{hw}",
        fn,
        make_inputs,
        flops=numel,
        bytes_moved=numel * 8,
        validate=validate,
    )


register(
    BenchmarkSpec(
        name="activation",
        level=2,
        dwarf="Unstructured Grid",
        domain=DNN_DOMAIN,
        cuda_feature=None,
        tpu_feature=None,
        presets=geometric_presets(
            {"n": 8, "c": 16, "hw": 32}, scale_keys={"n": 2.0, "c": 2.0}, round_to=4
        ),
        build=lambda n, c, hw: _make(n, c, hw),
    )
)
