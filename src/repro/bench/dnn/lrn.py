"""DNN: LRN — local response normalization fwd/bwd (paper eq. 3).

Forward runs the banded-matmul Pallas kernel on TPU (`kernels.lrn`); the
oracle cross-check keeps the two in lockstep.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.bench.dnn.common import dnn_workload
from repro.core.presets import geometric_presets
from repro.core.registry import DNN_DOMAIN, BenchmarkSpec, register
from repro.kernels import ops, ref


def _make(n: int, c: int, hw: int):
    shape = (n, c, hw, hw)

    def make_inputs(seed: int):
        return (jax.random.normal(jax.random.key(seed), shape, jnp.float32),)

    def fn(x):
        return ops.lrn(x)

    def validate(out, args):
        import numpy as np

        (x,) = args
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref.lrn_ref(x)), rtol=1e-4, atol=1e-5
        )

    numel = float(n * c * hw * hw)
    return dnn_workload(
        f"lrn.{n}x{c}x{hw}x{hw}",
        fn,
        make_inputs,
        flops=numel * (2 * c + 6),  # banded matmul dominates
        bytes_moved=numel * 8,
        validate=validate,
        pallas_kernel="lrn",
    )


register(
    BenchmarkSpec(
        name="lrn",
        level=2,
        dwarf="Unstructured Grid",
        domain=DNN_DOMAIN,
        cuda_feature=None,
        tpu_feature="banded matmul on MXU (Pallas)",
        presets=geometric_presets(
            {"n": 8, "c": 32, "hw": 16}, scale_keys={"n": 2.0, "c": 2.0}, round_to=4
        ),
        build=lambda n, c, hw: _make(n, c, hw),
    )
)
