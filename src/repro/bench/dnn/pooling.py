"""DNN: Pooling — average pooling fwd/bwd (paper: cuDNN avg pool).

Forward uses the Pallas reshape-reduce kernel on TPU (`kernels.avgpool`);
backward is the uniform-spread gradient (each input gets grad/k²).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.bench.dnn.common import dnn_workload
from repro.core.presets import geometric_presets
from repro.core.registry import DNN_DOMAIN, BenchmarkSpec, register
from repro.kernels import ops, ref


def _make(n: int, c: int, hw: int, ksize: int):
    shape = (n, c, hw, hw)

    def make_inputs(seed: int):
        return (jax.random.normal(jax.random.key(seed), shape, jnp.float32),)

    def fn(x):
        return ops.avgpool(x, ksize=ksize)

    def validate(out, args):
        import numpy as np

        (x,) = args
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref.avgpool_ref(x, ksize=ksize)), rtol=1e-5
        )

    numel = float(n * c * hw * hw)
    return dnn_workload(
        f"pooling.avg{ksize}.{n}x{c}x{hw}x{hw}",
        fn,
        make_inputs,
        flops=numel,
        bytes_moved=numel * 4 * (1 + 1 / ksize**2),
        validate=validate,
        pallas_kernel="avgpool",
    )


register(
    BenchmarkSpec(
        name="pooling",
        level=2,
        dwarf="Dense linear algebra",
        domain=DNN_DOMAIN,
        cuda_feature=None,
        tpu_feature="reshape-reduce kernel (Pallas)",
        presets=geometric_presets(
            {"n": 8, "c": 16, "hw": 32, "ksize": 2},
            scale_keys={"n": 2.0, "c": 2.0},
            round_to=4,
        ),
        build=lambda n, c, hw, ksize: _make(n, c, hw, ksize),
    )
)
