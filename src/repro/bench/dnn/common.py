"""Shared scaffolding for the DNN-section benchmarks (paper §IV-A.4).

Every DNN benchmark reports both passes (Figs. 3 and 4): ``fn`` is the layer
forward; ``fn_bwd`` computes the gradient of a scalar loss (mean of outputs)
w.r.t. every floating-point input — the cuDNN *Backward kernels of Table II
compute exactly these input/weight gradients. Backward FLOPs default to the
standard 2× forward.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.registry import Workload

__all__ = ["dnn_workload"]


def _mean_of_outputs(out) -> jax.Array:
    leaves = [l for l in jax.tree_util.tree_leaves(out) if jnp.issubdtype(l.dtype, jnp.floating)]
    return sum(jnp.mean(l.astype(jnp.float32)) for l in leaves)


def dnn_workload(
    name: str,
    fn: Callable,
    make_inputs: Callable[[int], tuple],
    *,
    flops: float,
    bytes_moved: float,
    flops_bwd: float | None = None,
    validate: Callable | None = None,
    diff_argnums: tuple[int, ...] | None = None,
    batch_dims: tuple[int | None, ...] | None = None,
    pallas_kernel: str | None = None,
) -> Workload:
    def loss(*args):
        return _mean_of_outputs(fn(*args))

    if diff_argnums is None or batch_dims is None:
        # Arity/dtype inspection only: abstract evaluation builds no arrays.
        sample = jax.eval_shape(lambda: make_inputs(0))
    if diff_argnums is None:
        # Differentiate w.r.t. every floating-point positional arg.
        diff_argnums = tuple(
            i
            for i, a in enumerate(sample)
            if hasattr(a, "dtype") and jnp.issubdtype(a.dtype, jnp.floating)
        )
    grad_fn = jax.grad(loss, argnums=diff_argnums) if diff_argnums else None
    # Every DNN layer is data-parallel over the example/batch dim of its
    # activation input (arg 0); weights and keys replicate. Both passes
    # shard the same way — gradients of replicated weights psum over the
    # batch shards, exactly DP training's gradient all-reduce.
    if batch_dims is None:
        batch_dims = (0,) + (None,) * (len(sample) - 1)
    return Workload(
        name=name,
        fn=fn,
        make_inputs=make_inputs,
        flops=flops,
        bytes_moved=bytes_moved,
        validate=validate,
        fn_bwd=grad_fn,
        flops_bwd=flops_bwd if flops_bwd is not None else 2.0 * flops,
        batch_dims=batch_dims,
        pallas_kernel=pallas_kernel,
        meta={"dnn": True},
    )
