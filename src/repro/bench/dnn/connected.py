"""DNN: Connected — fully-connected layer fwd/bwd (cuDNN sgemm analogue).

Forward is x@W+b on the Pallas matmul kernel (TPU) — the paper's Table II
maps this layer to `maxwell_sgemm_128x64_tn`; ours maps to the MXU blocked
matmul.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.bench.dnn.common import dnn_workload
from repro.core.presets import geometric_presets
from repro.core.registry import DNN_DOMAIN, BenchmarkSpec, register
from repro.kernels import ops


def _make(batch: int, din: int, dout: int):
    def make_inputs(seed: int):
        key = jax.random.key(seed)
        kx, kw, kb = jax.random.split(key, 3)
        s = din**-0.5
        return (
            jax.random.normal(kx, (batch, din), jnp.float32),
            s * jax.random.normal(kw, (din, dout), jnp.float32),
            s * jax.random.normal(kb, (dout,), jnp.float32),
        )

    def fn(x, w, b):
        return ops.matmul(x, w) + b[None]

    def validate(out, args):
        import numpy as np

        x, w, b = args
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(x) @ np.asarray(w) + np.asarray(b),
            rtol=2e-4, atol=2e-5,
        )

    return dnn_workload(
        f"connected.b{batch}.{din}x{dout}",
        fn,
        make_inputs,
        flops=2.0 * batch * din * dout,
        bytes_moved=4.0 * (batch * din + din * dout + batch * dout),
        validate=validate,
        pallas_kernel="matmul",
    )


register(
    BenchmarkSpec(
        name="connected",
        level=2,
        dwarf="Dense linear algebra",
        domain=DNN_DOMAIN,
        cuda_feature=None,
        tpu_feature="MXU blocked matmul (Pallas)",
        presets=geometric_presets(
            {"batch": 64, "din": 256, "dout": 256},
            scale_keys={"batch": 2.0, "din": 2.0, "dout": 2.0},
            round_to=64,
        ),
        build=lambda batch, din, dout: _make(batch, din, dout),
    )
)
