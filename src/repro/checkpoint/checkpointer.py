"""Async, atomic, keep-k checkpointing.

Design points for the 1000-node regime (DESIGN.md §9):

- **Per-leaf addressable format**: every pytree leaf is one raw-bytes file
  (``dtype``/``shape`` in the manifest) — restore cost scales with the local
  shard a host needs, not the global model; bf16 round-trips losslessly
  (raw bytes + ml_dtypes, no numpy-format dependence).
- **Atomicity**: writes land in ``<dir>/.tmp.<step>`` and are ``os.replace``d
  into ``step_<N>`` only after the manifest fsyncs — a crash mid-save never
  corrupts the latest complete checkpoint.
- **Async**: ``save`` snapshots device arrays to host (blocking only on
  D2H), then a daemon thread does the file I/O; ``wait()`` joins before the
  next save or process exit.
- **Keep-k**: old steps are pruned after a successful save, never before.
- **Exact resume**: the data-pipeline cursor and the RNG key are part of the
  payload, so ``--resume`` reproduces the exact step sequence (tested
  bit-for-bit in tests/test_checkpoint.py).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import numpy as np

import jax

import ml_dtypes  # noqa: F401  (registers bfloat16 etc. with numpy)

__all__ = ["Checkpointer"]

_SEP = "/"


def _flatten(tree) -> tuple[list[tuple[str, Any]], Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    items = []
    for path, leaf in flat:
        key = _SEP.join(_path_str(p) for p in path)
        items.append((key, leaf))
    return items, treedef


def _path_str(entry) -> str:
    if hasattr(entry, "key"):
        return str(entry.key)
    if hasattr(entry, "idx"):
        return str(entry.idx)
    if hasattr(entry, "name"):
        return str(entry.name)
    return str(entry)


class Checkpointer:
    def __init__(self, directory: str, *, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    # -- save ---------------------------------------------------------------
    def save(self, step: int, payload: Any, *, blocking: bool = False) -> None:
        """Snapshot payload (any pytree of arrays / scalars) at ``step``."""
        self.wait()
        items, _ = _flatten(payload)
        host_items = [
            (k, np.asarray(jax.device_get(v)) if hasattr(v, "dtype") else v)
            for k, v in items
        ]

        def _write():
            tmp = os.path.join(self.directory, f".tmp.{step}")
            final = os.path.join(self.directory, f"step_{step:010d}")
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            manifest = {"step": step, "leaves": {}}
            for i, (key, val) in enumerate(host_items):
                if isinstance(val, np.ndarray):
                    fname = f"leaf_{i:05d}.bin"
                    with open(os.path.join(tmp, fname), "wb") as f:
                        f.write(val.tobytes())
                    manifest["leaves"][key] = {
                        "file": fname,
                        "dtype": str(val.dtype),
                        "shape": list(val.shape),
                    }
                else:
                    manifest["leaves"][key] = {"value": val}
            mpath = os.path.join(tmp, "manifest.json")
            with open(mpath, "w") as f:
                json.dump(manifest, f, indent=1, sort_keys=True)
                f.flush()
                os.fsync(f.fileno())
            if os.path.exists(final):
                shutil.rmtree(final)
            os.replace(tmp, final)
            self._prune()

        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _prune(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:010d}"), ignore_errors=True)

    # -- restore --------------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and os.path.exists(
                os.path.join(self.directory, name, "manifest.json")
            ):
                out.append(int(name[5:]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template: Any, step: int | None = None) -> tuple[int, Any]:
        """Restore into the structure of ``template`` (shapes/dtypes checked).

        Returns (step, payload). Sharded targets: pass a template of arrays
        with the desired sharding; values are device_put against it — this is
        the elastic-re-mesh path (restore under a *different* mesh).
        """
        self.wait()
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no checkpoints in {self.directory}")
        d = os.path.join(self.directory, f"step_{step:010d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        items, treedef = _flatten(template)
        leaves = []
        for key, tmpl in items:
            if key not in manifest["leaves"]:
                raise KeyError(f"checkpoint {d} missing leaf {key!r}")
            meta = manifest["leaves"][key]
            if "value" in meta:
                leaves.append(meta["value"])
                continue
            with open(os.path.join(d, meta["file"]), "rb") as f:
                arr = np.frombuffer(f.read(), dtype=np.dtype(meta["dtype"]))
            arr = arr.reshape(meta["shape"])
            if hasattr(tmpl, "shape") and tuple(tmpl.shape) != tuple(arr.shape):
                raise ValueError(
                    f"leaf {key!r}: checkpoint shape {arr.shape} != template {tmpl.shape}"
                )
            if hasattr(tmpl, "sharding"):
                arr = jax.device_put(arr, tmpl.sharding)
            leaves.append(arr)
        return step, jax.tree_util.tree_unflatten(treedef, leaves)
