# Fault-tolerance substrate: asynchronous, atomic, keep-k checkpointing of
# (params, optimizer state, data cursor, rng) with exact-resume semantics.

from repro.checkpoint.checkpointer import Checkpointer  # noqa: F401
