"""The unified suite runner — a thin CLI over the staged execution engine.

``run_suite`` is what `examples/run_suite.py` and `python -m repro.core.suite`
invoke. Since the plan/engine refactor it only *assembles* an
:class:`~repro.core.plan.ExecutionPlan` (selection by level / name / tag /
domain, preset + overrides, passes, iters/warmup, device placement and
scaling sweep) and hands it to the module-level
:class:`~repro.core.engine.Engine`, which owns the stage sequence (build →
place → compile → measure → characterize → report), the compile-once cache
shared by every caller in the process, and per-benchmark fault isolation.
Output is the paper's Fig.-5-style table plus a machine-readable JSON report
and/or a streaming JSONL report with run metadata.

Placement flags: ``--placement {replicate,shard}`` picks what multi-device
runs put on each device; ``--scale-devices 1,2,4`` sweeps the selection
across device counts, producing one record per (benchmark, pass, count)
with ``scaling_efficiency`` on the multi-device rows.

Serving flags: ``--serve {open,closed}`` runs every selected workload
under generated load after measuring it (``--qps`` open-loop arrival rate,
``--concurrency`` closed-loop in-flight cap, ``--lanes`` dispatch lanes,
``--serve-duration`` seconds); ``--serve-client {single,threaded}`` picks
the host issue architecture (one thread for all lanes vs one issuing
thread per lane, with dispatch-overhead and per-lane QPS columns);
``--slo-us`` adds a latency SLO and the ``goodput_qps`` column;
``--colocate NAME`` serves each workload against a partner benchmark and
records both tenants' slowdown vs their isolated baselines.
``--cache-dir`` persists compile artifacts across processes — two tiers:
serialized executables (warm runs skip tracing AND XLA compilation — the
zero-compile warm start) over lowered HLO text (skips retracing only);
the CLI always prints the cache's hit/fallback/skip summary so a cache
that never hits is visible.

Timing flags: sync-mode timing (synchronize every call) always runs and
fills ``us_per_call``; ``--timing-window K`` (default 4; 1 disables)
additionally measures with K calls in flight per synchronization, riding
async dispatch, filling ``us_per_call_windowed`` and the derived per-call
dispatch overhead — the accurate-kernel-time story for small kernels on
an async runtime.

Implementation flags: ``--impl {xla,pallas}`` picks which lowering to
compile and time — the lax/XLA path (default) or the hand-written Pallas
kernel for workloads that declare one (others fall back to xla with the
reason recorded in the row); ``--tune`` sweeps each kernel's block/grid
tune space before compiling and times the winner (the winning config
persists in ``--cache-dir``, so a warm tuned run performs zero trials
and zero compiles).

Batching flags (mixed-shape serving): ``--serve-mix`` gives each open-loop
request a shape drawn from a weighted preset/override distribution;
``--serve-dispatch {lanes,loop,batched,dynamic}`` picks how requests map
onto device programs (``dynamic`` is the continuous batcher, coalescing
compatible requests into the largest vmapped bucket that fits under
``--batch-latency-budget`` microseconds, padding — measured as
``padding_waste`` — up to ``--max-batch``); ``--serve-trace PATH`` saves
the generated arrival+shape stream as replayable JSONL, or replays it
verbatim when the file already exists.
"""

from __future__ import annotations

import argparse
import sys
from typing import Any, Mapping, Sequence

from repro.core.engine import Engine
from repro.obs import Tracer
from repro.core.plan import (
    IMPLS,
    PLACEMENT_MODES,
    SERVE_CLIENTS,
    SERVE_DISPATCH,
    SERVE_MODES,
    ExecutionPlan,
    Placement,
    PlanError,
    ServeSpec,
    ShapeBucket,
)
from repro.core.results import BenchmarkRecord, to_csv_lines

__all__ = ["run_suite", "main", "DEFAULT_ENGINE"]

# Shared across run_suite callers (figure drivers, examples, tests) so a
# workload compiled for one section is reused by every later section.
DEFAULT_ENGINE = Engine()

_EPILOG = """\
examples:
  # open-loop serving: pathfinder at 200 QPS through 4 lanes for 3 s
  python -m repro.core.suite --names pathfinder --serve open --qps 200 \\
      --lanes 4 --serve-duration 3
  # threaded client: one issuing thread per lane, so host-side dispatch
  # contention is measured (dispatch_us column) instead of hidden
  python -m repro.core.suite --names gemm_f32_nn --serve closed \\
      --concurrency 8 --lanes 4 --serve-client threaded
  # co-location interference: gemm and kmeans share the lanes; both rows
  # carry slowdown-vs-isolated
  python -m repro.core.suite --names gemm_f32_nn --serve closed \\
      --concurrency 8 --lanes 4 --colocate kmeans
  # mixed-shape continuous batching: 2/3 of requests at preset 0, 1/3 at
  # preset 0 with cols=256, coalesced by the dynamic batcher into vmapped
  # buckets of up to 8 under a 2 ms wait budget
  python -m repro.core.suite --names pathfinder --serve open --qps 500 \\
      --serve-mix "0@2,0/cols=256@1" --serve-dispatch dynamic \\
      --batch-latency-budget 2000 --max-batch 8
  # trace-driven replay: the first run saves the arrival+shape stream,
  # later runs (any --serve-dispatch) replay the identical trace
  python -m repro.core.suite --names pathfinder --serve open --qps 500 \\
      --serve-mix "0@2,1@1" --serve-trace /tmp/mix.jsonl --serve-dispatch loop
  # distributed load generation: 4 client processes, each replaying its
  # own seeded sub-schedule, merged percentiles + per-process QPS in the
  # row; the shared cache dir makes the warm run zero-compile everywhere
  python -m repro.core.suite --names pathfinder --serve open --qps 400 \\
      --client-procs 4 --cache-dir /tmp/repro-cache
  # structured tracing: every engine stage, serve request, and batcher
  # flush becomes a span in a Chrome trace-event file
  python -m repro.core.suite --names gemm_f32_nn --serve closed \\
      --concurrency 8 --lanes 4 --trace-out run.trace.json

reading the trace in Perfetto:
  open https://ui.perfetto.dev (or chrome://tracing) and load the
  --trace-out file. The "engine" process holds one track of stage spans
  (build / place / tune / compile / measure / characterize / serve) with
  bench + impl attributes on each; the "serve" process has one named
  track per dispatch lane carrying request enqueue->complete events; the
  "batcher" process has one track per shape-bucket queue whose batch[N]
  spans carry width / filled / cause (full | expired | flush). Or skim it
  from the terminal: python tools/trace_report.py run.trace.json

serving semantics:
  open-loop rows report offered_qps (the target arrival rate); a schedule
  cut short at its request cap additionally carries truncated=1, so the
  row never claims a load it did not offer. --slo-us S adds goodput_qps,
  the rate of completions with latency <= S microseconds (a request at
  exactly the SLO counts as good); without an SLO, goodput == achieved.
  The threaded client splits the arrival process into per-lane Poisson
  sub-schedules from seeded child RNGs: the merged stream still offers
  the target QPS and is deterministic for a fixed --seed.

distributed serving (--client-procs N):
  the same SeedSequence split, applied across *processes*: process k of N
  replays sub-schedule k of an N-way split of the target load, so the
  merged arrival stream is Poisson at --qps and byte-identical per --seed
  (replayable via the serve-trace JSONL format), while load generation
  scales past one Python process's dispatch ceiling — the point where
  adding processes stops raising sustained QPS is the measured ceiling.
  Merged percentiles are computed over the *concatenation* of the
  per-process completion streams on one shared clock epoch — identical,
  by construction and by test, to the percentiles of a single stream —
  and rows carry client_procs plus per-process proc_qps. Each client
  process compiles through the shared --cache-dir, so a warm distributed
  run performs zero XLA compiles in every process (asserted from the
  "# dist-cache" stderr line next to "# hlocache:").

batching semantics:
  --serve-mix is a comma-separated list of PRESET[/PARAM=VALUE...][@WEIGHT]
  buckets (weights default 1 and are normalized); each request's bucket is
  drawn from its own seeded stream, so the arrival process is identical
  with and without a mix. The engine precompiles one vmapped executable
  per (bucket, batch width) through the compile cache AND --cache-dir, so
  a warm run restores every bucket with zero XLA compiles. The dynamic
  batcher dispatches a bucket's queue when it can fill --max-batch, or
  when its oldest request has waited --batch-latency-budget microseconds —
  a partial batch is padded up to the smallest compiled width that holds
  it. Padding is measured, not hidden: rows carry batch_occupancy
  (filled/dispatched slots) and padding_waste (padded/dispatched slots,
  = 1 - occupancy), plus per-bucket p50/p95/p99 in bucket_latency_us.
  Latency is stamped from the scheduled arrival, so time spent waiting in
  a coalescing queue counts toward latency and goodput.

static contracts:
  the invariants this suite depends on (Workload batch_dims/pallas_kernel
  declarations, cache-key completeness, _timed_stage coverage, the
  zero-overhead hot-loop rule, record-schema stability, serve/obs lock
  discipline) are enforced by `python -m repro.check` — stdlib-ast only,
  no JAX needed, wired into CI as the lint job and locally via
  `tools/smoke.sh --check`. See `python -m repro.check --help` for rule
  ids and the per-line suppression comment.
"""


def run_suite(
    *,
    levels: Sequence[int] = (0, 1, 2),
    names: Sequence[str] | None = None,
    tags: Sequence[str] | None = None,
    domains: Sequence[str] | None = None,
    preset: int = 0,
    overrides: Mapping[str, Mapping[str, Any]] | None = None,
    iters: int = 5,
    warmup: int = 2,
    include_backward: bool = True,
    seed: int = 0,
    timing_window: int | None = None,
    devices: int = 1,
    placement: str = "replicate",
    scale_devices: Sequence[int] | None = None,
    serve: ServeSpec | None = None,
    impl: str = "xla",
    tune: bool = False,
    report_path: str | None = None,
    jsonl_path: str | None = None,
    verbose: bool = True,
    engine: Engine | None = None,
) -> list[BenchmarkRecord]:
    plan_kwargs: dict[str, Any] = {}
    if timing_window is not None:  # None = the plan's default window
        plan_kwargs["timing_window"] = timing_window
    plan = ExecutionPlan(
        levels=tuple(levels),
        names=tuple(names) if names is not None else None,
        tags=tuple(tags) if tags is not None else None,
        domains=tuple(domains) if domains is not None else None,
        preset=preset,
        overrides=overrides or {},
        include_backward=include_backward,
        iters=iters,
        warmup=warmup,
        seed=seed,
        placement=Placement(devices=devices, mode=placement),
        device_sweep=tuple(scale_devices) if scale_devices is not None else None,
        serve=serve,
        impl=impl,
        tune=tune,
        **plan_kwargs,
    )
    result = (engine or DEFAULT_ENGINE).run(
        plan, report_path=report_path, jsonl_path=jsonl_path, verbose=verbose
    )
    return result.records


def _parse_overrides(items: Sequence[str]) -> dict[str, dict[str, Any]]:
    """``name.param=value`` CLI overrides -> {name: {param: value}}."""
    out: dict[str, dict[str, Any]] = {}
    for item in items:
        try:
            target, value = item.split("=", 1)
            name, param = target.rsplit(".", 1)
        except ValueError:
            raise SystemExit(f"bad --override {item!r}; expected name.param=value")
        try:
            parsed: Any = int(value)
        except ValueError:
            try:
                parsed = float(value)
            except ValueError:
                parsed = value
        out.setdefault(name, {})[param] = parsed
    return out


def _parse_scale_devices(text: str | None) -> tuple[int, ...] | None:
    """``"1,2,4"`` -> (1, 2, 4)."""
    if text is None:
        return None
    try:
        counts = tuple(int(part) for part in text.split(",") if part.strip())
    except ValueError:
        raise SystemExit(
            f"bad --scale-devices {text!r}; expected comma-separated ints, e.g. 1,2,4"
        )
    if not counts:
        raise SystemExit(f"bad --scale-devices {text!r}; no device counts given")
    return counts


def _parse_mix(text: str) -> tuple[ShapeBucket, ...]:
    """``"0@2,0/cols=256@1"`` -> weighted ShapeBuckets.

    Grammar per comma-separated bucket: ``PRESET[/PARAM=VALUE...][@WEIGHT]``
    (weight defaults to 1.0; values parse as int, then float, then str —
    the --override convention).
    """

    def parse_value(value: str) -> Any:
        try:
            return int(value)
        except ValueError:
            try:
                return float(value)
            except ValueError:
                return value

    buckets = []
    for part in text.split(","):
        part = part.strip()
        if not part:
            continue
        weight = 1.0
        if "@" in part:
            part, w = part.rsplit("@", 1)
            try:
                weight = float(w)
            except ValueError:
                raise SystemExit(
                    f"bad --serve-mix weight {w!r} in {text!r}; expected a number"
                )
        fields = part.split("/")
        try:
            preset = int(fields[0])
        except ValueError:
            raise SystemExit(
                f"bad --serve-mix bucket {part!r} in {text!r}; expected "
                "PRESET[/PARAM=VALUE...][@WEIGHT], e.g. 0@2,1/cols=256@1"
            )
        overrides = []
        for field in fields[1:]:
            if "=" not in field:
                raise SystemExit(
                    f"bad --serve-mix override {field!r} in {text!r}; "
                    "expected PARAM=VALUE"
                )
            k, v = field.split("=", 1)
            overrides.append((k, parse_value(v)))
        buckets.append(
            ShapeBucket(preset=preset, weight=weight, overrides=tuple(overrides))
        )
    if not buckets:
        raise SystemExit(f"bad --serve-mix {text!r}; no buckets given")
    return tuple(buckets)


def _parse_serve(args) -> ServeSpec | None:
    """A ServeSpec when any serving flag was used (--colocate alone
    implies a closed-loop serve), else None. Serve-tuning flags without a
    serve mode are a configuration error, not silently dropped."""
    tuning = {
        "--qps": args.qps,
        "--concurrency": args.concurrency,
        "--lanes": args.lanes,
        "--serve-duration": args.serve_duration,
        "--serve-client": args.serve_client,
        "--slo-us": args.slo_us,
        "--serve-dispatch": args.serve_dispatch,
        "--serve-mix": args.serve_mix,
        "--serve-trace": args.serve_trace,
        "--batch-latency-budget": args.batch_latency_budget,
        "--max-batch": args.max_batch,
        "--client-procs": args.client_procs,
    }
    if args.serve is None and args.colocate is None:
        stray = [flag for flag, value in tuning.items() if value is not None]
        if stray:
            raise PlanError(
                f"{', '.join(stray)} require --serve {{open,closed}} "
                "or --colocate NAME"
            )
        return None
    spec = ServeSpec()  # defaults live on the dataclass, not the CLI
    return ServeSpec(
        mode=args.serve or "closed",
        qps=args.qps if args.qps is not None else 50.0,
        concurrency=(
            args.concurrency if args.concurrency is not None else spec.concurrency
        ),
        lanes=args.lanes if args.lanes is not None else spec.lanes,
        duration_s=(
            args.serve_duration
            if args.serve_duration is not None
            else spec.duration_s
        ),
        colocate=args.colocate,
        client=args.serve_client if args.serve_client is not None else spec.client,
        slo_us=args.slo_us,
        dispatch=(
            args.serve_dispatch
            if args.serve_dispatch is not None
            else spec.dispatch
        ),
        mix=_parse_mix(args.serve_mix) if args.serve_mix is not None else None,
        trace=args.serve_trace,
        batch_budget_us=(
            args.batch_latency_budget
            if args.batch_latency_budget is not None
            else spec.batch_budget_us
        ),
        max_batch=args.max_batch if args.max_batch is not None else spec.max_batch,
        client_procs=(
            args.client_procs
            if args.client_procs is not None
            else spec.client_procs
        ),
    )


def main(argv: Sequence[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        description="Run the Mirovia/Altis suite",
        epilog=_EPILOG,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    ap.add_argument("--levels", type=int, nargs="*", default=[0, 1, 2])
    ap.add_argument("--names", type=str, nargs="*", default=None)
    ap.add_argument("--tags", type=str, nargs="*", default=None)
    ap.add_argument("--domains", type=str, nargs="*", default=None)
    ap.add_argument("--preset", type=int, default=0)
    ap.add_argument("--override", action="append", default=[],
                    metavar="NAME.PARAM=VALUE",
                    help="Rodinia-style size override, repeatable")
    ap.add_argument("--iters", type=int, default=5)
    ap.add_argument("--warmup", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--timing-window", type=int, default=None, metavar="K",
                    help="windowed timing: K calls in flight per "
                         "synchronization alongside the sync-mode number "
                         "(default 4; 1 = sync-only)")
    ap.add_argument("--devices", type=int, default=1,
                    help="run on the first N devices")
    ap.add_argument("--placement", choices=PLACEMENT_MODES, default="replicate",
                    help="what multi-device runs put on each device: full "
                         "copies (replicate) or batch_dims-partitioned "
                         "inputs (shard)")
    ap.add_argument("--scale-devices", type=str, default=None,
                    metavar="N1,N2,...",
                    help="device-scaling sweep, e.g. 1,2,4,8: one record "
                         "per (benchmark, pass, count)")
    ap.add_argument("--serve", choices=SERVE_MODES, default=None,
                    help="serve each selected workload under load after "
                         "measuring it: open-loop arrivals at --qps or "
                         "closed-loop at --concurrency")
    ap.add_argument("--qps", type=float, default=None,
                    help="open-loop arrival rate (requests/s, default 50)")
    ap.add_argument("--concurrency", type=int, default=None,
                    help="closed-loop in-flight requests (also the "
                         "open-loop in-flight cap; default 4)")
    ap.add_argument("--lanes", type=int, default=None,
                    help="dispatch lanes (HyperQ-style work queues, "
                         "default 2)")
    ap.add_argument("--serve-duration", type=float, default=None,
                    metavar="SECONDS",
                    help="serving duration per workload (default 2.0)")
    ap.add_argument("--serve-client", choices=SERVE_CLIENTS, default=None,
                    help="host issue architecture: 'single' dispatches "
                         "every lane from one thread (default); 'threaded' "
                         "gives each lane its own issuing thread and "
                         "records dispatch overhead + per-lane QPS")
    ap.add_argument("--slo-us", type=float, default=None, metavar="US",
                    help="latency SLO in microseconds; rows gain "
                         "goodput_qps (completions with latency <= SLO "
                         "per second; latency == SLO counts as good)")
    ap.add_argument("--client-procs", type=int, default=None, metavar="N",
                    help="distributed load generation: spawn N client "
                         "processes, each replaying a seeded per-process "
                         "sub-schedule (the merged stream is still Poisson "
                         "at --qps, byte-identical per --seed) and "
                         "streaming completion stamps back for merged "
                         "percentiles; requires --serve open. Rows carry "
                         "client_procs and per-process proc_qps; share "
                         "--cache-dir so a warm run compiles nothing in "
                         "any process")
    ap.add_argument("--serve-dispatch", choices=SERVE_DISPATCH, default=None,
                    help="how requests map onto device programs: classic "
                         "N-lane dispatch (lanes, default), or the mixed-"
                         "shape paths — sync per-request (loop), fixed-"
                         "width vmap that waits to fill (batched), or the "
                         "continuous batcher (dynamic)")
    ap.add_argument("--serve-mix", type=str, default=None,
                    metavar="P[/K=V...][@W],...",
                    help="weighted request-shape mix for open-loop serving, "
                         "e.g. '0@2,1@1' or '0@3,0/cols=256@1'; per-request "
                         "buckets are drawn from a seeded stream so the mix "
                         "is deterministic per --seed (see batching "
                         "semantics below)")
    ap.add_argument("--serve-trace", type=str, default=None, metavar="PATH",
                    help="replayable JSONL arrival+shape trace: replayed "
                         "verbatim when PATH exists, else the generated "
                         "schedule is saved there for later runs to replay")
    ap.add_argument("--batch-latency-budget", type=float, default=None,
                    metavar="US",
                    help="dynamic batcher wait budget in microseconds "
                         "(default 2000): a partial batch dispatches — "
                         "padded, and the padding measured — once its "
                         "oldest request has waited this long")
    ap.add_argument("--max-batch", type=int, default=None, metavar="N",
                    help="largest batch width (default 8); the dynamic "
                         "batcher compiles power-of-two widths up to N per "
                         "bucket, --serve-dispatch batched uses exactly N")
    ap.add_argument("--impl", choices=IMPLS, default="xla",
                    help="implementation to compile and time: the lax/XLA "
                         "lowering (xla, default) or the hand-written "
                         "Pallas kernel (pallas) for workloads that declare "
                         "one — others fall back to xla with the reason in "
                         "the row (interpret mode on non-TPU hosts, flagged "
                         "impl_interpret)")
    ap.add_argument("--tune", action="store_true",
                    help="sweep each Pallas kernel's block/grid tune space "
                         "before compiling (windowed-timer trials); the "
                         "winner joins the record (tuned_params) and "
                         "persists in --cache-dir so warm runs skip the "
                         "sweep entirely")
    ap.add_argument("--colocate", type=str, default=None, metavar="NAME",
                    help="co-locate every served workload with this "
                         "benchmark and record slowdown-vs-isolated "
                         "(implies --serve closed)")
    ap.add_argument("--cache-dir", type=str, default=None,
                    help="persist compile artifacts here (serialized "
                         "executables + lowered HLO text, keyed by compile-"
                         "cache key, versioned by jax/jaxlib/backend/"
                         "topology) so warm runs skip retracing and XLA "
                         "compilation entirely; a CI accelerator — warm-run "
                         "timings include a thin dispatch wrapper")
    ap.add_argument("--no-backward", action="store_true")
    ap.add_argument("--report", type=str, default=None, help="JSON report path")
    ap.add_argument("--jsonl", type=str, default=None,
                    help="streaming JSONL report path (with run metadata)")
    ap.add_argument("--trace-out", type=str, default=None, metavar="PATH",
                    help="write a Chrome trace-event JSON file (load in "
                         "https://ui.perfetto.dev or chrome://tracing, or "
                         "summarize with tools/trace_report.py): engine "
                         "stage spans plus per-lane serve requests and "
                         "per-queue batcher flushes as separate tracks")
    args = ap.parse_args(argv)
    tracer = Tracer() if args.trace_out else None
    # Engine(cache_dir=...) also points jax's own persistent compilation
    # cache at the directory, so input-builder compiles warm too.
    engine = (
        Engine(cache_dir=args.cache_dir, tracer=tracer)
        if (args.cache_dir or tracer is not None)
        else None
    )
    try:
        records = _run_cli(args, engine)
    except (PlanError, ValueError) as e:
        # Bad selection / placement / device count: a configuration error,
        # not a crash — exit 2 (the benchmarks/run.py --sections convention)
        # telling the operator what this host actually has.
        import jax

        print(f"error: {e}", file=sys.stderr)
        print(
            f"available devices: {jax.device_count()} "
            f"(backend={jax.default_backend()})",
            file=sys.stderr,
        )
        return 2
    for line in to_csv_lines(records):
        print(line)
    if engine is not None and engine.disk_cache is not None:
        # A disk cache that never hits is otherwise invisible from the
        # CLI: always say what it did, and why warm loads fell back.
        print(f"# {engine.disk_cache.summary()}", file=sys.stderr)
    if tracer is not None:
        n = tracer.export_chrome(args.trace_out)
        print(
            f"# trace: {n} spans -> {args.trace_out} "
            "(load in https://ui.perfetto.dev or chrome://tracing; "
            "summarize with tools/trace_report.py)",
            file=sys.stderr,
        )
    errors = [r for r in records if r.status != "ok"]
    for r in errors:
        print(f"# ERROR {r.name}: {r.error}", file=sys.stderr)
    return 1 if errors else 0


def _run_cli(args, engine: Engine | None = None) -> list[BenchmarkRecord]:
    return run_suite(
        levels=args.levels,
        names=args.names,
        tags=args.tags,
        domains=args.domains,
        preset=args.preset,
        overrides=_parse_overrides(args.override),
        iters=args.iters,
        warmup=args.warmup,
        seed=args.seed,
        timing_window=args.timing_window,
        devices=args.devices,
        placement=args.placement,
        scale_devices=_parse_scale_devices(args.scale_devices),
        serve=_parse_serve(args),
        impl=args.impl,
        tune=args.tune,
        include_backward=not args.no_backward,
        report_path=args.report,
        jsonl_path=args.jsonl,
        verbose=False,
        engine=engine,
    )


if __name__ == "__main__":
    sys.exit(main())
