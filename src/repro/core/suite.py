"""The unified suite runner — SHOC-style driver over the whole registry.

``run_suite`` is what `examples/run_suite.py` and `python -m repro.core.suite`
invoke: select benchmarks (by level / name), pick a preset (or per-benchmark
size overrides), then for each benchmark time the forward (and backward where
defined) pass and collect the static roofline characterization. Output is the
paper's Fig.-5-style table plus a machine-readable JSON report.
"""

from __future__ import annotations

import argparse
import sys
from typing import Sequence

from repro.core.harness import compile_workload, time_workload
from repro.core.registry import BenchmarkSpec, all_benchmarks
from repro.core.results import BenchmarkRecord, to_csv_lines, write_report

__all__ = ["run_suite", "main"]


def run_suite(
    *,
    levels: Sequence[int] = (0, 1, 2),
    names: Sequence[str] | None = None,
    preset: int = 0,
    iters: int = 5,
    warmup: int = 2,
    include_backward: bool = True,
    report_path: str | None = None,
    verbose: bool = True,
) -> list[BenchmarkRecord]:
    records: list[BenchmarkRecord] = []
    selected: list[BenchmarkSpec] = [
        s
        for s in all_benchmarks()
        if s.level in levels and (names is None or s.name in names)
    ]
    if not selected:
        raise ValueError(f"no benchmarks match levels={levels} names={names}")
    for spec in selected:
        p = preset if preset in spec.presets else min(spec.presets)
        workload = spec.build_preset(p)
        passes = [False] + ([True] if include_backward and workload.fn_bwd else [])
        for backward in passes:
            timing = time_workload(workload, iters=iters, warmup=warmup, backward=backward)
            compiled = compile_workload(workload, backward=backward)
            rec = BenchmarkRecord.from_measurement(spec, p, timing, compiled)
            records.append(rec)
            if verbose:
                print(rec.csv(), flush=True)
    if report_path:
        write_report(records, report_path)
    return records


def main(argv: Sequence[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description="Run the Mirovia/Altis suite")
    ap.add_argument("--levels", type=int, nargs="*", default=[0, 1, 2])
    ap.add_argument("--names", type=str, nargs="*", default=None)
    ap.add_argument("--preset", type=int, default=0)
    ap.add_argument("--iters", type=int, default=5)
    ap.add_argument("--warmup", type=int, default=2)
    ap.add_argument("--no-backward", action="store_true")
    ap.add_argument("--report", type=str, default=None)
    args = ap.parse_args(argv)
    records = run_suite(
        levels=args.levels,
        names=args.names,
        preset=args.preset,
        iters=args.iters,
        warmup=args.warmup,
        include_backward=not args.no_backward,
        report_path=args.report,
    )
    for line in to_csv_lines(records):
        print(line)
    return 0


if __name__ == "__main__":
    sys.exit(main())
