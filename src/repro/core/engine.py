"""Staged execution engine: build → compile → measure → characterize → report.

The imperative half of the plan/engine split (``core/plan.py`` holds the
declarative half). For every selected benchmark the engine runs the stages:

- **build**: instantiate the workload from the spec at the plan's preset
  (plus Rodinia-style overrides) and materialize its inputs; with
  ``plan.devices > 1`` inputs are replicated onto a data mesh
  (``runtime/sharding.data_mesh`` / ``replicate``) before compilation.
- **compile**: lower + compile through an in-process cache keyed on
  ``(name, preset, overrides, backward, backend, devices)`` so each
  workload is compiled **exactly once per pass** — the same executable
  feeds both the timer and the static analysis (the seed compiled twice:
  once in ``time_workload``, again in ``compile_workload``).
- **measure**: validate the first output, then time the compiled
  executable (``harness.time_fn``).
- **characterize**: static cost/memory/roofline analysis of the cached
  executable, computed once and memoized alongside it.
- **report**: a :class:`BenchmarkRecord`, streamed to the JSONL writer as
  it is produced.

Failures are isolated per benchmark: an exception in any stage yields an
``status="error"`` record naming the stage and the suite keeps going.

Adding a stage = add an ``_stage_name`` method, call it in ``_run_pass``
between its neighbours, and extend the record (see ROADMAP.md §Execution
engine).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax

from repro.core.harness import (
    CompiledInfo,
    characterize_compiled,
    empty_compiled_info,
    time_fn,
    timing_from_stats,
)
from repro.core.plan import ExecutionPlan
from repro.core.registry import BenchmarkSpec, Workload
from repro.core.results import (
    BenchmarkRecord,
    JsonlReportWriter,
    RunMetadata,
    write_report,
)

__all__ = ["CompileCache", "Engine", "RunResult"]

# (name, preset, frozen-overrides, backward, backend, devices)
CacheKey = tuple[str, int, tuple, bool, str, int]


@dataclasses.dataclass
class _CacheEntry:
    executable: Callable[..., Any]
    info: CompiledInfo | None = None  # memoized by the characterize stage


class CompileCache:
    """In-process compiled-executable cache with hit/miss counters."""

    def __init__(self) -> None:
        self._entries: dict[CacheKey, _CacheEntry] = {}
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._entries)

    def peek(self, key: CacheKey) -> _CacheEntry | None:
        """Lookup without counting a hit (callers count on actual use)."""
        return self._entries.get(key)

    def lookup(self, key: CacheKey, build: Callable[[], _CacheEntry]) -> _CacheEntry:
        entry = self._entries.get(key)
        if entry is not None:
            self.hits += 1
            return entry
        # Count the miss only after a successful build so a failing compile
        # retried later is not double-counted as two compilations.
        entry = build()
        self.misses += 1
        self._entries[key] = entry
        return entry

    def clear(self) -> None:
        self._entries.clear()


@dataclasses.dataclass
class RunResult:
    records: list[BenchmarkRecord]
    metadata: RunMetadata
    cache: CompileCache

    @property
    def ok_records(self) -> list[BenchmarkRecord]:
        return [r for r in self.records if r.status == "ok"]

    @property
    def error_records(self) -> list[BenchmarkRecord]:
        return [r for r in self.records if r.status != "ok"]


class Engine:
    """Executes plans. Holds the compile cache, so a long-lived engine

    (e.g. the module-level one behind ``run_suite``) reuses executables
    across runs, sections, and figure drivers within one process.
    """

    def __init__(self, cache: CompileCache | None = None) -> None:
        self.cache = cache if cache is not None else CompileCache()

    # -- stages ------------------------------------------------------------

    def _cache_key(
        self, spec: BenchmarkSpec, plan: ExecutionPlan, preset: int, backward: bool
    ) -> CacheKey:
        return (
            spec.name,
            preset,
            tuple(sorted(plan.overrides_for(spec.name).items())),
            backward,
            jax.default_backend(),
            plan.devices,
        )

    def _stage_build(
        self, spec: BenchmarkSpec, plan: ExecutionPlan, preset: int
    ) -> tuple[Workload, tuple]:
        workload = spec.build_preset(preset, **plan.overrides_for(spec.name))
        return workload, self._make_args(workload, plan)

    def _make_args(self, workload: Workload, plan: ExecutionPlan) -> tuple:
        args = workload.make_inputs(plan.seed)
        if plan.devices > 1 and not workload.meta.get("no_jit"):
            from repro.runtime.sharding import data_mesh, replicate

            args = replicate(args, data_mesh(plan.devices))
        return args

    def _stage_compile(
        self,
        spec: BenchmarkSpec,
        workload: Workload,
        args: tuple,
        plan: ExecutionPlan,
        preset: int,
        backward: bool,
    ) -> _CacheEntry:
        fn = workload.fn_bwd if backward else workload.fn
        if backward and fn is None:
            raise ValueError(f"workload {workload.name!r} has no backward pass")
        key = self._cache_key(spec, plan, preset, backward)

        def build() -> _CacheEntry:
            if workload.meta.get("no_jit"):
                # Host-transfer workloads time the un-jitted staging path and
                # have no device program to analyse.
                return _CacheEntry(
                    executable=fn,
                    info=empty_compiled_info(_pass_name(workload, backward)),
                )
            return _CacheEntry(executable=jax.jit(fn).lower(*args).compile())

        return self.cache.lookup(key, build)

    def _stage_measure(
        self,
        workload: Workload,
        entry: _CacheEntry,
        args: tuple,
        plan: ExecutionPlan,
        backward: bool,
    ):
        out = jax.block_until_ready(entry.executable(*args))
        if not backward and workload.validate is not None:
            workload.validate(out, args)
        mean, stdev = time_fn(
            entry.executable, args, iters=plan.iters, warmup=plan.warmup
        )
        return timing_from_stats(
            workload, mean_us=mean, stdev_us=stdev, iters=plan.iters, backward=backward
        )

    def _stage_characterize(
        self, workload: Workload, entry: _CacheEntry, backward: bool
    ) -> CompiledInfo:
        if entry.info is None:
            entry.info = characterize_compiled(
                entry.executable, _pass_name(workload, backward)
            )
        return entry.info

    def characterize(
        self,
        spec: BenchmarkSpec,
        plan: ExecutionPlan,
        *,
        backward: bool = False,
        workload: Workload | None = None,
    ) -> CompiledInfo:
        """Compile (through the cache) + characterize, without timing.

        For characterization-only consumers (Table II, dry-run style flows):
        shares executables with full runs of the same plan parameters. A
        warm cache with memoized analysis returns without building the
        workload or its inputs; pass ``workload`` to reuse one already built.
        """
        preset = plan.resolve_preset(spec)
        cached = self.cache.peek(self._cache_key(spec, plan, preset, backward))
        if cached is not None and cached.info is not None:
            self.cache.hits += 1
            return cached.info
        if workload is None:
            workload = spec.build_preset(preset, **plan.overrides_for(spec.name))
        args = self._make_args(workload, plan)
        entry = self._stage_compile(spec, workload, args, plan, preset, backward)
        return self._stage_characterize(workload, entry, backward)

    # -- orchestration -----------------------------------------------------

    def run(
        self,
        plan: ExecutionPlan,
        *,
        report_path: str | None = None,
        jsonl_path: str | None = None,
        verbose: bool = False,
    ) -> RunResult:
        specs = plan.select()
        if plan.devices > jax.device_count():
            raise ValueError(
                f"plan requests {plan.devices} devices but only "
                f"{jax.device_count()} available"
            )
        metadata = RunMetadata.capture(preset=plan.preset, devices=plan.devices)
        writer = JsonlReportWriter(jsonl_path, metadata) if jsonl_path else None
        records: list[BenchmarkRecord] = []

        def emit(rec: BenchmarkRecord) -> None:
            records.append(rec)
            if writer is not None:
                writer.write(rec)
            if verbose:
                print(rec.csv(), flush=True)

        try:
            for spec in specs:
                for rec in self._run_benchmark(spec, plan):
                    emit(rec)
        finally:
            if writer is not None:
                writer.close()
        if report_path:
            write_report(records, report_path)
        return RunResult(records=records, metadata=metadata, cache=self.cache)

    def _run_benchmark(
        self, spec: BenchmarkSpec, plan: ExecutionPlan
    ) -> list[BenchmarkRecord]:
        preset = plan.resolve_preset(spec)
        try:
            workload, args = self._stage_build(spec, plan, preset)
        except Exception as e:  # noqa: BLE001 — fault isolation is the contract
            return [
                BenchmarkRecord.from_error(
                    spec, preset, stage="build", error=_err_text(e)
                )
            ]
        out: list[BenchmarkRecord] = []
        for backward in plan.passes(workload):
            out.append(
                self._run_pass(spec, workload, args, plan, preset, backward)
            )
        return out

    def _run_pass(
        self,
        spec: BenchmarkSpec,
        workload: Workload,
        args: tuple,
        plan: ExecutionPlan,
        preset: int,
        backward: bool,
    ) -> BenchmarkRecord:
        stage = "compile"
        try:
            entry = self._stage_compile(spec, workload, args, plan, preset, backward)
            stage = "measure"
            timing = self._stage_measure(workload, entry, args, plan, backward)
            stage = "characterize"
            info = self._stage_characterize(workload, entry, backward)
            return BenchmarkRecord.from_measurement(spec, preset, timing, info)
        except Exception as e:  # noqa: BLE001 — fault isolation is the contract
            return BenchmarkRecord.from_error(
                spec, preset, stage=stage, error=_err_text(e), backward=backward
            )


def _pass_name(workload: Workload, backward: bool) -> str:
    return workload.name + (".bwd" if backward else "")


def _err_text(e: BaseException, limit: int = 500) -> str:
    # Collapse whitespace: error records land in one-line CSV/JSONL rows.
    text = " ".join(f"{type(e).__name__}: {e}".split())
    return text if len(text) <= limit else text[: limit - 3] + "..."
