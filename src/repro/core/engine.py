"""Staged execution engine: build → place → [tune] → compile → measure →
characterize → report.

The imperative half of the plan/engine split (``core/plan.py`` holds the
declarative half). For every selected benchmark the engine runs the stages:

- **build**: instantiate the workload from the spec at the plan's preset
  (plus Rodinia-style overrides) and materialize its inputs.
- **place**: realize the plan's :class:`~repro.core.plan.Placement` on a
  data mesh (``runtime/sharding``): ``replicate`` device_puts every input
  on all devices; ``shard`` partitions inputs along the workload's
  declared ``batch_dims`` (non-batchable workloads fall back to replicate
  and the record says so). Single-device runs pre-commit host-side inputs
  with ``harness.commit_args`` — one ``device_put`` before any loop, so
  neither the timer nor the serve stage ever pays per-call H2D transfer
  (``no_jit`` host-transfer workloads opt out: staging *is* their
  measurement).
- **tune** (only for ``impl="pallas"`` plans with ``tune=True``): sweep
  the declared kernel's ``tune_space()`` block/grid candidates, compiling
  each through the same cache and timing it with the windowed timer; the
  winner's params join the compile-cache key and persist in the HLO disk
  cache next to the executable, so a warm ``--tune`` run restores the
  winner and performs **zero trials and zero compiles**.
- **compile**: lower + compile through an in-process cache keyed on
  ``(name, preset, overrides, backward, backend, devices, placement,
  impl, tuned-params)`` so each workload is compiled **exactly once per
  (pass, placement, implementation)** — the sharded and replicated (and
  xla and pallas) lowerings are distinct executables, and the same
  executable feeds both the timer and the static analysis. The plan's
  ``impl`` axis resolves per workload (a pallas plan falls back to xla
  for workloads with no declared ``pallas_kernel``, recorded in
  ``impl_fallback``) and is realized by tracing under
  ``kernels.ops.force_impl`` — the kernel-vs-oracle choice is baked into
  the lowering, not dispatched per call.
- **measure**: validate the first output, then time the compiled
  executable (``harness.time_fn``) in sync mode (``us_per_call``, the
  comparable number) and — when ``plan.timing_window > 1`` — in windowed
  mode (``us_per_call_windowed``: K calls in flight per synchronization,
  riding async dispatch; the difference is the derived per-call dispatch
  overhead).
- **characterize**: static cost/memory/roofline analysis of the cached
  executable, computed once and memoized alongside it.
- **serve** (only when the plan carries a
  :class:`~repro.core.plan.ServeSpec`): run the *same cached executable*
  under generated load through ``repro.serve`` — open-loop arrivals at a
  target QPS or closed-loop at fixed concurrency, dispatched across N
  lanes by the spec's client (``single``: every lane issued from this
  thread; ``threaded``: one issuing thread per lane with per-lane
  deterministic sub-schedules and dispatch-overhead accounting) — and
  fold latency percentiles / achieved QPS / truncation honesty into the
  record.
  With ``colocate``, the workload is additionally served against a
  partner benchmark on split lanes and both rows carry their p50
  slowdown vs the isolated baseline. With a ``ServeSpec.mix`` of
  weighted :class:`~repro.core.plan.ShapeBucket`\\ s, arrivals are
  stamped with seeded bucket labels (or replayed from a saved JSONL
  trace) and the stage precompiles one vmapped executable per
  (bucket, batch-width) through the ordinary compile cache *and* the
  disk cache — warm runs restore every bucket with zero XLA compiles —
  then routes per bucket (``loop``/``lanes``/``batched``) or coalesces
  compatible requests under a latency budget (``dynamic``,
  ``serve/batcher.py``), recording occupancy / padding waste /
  per-bucket percentiles. Outside a mix, serving never compiles
  anything the measure stage didn't already put in the cache (the
  partner's own entry aside), and a sharded plan serves the sharded
  lowering.
- **report**: a :class:`BenchmarkRecord` carrying ``devices`` /
  ``placement`` / ``scaling_efficiency`` (plus the serve columns above),
  streamed to the JSONL writer as it is produced.

``run()`` iterates ``plan.device_sweep`` (ascending), re-running the
selection at each device count against the shared cache; multi-device rows
carry ``scaling_efficiency`` — speedup over the same run's 1-device row,
divided by the device count.

Failures are isolated per benchmark: an exception in any stage yields an
``status="error"`` record naming the stage and the suite keeps going.

Adding a stage = add an ``_stage_name`` method, call it in ``_run_pass``
between its neighbours, and extend the record (see ROADMAP.md §Execution
engine).
"""

from __future__ import annotations

import contextlib
import dataclasses
import os
import time
from typing import Any, Callable

import jax

from repro.core.harness import (
    CompiledInfo,
    characterize_compiled,
    commit_args,
    empty_compiled_info,
    time_fn,
    timing_from_stats,
)
from repro.core.hlocache import HloDiskCache
from repro.core.plan import ExecutionPlan, Placement, PlanError, ServeSpec
from repro.core.registry import BenchmarkSpec, Workload, get_benchmark
from repro.core.results import (
    BenchmarkRecord,
    JsonlReportWriter,
    RunMetadata,
    write_report,
)
from repro.obs import NULL_TRACER, NullTracer, Tracer, use_tracer

__all__ = ["CompileCache", "Engine", "RunResult", "SweepStat"]

# (name, preset, frozen-overrides, backward, backend, devices, placement,
#  impl, frozen-tuned-params). Mixed-shape serving appends ("vmap", width)
# for batch widths > 1 — a bucket's width-1 program at the plan's own
# preset/overrides shares the measure stage's key (and its executable).
CacheKey = tuple[str, int, tuple, bool, str, int, str, str, tuple]


@dataclasses.dataclass
class _CacheEntry:
    executable: Callable[..., Any]
    info: CompiledInfo | None = None  # memoized by the characterize stage


class CompileCache:
    """In-process compiled-executable cache with hit/miss counters."""

    def __init__(self) -> None:
        self._entries: dict[CacheKey, _CacheEntry] = {}
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._entries)

    def peek(self, key: CacheKey) -> _CacheEntry | None:
        """Lookup without counting a hit (callers count on actual use)."""
        return self._entries.get(key)

    def lookup(self, key: CacheKey, build: Callable[[], _CacheEntry]) -> _CacheEntry:
        entry = self._entries.get(key)
        if entry is not None:
            self.hits += 1
            return entry
        # Count the miss only after a successful build so a failing compile
        # retried later is not double-counted as two compilations.
        entry = build()
        self.misses += 1
        self._entries[key] = entry
        return entry

    def clear(self) -> None:
        self._entries.clear()


@dataclasses.dataclass(frozen=True)
class SweepStat:
    """Cache traffic of one device-sweep step (scaling-run diagnostics)."""

    devices: int
    misses: int
    hits: int


@dataclasses.dataclass
class RunResult:
    records: list[BenchmarkRecord]
    metadata: RunMetadata
    cache: CompileCache
    sweep_stats: list[SweepStat] = dataclasses.field(default_factory=list)

    @property
    def ok_records(self) -> list[BenchmarkRecord]:
        return [r for r in self.records if r.status == "ok"]

    @property
    def error_records(self) -> list[BenchmarkRecord]:
        return [r for r in self.records if r.status != "ok"]


class Engine:
    """Executes plans. Holds the compile cache, so a long-lived engine

    (e.g. the module-level one behind ``run_suite``) reuses executables
    across runs, sections, and figure drivers within one process.
    """

    def __init__(
        self,
        cache: CompileCache | None = None,
        cache_dir: str | None = None,
        tracer: Tracer | NullTracer | None = None,
    ) -> None:
        self.cache = cache if cache is not None else CompileCache()
        # Optional cross-process persistence of compile artifacts (two
        # tiers: serialized executables over lowered HLO text) — warm
        # entries skip retracing, and usually XLA compilation too. None =
        # in-process only. The raw root is kept so distributed client
        # processes can be pointed at the same cache.
        self.cache_dir = cache_dir
        self.disk_cache = HloDiskCache(cache_dir) if cache_dir else None
        # Structured tracing (repro.obs): every _stage_* becomes a span,
        # serve completions and batch executions become retrospective
        # events, and counter totals land in the final RunMetadata.
        # Default NULL_TRACER: falsy, no-op spans, swallowed counters —
        # the disabled cost at a guarded call site is one attribute read.
        self.tracer = tracer if tracer is not None else NULL_TRACER
        if cache_dir:
            _enable_jax_persistent_cache(cache_dir)

    # -- stages ------------------------------------------------------------

    def _cache_key(
        self,
        spec: BenchmarkSpec,
        plan: ExecutionPlan,
        preset: int,
        backward: bool,
        placement: Placement,
        impl: str = "xla",
        tuned_params: dict | None = None,
    ) -> CacheKey:
        return (
            spec.name,
            preset,
            tuple(sorted(plan.overrides_for(spec.name).items())),
            backward,
            jax.default_backend(),
            placement.devices,
            placement.mode,
            impl,
            tuple(sorted((tuned_params or {}).items())),
        )

    def _resolve_impl(
        self, workload: Workload, plan: ExecutionPlan, backward: bool
    ) -> tuple[str, str | None]:
        """The *effective* implementation for one (workload, pass):
        ``(impl, fallback_reason)``. A pallas plan degrades to xla — with
        the reason recorded, never silently — for workloads that declare
        no Pallas variant, for host-transfer (no_jit) workloads, and for
        backward passes (the hand-written kernels are forward programs;
        differentiating through ``pallas_call`` is not the measured path).
        """
        if plan.impl != "pallas":
            return "xla", None
        if workload.meta.get("no_jit"):
            return "xla", "no_jit"
        if workload.pallas_kernel is None:
            return "xla", "no_pallas_variant"
        from repro.kernels import ops as kernel_ops

        if workload.pallas_kernel not in kernel_ops.PALLAS_OPS:
            raise ValueError(
                f"workload {workload.name!r} declares pallas_kernel="
                f"{workload.pallas_kernel!r}, not a known op: "
                f"{sorted(kernel_ops.PALLAS_OPS)}"
            )
        if backward:
            return "xla", "backward_pass"
        return "pallas", None

    def _stage_build(
        self, spec: BenchmarkSpec, plan: ExecutionPlan, preset: int
    ) -> tuple[Workload, tuple]:
        workload = spec.build_preset(preset, **plan.overrides_for(spec.name))
        return workload, workload.make_inputs(plan.seed)

    def _resolve_placement(
        self, workload: Workload, args: tuple, requested: Placement
    ) -> Placement:
        """The *effective* placement, from shapes alone (no transfers):
        shard requests degrade to replicate for workloads that opt out of
        ``batch_dims`` (or whose dims don't divide), and no_jit host-
        transfer workloads always run — and are recorded — on one device."""
        if workload.meta.get("no_jit"):
            return Placement(devices=1, mode="replicate")
        if requested.devices == 1:
            return Placement(devices=1, mode="replicate")
        if requested.mode == "shard":
            from repro.runtime.sharding import shard_applies

            if shard_applies(args, workload, requested.devices):
                return requested
        return Placement(devices=requested.devices, mode="replicate")

    def _stage_place(
        self, workload: Workload, args: tuple, requested: Placement
    ) -> tuple[tuple, Placement]:
        """Put inputs where the placement says; the effective placement
        joins the compile-cache key. Single-device placement means
        committing host-side inputs once (numpy arrays from make_inputs
        would otherwise pay H2D on *every* timed and served call)."""
        placement = self._resolve_placement(workload, args, requested)
        if placement.devices == 1:
            if not workload.meta.get("no_jit"):
                args = commit_args(args)
            return args, placement
        from repro.runtime.sharding import data_mesh, place_args

        mesh = data_mesh(placement.devices)
        placed, mode = place_args(args, workload, mesh, placement.mode)
        assert mode == placement.mode, (mode, placement)
        return placed, placement

    def _impl_context(
        self, workload: Workload, impl: str, tuned_params: dict | None
    ):
        """The forced-dispatch context tracing must run under.

        Workloads that declare a ``pallas_kernel`` are *pinned* both ways:
        ``impl="pallas"`` forces the kernel (with the tuned block params
        merged in), ``impl="xla"`` forces the jnp reference — so an xla
        row on a TPU host is really the lax lowering, not ``mode="auto"``
        silently picking the kernel. Undeclared workloads trace untouched.
        """
        if workload.pallas_kernel is None:
            return contextlib.nullcontext()
        from repro.kernels import ops as kernel_ops

        mode = "pallas" if impl == "pallas" else "ref"
        return kernel_ops.force_impl(
            mode, workload.pallas_kernel, **(tuned_params or {})
        )

    def _stage_compile(
        self,
        spec: BenchmarkSpec,
        workload: Workload,
        args: tuple,
        plan: ExecutionPlan,
        preset: int,
        backward: bool,
        placement: Placement,
        impl: str = "xla",
        tuned_params: dict | None = None,
    ) -> _CacheEntry:
        fn = workload.fn_bwd if backward else workload.fn
        if backward and fn is None:
            raise ValueError(f"workload {workload.name!r} has no backward pass")
        key = self._cache_key(
            spec, plan, preset, backward, placement, impl, tuned_params
        )

        def build() -> _CacheEntry:
            if workload.meta.get("no_jit"):
                # Host-transfer workloads time the un-jitted staging path and
                # have no device program to analyse.
                return _CacheEntry(
                    executable=fn,
                    info=empty_compiled_info(_pass_name(workload, backward)),
                )
            # Disk cache: a warm entry skips the retrace — and, when the
            # serialized executable deserializes, the XLA compile too; a
            # cold or failed one falls through. Multi-device lowerings
            # embed placement-dependent shardings and device assignments,
            # so they persist through the sharded tier (AOT-serialized
            # jax.stages.Compiled under an explicit topology key) instead
            # of the raw single-device executable tier.
            return self._compile_through_caches(
                key, workload, fn, args,
                pass_name=_pass_name(workload, backward),
                impl=impl,
                tuned_params=tuned_params,
                use_disk=self.disk_cache is not None,
                sharded=placement.devices > 1,
            )

        return self.cache.lookup(key, build)

    def _compile_through_caches(
        self,
        key: CacheKey,
        workload: Workload,
        fn: Callable[..., Any],
        args: tuple,
        *,
        pass_name: str,
        impl: str,
        tuned_params: dict | None,
        use_disk: bool,
        sharded: bool = False,
    ) -> _CacheEntry:
        """Lower + compile one program through the disk cache: a warm
        entry skips the retrace — and, when the serialized executable
        deserializes, the XLA compile too. Shared by the measure-path
        compile stage and the mixed-shape serve stage's per-(bucket,
        width) executables, so every bucket persists and restores exactly
        like a measure executable. ``sharded`` routes multi-device
        programs through the cache's sharded tier (the lowering embeds
        device assignments, so it persists as an AOT-serialized
        ``jax.stages.Compiled`` rather than a raw executable blob)."""
        if use_disk:
            loaded = self.disk_cache.load(key, args, sharded=sharded)
            if loaded is not None:
                executable, info = loaded
                return _CacheEntry(executable=executable, info=info)
        # The impl choice is a trace-time decision: force_impl is
        # consulted by the kernel ops as fn traces, so the selected
        # implementation (and its tuned blocks) is baked into this
        # lowering — execution later needs no context.
        with self._impl_context(workload, impl, tuned_params):
            lowered = jax.jit(fn).lower(*args)
        compiled = lowered.compile()
        if use_disk:
            self.disk_cache.store(key, lowered, compiled, pass_name, sharded=sharded)
        return _CacheEntry(executable=compiled)

    def _stage_tune(
        self,
        spec: BenchmarkSpec,
        workload: Workload,
        args: tuple,
        plan: ExecutionPlan,
        preset: int,
        backward: bool,
        placement: Placement,
        impl: str,
    ) -> tuple[dict | None, int | None, float | None]:
        """Sweep the kernel's ``tune_space()`` -> (winner, trials, wall µs).

        Runs between place and compile, only for effective-pallas passes of
        tuning plans; every other pass returns ``(None, None, None)`` and
        costs nothing. Candidates compile through the ordinary cache under
        their full key — the winner's later compile stage is a guaranteed
        hit — and are timed with the windowed timer (small kernels are
        dispatch-bound; sync-mode timing would tune the host, not the
        block shape). Ties keep the earliest candidate, so a fixed seed
        and a deterministic timer give a deterministic winner. The winner
        persists in the disk cache under the *base* key (params excluded —
        the lookup must not need the answer), making a warm run's sweep
        zero trials: restored, not re-timed.
        """
        if impl != "pallas" or not plan.tune:
            return None, None, None
        from repro.kernels import ops as kernel_ops

        space = kernel_ops.tune_space(workload.pallas_kernel)
        if not space:
            space = ({},)
        if len(space) == 1:
            # Nothing to sweep (kernels without block params): the single
            # candidate wins by default, at zero trials.
            return dict(space[0]), 0, 0.0
        base_key = self._cache_key(
            spec, plan, preset, backward, placement, impl
        )
        use_disk = self.disk_cache is not None and placement.devices == 1
        if use_disk:
            won = self.disk_cache.load_tuned(base_key)
            if won is not None:
                return won, 0, 0.0
        best_us: float | None = None
        best: dict = {}
        trials = 0
        # tune_trials_us is the *sum of the per-candidate trial spans* —
        # each trial's wall time is measured once (c0/c1 below), added to
        # the total, and emitted as a trace event from the same pair, so
        # the record's number and the trace can never disagree.
        trials_us = 0.0
        tracer = self.tracer
        for cand in space:
            c0 = time.perf_counter()
            entry = self._stage_compile(
                spec, workload, args, plan, preset, backward, placement,
                impl, dict(cand),
            )
            mean_us = self._time_tune_trial(entry, args, plan)
            c1 = time.perf_counter()
            trials_us += (c1 - c0) * 1e6
            trials += 1
            if tracer.enabled:
                tracer.event(
                    "tune.trial", t_start=c0, t_end=c1, track="engine",
                    bench=spec.name, params=dict(cand), mean_us=mean_us,
                )
                tracer.counters.inc("tune.trials")
            if best_us is None or mean_us < best_us:
                best_us, best = mean_us, dict(cand)
        if use_disk:
            self.disk_cache.store_tuned(base_key, best, trials, trials_us)
        return best, trials, trials_us

    def _time_tune_trial(
        self, entry: _CacheEntry, args: tuple, plan: ExecutionPlan
    ) -> float:
        """One candidate's figure of merit (mean µs/call, windowed).
        A seam: tests monkeypatch this to pin the sweep's timing."""
        mean_us, _ = time_fn(
            entry.executable,
            args,
            iters=min(plan.iters, 3),  # a sweep trial, not the measurement
            warmup=1,
            window=plan.timing_window,
        )
        return mean_us

    def _stage_measure(
        self,
        workload: Workload,
        entry: _CacheEntry,
        args: tuple,
        plan: ExecutionPlan,
        backward: bool,
    ):
        out = jax.block_until_ready(entry.executable(*args))
        if not backward and workload.validate is not None:
            workload.validate(out, args)
        mean, stdev = time_fn(
            entry.executable, args, iters=plan.iters, warmup=plan.warmup
        )
        windowed_us = None
        window = plan.timing_window
        if window > 1 and not workload.meta.get("no_jit"):
            # Windowed mode rides async dispatch; the sync loop above
            # already warmed the executable, so no second warmup. no_jit
            # host-transfer workloads run synchronously by construction —
            # a windowed number for them would be the sync number with
            # extra noise, so their windowed columns stay empty.
            windowed_us, _ = time_fn(
                entry.executable, args, iters=plan.iters, warmup=0, window=window
            )
        return timing_from_stats(
            workload, mean_us=mean, stdev_us=stdev, iters=plan.iters,
            backward=backward, windowed_us=windowed_us, window=window,
        )

    def _stage_characterize(
        self, workload: Workload, entry: _CacheEntry, backward: bool
    ) -> CompiledInfo:
        if entry.info is None:
            entry.info = characterize_compiled(
                entry.executable, _pass_name(workload, backward)
            )
        return entry.info

    # -- serving -----------------------------------------------------------

    def _trace_completions(self, completions) -> None:
        """Retrospective per-request trace events, one per completion,
        attributed to its dispatch lane (``serve`` track, one tid per
        lane). Emitted *after* the serving run from timestamps the lanes
        already recorded — the serve hot path is never instrumented."""
        tracer = self.tracer
        if not tracer.enabled:
            return
        for c in completions:
            attrs = {"index": c.index, "warmup": c.warmup}
            if c.bucket is not None:
                attrs["bucket"] = c.bucket
            tracer.event(
                "request", t_start=c.t_submit, t_end=c.t_done,
                track="serve", tid=f"lane {c.lane}", **attrs,
            )
        tracer.counters.inc("serve.requests", len(completions))

    def _trace_batches(self, report) -> None:
        """Retrospective per-batch events from a ``BatchReport``: one
        span per dispatched device program on the ``batcher`` track (one
        tid per bucket queue), plus the flush/expiry/padding counters."""
        tracer = self.tracer
        if not tracer.enabled:
            return
        counters = tracer.counters
        for b in report.batches:
            tracer.event(
                f"batch[{b.width}]", t_start=b.t_dispatch, t_end=b.t_done,
                track="batcher", tid=f"queue {b.bucket}",
                width=b.width, filled=b.filled, cause=b.cause,
            )
            counters.inc("batcher.flushes")
            if b.cause == "expired":
                counters.inc("batcher.budget_expiries")
            counters.inc("batcher.dispatched_slots", b.width)
            counters.inc("batcher.padded_slots", b.width - b.filled)

    def _serve_call(self, call, serve: ServeSpec, seed: int):
        """One isolated serving run of an already-compiled callable.

        Selects the host issue architecture the spec asked for: the
        ``single`` client dispatches every lane from this thread; the
        ``threaded`` client gives each lane its own issuing thread fed
        from a per-lane deterministic sub-schedule, and its per-request
        dispatch overhead lands in the stats. Open-loop stats carry the
        schedule's ``truncated`` flag so a request-capped run never
        reports the full target as its offered load.
        """
        from repro.serve.client import (
            run_closed_loop_threaded,
            run_open_loop_threaded,
        )
        from repro.serve.lanes import run_closed_loop, run_open_loop
        from repro.serve.latency import stats_from_completions
        from repro.serve.loadgen import open_loop_lane_schedules, open_loop_schedule

        # Fill the whole pipeline (every in-flight slot, not just one per
        # lane) before measuring, like time_fn's warmup: early requests
        # submitted into an empty window see less queueing than steady
        # state and would bias the percentiles low.
        warmup = max(serve.concurrency, serve.lanes, 2)
        if serve.mode == "open":
            if serve.client == "threaded":
                lane_schedules = open_loop_lane_schedules(
                    qps=serve.qps,
                    duration_s=serve.duration_s,
                    n_lanes=serve.lanes,
                    seed=seed,
                    warmup=warmup,
                )
                result = run_open_loop_threaded(
                    call, lane_schedules, concurrency=serve.concurrency
                )
                self._trace_completions(result.completions)
                return stats_from_completions(
                    result.completions,
                    offered_qps=serve.qps,
                    slo_us=serve.slo_us,
                    truncated=any(s.truncated for s in lane_schedules),
                    dispatch_overhead_us=result.dispatch_overhead_us,
                    n_lanes=serve.lanes,
                )
            schedule = open_loop_schedule(
                qps=serve.qps,
                duration_s=serve.duration_s,
                seed=seed,
                warmup=warmup,
            )
            completions = run_open_loop(
                call, schedule, n_lanes=serve.lanes, concurrency=serve.concurrency
            )
            self._trace_completions(completions)
            return stats_from_completions(
                completions,
                offered_qps=serve.qps,
                slo_us=serve.slo_us,
                truncated=schedule.truncated,
                n_lanes=serve.lanes,
            )
        if serve.client == "threaded":
            result = run_closed_loop_threaded(
                call,
                concurrency=serve.concurrency,
                n_lanes=serve.lanes,
                duration_s=serve.duration_s,
                warmup=warmup,
            )
            self._trace_completions(result.completions)
            return stats_from_completions(
                result.completions,
                slo_us=serve.slo_us,
                dispatch_overhead_us=result.dispatch_overhead_us,
                n_lanes=serve.lanes,
            )
        completions = run_closed_loop(
            call,
            concurrency=serve.concurrency,
            n_lanes=serve.lanes,
            duration_s=serve.duration_s,
            warmup=warmup,
        )
        self._trace_completions(completions)
        return stats_from_completions(
            completions, slo_us=serve.slo_us, n_lanes=serve.lanes
        )

    def _bucket_key(
        self,
        spec: BenchmarkSpec,
        bucket_preset: int,
        merged_overrides: dict,
        placement: Placement,
        impl: str,
        tuned_params: dict | None,
        width: int,
    ) -> tuple:
        """Compile-cache key for one (shape bucket, batch width) serve
        executable. Width 1 uses the ordinary key shape, so a bucket at
        the plan's own preset/overrides *shares the measure stage's
        executable*; wider programs append ("vmap", width)."""
        base = (
            spec.name,
            bucket_preset,
            tuple(sorted(merged_overrides.items())),
            False,
            jax.default_backend(),
            placement.devices,
            placement.mode,
            impl,
            tuple(sorted((tuned_params or {}).items())),
        )
        return base if width == 1 else base + ("vmap", width)

    def _build_bucket_calls(
        self,
        spec: BenchmarkSpec,
        plan: ExecutionPlan,
        preset: int,
        placement: Placement,
        impl: str,
        tuned_params: dict | None,
    ) -> dict[str, dict[int, Callable[[], Any]]]:
        """Precompile one executable per (shape bucket, batch width).

        Every program goes through the in-process CompileCache AND the
        two-tier disk cache under a bucket-specific key, so a warm run
        restores the whole table with zero XLA compiles. Batch member j
        gets inputs from ``make_inputs(seed + j)`` — a width-w program
        computes w *distinct* requests, stacked on a new leading axis and
        committed to the device once. Each executable is run once here
        (pipeline warmup), so first-execution overhead never lands in a
        served request's latency.
        """
        import numpy as np

        from repro.serve.batcher import bucket_widths

        serve = plan.serve
        widths = bucket_widths(serve.dispatch, serve.max_batch)
        calls: dict[str, dict[int, Callable[[], Any]]] = {}
        for bucket in serve.buckets(preset):
            bp = (
                bucket.preset
                if bucket.preset in spec.presets
                else min(spec.presets)
            )
            merged = {
                **plan.overrides_for(spec.name),
                **dict(bucket.overrides),
            }
            workload = spec.build_preset(bp, **merged)
            if workload.meta.get("no_jit"):
                raise ValueError(
                    f"mixed-shape serving needs a jittable workload; "
                    f"{workload.name!r} is no_jit (host-transfer)"
                )
            instances = [
                workload.make_inputs(plan.seed + j) for j in range(max(widths))
            ]
            per_width: dict[int, Callable[[], Any]] = {}
            for width in widths:
                if width == 1:
                    fn, wargs = workload.fn, instances[0]
                else:
                    fn = jax.vmap(workload.fn)
                    wargs = jax.tree_util.tree_map(
                        lambda *xs: np.stack(xs), *instances[:width]
                    )
                wargs = commit_args(wargs)
                key = self._bucket_key(
                    spec, bp, merged, placement, impl, tuned_params, width
                )
                entry = self.cache.lookup(
                    key,
                    lambda key=key, wl=workload, fn=fn, a=wargs, w=width: (
                        self._compile_through_caches(
                            key, wl, fn, a,
                            pass_name=f"{wl.name}.serve[{w}]",
                            impl=impl,
                            tuned_params=tuned_params,
                            use_disk=self.disk_cache is not None,
                        )
                    ),
                )
                call = lambda e=entry, a=wargs: e.executable(*a)  # noqa: E731
                jax.block_until_ready(call())  # warm: allocs, first dispatch
                per_width[width] = call
            calls[bucket.label] = per_width
        return calls

    def _mixed_schedule(self, serve: ServeSpec, seed: int, bucket_labels):
        """The mixed-shape request stream: load ``serve.trace`` verbatim
        when the file exists (the trace IS the load — qps/mix knobs are
        ignored on replay), else generate seeded Poisson arrivals, sample
        each request's bucket from the mix, and save to ``serve.trace``
        if one was named — so the next run (any dispatch policy) replays
        this exact stream."""
        from repro.serve.loadgen import (
            load_trace,
            open_loop_schedule,
            sample_mix,
            save_trace,
        )

        warmup = max(serve.concurrency, serve.max_batch, serve.lanes, 2)
        if serve.trace is not None and os.path.exists(serve.trace):
            schedule = load_trace(serve.trace)
            unknown = {r.bucket for r in schedule} - set(bucket_labels)
            if unknown:
                raise ValueError(
                    f"trace {serve.trace!r} names buckets {sorted(map(str, unknown))} "
                    f"absent from this run's mix {sorted(bucket_labels)}"
                )
            return schedule
        schedule = open_loop_schedule(
            qps=serve.qps,
            duration_s=serve.duration_s,
            seed=seed,
            warmup=warmup,
        )
        schedule = sample_mix(
            schedule,
            {b.label: b.weight for b in serve.buckets(0)}
            if serve.mix is not None
            else {label: 1.0 for label in bucket_labels},
            seed=seed,
        )
        if serve.trace is not None:
            save_trace(schedule, serve.trace)
        return schedule

    def _serve_mixed(
        self,
        spec: BenchmarkSpec,
        plan: ExecutionPlan,
        preset: int,
        placement: Placement,
        impl: str,
        tuned_params: dict | None,
    ):
        """The continuous-batching serve path: per-bucket executables
        (every (bucket, width) through both compile caches), a mixed-shape
        schedule (generated or replayed from a trace), and the spec's
        dispatch policy from ``repro.serve.batcher``. Stats carry batch
        occupancy, padding waste, and per-bucket latency percentiles."""
        from repro.serve.batcher import (
            serve_dynamic,
            serve_fixed_batched,
            serve_mixed_lanes,
            serve_mixed_loop,
        )
        from repro.serve.latency import stats_from_completions

        serve = plan.serve
        calls = self._build_bucket_calls(
            spec, plan, preset, placement, impl, tuned_params
        )
        schedule = self._mixed_schedule(serve, plan.seed, set(calls))
        if serve.dispatch == "loop":
            report = serve_mixed_loop(calls, schedule)
        elif serve.dispatch == "lanes":
            report = serve_mixed_lanes(
                calls, schedule,
                n_lanes=serve.lanes, concurrency=serve.concurrency,
            )
        elif serve.dispatch == "batched":
            report = serve_fixed_batched(
                calls, schedule,
                batch=serve.max_batch, concurrency=serve.concurrency,
            )
        else:
            report = serve_dynamic(
                calls, schedule,
                budget_s=serve.batch_budget_us / 1e6,
                concurrency=serve.concurrency,
            )
        self._trace_completions(report.completions)
        self._trace_batches(report)
        return stats_from_completions(
            report.completions,
            # A replayed trace's offered load is the trace's, not the
            # spec's qps knob (which replay ignores).
            offered_qps=(
                schedule.offered_qps
                if schedule.offered_qps is not None
                else serve.qps
            ),
            slo_us=serve.slo_us,
            truncated=schedule.truncated,
            n_lanes=serve.lanes if serve.dispatch == "lanes" else 1,
            batch_occupancy=report.occupancy,
            padding_waste=report.padding_waste,
            n_batches=len(report.batches),
        )

    def _stage_serve(
        self,
        spec: BenchmarkSpec,
        entry: _CacheEntry,
        args: tuple,
        plan: ExecutionPlan,
        preset: int,
        placement: Placement,
        impl: str = "xla",
        tuned_params: dict | None = None,
    ) -> tuple[Any, str | None, float | None, list[BenchmarkRecord]]:
        """Serve the measured executable under the plan's ServeSpec.

        Returns ``(stats, colocate, slowdown, partner_records)``. Without
        co-location this reuses the cache entry the measure stage compiled
        — zero new compilations. With ``colocate``, the partner benchmark
        is built/placed/compiled through the same cache and both tenants
        are served isolated then together (``serve.interference``); the
        partner's colocated row is returned for the report. A mixed-shape
        spec (``serve.is_mixed``) routes through ``_serve_mixed`` instead:
        per-bucket vmapped executables and the batcher dispatch policies.
        """
        serve = plan.serve
        if serve.is_mixed:
            stats = self._serve_mixed(
                spec, plan, preset, placement, impl, tuned_params
            )
            return stats, None, None, []
        if serve.client_procs > 0:
            # Distributed load generation (repro.dist): N client
            # processes, each compiling through the shared cache dir and
            # replaying its own seeded sub-schedule; the launcher merges
            # their completion streams into one stats object carrying
            # per-process QPS.
            from repro.dist.launcher import run_distributed

            stats = run_distributed(
                benchmark=spec.name,
                preset=preset,
                overrides=dict(plan.overrides_for(spec.name)),
                serve=serve,
                seed=plan.seed,
                devices=placement.devices,
                placement_mode=placement.mode,
                impl=impl,
                cache_dir=self.cache_dir,
            )
            return stats, None, None, []
        call = lambda: entry.executable(*args)  # noqa: E731
        if serve.colocate is None:
            return self._serve_call(call, serve, plan.seed), None, None, []

        from repro.serve.interference import measure_colocation

        partner_spec = get_benchmark(serve.colocate)
        p_preset = plan.resolve_preset(partner_spec)
        p_workload, p_args = self._stage_build(partner_spec, plan, p_preset)
        p_args, p_placement = self._stage_place(
            p_workload, p_args, plan.placement_at(placement.devices)
        )
        p_entry = self._stage_compile(
            partner_spec, p_workload, p_args, plan, p_preset, False, p_placement
        )
        p_call = lambda: p_entry.executable(*p_args)  # noqa: E731

        a_name = spec.name
        b_name = serve.colocate if serve.colocate != spec.name else spec.name + "#2"
        result = measure_colocation(
            {a_name: call, b_name: p_call},
            concurrency=serve.concurrency,
            n_lanes=serve.lanes,
            duration_s=serve.duration_s,
            warmup=max(serve.concurrency, serve.lanes, 2),
            slo_us=serve.slo_us,
        )
        partner = BenchmarkRecord.from_serve(
            partner_spec,
            p_preset,
            result.colocated[b_name],
            mode=serve.mode,
            lanes=serve.lanes,
            client=serve.client,
            name=f"{b_name}@{a_name}",
            colocate=a_name,
            slowdown=result.slowdown(b_name),
            devices=p_placement.devices,
            placement=p_placement.mode,
        )
        return (
            result.colocated[a_name],
            b_name,
            result.slowdown(a_name),
            [partner],
        )

    def characterize(
        self,
        spec: BenchmarkSpec,
        plan: ExecutionPlan,
        *,
        backward: bool = False,
        workload: Workload | None = None,
    ) -> CompiledInfo:
        """Compile (through the cache) + characterize, without timing.

        For characterization-only consumers (Table II, dry-run style flows):
        shares executables with full runs of the same plan parameters. A
        warm cache with memoized analysis returns without building the
        workload or its inputs; pass ``workload`` to reuse one already built.

        Uses the plan placement at ``plan.devices`` (not the sweep): the
        cache key needs the effective placement, which for a shard request
        depends on the workload's ``batch_dims`` and input shapes — so a
        shard-mode lookup builds the workload (shapes only, no transfers)
        to resolve the key; inputs are placed on devices only on a miss.
        Likewise the plan's ``impl`` resolves per workload, so a pallas
        lookup also builds the workload first. Characterization always
        analyses the kernel's *default* blocks (``plan.tune`` is a timing
        concern; the static analysis does not sweep).
        """
        preset = plan.resolve_preset(spec)
        requested = plan.placement_at(plan.devices)
        if requested.mode == "replicate" and plan.impl == "xla":
            # Effective placement/impl == requested without building the
            # workload (xla is every workload's fallback).
            cached = self.cache.peek(
                self._cache_key(spec, plan, preset, backward, requested)
            )
            if cached is not None and cached.info is not None:
                self.cache.hits += 1
                return cached.info
        if workload is None:
            workload = spec.build_preset(preset, **plan.overrides_for(spec.name))
        impl, _ = self._resolve_impl(workload, plan, backward)
        args = workload.make_inputs(plan.seed)
        placement = self._resolve_placement(workload, args, requested)
        cached = self.cache.peek(
            self._cache_key(spec, plan, preset, backward, placement, impl)
        )
        if cached is not None and cached.info is not None:
            self.cache.hits += 1
            return cached.info
        # Characterize-only flows still emit stage spans (no-ops under
        # NULL_TRACER) so traced dry runs account for where time went.
        timings: dict[str, float] = {}
        with self._timed_stage("place", timings, bench=spec.name):
            args, placement = self._stage_place(workload, args, requested)
        with self._timed_stage("compile", timings, bench=spec.name):
            entry = self._stage_compile(
                spec, workload, args, plan, preset, backward, placement, impl
            )
        with self._timed_stage("characterize", timings, bench=spec.name):
            return self._stage_characterize(workload, entry, backward)

    # -- orchestration -----------------------------------------------------

    def run(
        self,
        plan: ExecutionPlan,
        *,
        report_path: str | None = None,
        jsonl_path: str | None = None,
        verbose: bool = False,
    ) -> RunResult:
        specs = plan.select()
        available = jax.device_count()
        want = max(plan.device_sweep)
        if want > available:
            raise PlanError(
                f"plan requests {want} devices but only "
                f"{available} available"
            )
        if plan.serve is not None and plan.serve.colocate is not None:
            try:
                get_benchmark(plan.serve.colocate)
            except KeyError as e:
                raise PlanError(str(e)) from None
        if plan.serve is not None and plan.serve.is_mixed and want > 1:
            raise PlanError(
                "mixed-shape serving (mix/trace/batcher dispatch) is "
                f"single-device; the plan sweeps up to {want} devices"
            )
        metadata = RunMetadata.capture(
            preset=plan.preset,
            devices=plan.devices,
            placement=plan.placement.mode,
            device_sweep=plan.device_sweep,
            serve=plan.serve,
            timing_window=plan.timing_window,
            impl=plan.impl,
            tune=plan.tune,
        )
        writer = JsonlReportWriter(jsonl_path, metadata) if jsonl_path else None
        records: list[BenchmarkRecord] = []
        sweep_stats: list[SweepStat] = []
        # 1-device us_per_call per row name: the scaling baseline. The sweep
        # is sorted ascending, so baselines exist before multi-device rows
        # stream out.
        baseline_us: dict[str, float] = {}

        def emit(rec: BenchmarkRecord) -> None:
            if rec.status == "ok":
                if rec.devices == 1:
                    baseline_us[rec.name] = rec.us_per_call
                elif rec.name in baseline_us and rec.us_per_call > 0:
                    rec.scaling_efficiency = (
                        baseline_us[rec.name] / rec.us_per_call / rec.devices
                    )
            records.append(rec)
            if writer is not None:
                writer.write(rec)
            if verbose:
                print(rec.csv(), flush=True)

        if verbose:
            print(BenchmarkRecord.csv_header(), flush=True)
        try:
            # The engine's tracer becomes the ambient one for the run, so
            # the serve layer (lane workers, batcher) reaches it without
            # a parameter threaded through every client signature.
            with use_tracer(self.tracer):
                for devices in plan.device_sweep:
                    misses0, hits0 = self.cache.misses, self.cache.hits
                    for spec in specs:
                        for rec in self._run_benchmark(spec, plan, devices):
                            emit(rec)
                    sweep_stats.append(
                        SweepStat(
                            devices=devices,
                            misses=self.cache.misses - misses0,
                            hits=self.cache.hits - hits0,
                        )
                    )
        finally:
            metadata = self._final_metadata(metadata)
            if writer is not None:
                writer.write_meta(metadata)
                writer.close()
        if verbose and self.disk_cache is not None:
            # A disk cache that never hits is otherwise invisible: say what
            # it did, and why any warm load fell back to retracing.
            print(f"# {self.disk_cache.summary()}", flush=True)
        if report_path:
            write_report(records, report_path)
        return RunResult(
            records=records,
            metadata=metadata,
            cache=self.cache,
            sweep_stats=sweep_stats,
        )

    def _final_metadata(self, metadata: RunMetadata) -> RunMetadata:
        """End-of-run observability stamped into the (frozen) metadata:
        the disk cache's counter totals whenever a --cache-dir was in
        play — committed reports must show whether the run was warm,
        which `verbose` stdout alone cannot — and the obs counter
        snapshot (cache totals folded in under a ``cache.`` prefix) when
        tracing was on."""
        cache_stats = (
            self.disk_cache.counter_dict()
            if self.disk_cache is not None
            else None
        )
        if self.tracer.enabled and cache_stats:
            for k, v in cache_stats.items():
                # set, not inc: the disk cache accumulates across runs of
                # a long-lived engine; incrementing would double-count.
                self.tracer.counters.set(f"cache.{k}", v)
        counters = (
            self.tracer.counters.snapshot() if self.tracer.enabled else None
        )
        if cache_stats is None and counters is None:
            return metadata
        return dataclasses.replace(
            metadata, cache_stats=cache_stats, counters=counters
        )

    @contextlib.contextmanager
    def _timed_stage(self, name: str, timings: dict, **attrs: Any):
        """One engine stage = one tracer span + one ``stage_timings_us``
        entry, from a single perf_counter pair. The timing lands even
        when the stage raises, so error records still say where the time
        went. The dict entry is always written (tracing on or off):
        per-stage wall time is a record column, not just a trace row."""
        t0 = time.perf_counter()
        try:
            with self.tracer.span(name, **attrs):
                yield
        finally:
            timings[name] = (time.perf_counter() - t0) * 1e6

    def _run_benchmark(
        self, spec: BenchmarkSpec, plan: ExecutionPlan, devices: int
    ) -> list[BenchmarkRecord]:
        preset = plan.resolve_preset(spec)
        requested = plan.placement_at(devices)
        # Build/place run once per benchmark and their timings are copied
        # into every pass's stage_timings_us (the passes share the work).
        base_timings: dict[str, float] = {}
        try:
            with self._timed_stage(
                "build", base_timings, bench=spec.name, devices=devices
            ):
                workload, args = self._stage_build(spec, plan, preset)
        except Exception as e:  # noqa: BLE001 — fault isolation is the contract
            rec = BenchmarkRecord.from_error(
                spec, preset, stage="build", error=_err_text(e),
                devices=devices, placement=requested.mode,
            )
            rec.stage_timings_us = dict(base_timings)
            return [rec]
        try:
            with self._timed_stage(
                "place", base_timings, bench=spec.name, devices=devices
            ):
                args, placement = self._stage_place(workload, args, requested)
        except Exception as e:  # noqa: BLE001 — fault isolation is the contract
            rec = BenchmarkRecord.from_error(
                spec, preset, stage="place", error=_err_text(e),
                devices=devices, placement=requested.mode,
            )
            rec.stage_timings_us = dict(base_timings)
            return [rec]
        out: list[BenchmarkRecord] = []
        for backward in plan.passes(workload):
            out.extend(
                self._run_pass(
                    spec, workload, args, plan, preset, backward, placement,
                    base_timings,
                )
            )
        return out

    def _run_pass(
        self,
        spec: BenchmarkSpec,
        workload: Workload,
        args: tuple,
        plan: ExecutionPlan,
        preset: int,
        backward: bool,
        placement: Placement,
        base_timings: dict[str, float] | None = None,
    ) -> list[BenchmarkRecord]:
        stage = "tune"
        impl, impl_fallback = "xla", None
        # Per-stage wall microseconds for this pass (schema v8). Stages
        # run back to back, so the dict's sum tracks the pass's wall time
        # by construction; the _timed_stage helper fills it whether or
        # not tracing is on, and keeps filling it when a stage raises, so
        # error records carry the partial breakdown too.
        timings: dict[str, float] = dict(base_timings or {})
        span_attrs = dict(bench=_pass_name(workload, backward))
        try:
            impl, impl_fallback = self._resolve_impl(workload, plan, backward)
            span_attrs["impl"] = impl
            with self._timed_stage("tune", timings, **span_attrs):
                tuned_params, tune_trials, tune_trials_us = self._stage_tune(
                    spec, workload, args, plan, preset, backward, placement,
                    impl,
                )
            stage = "compile"
            with self._timed_stage("compile", timings, **span_attrs):
                entry = self._stage_compile(
                    spec, workload, args, plan, preset, backward, placement,
                    impl, tuned_params,
                )
            stage = "measure"
            with self._timed_stage("measure", timings, **span_attrs):
                timing = self._stage_measure(
                    workload, entry, args, plan, backward
                )
            stage = "characterize"
            with self._timed_stage("characterize", timings, **span_attrs):
                info = self._stage_characterize(workload, entry, backward)
            rec = BenchmarkRecord.from_measurement(
                spec, preset, timing, info,
                devices=placement.devices, placement=placement.mode,
                impl=impl,
                # Explicit interpret flag: a pallas row on a non-TPU host
                # ran the kernel interpreted — a dispatch study, never a
                # compiled-kernel number. None (not False) on xla rows.
                impl_interpret=(
                    jax.default_backend() != "tpu" if impl == "pallas" else None
                ),
                impl_fallback=impl_fallback,
                tuned_params=tuned_params,
                tune_trials=tune_trials,
                tune_trials_us=tune_trials_us,
            )
            rec.stage_timings_us = timings
            extra: list[BenchmarkRecord] = []
            # Serving measures request-level concurrency of the forward
            # pass; backward rows keep their isolation-mode semantics.
            if plan.serve is not None and not backward:
                stage = "serve"
                with self._timed_stage("serve", timings, **span_attrs):
                    stats, colocate, slowdown, extra = self._stage_serve(
                        spec, entry, args, plan, preset, placement,
                        impl, tuned_params,
                    )
                rec.apply_serve(
                    stats,
                    mode=plan.serve.mode,
                    lanes=plan.serve.lanes,
                    client=plan.serve.client,
                    colocate=colocate,
                    slowdown=slowdown,
                    dispatch=plan.serve.dispatch,
                    mix=_mix_label(plan.serve),
                )
            return [rec] + extra
        except Exception as e:  # noqa: BLE001 — fault isolation is the contract
            err = BenchmarkRecord.from_error(
                spec, preset, stage=stage, error=_err_text(e), backward=backward,
                devices=placement.devices, placement=placement.mode,
                impl=impl,
            )
            err.stage_timings_us = timings
            return [err]


def _enable_jax_persistent_cache(cache_dir: str) -> None:
    """Point jax's own persistent compilation cache at a subdirectory of
    the engine's cache dir. The two-tier artifact cache covers the
    benchmark executables; this covers everything *around* them — input
    builders, validators, one-off jnp ops — which otherwise re-compile in
    every process and dominate warm-run wall time. Best-effort and
    process-global (last cache_dir wins): older jaxlibs without CPU
    support simply skip it."""
    try:
        jax.config.update(
            "jax_compilation_cache_dir",
            os.path.join(cache_dir, "jax-persistent"),
        )
        jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
        jax.config.update("jax_persistent_cache_min_entry_size_bytes", 0)
    except Exception:  # noqa: BLE001 — an accelerator, never a failure
        pass


def _pass_name(workload: Workload, backward: bool) -> str:
    return workload.name + (".bwd" if backward else "")


def _mix_label(serve: ServeSpec) -> str | None:
    """The record's compact mix description: ``label@weight`` per bucket
    (None for non-mixed serve specs)."""
    if not serve.is_mixed:
        return None
    if serve.mix is None:
        return None
    return ",".join(f"{b.label}@{b.weight:g}" for b in serve.mix)


def _err_text(e: BaseException, limit: int = 500) -> str:
    # Collapse whitespace: error records land in one-line CSV/JSONL rows.
    text = " ".join(f"{type(e).__name__}: {e}".split())
    return text if len(text) <= limit else text[: limit - 3] + "..."
