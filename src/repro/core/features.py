"""Modern-platform feature analogues (paper §IV/§V-B, DESIGN.md §2).

Each CUDA feature the paper studies is mapped to the TPU/JAX idiom that
serves the same *purpose*, and exposed here as a reusable helper so the
feature benchmarks (`benchmarks/feat_*.py`) and the suite share one
implementation:

- HyperQ → ``concurrent_instances``: run N independent instances of a
  workload in one program via ``vmap`` (fills idle MXU/VPU lanes the way
  HyperQ fills idle work queues) and ``async_launch``: dispatch N jitted
  calls without intermediate synchronization (JAX's async runtime overlaps
  host dispatch with device execution).
- Unified Memory → ``DemandStager`` / ``Prefetcher``: host-resident arrays
  staged to device on first use vs ahead-of-use double-buffered prefetch —
  the `cudaMemAdvise`/`cudaMemPrefetchAsync` study of §V-B.
- Dynamic Parallelism → ``adaptive_refine``: coarse-phase classification +
  fine-phase masked iteration (Mariani–Silver structure) as a reusable
  combinator over ``lax.while_loop``.
- Cooperative Groups → kernel-fusion toggles live in the SRAD kernel itself
  (`repro.kernels.srad_stencil`: fused two-phase vs split calls).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp

__all__ = [
    "concurrent_instances",
    "async_launch",
    "DemandStager",
    "Prefetcher",
    "adaptive_refine",
]


def concurrent_instances(fn: Callable[..., Any], n: int) -> Callable[..., Any]:
    """HyperQ analogue: one program that executes ``n`` independent instances.

    The returned callable takes *stacked* inputs (leading axis ``n``). On GPU
    the paper launches N kernels on N streams; on TPU a single core runs one
    program, so concurrency means *occupancy*: vmapping the instances lets
    XLA batch/interleave them across MXU/VPU lanes.
    """
    return jax.vmap(fn)


def async_launch(fn: Callable[..., Any], args_list: Sequence[tuple]) -> list[Any]:
    """Dispatch many independent calls before synchronizing any of them.

    JAX's async dispatch queues device work and returns futures-like arrays;
    blocking only at the end lets host-side launch overlap device execution —
    the stream-level half of the HyperQ story.
    """
    outs = [fn(*args) for args in args_list]
    return jax.block_until_ready(outs)


@dataclasses.dataclass
class DemandStager:
    """Unified-memory analogue: host arrays staged to device on first touch."""

    _cache: dict[int, jax.Array] = dataclasses.field(default_factory=dict)

    def get(self, host_array) -> jax.Array:
        key = id(host_array)
        if key not in self._cache:
            self._cache[key] = jax.device_put(jnp.asarray(host_array))
        return self._cache[key]


class Prefetcher:
    """`cudaMemPrefetchAsync` analogue: overlap next-transfer with compute.

    ``prefetch`` starts an async host→device transfer; ``get`` blocks only if
    the transfer has not completed. JAX's async dispatch makes device_put
    non-blocking, so interleaving prefetch(i+1) with compute(i) overlaps the
    PCI/host link with device execution.
    """

    def __init__(self) -> None:
        self._pending: dict[Any, jax.Array] = {}

    def prefetch(self, key, host_array) -> None:
        self._pending[key] = jax.device_put(jnp.asarray(host_array))

    def get(self, key) -> jax.Array:
        return self._pending.pop(key)


def adaptive_refine(
    coarse_fn: Callable[..., jax.Array],
    fine_fn: Callable[..., jax.Array],
    needs_refine: Callable[[jax.Array], jax.Array],
) -> Callable[..., jax.Array]:
    """Dynamic-parallelism analogue (Mariani–Silver structure).

    ``coarse_fn(x)`` produces a cheap approximation; ``needs_refine(out)``
    marks elements requiring fine work; ``fine_fn(x)`` computes the exact
    value. The combinator evaluates fine work only where needed via
    ``jnp.where`` masking — on TPU, skipped lanes cost vector-issue slots but
    no memory traffic, which is the realizable fraction of the GPU win (the
    paper's child-kernel launches have no TPU equivalent; DESIGN.md §2).
    """

    def run(x: jax.Array) -> jax.Array:
        coarse = coarse_fn(x)
        mask = needs_refine(coarse)
        # fine_fn must be total (defined everywhere) — masking selects, it
        # does not guard evaluation.
        fine = fine_fn(x)
        return jnp.where(mask, fine, coarse)

    return run
