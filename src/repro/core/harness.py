"""Timing + characterization primitives — the CUDA Event API analogue.

The paper replaces Rodinia's system-time measurement with CUDA events for
accurate kernel timing. JAX dispatch is asynchronous, so this module
offers **two timing modes** over a monotonic clock:

- **sync mode** (``time_fn`` with ``window=1``, the default): warm up,
  then ``jax.block_until_ready`` around every measured call. Each sample
  is one full host round trip — dispatch, device execution, and the
  host's completion wakeup — which is the comparable, conservative number
  every prior record carries (``us_per_call``). For small level-0/1
  kernels it measures host dispatch latency as much as kernel time:
  exactly the async-runtime pitfall the K80→A100 lineage study warns
  about.
- **windowed mode** (``time_fn`` with ``window=K``): dispatch a window of
  K calls back to back, riding JAX's async dispatch, and synchronize
  *once per window* on **all** K outputs (blocking only on the last
  output could under-measure if the runtime completes computations out
  of order). Host dispatch of call *i+1* overlaps device execution of
  call *i*, so the per-call quotient (``us_per_call_windowed``)
  approaches true device throughput; ``sync − windowed`` is the measured
  per-call dispatch + sync overhead the sync mode folds into its number.

Both modes assume device-resident inputs: ``commit_args`` pre-commits
host-side arguments (numpy arrays, python scalars) with ``device_put``
*once, before the loop*, so per-call H2D transfer never pollutes either
number. Host-transfer benchmarks (``no_jit`` meta) opt out — staging cost
is what they measure.

Layering (post staged-engine refactor): this module holds the *primitives*
— ``time_fn`` for an already-compiled callable, ``characterize_compiled``
for the static analysis of a compiled executable, and small constructors
for the result dataclasses. The staged path that compiles each workload
exactly once (or restores it from the two-tier disk cache without any
compilation) and feeds the same executable to the timer, the roofline
characterization, and the serve stage lives in ``core/engine.py``;
``time_workload`` / ``compile_workload`` remain as standalone one-shot
conveniences (each compiles on its own — use the engine for suite runs).
"""

from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Any, Callable, Sequence

import jax

from repro.core.metrics import (
    RooflineTerms,
    collective_bytes_from_hlo,
    cost_analysis_dict,
    roofline_terms,
)
from repro.core.registry import Workload

__all__ = [
    "TimingResult",
    "CompiledInfo",
    "commit_args",
    "time_workload",
    "compile_workload",
    "time_fn",
    "timing_from_stats",
    "characterize_compiled",
    "empty_compiled_info",
]


@dataclasses.dataclass(frozen=True)
class TimingResult:
    name: str
    us_per_call: float
    us_stdev: float
    iters: int
    achieved_gflops: float  # from the workload's analytic FLOP count
    achieved_gbps: float  # from the workload's analytic byte count
    # Windowed-mode companion numbers (None when only sync mode ran):
    # per-call time with K calls in flight per sync, the window size K,
    # and the derived per-call dispatch+sync overhead (sync − windowed,
    # clamped at 0 — noise can put windowed above sync).
    us_per_call_windowed: float | None = None
    timing_window: int | None = None
    timer_dispatch_us: float | None = None

    def csv(self) -> str:
        return (
            f"{self.name},{self.us_per_call:.2f},"
            f"gflops={self.achieved_gflops:.2f};gbps={self.achieved_gbps:.2f}"
        )


@dataclasses.dataclass(frozen=True)
class CompiledInfo:
    name: str
    cost: dict[str, float]
    memory: dict[str, float]
    roofline: RooflineTerms
    hlo_collectives_bytes: float


def commit_args(args: Sequence[Any]) -> tuple:
    """Pre-commit host-side argument leaves to the device, once.

    Leaves that are already ``jax.Array`` (including placed/sharded
    arrays) pass through untouched; numpy arrays and python scalars are
    ``device_put`` and blocked on, so a timing loop over the result never
    pays per-call H2D transfer. Non-array leaves it cannot commit (e.g.
    ``ShapeDtypeStruct`` in dry-run flows) also pass through unchanged.
    """

    def commit(leaf: Any) -> Any:
        if isinstance(leaf, jax.Array):
            return leaf
        try:
            return jax.block_until_ready(jax.device_put(leaf))
        except (TypeError, ValueError):
            return leaf

    return tuple(jax.tree_util.tree_map(commit, tuple(args)))


def time_fn(
    fn: Callable[..., Any],
    args: Sequence[Any],
    *,
    iters: int = 10,
    warmup: int = 3,
    window: int = 1,
) -> tuple[float, float]:
    """Return (mean_us, stdev_us) per call for an already-compiled callable.

    ``window=1`` is sync mode: synchronize after every call. ``window=K``
    is windowed mode: each of ``iters`` samples dispatches K calls and
    synchronizes once on all K outputs; the sample is the per-call
    quotient. Callers wanting device-resident inputs should pass args
    through :func:`commit_args` first (the engine and one-shot paths do).
    """
    if window < 1:
        raise ValueError(f"window must be >= 1, got {window}")
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    samples = []
    for _ in range(iters):
        if window == 1:
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*args))
            samples.append((time.perf_counter() - t0) * 1e6)
        else:
            t0 = time.perf_counter()
            outs = [fn(*args) for _ in range(window)]
            jax.block_until_ready(outs)
            samples.append((time.perf_counter() - t0) * 1e6 / window)
    mean = statistics.fmean(samples)
    stdev = statistics.stdev(samples) if len(samples) > 1 else 0.0
    return mean, stdev


def timing_from_stats(
    workload: Workload,
    *,
    mean_us: float,
    stdev_us: float,
    iters: int,
    backward: bool = False,
    windowed_us: float | None = None,
    window: int | None = None,
) -> TimingResult:
    """Fold measured wall time with the workload's analytic FLOP/byte counts.

    ``windowed_us`` / ``window`` attach the windowed-mode companion number
    when both modes ran; the derived dispatch overhead is computed here so
    every consumer sees the same clamping convention.
    """
    flops = workload.flops_bwd if backward else workload.flops
    sec = mean_us / 1e6
    return TimingResult(
        name=workload.name + (".bwd" if backward else ""),
        us_per_call=mean_us,
        us_stdev=stdev_us,
        iters=iters,
        achieved_gflops=(flops / sec / 1e9) if (flops and sec > 0) else 0.0,
        achieved_gbps=(workload.bytes_moved / sec / 1e9)
        if (workload.bytes_moved and sec > 0)
        else 0.0,
        us_per_call_windowed=windowed_us,
        timing_window=window if windowed_us is not None else None,
        timer_dispatch_us=(
            max(mean_us - windowed_us, 0.0) if windowed_us is not None else None
        ),
    )


def time_workload(
    workload: Workload,
    *,
    iters: int = 10,
    warmup: int = 3,
    seed: int = 0,
    backward: bool = False,
    window: int = 1,
) -> TimingResult:
    """Compile + validate + time one workload (forward or backward pass).

    Inputs are pre-committed to the device (``commit_args``) before the
    timing loop so standalone timings, like engine runs, never include
    per-call host transfer — except for ``no_jit`` host-transfer
    workloads, whose staging path is the measurement. ``window=K`` adds a
    windowed measurement alongside the sync one.
    """
    args = workload.make_inputs(seed)
    fn = workload.fn_bwd if backward else workload.fn
    if backward and fn is None:
        raise ValueError(f"workload {workload.name!r} has no backward pass")
    no_jit = bool(workload.meta.get("no_jit"))
    # Host-transfer benchmarks (BusSpeed*) measure the un-jitted staging path.
    jitted = fn if no_jit else jax.jit(fn)
    if not no_jit:
        args = commit_args(args)
    out = jax.block_until_ready(jitted(*args))
    if not backward and workload.validate is not None:
        workload.validate(out, args)
    mean, stdev = time_fn(jitted, args, iters=iters, warmup=warmup)
    windowed_us = None
    if window > 1 and not no_jit:
        windowed_us, _ = time_fn(jitted, args, iters=iters, warmup=0, window=window)
    return timing_from_stats(
        workload, mean_us=mean, stdev_us=stdev, iters=iters, backward=backward,
        windowed_us=windowed_us, window=window,
    )


def _memory_analysis_dict(compiled: Any) -> dict[str, float]:
    ma = compiled.memory_analysis()
    out: dict[str, float] = {}
    for key in (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "generated_code_size_in_bytes",
        "alias_size_in_bytes",
        "host_argument_size_in_bytes",
        "host_output_size_in_bytes",
        "host_temp_size_in_bytes",
    ):
        if hasattr(ma, key):
            out[key] = float(getattr(ma, key))
    return out


def characterize_compiled(compiled: Any, name: str) -> CompiledInfo:
    """Static cost/memory/roofline analysis of a compiled executable."""
    cost = cost_analysis_dict(compiled)
    coll = collective_bytes_from_hlo(compiled.as_text())
    return CompiledInfo(
        name=name,
        cost=cost,
        memory=_memory_analysis_dict(compiled),
        roofline=roofline_terms(cost, collective_bytes=coll),
        hlo_collectives_bytes=coll,
    )


def empty_compiled_info(name: str) -> CompiledInfo:
    """Placeholder for workloads with no device program (``no_jit`` meta)."""
    return CompiledInfo(
        name=name,
        cost={},
        memory={},
        roofline=roofline_terms({}, collective_bytes=0.0),
        hlo_collectives_bytes=0.0,
    )


def compile_workload(
    workload: Workload,
    *,
    seed: int = 0,
    backward: bool = False,
    abstract_args: Sequence[Any] | None = None,
) -> CompiledInfo:
    """Lower + compile, returning static cost/memory/roofline analysis.

    ``abstract_args`` lets callers pass ShapeDtypeStructs (dry-run path: no
    allocation); otherwise concrete inputs are built from ``seed`` and
    pre-committed to the device (``commit_args`` passes abstract leaves
    through untouched).
    """
    args = abstract_args if abstract_args is not None else workload.make_inputs(seed)
    fn = workload.fn_bwd if backward else workload.fn
    if backward and fn is None:
        raise ValueError(f"workload {workload.name!r} has no backward pass")
    name = workload.name + (".bwd" if backward else "")
    if workload.meta.get("no_jit"):
        # Host-transfer workloads have no device program to analyse.
        return empty_compiled_info(name)
    compiled = jax.jit(fn).lower(*commit_args(args)).compile()
    return characterize_compiled(compiled, name)
