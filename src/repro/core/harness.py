"""Timing + characterization primitives — the CUDA Event API analogue.

The paper replaces Rodinia's system-time measurement with CUDA events for
accurate kernel timing. JAX dispatch is asynchronous, so the analogue is:

- synchronize with ``jax.block_until_ready`` around a monotonic clock,
- warm up before measuring (spreads one-time allocation/transfer cost),
- report per-call microseconds with spread, plus the compiled artifact's
  static cost/memory analysis for the roofline pipeline.

Layering (post staged-engine refactor): this module holds the *primitives*
— ``time_fn`` for an already-compiled callable, ``characterize_compiled``
for the static analysis of a compiled executable, and small constructors
for the result dataclasses. The staged path that compiles each workload
exactly once and feeds the same executable to both the timer and the
characterization lives in ``core/engine.py``; ``time_workload`` /
``compile_workload`` remain as standalone one-shot conveniences (each
compiles on its own — use the engine for suite runs).
"""

from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Any, Callable, Sequence

import jax

from repro.core.metrics import (
    RooflineTerms,
    collective_bytes_from_hlo,
    cost_analysis_dict,
    roofline_terms,
)
from repro.core.registry import Workload

__all__ = [
    "TimingResult",
    "CompiledInfo",
    "time_workload",
    "compile_workload",
    "time_fn",
    "timing_from_stats",
    "characterize_compiled",
    "empty_compiled_info",
]


@dataclasses.dataclass(frozen=True)
class TimingResult:
    name: str
    us_per_call: float
    us_stdev: float
    iters: int
    achieved_gflops: float  # from the workload's analytic FLOP count
    achieved_gbps: float  # from the workload's analytic byte count

    def csv(self) -> str:
        return (
            f"{self.name},{self.us_per_call:.2f},"
            f"gflops={self.achieved_gflops:.2f};gbps={self.achieved_gbps:.2f}"
        )


@dataclasses.dataclass(frozen=True)
class CompiledInfo:
    name: str
    cost: dict[str, float]
    memory: dict[str, float]
    roofline: RooflineTerms
    hlo_collectives_bytes: float


def time_fn(
    fn: Callable[..., Any],
    args: Sequence[Any],
    *,
    iters: int = 10,
    warmup: int = 3,
) -> tuple[float, float]:
    """Return (mean_us, stdev_us) for an already-compiled callable."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    samples = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        samples.append((time.perf_counter() - t0) * 1e6)
    mean = statistics.fmean(samples)
    stdev = statistics.stdev(samples) if len(samples) > 1 else 0.0
    return mean, stdev


def timing_from_stats(
    workload: Workload,
    *,
    mean_us: float,
    stdev_us: float,
    iters: int,
    backward: bool = False,
) -> TimingResult:
    """Fold measured wall time with the workload's analytic FLOP/byte counts."""
    flops = workload.flops_bwd if backward else workload.flops
    sec = mean_us / 1e6
    return TimingResult(
        name=workload.name + (".bwd" if backward else ""),
        us_per_call=mean_us,
        us_stdev=stdev_us,
        iters=iters,
        achieved_gflops=(flops / sec / 1e9) if (flops and sec > 0) else 0.0,
        achieved_gbps=(workload.bytes_moved / sec / 1e9)
        if (workload.bytes_moved and sec > 0)
        else 0.0,
    )


def time_workload(
    workload: Workload,
    *,
    iters: int = 10,
    warmup: int = 3,
    seed: int = 0,
    backward: bool = False,
) -> TimingResult:
    """Compile + validate + time one workload (forward or backward pass)."""
    args = workload.make_inputs(seed)
    fn = workload.fn_bwd if backward else workload.fn
    if backward and fn is None:
        raise ValueError(f"workload {workload.name!r} has no backward pass")
    # Host-transfer benchmarks (BusSpeed*) measure the un-jitted staging path.
    jitted = fn if workload.meta.get("no_jit") else jax.jit(fn)
    out = jax.block_until_ready(jitted(*args))
    if not backward and workload.validate is not None:
        workload.validate(out, args)
    mean, stdev = time_fn(jitted, args, iters=iters, warmup=warmup)
    return timing_from_stats(
        workload, mean_us=mean, stdev_us=stdev, iters=iters, backward=backward
    )


def _memory_analysis_dict(compiled: Any) -> dict[str, float]:
    ma = compiled.memory_analysis()
    out: dict[str, float] = {}
    for key in (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "generated_code_size_in_bytes",
        "alias_size_in_bytes",
        "host_argument_size_in_bytes",
        "host_output_size_in_bytes",
        "host_temp_size_in_bytes",
    ):
        if hasattr(ma, key):
            out[key] = float(getattr(ma, key))
    return out


def characterize_compiled(compiled: Any, name: str) -> CompiledInfo:
    """Static cost/memory/roofline analysis of a compiled executable."""
    cost = cost_analysis_dict(compiled)
    coll = collective_bytes_from_hlo(compiled.as_text())
    return CompiledInfo(
        name=name,
        cost=cost,
        memory=_memory_analysis_dict(compiled),
        roofline=roofline_terms(cost, collective_bytes=coll),
        hlo_collectives_bytes=coll,
    )


def empty_compiled_info(name: str) -> CompiledInfo:
    """Placeholder for workloads with no device program (``no_jit`` meta)."""
    return CompiledInfo(
        name=name,
        cost={},
        memory={},
        roofline=roofline_terms({}, collective_bytes=0.0),
        hlo_collectives_bytes=0.0,
    )


def compile_workload(
    workload: Workload,
    *,
    seed: int = 0,
    backward: bool = False,
    abstract_args: Sequence[Any] | None = None,
) -> CompiledInfo:
    """Lower + compile, returning static cost/memory/roofline analysis.

    ``abstract_args`` lets callers pass ShapeDtypeStructs (dry-run path: no
    allocation); otherwise concrete inputs are built from ``seed``.
    """
    args = abstract_args if abstract_args is not None else workload.make_inputs(seed)
    fn = workload.fn_bwd if backward else workload.fn
    if backward and fn is None:
        raise ValueError(f"workload {workload.name!r} has no backward pass")
    name = workload.name + (".bwd" if backward else "")
    if workload.meta.get("no_jit"):
        # Host-transfer workloads have no device program to analyse.
        return empty_compiled_info(name)
    compiled = jax.jit(fn).lower(*args).compile()
    return characterize_compiled(compiled, name)
