"""Timing + compilation harness — the CUDA Event API analogue.

The paper replaces Rodinia's system-time measurement with CUDA events for
accurate kernel timing. JAX dispatch is asynchronous, so the analogue is:

- compile first (``jax.jit(fn).lower(...).compile()``) so timing never
  includes tracing/compilation,
- synchronize with ``jax.block_until_ready`` around a monotonic clock,
- warm up before measuring (spreads one-time allocation/transfer cost),
- report per-call microseconds with spread, plus the compiled artifact's
  static cost/memory analysis for the roofline pipeline.
"""

from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Any, Callable, Sequence

import jax

from repro.core.metrics import (
    RooflineTerms,
    collective_bytes_from_hlo,
    roofline_terms,
)
from repro.core.registry import Workload

__all__ = ["TimingResult", "CompiledInfo", "time_workload", "compile_workload", "time_fn"]


@dataclasses.dataclass(frozen=True)
class TimingResult:
    name: str
    us_per_call: float
    us_stdev: float
    iters: int
    achieved_gflops: float  # from the workload's analytic FLOP count
    achieved_gbps: float  # from the workload's analytic byte count

    def csv(self) -> str:
        return (
            f"{self.name},{self.us_per_call:.2f},"
            f"gflops={self.achieved_gflops:.2f};gbps={self.achieved_gbps:.2f}"
        )


@dataclasses.dataclass(frozen=True)
class CompiledInfo:
    name: str
    cost: dict[str, float]
    memory: dict[str, float]
    roofline: RooflineTerms
    hlo_collectives_bytes: float


def time_fn(
    fn: Callable[..., Any],
    args: Sequence[Any],
    *,
    iters: int = 10,
    warmup: int = 3,
) -> tuple[float, float]:
    """Return (mean_us, stdev_us) for an already-compiled callable."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    samples = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        samples.append((time.perf_counter() - t0) * 1e6)
    mean = statistics.fmean(samples)
    stdev = statistics.stdev(samples) if len(samples) > 1 else 0.0
    return mean, stdev


def time_workload(
    workload: Workload,
    *,
    iters: int = 10,
    warmup: int = 3,
    seed: int = 0,
    backward: bool = False,
) -> TimingResult:
    """Compile + validate + time one workload (forward or backward pass)."""
    args = workload.make_inputs(seed)
    fn = workload.fn_bwd if backward else workload.fn
    if backward and fn is None:
        raise ValueError(f"workload {workload.name!r} has no backward pass")
    # Host-transfer benchmarks (BusSpeed*) measure the un-jitted staging path.
    jitted = fn if workload.meta.get("no_jit") else jax.jit(fn)
    out = jax.block_until_ready(jitted(*args))
    if not backward and workload.validate is not None:
        workload.validate(out, args)
    mean, stdev = time_fn(jitted, args, iters=iters, warmup=warmup)
    flops = workload.flops_bwd if backward else workload.flops
    name = workload.name + (".bwd" if backward else "")
    sec = mean / 1e6
    return TimingResult(
        name=name,
        us_per_call=mean,
        us_stdev=stdev,
        iters=iters,
        achieved_gflops=(flops / sec / 1e9) if (flops and sec > 0) else 0.0,
        achieved_gbps=(workload.bytes_moved / sec / 1e9)
        if (workload.bytes_moved and sec > 0)
        else 0.0,
    )


def _memory_analysis_dict(compiled: Any) -> dict[str, float]:
    ma = compiled.memory_analysis()
    out: dict[str, float] = {}
    for key in (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "generated_code_size_in_bytes",
        "alias_size_in_bytes",
        "host_argument_size_in_bytes",
        "host_output_size_in_bytes",
        "host_temp_size_in_bytes",
    ):
        if hasattr(ma, key):
            out[key] = float(getattr(ma, key))
    return out


def compile_workload(
    workload: Workload,
    *,
    seed: int = 0,
    backward: bool = False,
    abstract_args: Sequence[Any] | None = None,
) -> CompiledInfo:
    """Lower + compile, returning static cost/memory/roofline analysis.

    ``abstract_args`` lets callers pass ShapeDtypeStructs (dry-run path: no
    allocation); otherwise concrete inputs are built from ``seed``.
    """
    args = abstract_args if abstract_args is not None else workload.make_inputs(seed)
    fn = workload.fn_bwd if backward else workload.fn
    if backward and fn is None:
        raise ValueError(f"workload {workload.name!r} has no backward pass")
    if workload.meta.get("no_jit"):
        # Host-transfer workloads have no device program to analyse.
        from repro.core.metrics import roofline_terms as _rt

        return CompiledInfo(
            name=workload.name + (".bwd" if backward else ""),
            cost={},
            memory={},
            roofline=_rt({}, collective_bytes=0.0),
            hlo_collectives_bytes=0.0,
        )
    lowered = jax.jit(fn).lower(*args)
    compiled = lowered.compile()
    cost = dict(compiled.cost_analysis() or {})
    coll = collective_bytes_from_hlo(compiled.as_text())
    return CompiledInfo(
        name=workload.name + (".bwd" if backward else ""),
        cost={k: float(v) for k, v in cost.items() if isinstance(v, (int, float))},
        memory=_memory_analysis_dict(compiled),
        roofline=roofline_terms(cost, collective_bytes=coll),
        hlo_collectives_bytes=coll,
    )
