"""Execution plans — the declarative half of the staged engine.

An :class:`ExecutionPlan` is a frozen value object describing *what* to run
(selection by level / name / tag / domain, or an explicit spec list), *at
what size* (SHOC-style preset plus Rodinia-style per-benchmark overrides),
*which passes* (forward, and backward where a workload defines one), *how to
measure* (iters / warmup / seed, plus ``timing_window`` — sync-mode timing
always runs; a window K > 1 additionally measures with K calls in flight
per synchronization, riding async dispatch, so records carry both
``us_per_call`` and ``us_per_call_windowed``), *where* (a :class:`Placement` —
device count plus mode, ``replicate`` or ``shard``, realized through
``runtime/sharding`` helpers; ``device_sweep`` runs the same selection at
several device counts for scaling curves), and *under what load* (an
optional :class:`ServeSpec` — open/closed-loop serving through N dispatch
lanes issued by a single-threaded or thread-per-lane client, with
optional SLO goodput and co-location; realized by the engine's serve
stage via ``repro.serve``).

Plans carry no execution state: the engine (``core/engine.py``) consumes a
plan, owns the compilation cache and the stage sequence, and emits records.
Two engines given equal plans produce comparable runs; the same plan can be
re-run against a warm engine to reuse every compiled executable.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Mapping

from repro.core.registry import BenchmarkSpec, Workload, all_benchmarks

__all__ = [
    "ExecutionPlan",
    "Placement",
    "ServeSpec",
    "ShapeBucket",
    "PlanError",
    "PLACEMENT_MODES",
    "SERVE_MODES",
    "SERVE_CLIENTS",
    "SERVE_DISPATCH",
    "IMPLS",
]

PLACEMENT_MODES = ("replicate", "shard")
IMPLS = ("xla", "pallas")
SERVE_MODES = ("open", "closed")
SERVE_CLIENTS = ("single", "threaded")
# How requests map onto device programs. "lanes" is the pre-mix default
# (N dispatch lanes over the measure-stage executable); the other three
# are the mixed-shape paths realized by serve/batcher.py: "loop" is the
# sync-per-request floor, "batched" a fixed-width vmap that waits to fill,
# "dynamic" the continuous batcher that coalesces compatible requests into
# the largest width that fits under the latency budget.
SERVE_DISPATCH = ("lanes", "loop", "batched", "dynamic")


class PlanError(ValueError):
    """A plan or placement that cannot be executed as configured (bad
    selection, unknown mode, more devices than the host offers). CLIs treat
    it as a configuration error — exit 2, no traceback."""


@dataclasses.dataclass(frozen=True)
class Placement:
    """Where a plan runs: how many devices, and what lands on them.

    - ``replicate``: every input is device_put fully replicated across the
      data mesh — all devices do identical work (the pre-placement
      behaviour of the old scalar ``devices`` knob).
    - ``shard``: inputs of workloads that declare ``batch_dims`` are
      partitioned along those dims across the data mesh (data parallelism);
      workloads that opt out (``batch_dims=None``) fall back to replicate,
      and the record says so.

    A placement is part of the engine's compile-cache key: the sharded and
    replicated lowerings of one workload are distinct executables.
    """

    devices: int = 1
    mode: str = "replicate"

    def __post_init__(self) -> None:
        if self.devices < 1:
            raise PlanError(f"placement devices must be >= 1, got {self.devices}")
        if self.mode not in PLACEMENT_MODES:
            raise PlanError(
                f"placement mode must be one of {PLACEMENT_MODES}, got {self.mode!r}"
            )


@dataclasses.dataclass(frozen=True)
class ShapeBucket:
    """One request shape in a serve mix: a preset (plus optional per-param
    overrides on top of it) drawn with probability proportional to
    ``weight``. Buckets are identified everywhere — requests, traces,
    compile-cache keys, per-bucket record columns — by :attr:`label`
    (``p<preset>`` plus ``/param=value`` for each override)."""

    preset: int = 0
    weight: float = 1.0
    overrides: tuple[tuple[str, Any], ...] = ()

    def __post_init__(self) -> None:
        if self.preset < 0:
            raise PlanError(f"mix bucket preset must be >= 0, got {self.preset}")
        if not self.weight > 0:
            raise PlanError(f"mix bucket weight must be > 0, got {self.weight}")
        if not isinstance(self.overrides, tuple):
            object.__setattr__(
                self,
                "overrides",
                tuple(tuple(kv) for kv in self.overrides),
            )
        else:
            object.__setattr__(
                self,
                "overrides",
                tuple(
                    kv if isinstance(kv, tuple) else tuple(kv)
                    for kv in self.overrides
                ),
            )
        for kv in self.overrides:
            if len(kv) != 2 or not isinstance(kv[0], str):
                raise PlanError(
                    f"mix bucket overrides must be (param, value) pairs, "
                    f"got {self.overrides!r}"
                )
            _freeze_value("mix", kv[0], kv[1])

    @property
    def label(self) -> str:
        parts = [f"p{self.preset}"]
        parts += [f"{k}={v}" for k, v in sorted(self.overrides)]
        return "/".join(parts)


@dataclasses.dataclass(frozen=True)
class ServeSpec:
    """How to serve the selected workloads under load (``repro.serve``).

    - ``mode="closed"``: keep ``concurrency`` requests in flight across
      ``lanes`` dispatch lanes for ``duration_s`` seconds (throughput-
      oriented; the next request is issued the moment a slot frees).
    - ``mode="open"``: Poisson arrivals at ``qps`` for ``duration_s``
      seconds, deterministic for the plan's seed; ``concurrency`` caps
      total in-flight work under overload.
    - ``client``: the host-side issue architecture. ``single`` dispatches
      every lane from one host thread (the pre-threaded behaviour);
      ``threaded`` gives each lane its own issuing thread fed from a
      deterministic per-lane sub-schedule, so host-side contention between
      lanes becomes part of the measurement (``repro.serve.client``).
    - ``slo_us``: optional latency SLO; rows then carry ``goodput_qps``
      (completions with latency <= the SLO per second — a request at
      exactly the SLO counts as good).
    - ``colocate``: serve every selected workload *paired* with this
      registered benchmark, splitting the lanes between the two tenants,
      and record each tenant's slowdown vs its isolated baseline. A
      closed-loop measurement (open arrivals would conflate queueing with
      interference), so it requires ``mode="closed"``; its dispatch is
      single-threaded by construction (tenants alternate submissions), so
      it requires ``client="single"``.
    - ``mix``: a tuple of :class:`ShapeBucket` — each open-loop request
      draws its shape from this weighted distribution (seeded off the
      plan seed, independently of the arrival draws). The engine then
      precompiles one executable per (bucket, batch width) through both
      compile caches and serves via ``repro.serve.batcher``.
    - ``dispatch``: how requests map onto device programs (one of
      ``SERVE_DISPATCH``). ``lanes`` is the classic N-lane path; ``loop``
      / ``batched`` / ``dynamic`` are the mixed-shape paths — sync
      per-request floor, fixed-width vmap that waits to fill, and the
      continuous batcher that coalesces queued requests of one bucket
      into the largest width that fits under ``batch_budget_us``.
      Padding to a width edge is *measured* (``padding_waste``), never
      hidden.
    - ``trace``: path to a replayable JSONL arrival+shape trace. If the
      file exists it is loaded verbatim (qps/duration/mix draws are
      ignored — the trace IS the load); otherwise the generated schedule
      is saved there, so two runs with different dispatch modes replay
      the identical request stream.
    - ``batch_budget_us`` / ``max_batch``: dynamic-batcher knobs — how
      long the oldest queued request may wait before a partial batch
      dispatches anyway, and the largest vmap width (widths are powers
      of two up to it).
    - ``client_procs``: distributed load generation (``repro.dist``).
      0 (default) generates all load in this process; N > 0 spawns N
      client *processes*, each replaying a seeded per-process
      sub-schedule (``SeedSequence.spawn`` off the plan seed — the merged
      stream is still Poisson at ``qps`` and byte-identical per seed) and
      streaming completion stamps back over a local socket for merged
      percentile accounting, so offered QPS scales past one Python
      process's dispatch ceiling. Open-loop only; within each process the
      sub-schedule is dispatched single-threaded across ``lanes`` lanes.

    The engine runs serving as a stage after ``measure``. Dispatch
    ``lanes`` without a mix calls the *same cached executable* the timer
    used — never a recompile (and a sharded plan serves the sharded
    lowering); the mixed-shape paths serve per-bucket executables that
    went through the ordinary CompileCache and the HLO disk cache, so a
    warm run restores every bucket with zero XLA compiles.
    """

    mode: str = "closed"
    qps: float = 0.0
    concurrency: int = 4
    lanes: int = 2
    duration_s: float = 2.0
    colocate: str | None = None
    client: str = "single"
    slo_us: float | None = None
    dispatch: str = "lanes"
    mix: tuple[ShapeBucket, ...] | None = None
    trace: str | None = None
    batch_budget_us: float = 2000.0
    max_batch: int = 8
    client_procs: int = 0

    def __post_init__(self) -> None:
        if self.mix is not None:
            entries = []
            for entry in self.mix:
                if isinstance(entry, Mapping):  # RunMetadata JSON round-trip
                    known = {f.name for f in dataclasses.fields(ShapeBucket)}
                    entry = ShapeBucket(
                        **{k: v for k, v in entry.items() if k in known}
                    )
                elif not isinstance(entry, ShapeBucket):
                    raise PlanError(
                        f"serve mix entries must be ShapeBucket, got {entry!r}"
                    )
                entries.append(entry)
            if not entries:
                raise PlanError("serve mix must have at least one bucket")
            labels = [e.label for e in entries]
            if len(set(labels)) != len(labels):
                raise PlanError(f"serve mix has duplicate buckets: {labels}")
            object.__setattr__(self, "mix", tuple(entries))
        if self.mode not in SERVE_MODES:
            raise PlanError(
                f"serve mode must be one of {SERVE_MODES}, got {self.mode!r}"
            )
        if self.client not in SERVE_CLIENTS:
            raise PlanError(
                f"serve client must be one of {SERVE_CLIENTS}, got {self.client!r}"
            )
        if self.mode == "open" and self.qps <= 0:
            raise PlanError(f"open-loop serving needs qps > 0, got {self.qps}")
        if self.concurrency < 1:
            raise PlanError(f"serve concurrency must be >= 1, got {self.concurrency}")
        if self.lanes < 1:
            raise PlanError(f"serve lanes must be >= 1, got {self.lanes}")
        if self.duration_s <= 0:
            raise PlanError(f"serve duration_s must be > 0, got {self.duration_s}")
        if self.slo_us is not None and self.slo_us <= 0:
            raise PlanError(f"serve slo_us must be > 0, got {self.slo_us}")
        if self.colocate is not None and self.mode != "closed":
            raise PlanError(
                "co-location is a closed-loop measurement; "
                f"got colocate={self.colocate!r} with mode={self.mode!r}"
            )
        if self.colocate is not None and self.client != "single":
            raise PlanError(
                "co-location dispatch is single-threaded (tenants alternate "
                f"submissions); got colocate={self.colocate!r} with "
                f"client={self.client!r}"
            )
        if self.dispatch not in SERVE_DISPATCH:
            raise PlanError(
                f"serve dispatch must be one of {SERVE_DISPATCH}, "
                f"got {self.dispatch!r}"
            )
        if self.batch_budget_us <= 0:
            raise PlanError(
                f"batch_budget_us must be > 0, got {self.batch_budget_us}"
            )
        if self.max_batch < 1:
            raise PlanError(f"max_batch must be >= 1, got {self.max_batch}")
        mixed = (
            self.mix is not None
            or self.trace is not None
            or self.dispatch != "lanes"
        )
        if mixed and self.mode != "open":
            raise PlanError(
                "mixed-shape serving (mix/trace/dispatch != 'lanes') is "
                f"arrival-driven; it requires mode='open', got {self.mode!r}"
            )
        if mixed and self.client != "single":
            raise PlanError(
                "mixed-shape serving dispatches from one host thread; "
                f"it requires client='single', got {self.client!r}"
            )
        if mixed and self.colocate is not None:
            raise PlanError(
                "mixed-shape serving cannot be combined with colocate "
                f"(got colocate={self.colocate!r})"
            )
        if self.client_procs < 0:
            raise PlanError(
                f"client_procs must be >= 0, got {self.client_procs}"
            )
        if self.client_procs > 0:
            if self.mode != "open":
                raise PlanError(
                    "distributed client processes replay seeded arrival "
                    "sub-schedules; client_procs requires mode='open', "
                    f"got {self.mode!r}"
                )
            if mixed:
                raise PlanError(
                    "distributed serving covers the classic lanes path; "
                    "client_procs cannot be combined with mix/trace/"
                    f"dispatch != 'lanes' (got dispatch={self.dispatch!r})"
                )
            if self.colocate is not None:
                raise PlanError(
                    "co-location is a closed-loop single-process "
                    f"measurement; got colocate={self.colocate!r} with "
                    f"client_procs={self.client_procs}"
                )
            if self.client != "single":
                raise PlanError(
                    "each distributed client process dispatches its "
                    "sub-schedule from one thread; client_procs requires "
                    f"client='single', got {self.client!r}"
                )

    @property
    def is_mixed(self) -> bool:
        """True when serving goes through the mixed-shape/batcher path
        (per-bucket executables) rather than the classic lanes path."""
        return (
            self.mix is not None
            or self.trace is not None
            or self.dispatch != "lanes"
        )

    def buckets(self, default_preset: int) -> tuple[ShapeBucket, ...]:
        """The effective bucket set: the mix, or one bucket at the plan's
        preset when only trace/dispatch selected the mixed path."""
        if self.mix is not None:
            return self.mix
        return (ShapeBucket(preset=default_preset),)


def _freeze_value(name: str, param: str, value: Any) -> Any:
    """Override values feed the engine's compile-cache key: fail fast on
    unhashable ones (lists become tuples) instead of erroring per-benchmark."""
    if isinstance(value, list):
        value = tuple(value)
    try:
        hash(value)
    except TypeError:
        raise ValueError(
            f"override {name}.{param}={value!r} is not hashable; "
            f"use scalars or tuples"
        ) from None
    return value


def _freeze_overrides(
    overrides: Mapping[str, Mapping[str, Any]] | None,
) -> tuple[tuple[str, tuple[tuple[str, Any], ...]], ...]:
    """Canonicalize name->{param: value} into a hashable, sorted tuple."""
    if not overrides:
        return ()
    return tuple(
        (
            name,
            tuple(
                (param, _freeze_value(name, param, value))
                for param, value in sorted(kwargs.items())
            ),
        )
        for name, kwargs in sorted(overrides.items())
    )


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """What to run, at what size, how many passes, on how many devices."""

    levels: tuple[int, ...] = (0, 1, 2)
    names: tuple[str, ...] | None = None
    tags: tuple[str, ...] | None = None
    domains: tuple[str, ...] | None = None
    preset: int = 0
    # Rodinia-style size overrides: benchmark name -> {param: value}, applied
    # on top of the preset by BenchmarkSpec.build_preset.
    overrides: tuple[tuple[str, tuple[tuple[str, Any], ...]], ...] = ()
    include_backward: bool = True
    iters: int = 5
    warmup: int = 2
    seed: int = 0
    # Windowed timing: per measured pass, additionally dispatch `iters`
    # windows of K calls and synchronize once per window (K=1 disables —
    # sync-only, the pre-v5 behaviour). Sync mode always runs; the
    # windowed number amortizes per-call dispatch+sync overhead, which is
    # the paper's async-runtime timing pitfall for small kernels.
    timing_window: int = 4
    # Multi-device placement: a frozen Placement(devices, mode) value object.
    # `devices=N` remains accepted as back-compat sugar for
    # Placement(devices=N, mode="replicate"); after construction
    # `plan.devices` always mirrors `plan.placement.devices`.
    placement: Placement | None = None
    devices: int | None = None
    # Scaling sweep: run the selection once per device count (sorted
    # ascending, deduplicated) under placement.mode, sharing the compile
    # cache across counts. None = just (placement.devices,).
    device_sweep: tuple[int, ...] | None = None
    # Implementation axis: which lowering of each workload to compile and
    # time. "xla" (default) traces the jnp/lax path; "pallas" traces the
    # hand-written kernel for workloads that declare one (pallas_kernel on
    # the Workload — registry.py impl contract), with a recorded fallback
    # to xla otherwise. Part of the compile-cache key, like placement.
    impl: str = "xla"
    # Autotune: sweep each Pallas kernel's tune_space() in a stage between
    # place and compile, timing candidates with the windowed timer; the
    # winner persists in the HLO disk cache so warm runs skip the sweep.
    # No-op for impl="xla" (there is nothing to tune on the lax path).
    tune: bool = False
    # Serve the selection under generated load after measuring it: a frozen
    # ServeSpec (mode/qps/concurrency/lanes/duration/colocate), or None for
    # isolation-only runs (the pre-serve behaviour).
    serve: ServeSpec | None = None
    # Escape hatch for tests and programmatic callers: bypass the registry
    # and run exactly these specs (selection filters are ignored).
    specs: tuple[BenchmarkSpec, ...] | None = None

    def __post_init__(self) -> None:
        # Normalize sequence-ish fields so equal plans compare/hash equal.
        def norm(field: str, value):
            if value is not None and not isinstance(value, tuple):
                object.__setattr__(self, field, tuple(value))

        for f in ("levels", "names", "tags", "domains", "specs"):
            norm(f, getattr(self, f))
        if not isinstance(self.overrides, tuple):
            object.__setattr__(self, "overrides", _freeze_overrides(self.overrides))
        if self.iters < 1:
            raise ValueError(f"iters must be >= 1, got {self.iters}")
        if self.warmup < 0:
            raise ValueError(f"warmup must be >= 0, got {self.warmup}")
        if self.timing_window < 1:
            raise ValueError(
                f"timing_window must be >= 1 (1 = sync-only), "
                f"got {self.timing_window}"
            )
        if self.impl not in IMPLS:
            raise PlanError(f"impl must be one of {IMPLS}, got {self.impl!r}")
        if self.serve is not None and not isinstance(self.serve, ServeSpec):
            raise PlanError(f"serve must be a ServeSpec, got {self.serve!r}")
        self._resolve_placement()

    def _resolve_placement(self) -> None:
        placement = self.placement
        if placement is None:
            devices = 1 if self.devices is None else self.devices
            if devices < 1:
                raise PlanError(f"devices must be >= 1, got {devices}")
            placement = Placement(devices=devices, mode="replicate")
        elif isinstance(placement, int):  # Placement-shaped sugar
            placement = Placement(devices=placement, mode="replicate")
        elif not isinstance(placement, Placement):
            raise PlanError(
                f"placement must be a Placement (or int), got {placement!r}"
            )
        if self.devices is not None and self.devices != placement.devices:
            raise PlanError(
                f"conflicting device counts: devices={self.devices} vs "
                f"placement.devices={placement.devices}; pass one or the other"
            )
        object.__setattr__(self, "placement", placement)
        object.__setattr__(self, "devices", placement.devices)
        sweep = self.device_sweep
        if sweep is None:
            sweep = (placement.devices,)
        else:
            if not isinstance(sweep, tuple):
                sweep = tuple(sweep)
            if not sweep:
                raise PlanError("device_sweep is empty")
            for n in sweep:
                if not isinstance(n, int) or n < 1:
                    raise PlanError(
                        f"device_sweep entries must be ints >= 1, got {sweep}"
                    )
            # Ascending order puts the 1-device baseline first, so sweep
            # records can carry scaling_efficiency as they stream out.
            sweep = tuple(sorted(set(sweep)))
        object.__setattr__(self, "device_sweep", sweep)

    def placement_at(self, devices: int) -> Placement:
        """The effective placement for one sweep step: the plan's mode at
        ``devices`` (sharding over one device degenerates to replicate)."""
        mode = self.placement.mode if devices > 1 else "replicate"
        return Placement(devices=devices, mode=mode)

    # -- selection ---------------------------------------------------------

    def select(self) -> list[BenchmarkSpec]:
        """Resolve the plan's selection against the registry (or ``specs``)."""
        if self.specs is not None:
            if not self.specs:
                raise ValueError("plan.specs is empty")
            return list(self.specs)
        cands = all_benchmarks()
        if self.names is not None:
            known = {s.name for s in cands}
            unknown = sorted(set(self.names) - known)
            if unknown:
                raise ValueError(
                    f"unknown benchmark(s) {unknown}; known: {sorted(known)}"
                )
        selected = [
            s
            for s in cands
            if s.level in self.levels
            and (self.names is None or s.name in self.names)
            and (self.tags is None or set(self.tags) & set(s.tags))
            and (self.domains is None or s.domain in self.domains)
        ]
        if not selected:
            raise ValueError(
                f"no benchmarks match levels={self.levels} names={self.names} "
                f"tags={self.tags} domains={self.domains}"
            )
        return selected

    def resolve_preset(self, spec: BenchmarkSpec) -> int:
        """The plan preset, clamped to the smallest one the spec defines."""
        return self.preset if self.preset in spec.presets else min(spec.presets)

    def overrides_for(self, name: str) -> dict[str, Any]:
        for n, kwargs in self.overrides:
            if n == name:
                return dict(kwargs)
        return {}

    def passes(self, workload: Workload) -> list[bool]:
        """[False] (forward), plus [True] when backward is planned+defined."""
        out = [False]
        if self.include_backward and workload.fn_bwd is not None:
            out.append(True)
        return out
