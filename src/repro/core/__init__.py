# The paper's primary contribution — the Mirovia/Altis benchmark-suite
# SYSTEM: registry (Table I), preset/custom problem sizing, timing harness
# (CUDA-event analogue), roofline characterization (nvprof analogue),
# result reporting, the unified suite runner, and the modern-platform
# feature analogues (HyperQ / Unified Memory / Dynamic Parallelism /
# Cooperative Groups mapped to TPU idioms).

from repro.core.registry import (  # noqa: F401
    BenchmarkSpec,
    Workload,
    all_benchmarks,
    get_benchmark,
    register,
)
from repro.core.harness import TimingResult, compile_workload, time_workload  # noqa: F401
from repro.core.metrics import (  # noqa: F401
    TPUv5e,
    RooflineTerms,
    collective_bytes_from_hlo,
    roofline_terms,
    utilization_scale10,
)
from repro.core.results import (  # noqa: F401
    BenchmarkRecord,
    JsonlReportWriter,
    RunMetadata,
    load_records,
    load_run,
    to_csv_lines,
    write_report,
)
from repro.core.plan import ExecutionPlan, Placement, PlanError  # noqa: F401
from repro.core.engine import CompileCache, Engine, RunResult  # noqa: F401
from repro.core.suite import run_suite  # noqa: F401
