"""Roofline characterization — the TPU analogue of the paper's nvprof study.

Mirovia/Altis characterizes every benchmark with per-functional-unit
utilization (0–10) sampled by nvprof (Figs. 1, 2, 5) and uses it to classify
kernels compute- vs memory-bound (§V-A). TPUs expose no nvprof; instead the
compiled artifact gives us *exact* static FLOP and byte counts
(``compiled.cost_analysis()``) and the full collective schedule (the optimized
HLO text). From these we derive a three-term roofline per program:

    compute_s    = HLO_FLOPs_per_device   / peak_flops
    memory_s     = HLO_bytes_per_device   / hbm_bw
    collective_s = collective_bytes_per_device / ici_bw

The dominant term is the bottleneck; ``compute_s / max(terms)`` is the
roofline fraction the perf loop hillclimbs. ``utilization_scale10`` maps
fractions onto the paper's 0–10 bar scale so the Fig. 1/2/5 analogues read
identically to the original plots.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any, Mapping

__all__ = [
    "TPUv5e",
    "RooflineTerms",
    "roofline_terms",
    "cost_analysis_dict",
    "collective_bytes_from_hlo",
    "collective_ops_from_hlo",
    "utilization_scale10",
    "model_flops",
]


def cost_analysis_dict(compiled: Any) -> dict[str, float]:
    """``compiled.cost_analysis()`` normalized across jax versions.

    Older jax returns a one-element list of dicts; newer returns the dict
    directly. Non-numeric entries are dropped.
    """
    raw = compiled.cost_analysis()
    if isinstance(raw, (list, tuple)):
        raw = raw[0] if raw else {}
    return {k: float(v) for k, v in dict(raw or {}).items() if isinstance(v, (int, float))}


@dataclasses.dataclass(frozen=True)
class _HW:
    """Roofline target hardware constants."""

    name: str
    peak_bf16_flops: float  # FLOP/s per chip
    hbm_bw: float  # bytes/s per chip
    hbm_bytes: float  # capacity per chip
    ici_bw: float  # bytes/s per link
    vmem_bytes: float  # on-chip vector memory


# The assigned roofline target: TPU v5e (197 TFLOP/s bf16, 16 GiB @ 819 GB/s,
# ~50 GB/s per ICI link).
TPUv5e = _HW(
    name="tpu_v5e",
    peak_bf16_flops=197e12,
    hbm_bw=819e9,
    hbm_bytes=16 * 1024**3,
    ici_bw=50e9,
    vmem_bytes=128 * 1024**2,
)


@dataclasses.dataclass(frozen=True)
class RooflineTerms:
    """Three-term roofline for one compiled program on one chip."""

    flops: float  # per-device HLO FLOPs
    hbm_bytes: float  # per-device HLO bytes accessed
    collective_bytes: float  # per-device bytes over ICI
    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.__getitem__)

    @property
    def total_s(self) -> float:
        # No-overlap upper bound; with perfect overlap the step time is
        # max(...) instead. Both are reported; the fraction uses max().
        return self.compute_s + self.memory_s + self.collective_s

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the step spent doing peak-rate compute, assuming
        perfect overlap: 1.0 means MXU-bound at peak."""
        return 0.0 if self.bound_s == 0 else self.compute_s / self.bound_s

    def arithmetic_intensity(self) -> float:
        return self.flops / max(self.hbm_bytes, 1.0)


def roofline_terms(
    cost: Mapping[str, float],
    *,
    collective_bytes: float = 0.0,
    hw: _HW = TPUv5e,
) -> RooflineTerms:
    """Build roofline terms from ``compiled.cost_analysis()`` output.

    ``cost_analysis`` runs *after* SPMD partitioning, so flops/bytes are
    per-device numbers (verified in tests/test_metrics.py against a matmul of
    known size). ``bytes accessed`` includes operand + output traffic, i.e.
    an HBM-roundtrip upper bound that double counts what stays resident in
    VMEM — acceptable for a static bound, and consistent across benchmarks.
    """
    flops = float(cost.get("flops", 0.0))
    # Sum every "bytes accessed..." key once; XLA splits operand/output
    # traffic into e.g. 'bytes accessed', 'bytes accessed0{}', 'utilization..'.
    if "bytes accessed" in cost:
        hbm = float(cost["bytes accessed"])
    else:
        hbm = float(
            sum(v for k, v in cost.items() if k.startswith("bytes accessed"))
        )
    return RooflineTerms(
        flops=flops,
        hbm_bytes=hbm,
        collective_bytes=collective_bytes,
        compute_s=flops / hw.peak_bf16_flops,
        memory_s=hbm / hw.hbm_bw,
        collective_s=collective_bytes / hw.ici_bw,
    )


# ---------------------------------------------------------------------------
# Collective traffic from optimized HLO text.
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {
    "pred": 1,
    "s4": 1, "u4": 1,
    "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# Matches e.g. `  %x = bf16[16,512,128]{2,1,0:T(8,128)} all-gather(...)` and
# tuple-shaped starts `(f32[8,128]{...}, f32[8,128]{...}) all-reduce(...)`.
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s*(?P<shape>\([^)]*\)|\w+\[[\d,]*\](?:\{[^}]*\})?)\s*"
    r"(?P<op>" + "|".join(_COLLECTIVES) + r")(?:-start|-done)?\("
)


def _shape_bytes(shape_str: str) -> float:
    """Total bytes of a (possibly tuple) HLO shape string."""
    total = 0.0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_ops_from_hlo(hlo_text: str) -> list[tuple[str, float]]:
    """Return (op_kind, ici_bytes_per_device) for every collective in the HLO.

    Bytes use ring-algorithm estimates with the (n-1)/n factor dropped
    (documented upper bound, exact as n→∞):

    - all-gather:        result bytes (each device receives the full result)
    - reduce-scatter:    operand ≈ result × n; we charge result × 1 per hop
      summed over n-1 hops ≈ full-operand bytes ≈ result bytes × n. Since n
      is not recoverable from the shape alone, we charge the *operand* side:
      the `-start` op result already reflects the scattered shape, so we
      approximate with gathered bytes when derivable, else result bytes.
    - all-reduce:        2 × result bytes (reduce-scatter + all-gather ring)
    - all-to-all:        result bytes
    - collective-permute: result bytes

    Only `-start` (or plain) forms are counted; `-done` carries no traffic.
    """
    out: list[tuple[str, float]] = []
    for line in hlo_text.splitlines():
        if "-done(" in line or "-done.(" in line:
            continue
        m = _OP_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        nbytes = _shape_bytes(m.group("shape"))
        if op == "all-reduce":
            nbytes *= 2.0
        out.append((op, nbytes))
    return out


def collective_bytes_from_hlo(hlo_text: str) -> float:
    return float(sum(b for _, b in collective_ops_from_hlo(hlo_text)))


def utilization_scale10(fraction: float) -> int:
    """Map a roofline fraction onto the paper's 0–10 utilization bar scale."""
    return max(0, min(10, round(10.0 * fraction)))


def model_flops(n_params: float, n_tokens: float, *, active_params: float | None = None) -> float:
    """The paper-of-record useful-FLOPs estimate: 6·N·D (dense) or
    6·N_active·D (MoE) — used for the 'useful compute' ratio in §Roofline."""
    n = active_params if active_params is not None else n_params
    return 6.0 * n * n_tokens
