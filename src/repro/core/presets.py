"""Problem-size presets — the paper's §III-B sizing contribution.

SHOC ships 4 frozen sizes (too small, forever); Rodinia ships none (users
must guess). Mirovia/Altis ships *presets plus overrides*. Here every
benchmark declares presets ``0..4`` built by geometric scaling from a base
size, and ``BenchmarkSpec.build_preset(preset, **overrides)`` applies
Rodinia-style per-parameter overrides on top. Preset intents:

- 0: CI/smoke — milliseconds on one CPU core (what tests and the default
     suite run use in this container),
- 1: laptop-scale,
- 2: single accelerator,
- 3: large single accelerator (fills a v5e),
- 4: future headroom (explicitly allowed to exceed today's devices so the
     suite "stays relevant as problem sizes grow" — §III-B).
"""

from __future__ import annotations

from typing import Any, Mapping

__all__ = ["geometric_presets", "PRESET_LEVELS"]

PRESET_LEVELS = (0, 1, 2, 3, 4)


def geometric_presets(
    base: Mapping[str, Any],
    *,
    scale_keys: Mapping[str, float],
    levels: tuple[int, ...] = PRESET_LEVELS,
    round_to: int = 1,
) -> dict[int, dict[str, Any]]:
    """Build presets by scaling ``scale_keys`` of ``base`` geometrically.

    ``scale_keys`` maps parameter name -> per-level multiplier (applied
    ``level`` times). Non-scaled keys are copied verbatim. Integer parameters
    are rounded to a multiple of ``round_to`` (e.g. 8 or 128 for
    MXU-alignment-sensitive sizes).
    """
    out: dict[int, dict[str, Any]] = {}
    for level in levels:
        kwargs = dict(base)
        for key, factor in scale_keys.items():
            v = base[key]
            scaled = v * (factor**level)
            if isinstance(v, int):
                scaled = max(round_to, int(round(scaled / round_to)) * round_to)
            kwargs[key] = scaled
        out[level] = kwargs
    return out
