"""Cross-process persistence of compile artifacts — a two-tier cache that
closes the ROADMAP's "serialized executables" open item.

The in-process :class:`~repro.core.engine.CompileCache` dies with the
process, so every CI suite run re-pays tracing *and* XLA compilation for
every workload. This cache persists, per compile-cache key, **two tiers**
of what the compile stage produced, plus the static characterization
(cost / memory / collective bytes) that rebuilds
:class:`~repro.core.harness.CompiledInfo` without touching an executable:

- **Tier 1 — serialized executable** (``<key>.exe``): the AOT-serialized
  compiled executable (``backend.serialize_executable``). A warm load
  deserializes it straight into a runnable — *zero* retracing and *zero*
  XLA compilation. This is what makes a warm ``--cache-dir`` suite run a
  zero-compile run.
- **Tier 2 — lowered HLO text** (``<key>.json``): the StableHLO module
  text. A warm load hands it to the backend compiler (``client.compile``)
  — it still pays one XLA compilation but skips Python retracing. This is
  the fallback when the executable blob is missing or no longer
  deserializes (toolchain drift).

A third sidecar (``<key>.tune.json``, :meth:`store_tuned` /
:meth:`load_tuned`) persists the engine's autotune winner — the Pallas
block config ``_stage_tune`` selected — next to the executable it was
selected for. It is keyed on the *base* compile-cache key (the one without
tuned params folded in), so a warm ``--tune`` run restores the winner
first, then loads the winner's executable: zero tune trials, zero
compiles. The same versioned directory scopes it: an edited kernel or a
new toolchain invalidates winners along with executables.

Entries are versioned by ``jax.__version__``, ``jaxlib.__version__``, the
backend, an explicit topology token (device kind × device count ×
process count — a serialized executable is compiled *for* a topology),
and a content hash of the ``repro`` package source (a new toolchain *or
an edited kernel* gets a fresh directory rather than stale artifacts),
keyed by a hash of the engine's compile-cache key.

**Multi-device (sharded) entries** persist too: their lowerings embed
placement-dependent shardings and device assignments, so the raw
executable tier would silently collapse outputs to one shard. They go
through a dedicated sharded tier instead — the whole
``jax.stages.Compiled`` AOT-serialized via
``jax.experimental.serialize_executable`` (payload + in/out trees), which
round-trips sharding, argument pruning, and the pytree call convention.
A sharded entry has **no HLO-text tier**: recompiling the stored text
would target a single device, so an unusable sharded blob degrades
straight to retracing. Each sharded payload records the topology it was
compiled for and a load under a different topology is a counted
fallback, never a wrong answer. (Pre-v3 behaviour — skipping the disk
cache for multi-device placements, counted in ``skips`` — is retired;
``note_skip`` remains for callers that decline lookups for other
reasons.)

Every warm load is validated by one trial execution; *any* failure —
corrupt file, toolchain drift, call-convention mismatch — degrades one
tier at a time: executable → HLO text → the normal trace-and-compile
path. The cache can only ever make a run faster, never wronger.
Degradations are *counted and explained* rather than swallowed:
``exe_fallbacks`` / ``last_exe_fallback`` record executables that no
longer deserialize (the run then pays one compile from tier 2), and
``fallback_count`` / ``fallback_reasons`` / ``last_fallback`` record
entries that fell all the way back to retracing. ``xla_compiles`` counts
the compilations the cache itself triggered (tier-2 loads), so "the warm
run performed zero XLA compiles" is an assertable counter:
``exe_hits == lookups`` with ``hlo_hits == misses == fallbacks == 0``.
``summary()`` is the one-line diagnosis the engine prints in verbose runs.

Caveat: warm entries execute through the backend client's raw call
convention rather than ``jax.jit``'s dispatch path, which adds a few
hundred microseconds of host overhead per call. This cache is a CI /
repeat-run accelerator (where wall-clock is dominated by tracing and
compilation); runs whose *measured microseconds* are the artifact should
stay cold — or read the windowed column, which amortizes dispatch.
"""

from __future__ import annotations

import hashlib
import json
import os
import pickle
import re
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core.harness import CompiledInfo
from repro.core.metrics import roofline_terms

__all__ = ["HloDiskCache"]

# v2: sidecar serialized-executable tier
# v3: sharded tier (AOT-serialized jax.stages.Compiled for multi-device
#     placements) + explicit topology recorded per payload
_FORMAT_VERSION = 3
_MAX_REASONS = 20  # keep fallback/skip reason lists bounded


def _flat_out_structure(out_info: Any) -> tuple[int, bool] | None:
    """(n_outputs, is_single_leaf) when the output pytree is a leaf or a
    flat tuple/list of leaves; None for nested structures (not cached —
    the raw executable returns a flat list we could not fold back)."""
    leaves, treedef = jax.tree_util.tree_flatten(out_info)
    if not leaves:
        return None
    if len(leaves) == 1 and treedef == jax.tree_util.tree_structure(leaves[0]):
        return 1, True
    if treedef == jax.tree_util.tree_structure(tuple(leaves)):
        return len(leaves), False
    if treedef == jax.tree_util.tree_structure(list(leaves)):
        return len(leaves), False
    return None


def _source_digest() -> str:
    """Content hash of every .py file in the repro package: the compile-
    cache key says *which* workload, this says *which code* — an edited
    kernel must miss, not silently replay its old lowering."""
    import repro

    pkg_root = os.path.dirname(os.path.abspath(repro.__file__))
    h = hashlib.sha256()
    for dirpath, dirnames, filenames in sorted(os.walk(pkg_root)):
        dirnames.sort()
        for fname in sorted(filenames):
            if not fname.endswith(".py"):
                continue
            path = os.path.join(dirpath, fname)
            h.update(os.path.relpath(path, pkg_root).encode())
            with open(path, "rb") as f:
                h.update(f.read())
    return h.hexdigest()[:12]


def _topology_token() -> str:
    """Device kind × device count × process count: a serialized
    executable is compiled for a topology, so a different accelerator, a
    different forced host-device count, or a different ``jax.distributed``
    process count must get its own cache directory, not a
    deserialization failure. (Distributed serving clients share the
    launcher's environment, so they land in the same directory.)"""
    devices = jax.devices()
    kind = re.sub(r"[^A-Za-z0-9_.-]+", "_", devices[0].device_kind) or "unknown"
    return f"{kind}x{len(devices)}p{jax.process_count()}"


def _topology_dict() -> dict:
    """The explicit topology a sharded payload was compiled for."""
    devices = jax.devices()
    return {
        "kind": devices[0].device_kind,
        "devices": len(devices),
        "processes": jax.process_count(),
    }


def _jaxlib_version() -> str:
    try:
        import jaxlib

        return getattr(jaxlib, "__version__", "unknown")
    except Exception:  # noqa: BLE001 — version tag is best-effort
        return "unknown"


class HloDiskCache:
    """Two-tier persistent artifact cache: serialized executables over
    lowered HLO text, both keyed per compile-cache key."""

    def __init__(self, root: str) -> None:
        backend = jax.default_backend()
        self.root = os.path.join(
            root,
            f"jax-{jax.__version__}-jaxlib-{_jaxlib_version()}-{backend}-"
            f"{_topology_token()}-{_source_digest()}",
        )
        os.makedirs(self.root, exist_ok=True)
        self.hits = 0  # warm loads that produced a working executable
        self.exe_hits = 0  # ...of which tier 1: zero XLA compilation
        self.hlo_hits = 0  # ...of which tier 2: one compile, no retrace
        self.misses = 0  # lookups that fell back to tracing
        self.stores = 0  # payloads written (HLO text + characterization)
        self.exe_stores = 0  # ...with a serialized-executable sidecar
        self.xla_compiles = 0  # compilations this cache triggered (tier 2)
        # Fallback diagnostics: a *fallback* is a present-but-unusable
        # entry (corrupt payload, stale format, failed trial call) — a
        # missing file is just a cold miss and is not recorded here.
        self.fallback_count = 0  # fell all the way back to retracing
        self.fallback_reasons: list[str] = []  # capped at _MAX_REASONS
        self.last_fallback: str | None = None
        self.exe_fallbacks = 0  # tier 1 unusable, degraded to tier 2
        self.last_exe_fallback: str | None = None
        # Lookups the engine declined to attempt (multi-device placements):
        # counted here so the skip is visible in summary(), not silent.
        self.skips = 0
        self.skip_reasons: list[str] = []  # capped at _MAX_REASONS
        self.last_skip: str | None = None
        # Autotune-winner sidecar traffic (store_tuned / load_tuned).
        self.tune_hits = 0  # winners restored (warm run: zero trials)
        self.tune_stores = 0  # winners persisted

    def _path(self, key: tuple) -> str:
        digest = hashlib.sha256(repr(key).encode()).hexdigest()[:24]
        return os.path.join(self.root, f"{digest}.json")

    def _exe_path(self, key: tuple) -> str:
        return self._path(key)[: -len(".json")] + ".exe"

    @staticmethod
    def _reason(key: tuple, exc: BaseException) -> str:
        name = key[0] if key else "?"
        reason = " ".join(f"{name}: {type(exc).__name__}: {exc}".split())
        return reason if len(reason) <= 200 else reason[:197] + "..."

    def _note_fallback(self, key: tuple, exc: BaseException) -> None:
        reason = self._reason(key, exc)
        self.fallback_count += 1
        self.last_fallback = reason
        if len(self.fallback_reasons) < _MAX_REASONS:
            self.fallback_reasons.append(reason)

    def _note_exe_fallback(self, key: tuple, exc: BaseException) -> None:
        self.exe_fallbacks += 1
        self.last_exe_fallback = self._reason(key, exc)

    def note_skip(self, key: tuple, reason: str) -> None:
        """Record a lookup the caller declined to attempt (and why)."""
        name = key[0] if key else "?"
        self.skips += 1
        self.last_skip = f"{name}: {reason}"
        if len(self.skip_reasons) < _MAX_REASONS:
            self.skip_reasons.append(self.last_skip)

    def counter_dict(self) -> dict[str, int]:
        """The numeric counter totals as a plain dict — what the engine
        stamps into ``RunMetadata.cache_stats`` (schema v8) so a committed
        JSONL report says whether the run was warm without verbose stdout.
        Numbers only; the reason strings stay on the object / summary()."""
        return {
            "hits": self.hits,
            "exe_hits": self.exe_hits,
            "hlo_hits": self.hlo_hits,
            "misses": self.misses,
            "stores": self.stores,
            "exe_stores": self.exe_stores,
            "xla_compiles": self.xla_compiles,
            "fallback_count": self.fallback_count,
            "exe_fallbacks": self.exe_fallbacks,
            "skips": self.skips,
            "tune_hits": self.tune_hits,
            "tune_stores": self.tune_stores,
        }

    def summary(self) -> str:
        """One-line cache diagnosis for verbose engine output."""
        line = (
            f"hlocache: hits={self.hits} exe_hits={self.exe_hits} "
            f"hlo_hits={self.hlo_hits} misses={self.misses} "
            f"stores={self.stores} exe_stores={self.exe_stores} "
            f"xla_compiles={self.xla_compiles} "
            f"fallbacks={self.fallback_count} exe_fallbacks={self.exe_fallbacks} "
            f"tune_hits={self.tune_hits} tune_stores={self.tune_stores}"
        )
        if self.skips:
            line += f" skips={self.skips} last_skip=[{self.last_skip}]"
        if self.last_exe_fallback is not None:
            line += f" last_exe_fallback=[{self.last_exe_fallback}]"
        if self.last_fallback is not None:
            line += f" last_fallback=[{self.last_fallback}]"
        return line

    def _tune_path(self, key: tuple) -> str:
        return self._path(key)[: -len(".json")] + ".tune.json"

    # -- autotune winners ----------------------------------------------------

    def store_tuned(
        self, key: tuple, params: dict, trials: int, trials_us: float
    ) -> None:
        """Persist the autotune stage's winning block config for ``key``
        (the *base* compile-cache key, without the params folded in), plus
        what the sweep cost — provenance for warm-run records."""
        try:
            payload = {
                "format": _FORMAT_VERSION,
                "params": dict(params),
                "trials": int(trials),
                "trials_us": float(trials_us),
            }
            path = self._tune_path(key)
            tmp = path + ".tmp"
            with open(tmp, "w") as f:
                json.dump(payload, f)
            os.replace(tmp, path)
            self.tune_stores += 1
        except Exception:  # noqa: BLE001 — persistence is advisory
            return

    def load_tuned(self, key: tuple) -> dict | None:
        """Restore a persisted autotune winner, or None (cold / unusable).
        A hit means the warm run skips the sweep entirely: zero trials."""
        path = self._tune_path(key)
        if not os.path.exists(path):
            return None
        try:
            with open(path) as f:
                payload = json.load(f)
            if payload.get("format") != _FORMAT_VERSION:
                raise ValueError("stale tune cache format")
            params = {str(k): v for k, v in dict(payload["params"]).items()}
        except Exception as e:  # noqa: BLE001 — unusable winner = re-sweep
            self._note_fallback(key, e)
            return None
        self.tune_hits += 1
        return params

    # -- store -------------------------------------------------------------

    def store(
        self,
        key: tuple,
        lowered: Any,
        compiled: Any,
        name: str,
        *,
        sharded: bool = False,
    ) -> None:
        """Persist one compile: the HLO-text payload, and — when the
        backend supports AOT serialization — the executable sidecar.
        Best-effort: outputs that are not a flat tuple of arrays, or
        analyses this backend does not expose, simply skip the store — a
        miss next run, never an error this run. ``sharded`` routes
        multi-device programs through the sharded tier (the whole
        ``jax.stages.Compiled`` serialized, no HLO-text fallback)."""
        if sharded:
            self._store_sharded(key, compiled, name)
            return
        try:
            out = _flat_out_structure(lowered.out_info)
            if out is None:
                return
            n_outputs, single = out
            from repro.core.metrics import (
                collective_bytes_from_hlo,
                cost_analysis_dict,
            )
            from repro.core.harness import _memory_analysis_dict

            text = lowered.as_text()
            payload = {
                "format": _FORMAT_VERSION,
                "name": name,
                "hlo": text,
                "n_outputs": n_outputs,
                "single": single,
                # jax.jit prunes arguments the program never reads; the raw
                # executable then wants only the kept ones. None = keep all
                # (also the right answer when the internal attr moves — the
                # trial call catches any drift).
                "kept_args": _kept_arg_indices(compiled),
                "cost": cost_analysis_dict(compiled),
                "memory": _memory_analysis_dict(compiled),
                "collective_bytes": collective_bytes_from_hlo(compiled.as_text()),
            }
            # Executable sidecar first: if serialization is unsupported the
            # payload alone still buys tier 2; if the payload write then
            # fails, an orphan .exe is unreachable (loads start at .json).
            exe_path = self._exe_path(key)
            try:
                blob = _serialize_executable(compiled)
                tmp = exe_path + ".tmp"
                with open(tmp, "wb") as f:
                    f.write(blob)
                os.replace(tmp, exe_path)
                self.exe_stores += 1
            except Exception:  # noqa: BLE001 — tier 1 is an accelerator
                for stale in (exe_path + ".tmp", exe_path):
                    # Drop both the torn tmp and any stale sidecar: never
                    # pair an old executable with new lowering text.
                    if os.path.exists(stale):
                        try:
                            os.remove(stale)
                        except OSError:
                            pass
            path = self._path(key)
            tmp = path + ".tmp"
            with open(tmp, "w") as f:
                json.dump(payload, f)
            os.replace(tmp, path)
            self.stores += 1
        except Exception:  # noqa: BLE001 — persistence is advisory
            return

    def _store_sharded(self, key: tuple, compiled: Any, name: str) -> None:
        """Persist one multi-device compile: the AOT-serialized
        ``jax.stages.Compiled`` (sharding, argument pruning, and pytree
        call convention all round-trip) plus a payload recording the
        explicit topology it was compiled for. The sidecar is written
        first — a payload without its blob is useless here (there is no
        HLO-text tier for sharded entries), so a failed blob write stores
        nothing and a failed payload write removes the orphan."""
        exe_path = self._exe_path(key)
        try:
            from repro.core.harness import _memory_analysis_dict
            from repro.core.metrics import (
                collective_bytes_from_hlo,
                cost_analysis_dict,
            )

            payload = {
                "format": _FORMAT_VERSION,
                "name": name,
                "sharded": True,
                "topology": _topology_dict(),
                "cost": cost_analysis_dict(compiled),
                "memory": _memory_analysis_dict(compiled),
                "collective_bytes": collective_bytes_from_hlo(compiled.as_text()),
            }
            blob = _serialize_sharded(compiled)
            tmp = exe_path + ".tmp"
            with open(tmp, "wb") as f:
                f.write(blob)
            os.replace(tmp, exe_path)
            path = self._path(key)
            tmp = path + ".tmp"
            with open(tmp, "w") as f:
                json.dump(payload, f)
            os.replace(tmp, path)
            self.stores += 1
            self.exe_stores += 1
        except Exception:  # noqa: BLE001 — persistence is advisory
            for stale in (exe_path + ".tmp", exe_path):
                if os.path.exists(stale):
                    try:
                        os.remove(stale)
                    except OSError:
                        pass
            return

    # -- load --------------------------------------------------------------

    def load(
        self, key: tuple, args: tuple, *, sharded: bool = False
    ) -> tuple[Callable[..., Any], CompiledInfo] | None:
        """Restore one compile from disk, best tier first.

        Tier 1 deserializes the stored executable (no retrace, no XLA
        compile); tier 2 compiles the stored HLO text directly (no
        retrace). Either way the memoized characterization is rebuilt and
        one trial execution validates the call convention; any failure
        degrades to the next tier and — unless the entry simply wasn't
        there — is counted and named in the fallback diagnostics.
        ``sharded`` loads go through the sharded tier only: the stored
        ``jax.stages.Compiled`` is deserialized under the recorded
        topology (a mismatch is a counted fallback) with no HLO-text
        fallback — recompiling sharded text would target one device.
        Returns None when the caller must retrace."""
        path = self._path(key)
        if not os.path.exists(path):
            self.misses += 1  # cold miss: nothing to fall back from
            return None
        try:
            with open(path) as f:
                payload = json.load(f)
            if payload.get("format") != _FORMAT_VERSION:
                raise ValueError("stale cache format")
            if bool(payload.get("sharded", False)) != sharded:
                raise ValueError(
                    "entry tier mismatch: stored "
                    f"sharded={payload.get('sharded', False)!r}, "
                    f"requested sharded={sharded!r}"
                )
            if sharded:
                topology = payload.get("topology")
                if topology != _topology_dict():
                    raise ValueError(
                        f"topology mismatch: entry compiled for {topology}, "
                        f"host is {_topology_dict()}"
                    )
                with open(self._exe_path(key), "rb") as f:
                    blob = f.read()
                executable = _deserialize_sharded(blob)
                jax.block_until_ready(executable(*args))  # trial call
                via_exe = True
            else:
                executable = self._load_single(key, payload, args)
                via_exe = executable is not None
                if executable is None:
                    n_outputs = int(payload["n_outputs"])
                    single = bool(payload["single"])
                    kept = payload.get("kept_args")
                    kept = [int(i) for i in kept] if kept is not None else None
                    executable = _compile_text(
                        payload["hlo"], n_outputs, single, kept
                    )
                    self.xla_compiles += 1
                    jax.block_until_ready(executable(*args))  # trial call
            info = CompiledInfo(
                name=payload["name"],
                cost=dict(payload["cost"]),
                memory=dict(payload["memory"]),
                roofline=roofline_terms(
                    dict(payload["cost"]),
                    collective_bytes=float(payload["collective_bytes"]),
                ),
                hlo_collectives_bytes=float(payload["collective_bytes"]),
            )
        except Exception as e:  # noqa: BLE001 — any problem means "retrace"
            self.misses += 1
            self._note_fallback(key, e)
            return None
        self.hits += 1
        if via_exe:
            self.exe_hits += 1
        else:
            self.hlo_hits += 1
        return executable, info

    def _load_single(
        self, key: tuple, payload: dict, args: tuple
    ) -> Callable[..., Any] | None:
        """Tier-1 attempt for a single-device entry: the raw serialized
        executable, trial-called; None (with the exe fallback counted)
        when the blob is missing or no longer deserializes — the caller
        then degrades to tier 2."""
        exe_path = self._exe_path(key)
        if not os.path.exists(exe_path):
            return None
        n_outputs = int(payload["n_outputs"])
        single = bool(payload["single"])
        kept = payload.get("kept_args")
        kept = [int(i) for i in kept] if kept is not None else None
        try:
            with open(exe_path, "rb") as f:
                blob = f.read()
            executable = _deserialize_executable(blob, n_outputs, single, kept)
            jax.block_until_ready(executable(*args))  # trial call
        except Exception as e:  # noqa: BLE001 — degrade to tier 2
            self._note_exe_fallback(key, e)
            return None
        return executable


def _kept_arg_indices(compiled: Any) -> list[int] | None:
    """Flat indices of the arguments the compiled program actually reads
    (jax.jit prunes unused ones from the XLA signature), or None for
    all-kept / attr-unavailable — best-effort, backstopped by the trial
    call at load time."""
    try:
        kept = compiled._executable._kept_var_idx
        return sorted(int(i) for i in kept)
    except Exception:  # noqa: BLE001 — internal attr, may move across jax
        return None


def _wrap_executable(
    exe: Any, n_outputs: int, single: bool, kept: list[int] | None = None
) -> Callable[..., Any]:
    """Adapt a raw loaded executable to the jitted-call convention the
    engine's timer/serve stages use (flat args in, folded outputs out,
    pruned args dropped)."""

    def call(*args: Any) -> Any:
        flat = [
            a if isinstance(a, jax.Array) else jnp.asarray(a)
            for a in jax.tree_util.tree_leaves(args)
        ]
        if kept is not None:
            flat = [flat[i] for i in kept]
        outs = exe.execute(flat)
        if len(outs) != n_outputs:
            raise RuntimeError(
                f"cached executable returned {len(outs)} outputs, "
                f"expected {n_outputs}"
            )
        return outs[0] if single else tuple(outs)

    return call


def _serialize_sharded(compiled: Any) -> bytes:
    """AOT-serialize a (possibly multi-device) ``jax.stages.Compiled``
    whole: executable payload plus input/output pytree defs. Unlike the
    raw-executable tier, deserializing this reproduces sharded outputs
    and the jit call convention (pruned args included)."""
    from jax.experimental import serialize_executable as jse

    payload, in_tree, out_tree = jse.serialize(compiled)
    return pickle.dumps((payload, in_tree, out_tree))


def _deserialize_sharded(blob: bytes) -> Callable[..., Any]:
    """Sharded tier: bytes → a loaded ``jax.stages.Compiled`` (callable
    with the original arguments), with zero XLA compilation."""
    from jax.experimental import serialize_executable as jse

    payload, in_tree, out_tree = pickle.loads(blob)
    return jse.deserialize_and_load(payload, in_tree, out_tree)


def _serialize_executable(compiled: Any) -> bytes:
    """AOT-serialize a ``jax.stages.Compiled``'s loaded executable."""
    from jax.extend import backend as jex_backend

    exe = compiled.runtime_executable()
    return jex_backend.get_backend().serialize_executable(exe)


def _deserialize_executable(
    blob: bytes, n_outputs: int, single: bool, kept: list[int] | None = None
) -> Callable[..., Any]:
    """Tier 1: bytes → runnable, with zero XLA compilation."""
    from jax.extend import backend as jex_backend

    exe = jex_backend.get_backend().deserialize_executable(blob)
    return _wrap_executable(exe, n_outputs, single, kept)


def _compile_text(
    text: str, n_outputs: int, single: bool, kept: list[int] | None = None
) -> Callable[..., Any]:
    """Tier 2: stored StableHLO text → runnable (one XLA compilation,
    no Python retrace)."""
    from jax.extend import backend as jex_backend

    exe = jex_backend.get_backend().compile(text)
    return _wrap_executable(exe, n_outputs, single, kept)
