"""Cross-process persistence of lowered HLO text (the ROADMAP open item,
scoped to lowering text — *not* serialized executables).

The in-process :class:`~repro.core.engine.CompileCache` dies with the
process, so every CI suite run re-traces and re-lowers every workload.
This cache persists, per compile-cache key, exactly what the lowering
produced: the StableHLO module text plus the static characterization
(cost / memory / collective bytes) computed from the compiled artifact.
A warm run skips Python retracing entirely — the stored text is handed
straight to the backend compiler (``client.compile``), and the stored
characterization rebuilds :class:`~repro.core.harness.CompiledInfo`
without touching the executable.

Entries are versioned by ``jax.__version__``, backend, and a content hash
of the ``repro`` package source (a new toolchain *or an edited kernel*
gets a fresh directory rather than stale lowerings), keyed by a hash of
the engine's compile-cache key, and scoped to **single-device** entries:
multi-device lowerings embed placement-dependent shardings and always
retrace.

Every warm load is validated by one trial execution; *any* failure —
corrupt file, toolchain drift, call-convention mismatch — falls back to
the normal trace-and-compile path. The cache can only ever make a run
faster, never wronger. Fallbacks are *counted and explained* rather than
swallowed: ``fallback_count`` / ``fallback_reasons`` / ``last_fallback``
record why each present-but-unusable entry was rejected (a missing file
is an ordinary cold miss, not a fallback), and ``summary()`` is the
one-line diagnosis the engine prints in verbose runs — so a cache that
never hits is diagnosable instead of invisible.

Caveat: warm entries execute through the backend client's raw
call convention rather than ``jax.jit``'s dispatch path, which adds a few
hundred microseconds of host overhead per call. This cache is a CI /
repeat-run accelerator (where wall-clock is dominated by tracing and
compilation); runs whose *measured microseconds* are the artifact should
stay cold.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core.harness import CompiledInfo
from repro.core.metrics import roofline_terms

__all__ = ["HloDiskCache"]

_FORMAT_VERSION = 1
_MAX_REASONS = 20  # keep fallback_reasons bounded on pathological runs


def _flat_out_structure(out_info: Any) -> tuple[int, bool] | None:
    """(n_outputs, is_single_leaf) when the output pytree is a leaf or a
    flat tuple/list of leaves; None for nested structures (not cached —
    the raw executable returns a flat list we could not fold back)."""
    leaves, treedef = jax.tree_util.tree_flatten(out_info)
    if not leaves:
        return None
    if len(leaves) == 1 and treedef == jax.tree_util.tree_structure(leaves[0]):
        return 1, True
    if treedef == jax.tree_util.tree_structure(tuple(leaves)):
        return len(leaves), False
    if treedef == jax.tree_util.tree_structure(list(leaves)):
        return len(leaves), False
    return None


def _source_digest() -> str:
    """Content hash of every .py file in the repro package: the compile-
    cache key says *which* workload, this says *which code* — an edited
    kernel must miss, not silently replay its old lowering."""
    import repro

    pkg_root = os.path.dirname(os.path.abspath(repro.__file__))
    h = hashlib.sha256()
    for dirpath, dirnames, filenames in sorted(os.walk(pkg_root)):
        dirnames.sort()
        for fname in sorted(filenames):
            if not fname.endswith(".py"):
                continue
            path = os.path.join(dirpath, fname)
            h.update(os.path.relpath(path, pkg_root).encode())
            with open(path, "rb") as f:
                h.update(f.read())
    return h.hexdigest()[:12]


class HloDiskCache:
    """Persist lowered HLO text + static characterization per cache key."""

    def __init__(self, root: str) -> None:
        backend = jax.default_backend()
        self.root = os.path.join(
            root, f"jax-{jax.__version__}-{backend}-{_source_digest()}"
        )
        os.makedirs(self.root, exist_ok=True)
        self.hits = 0  # warm loads that produced a working executable
        self.misses = 0  # lookups that fell back to tracing
        self.stores = 0
        # Fallback diagnostics: a *fallback* is a present-but-unusable
        # entry (corrupt payload, stale format, failed trial call) — a
        # missing file is just a cold miss and is not recorded here.
        self.fallback_count = 0
        self.fallback_reasons: list[str] = []  # capped at _MAX_REASONS
        self.last_fallback: str | None = None

    def _path(self, key: tuple) -> str:
        digest = hashlib.sha256(repr(key).encode()).hexdigest()[:24]
        return os.path.join(self.root, f"{digest}.json")

    def _note_fallback(self, key: tuple, exc: BaseException) -> None:
        name = key[0] if key else "?"
        reason = " ".join(f"{name}: {type(exc).__name__}: {exc}".split())
        if len(reason) > 200:
            reason = reason[:197] + "..."
        self.fallback_count += 1
        self.last_fallback = reason
        if len(self.fallback_reasons) < _MAX_REASONS:
            self.fallback_reasons.append(reason)

    def summary(self) -> str:
        """One-line cache diagnosis for verbose engine output."""
        line = (
            f"hlocache: hits={self.hits} misses={self.misses} "
            f"stores={self.stores} fallbacks={self.fallback_count}"
        )
        if self.last_fallback is not None:
            line += f" last_fallback=[{self.last_fallback}]"
        return line

    # -- store -------------------------------------------------------------

    def store(self, key: tuple, lowered: Any, compiled: Any, name: str) -> None:
        """Persist one lowering. Best-effort: outputs that are not a flat
        tuple of arrays, or analyses this backend does not expose, simply
        skip the store — a miss next run, never an error this run."""
        try:
            out = _flat_out_structure(lowered.out_info)
            if out is None:
                return
            n_outputs, single = out
            from repro.core.metrics import (
                collective_bytes_from_hlo,
                cost_analysis_dict,
            )
            from repro.core.harness import _memory_analysis_dict

            text = lowered.as_text()
            payload = {
                "format": _FORMAT_VERSION,
                "name": name,
                "hlo": text,
                "n_outputs": n_outputs,
                "single": single,
                "cost": cost_analysis_dict(compiled),
                "memory": _memory_analysis_dict(compiled),
                "collective_bytes": collective_bytes_from_hlo(compiled.as_text()),
            }
            path = self._path(key)
            tmp = path + ".tmp"
            with open(tmp, "w") as f:
                json.dump(payload, f)
            os.replace(tmp, path)
            self.stores += 1
        except Exception:  # noqa: BLE001 — persistence is advisory
            return

    # -- load --------------------------------------------------------------

    def load(
        self, key: tuple, args: tuple
    ) -> tuple[Callable[..., Any], CompiledInfo] | None:
        """Compile the stored HLO text directly (no retrace) and rebuild the
        memoized characterization. One trial execution validates the
        call convention; any failure returns None (caller retraces) and —
        unless the entry simply wasn't there — is counted and named in
        the fallback diagnostics."""
        path = self._path(key)
        if not os.path.exists(path):
            self.misses += 1  # cold miss: nothing to fall back from
            return None
        try:
            with open(path) as f:
                payload = json.load(f)
            if payload.get("format") != _FORMAT_VERSION:
                raise ValueError("stale cache format")
            executable = _compile_text(
                payload["hlo"], int(payload["n_outputs"]), bool(payload["single"])
            )
            jax.block_until_ready(executable(*args))  # trial call
            info = CompiledInfo(
                name=payload["name"],
                cost=dict(payload["cost"]),
                memory=dict(payload["memory"]),
                roofline=roofline_terms(
                    dict(payload["cost"]),
                    collective_bytes=float(payload["collective_bytes"]),
                ),
                hlo_collectives_bytes=float(payload["collective_bytes"]),
            )
        except Exception as e:  # noqa: BLE001 — any problem means "retrace"
            self.misses += 1
            self._note_fallback(key, e)
            return None
        self.hits += 1
        return executable, info


def _compile_text(
    text: str, n_outputs: int, single: bool
) -> Callable[..., Any]:
    from jax.extend import backend as jex_backend

    exe = jex_backend.get_backend().compile(text)

    def call(*args: Any) -> Any:
        flat = [
            a if isinstance(a, jax.Array) else jnp.asarray(a)
            for a in jax.tree_util.tree_leaves(args)
        ]
        outs = exe.execute(flat)
        if len(outs) != n_outputs:
            raise RuntimeError(
                f"cached executable returned {len(outs)} outputs, "
                f"expected {n_outputs}"
            )
        return outs[0] if single else tuple(outs)

    return call
