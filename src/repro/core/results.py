"""Result records and reports for the suite runner and benchmark drivers.

Two report formats share one record schema:

- **JSON** (``write_report`` / legacy): one array of record objects, written
  atomically at the end of a run — the artifact EXPERIMENTS.md reads.
- **JSONL** (``JsonlReportWriter``): streaming — a ``meta`` line carrying
  run provenance (backend, device count, jax version, schema version)
  followed by one ``record`` line per benchmark, flushed as each finishes,
  so a killed or crashed run still leaves every completed row on disk.

``load_records`` sniffs the format and reads either; ``load_run`` also
returns the :class:`RunMetadata` when the file carries it. Error rows
(per-benchmark fault isolation in the engine) are ordinary records with
``status="error"`` so both formats round-trip them unchanged. A missing,
empty, or unparseable report raises :class:`ReportError` — a one-line
configuration-style error CLI drivers print without a traceback.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import IO, Iterable, Sequence

from repro.core.harness import CompiledInfo, TimingResult
from repro.core.metrics import utilization_scale10
from repro.core.plan import ServeSpec

__all__ = [
    "SCHEMA_VERSION",
    "BenchmarkRecord",
    "RunMetadata",
    "JsonlReportWriter",
    "ReportError",
    "to_csv_lines",
    "write_report",
    "load_records",
    "load_run",
]

# Bump when BenchmarkRecord/RunMetadata fields change incompatibly.
# v2: placement-aware rows — devices / placement / scaling_efficiency.
# v3: serving rows — latency percentiles / achieved QPS / goodput /
#     co-location slowdown; RunMetadata carries the ServeSpec.
# v4: serving-client rows — serve_client (single|threaded), truncation
#     honesty flag, dispatch_overhead_us, per-lane achieved QPS.
# v5: windowed timing — us_per_call_windowed (K calls in flight per
#     synchronization), timing_window, timer_dispatch_us (sync − windowed,
#     the per-call dispatch+sync overhead sync mode folds in); RunMetadata
#     carries the plan's timing_window.
# v6: implementation axis — impl (xla|pallas, the lowering actually timed),
#     impl_interpret (pallas ran in interpret mode — non-TPU hosts; such
#     rows are dispatch studies, not compiled-kernel numbers),
#     impl_fallback (why a pallas plan fell back to xla for this row),
#     tuned_params / tune_trials / tune_trials_us (the autotune stage's
#     winning block config and what the sweep cost); RunMetadata carries
#     the plan's impl and tune flags.
# v7: continuous batching — serve_dispatch (lanes|loop|batched|dynamic, how
#     requests mapped onto device programs), serve_mix (the weighted
#     shape-bucket mix served, "label@weight,..."), batch_occupancy
#     (filled / dispatched batch slots), padding_waste (padded / dispatched
#     slots — padding to a bucket edge is measured, never hidden),
#     serve_batches (device programs dispatched), bucket_latency_us
#     (per-bucket requests + p50/p95/p99 keyed by bucket label); the
#     ServeSpec in RunMetadata carries dispatch/mix/trace/batch knobs.
# v8: observability — stage_timings_us (per-stage wall microseconds for
#     the row: build/place/tune/compile/measure/characterize/serve —
#     always collected, tracing on or off); RunMetadata carries
#     cache_stats (the HloDiskCache counter totals, so committed reports
#     show whether a run was warm) and counters (the obs layer's counter
#     snapshot: cache traffic, tune trials, batcher flushes/expiries/
#     padding, lane submit-block time — None when tracing was off). The
#     JSONL writer re-emits the final metadata as a second meta line at
#     close (load_run is last-meta-wins), so streamed reports carry
#     end-of-run counter totals without giving up streaming.
# v9: distributed serving — client_procs (how many load-generation client
#     processes replayed seeded sub-schedules; 0/None = in-process
#     serving) and proc_qps (per-process achieved QPS over the merged
#     completion stream, the column that shows whether every client
#     pulled its weight). Merged latency columns reuse the existing
#     percentile fields: the launcher computes them over the
#     concatenation of the per-process streams, which tests pin as
#     identical to a single stream's percentiles. The ServeSpec in
#     RunMetadata carries client_procs.
SCHEMA_VERSION = 9


class ReportError(ValueError):
    """A report that cannot be read as asked (missing file, empty file,
    no usable records). CLIs print the one-line message and exit nonzero
    instead of dumping a traceback."""


@dataclasses.dataclass
class BenchmarkRecord:
    """One row of suite output: timing + static characterization.

    ``status`` is ``"ok"`` for measured rows and ``"error"`` for rows the
    engine emitted after a per-benchmark failure (``error`` holds the stage
    and exception text; the numeric fields are zeroed). ``devices`` /
    ``placement`` record where the row actually ran (``placement`` is the
    *effective* mode: a sharded plan over a non-batchable workload reads
    ``replicate``); ``scaling_efficiency`` is speedup over the same run's
    1-device row divided by the device count (None when no baseline row
    exists, e.g. single-count runs or a failed baseline).

    The ``serve_*`` / ``latency_*`` / ``*_qps`` columns are populated only
    when the plan carried a :class:`~repro.core.plan.ServeSpec` (schema
    v3): latency percentiles over non-warmup requests, achieved QPS, and —
    for co-located runs — the partner's name and this row's p50 slowdown
    vs its isolated baseline. Schema v4 adds the client-side issue
    accounting: ``serve_client`` (which host issue architecture served the
    row), ``serve_truncated`` (the open-loop schedule hit its request cap,
    so the run offered *less* than ``offered_qps``),
    ``dispatch_overhead_us`` (mean host time per dispatch, threaded
    client), and ``lane_qps`` (per-lane achieved QPS).

    Schema v5 adds the windowed-timing columns: ``us_per_call`` stays the
    sync-mode number (synchronize every call — comparable across all
    schema versions), ``us_per_call_windowed`` is the per-call time with
    ``timing_window`` calls in flight per synchronization (closer to true
    device throughput for dispatch-bound kernels), and
    ``timer_dispatch_us`` is their difference — the measured per-call
    host dispatch + sync overhead.

    Schema v6 adds the implementation axis: ``impl`` is the lowering this
    row actually timed (``xla`` or ``pallas`` — the *effective* choice;
    a pallas plan over a workload with no Pallas variant reads ``xla``
    and ``impl_fallback`` says why). ``impl_interpret=True`` flags pallas
    rows that ran the kernel in interpret mode (non-TPU hosts) so CPU CI
    rows are never mistaken for compiled-kernel numbers. ``tuned_params``
    / ``tune_trials`` / ``tune_trials_us`` report the autotune stage:
    the winning block config, how many candidates were timed (0 = winner
    restored from the disk cache), and the sweep's wall-clock cost.

    Schema v7 adds the continuous-batching columns: ``serve_dispatch``
    (how requests mapped onto device programs — classic ``lanes``, or the
    mixed-shape ``loop`` / ``batched`` / ``dynamic`` batcher paths),
    ``serve_mix`` (the weighted shape mix served), ``batch_occupancy``
    (filled / dispatched batch slots), ``padding_waste`` (padded slots —
    a dynamic batcher that pads a 3-request batch to width 4 *reports*
    that quarter, never hides it), ``serve_batches`` (device programs
    dispatched), and ``bucket_latency_us`` (per-bucket request counts and
    p50/p95/p99 latency percentiles keyed by bucket label).
    """

    name: str
    level: int
    dwarf: str | None
    domain: str | None
    preset: int
    us_per_call: float
    achieved_gflops: float
    achieved_gbps: float
    compute_util10: int  # paper-style 0..10 bar (roofline fraction of compute)
    memory_util10: int
    dominant: str
    derived: str = ""
    status: str = "ok"
    error: str = ""
    devices: int = 1
    placement: str = "replicate"
    scaling_efficiency: float | None = None
    # Windowed timing columns (schema v5) — None when only sync mode ran
    # (timing_window=1 plans, no_jit workloads, pre-v5 rows).
    us_per_call_windowed: float | None = None
    timing_window: int | None = None
    timer_dispatch_us: float | None = None  # sync − windowed, clamped at 0
    # Implementation axis (schema v6). impl is the *effective* lowering;
    # pre-v6 rows loaded from disk read the default "xla", which is what
    # they were.
    impl: str = "xla"
    impl_interpret: bool | None = None  # pallas ran interpret (non-TPU host)
    impl_fallback: str | None = None  # why a pallas plan fell back to xla
    tuned_params: dict | None = None  # autotune winner (None = not tuned)
    tune_trials: int | None = None  # candidates timed (0 = cache restore)
    tune_trials_us: float | None = None  # sweep wall-clock cost
    # Serving columns (schema v3) — None unless the plan had a ServeSpec.
    serve_mode: str | None = None
    serve_lanes: int | None = None
    serve_requests: int | None = None
    latency_p50_us: float | None = None
    latency_p95_us: float | None = None
    latency_p99_us: float | None = None
    latency_max_us: float | None = None
    achieved_qps: float | None = None
    offered_qps: float | None = None
    goodput_qps: float | None = None
    serve_colocate: str | None = None
    slowdown_vs_isolated: float | None = None
    # Serving-client columns (schema v4).
    serve_client: str | None = None
    serve_truncated: bool | None = None
    serve_slo_us: float | None = None  # the SLO goodput was measured against
    dispatch_overhead_us: float | None = None
    lane_qps: list[float] | None = None  # list, not tuple: JSON round-trip
    # Continuous-batching columns (schema v7) — None unless the row was
    # served. batch_occupancy / padding_waste / serve_batches are further
    # None outside the mixed-shape dispatch paths (classic lanes serving
    # dispatches no batches).
    serve_dispatch: str | None = None
    serve_mix: str | None = None  # "label@weight,..." (None = no mix)
    batch_occupancy: float | None = None  # filled / dispatched slots
    padding_waste: float | None = None  # padded / dispatched slots
    serve_batches: int | None = None  # device programs dispatched
    # bucket label -> {"requests", "p50_us", "p95_us", "p99_us"}; a plain
    # dict (not a dataclass) so JSON round-trips it unchanged.
    bucket_latency_us: dict | None = None
    # Distributed serving columns (schema v9) — None unless the row was
    # served through repro.dist (ServeSpec.client_procs > 0).
    client_procs: int | None = None  # load-generation client processes
    proc_qps: list[float] | None = None  # per-process achieved QPS
    # Observability (schema v8): stage name -> wall microseconds this row
    # spent in that stage (build/place shared timings are copied into
    # every pass's row). Always collected — the perf_counter pairs cost
    # nanoseconds — so committed reports explain where time went even
    # without --trace-out. None only on pre-v8 rows and serve-only
    # partner rows.
    stage_timings_us: dict | None = None

    def apply_serve(
        self,
        stats,
        *,
        mode: str,
        lanes: int,
        client: str = "single",
        colocate: str | None = None,
        slowdown: float | None = None,
        dispatch: str | None = None,
        mix: str | None = None,
    ) -> "BenchmarkRecord":
        """Fold a ``serve.latency.LatencyStats`` into this record."""
        self.serve_mode = mode
        self.serve_lanes = lanes
        self.serve_requests = stats.requests
        self.latency_p50_us = stats.p50_us
        self.latency_p95_us = stats.p95_us
        self.latency_p99_us = stats.p99_us
        self.latency_max_us = stats.max_us
        self.achieved_qps = stats.achieved_qps
        self.offered_qps = stats.offered_qps
        self.goodput_qps = stats.goodput_qps
        self.serve_colocate = colocate
        self.slowdown_vs_isolated = slowdown
        self.serve_client = client
        self.serve_truncated = stats.truncated
        self.serve_slo_us = stats.slo_us
        self.dispatch_overhead_us = stats.dispatch_overhead_us
        self.lane_qps = (
            list(stats.lane_qps) if stats.lane_qps is not None else None
        )
        # Distributed-serving accounting (schema v9). getattr-tolerant:
        # only DistLatencyStats (repro.dist.launcher) carries these.
        procs = getattr(stats, "client_procs", None)
        self.client_procs = procs if procs else None
        proc_qps = getattr(stats, "proc_qps", None)
        self.proc_qps = list(proc_qps) if proc_qps is not None else None
        # Continuous-batching accounting (schema v7). getattr-tolerant so
        # plain stats objects without the batching fields still fold in.
        self.serve_dispatch = dispatch
        self.serve_mix = mix
        self.batch_occupancy = getattr(stats, "batch_occupancy", None)
        self.padding_waste = getattr(stats, "padding_waste", None)
        self.serve_batches = getattr(stats, "n_batches", None)
        bucket_stats = getattr(stats, "bucket_stats", None)
        self.bucket_latency_us = (
            {
                label: {
                    "requests": b.requests,
                    "p50_us": b.p50_us,
                    "p95_us": b.p95_us,
                    "p99_us": b.p99_us,
                }
                for label, b in bucket_stats
            }
            if bucket_stats
            else None
        )
        return self

    @classmethod
    def from_serve(
        cls,
        spec,
        preset: int,
        stats,
        *,
        mode: str,
        lanes: int,
        client: str = "single",
        name: str | None = None,
        colocate: str | None = None,
        slowdown: float | None = None,
        devices: int = 1,
        placement: str = "replicate",
    ) -> "BenchmarkRecord":
        """A serve-only row (the co-location partner, which was served but
        not separately measured/characterized): ``us_per_call`` is its p50
        serving latency so tables stay meaningfully sortable."""
        rec = cls(
            name=name if name is not None else spec.name,
            level=spec.level,
            dwarf=spec.dwarf,
            domain=spec.domain,
            preset=preset,
            us_per_call=stats.p50_us,
            achieved_gflops=0.0,
            achieved_gbps=0.0,
            compute_util10=0,
            memory_util10=0,
            dominant="serve",
            derived=f"colocated_with={colocate}" if colocate else "serve",
            devices=devices,
            placement=placement,
        )
        return rec.apply_serve(
            stats, mode=mode, lanes=lanes, client=client,
            colocate=colocate, slowdown=slowdown,
        )

    @classmethod
    def from_measurement(
        cls,
        spec,
        preset: int,
        timing: TimingResult,
        compiled: CompiledInfo,
        *,
        devices: int = 1,
        placement: str = "replicate",
        impl: str = "xla",
        impl_interpret: bool | None = None,
        impl_fallback: str | None = None,
        tuned_params: dict | None = None,
        tune_trials: int | None = None,
        tune_trials_us: float | None = None,
    ) -> "BenchmarkRecord":
        r = compiled.roofline
        bound = r.bound_s if r.bound_s > 0 else 1.0
        return cls(
            name=timing.name,
            level=spec.level,
            dwarf=spec.dwarf,
            domain=spec.domain,
            preset=preset,
            us_per_call=timing.us_per_call,
            achieved_gflops=timing.achieved_gflops,
            achieved_gbps=timing.achieved_gbps,
            compute_util10=utilization_scale10(r.compute_s / bound),
            memory_util10=utilization_scale10(r.memory_s / bound),
            dominant=r.dominant,
            derived=(
                f"flops={r.flops:.3e};bytes={r.hbm_bytes:.3e};"
                f"coll={r.collective_bytes:.3e}"
            ),
            devices=devices,
            placement=placement,
            us_per_call_windowed=timing.us_per_call_windowed,
            timing_window=timing.timing_window,
            timer_dispatch_us=timing.timer_dispatch_us,
            impl=impl,
            impl_interpret=impl_interpret,
            impl_fallback=impl_fallback,
            tuned_params=tuned_params,
            tune_trials=tune_trials,
            tune_trials_us=tune_trials_us,
        )

    @classmethod
    def from_error(
        cls,
        spec,
        preset: int,
        *,
        stage: str,
        error: str,
        backward: bool = False,
        devices: int = 1,
        placement: str = "replicate",
        impl: str = "xla",
    ) -> "BenchmarkRecord":
        return cls(
            name=spec.name + (".bwd" if backward else ""),
            level=spec.level,
            dwarf=spec.dwarf,
            domain=spec.domain,
            preset=preset,
            us_per_call=0.0,
            achieved_gflops=0.0,
            achieved_gbps=0.0,
            compute_util10=0,
            memory_util10=0,
            dominant="error",
            derived=f"stage={stage}",
            status="error",
            error=error,
            devices=devices,
            placement=placement,
            impl=impl,
        )

    @classmethod
    def csv_header(cls) -> str:
        return "name,us_per_call,devices,placement,derived"

    def csv(self) -> str:
        eff = (
            f";eff={self.scaling_efficiency:.3f}"
            if self.scaling_efficiency is not None
            else ""
        )
        if self.us_per_call_windowed is not None:
            # The windowed per-call time and the dispatch overhead it
            # exposes ride the derived field next to the sync number.
            eff += (
                f";win_us={self.us_per_call_windowed:.2f}"
                f";timer_dispatch_us={self.timer_dispatch_us:.2f}"
            )
        imp = ""
        if self.impl != "xla" or self.impl_fallback is not None:
            imp = f";impl={self.impl}"
            if self.impl_interpret:
                imp += ";interpret=1"
            if self.impl_fallback is not None:
                imp += f";impl_fallback={self.impl_fallback}"
        if self.tuned_params is not None:
            tuned = "/".join(
                f"{k}={v}" for k, v in sorted(self.tuned_params.items())
            )
            imp += (
                f";tuned={tuned or 'default'};tune_trials={self.tune_trials};"
                f"tune_us={self.tune_trials_us:.0f}"
            )
        serve = ""
        if self.serve_mode is not None:
            # Pre-v4 rows have no serve_client; they were served by the
            # only client that existed then.
            client = self.serve_client if self.serve_client else "single"
            serve = (
                f";serve={self.serve_mode};client={client};"
                f"lanes={self.serve_lanes};"
                f"p50_us={self.latency_p50_us:.1f};"
                f"p99_us={self.latency_p99_us:.1f};qps={self.achieved_qps:.1f}"
            )
            if self.serve_truncated:
                serve += ";truncated=1"
            if self.serve_slo_us is not None:
                # Goodput is only a distinct number under an SLO; emitting
                # it SLO-less would just repeat qps.
                serve += (
                    f";slo_us={self.serve_slo_us:.0f};"
                    f"goodput_qps={self.goodput_qps:.1f}"
                )
            if self.dispatch_overhead_us is not None:
                serve += f";dispatch_us={self.dispatch_overhead_us:.1f}"
            if self.client_procs:
                serve += f";client_procs={self.client_procs}"
            if self.serve_dispatch is not None and self.serve_dispatch != "lanes":
                serve += f";dispatch={self.serve_dispatch}"
            if self.batch_occupancy is not None:
                serve += (
                    f";occupancy={self.batch_occupancy:.3f};"
                    f"padding_waste={self.padding_waste:.3f}"
                )
            if self.bucket_latency_us:
                buckets = "/".join(
                    f"{label}:p50={b['p50_us']:.0f}"
                    for label, b in sorted(self.bucket_latency_us.items())
                )
                serve += f";buckets={buckets}"
            if self.slowdown_vs_isolated is not None:
                serve += (
                    f";colocate={self.serve_colocate};"
                    f"slowdown={self.slowdown_vs_isolated:.2f}"
                )
        if self.status != "ok":
            return (
                f"{self.name},0.00,{self.devices},{self.placement},"
                f"{self.status}:{self.derived}"
            )
        return (
            f"{self.name},{self.us_per_call:.2f},{self.devices},"
            f"{self.placement},{self.derived}{eff}{imp}{serve}"
        )


@dataclasses.dataclass(frozen=True)
class RunMetadata:
    """Provenance header for a run: enough to interpret the rows later."""

    backend: str
    device_count: int
    jax_version: str
    schema_version: int = SCHEMA_VERSION
    preset: int | None = None
    devices: int = 1
    placement: str = "replicate"
    device_sweep: tuple[int, ...] = (1,)
    serve: ServeSpec | None = None
    timing_window: int = 1  # 1 = sync-only (pre-v5 runs)
    impl: str = "xla"  # the plan's requested implementation axis
    tune: bool = False  # whether the autotune stage was enabled
    # Observability (schema v8), stamped at end of run — None at capture
    # time and on pre-v8 reports. cache_stats is the HloDiskCache counter
    # totals (exe_hits/hlo_hits/xla_compiles/fallback_count/skips/...),
    # present whenever the run had a --cache-dir, so a committed report
    # says whether the run was warm without needing verbose stdout.
    # counters is the obs layer's counter snapshot, present when tracing
    # was enabled.
    cache_stats: dict | None = None
    counters: dict | None = None

    def __post_init__(self) -> None:
        # JSON round-trips tuples as lists and nested dataclasses as dicts;
        # normalize so loaded metadata compares equal to captured metadata.
        if not isinstance(self.device_sweep, tuple):
            object.__setattr__(self, "device_sweep", tuple(self.device_sweep))
        if isinstance(self.serve, dict):
            fields = {f.name for f in dataclasses.fields(ServeSpec)}
            object.__setattr__(
                self,
                "serve",
                ServeSpec(**{k: v for k, v in self.serve.items() if k in fields}),
            )

    @classmethod
    def capture(
        cls,
        *,
        preset: int | None = None,
        devices: int = 1,
        placement: str = "replicate",
        device_sweep: tuple[int, ...] | None = None,
        serve: ServeSpec | None = None,
        timing_window: int = 1,
        impl: str = "xla",
        tune: bool = False,
    ) -> "RunMetadata":
        import jax

        return cls(
            backend=jax.default_backend(),
            device_count=jax.device_count(),
            jax_version=jax.__version__,
            preset=preset,
            devices=devices,
            placement=placement,
            device_sweep=device_sweep if device_sweep is not None else (devices,),
            serve=serve,
            timing_window=timing_window,
            impl=impl,
            tune=tune,
        )


def to_csv_lines(records: Iterable[BenchmarkRecord]) -> list[str]:
    return [BenchmarkRecord.csv_header()] + [r.csv() for r in records]


def write_report(records: Sequence[BenchmarkRecord], path: str) -> None:
    """JSON report, one object per record (the artifact EXPERIMENTS.md reads)."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump([dataclasses.asdict(r) for r in records], f, indent=1, sort_keys=True)
    os.replace(tmp, path)


class JsonlReportWriter:
    """Streaming JSONL report: a ``meta`` line, then one line per record.

    Each line is flushed as written so partial runs leave usable reports.
    """

    def __init__(self, path: str, metadata: RunMetadata | None = None) -> None:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        self.path = path
        self._f: IO[str] = open(path, "w")
        if metadata is not None:
            self._emit({"kind": "meta", **dataclasses.asdict(metadata)})

    def _emit(self, obj: dict) -> None:
        self._f.write(json.dumps(obj, sort_keys=True) + "\n")
        self._f.flush()

    def write(self, record: BenchmarkRecord) -> None:
        self._emit({"kind": "record", **dataclasses.asdict(record)})

    def write_meta(self, metadata: RunMetadata) -> None:
        """Emit a(nother) meta line. ``load_run`` is last-meta-wins, so
        the engine re-emits the final metadata — with end-of-run cache
        stats and counter totals — just before close, and readers of a
        *complete* report see the stamped version while a killed run
        still has the header line from open time."""
        self._emit({"kind": "meta", **dataclasses.asdict(metadata)})

    def close(self) -> None:
        if not self._f.closed:
            self._f.close()

    def __enter__(self) -> "JsonlReportWriter":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def _record_from_dict(d: dict) -> BenchmarkRecord:
    fields = {f.name for f in dataclasses.fields(BenchmarkRecord)}
    return BenchmarkRecord(**{k: v for k, v in d.items() if k in fields})


def load_run(path: str) -> tuple[RunMetadata | None, list[BenchmarkRecord]]:
    """Read either report format; metadata is None for legacy JSON arrays.

    Raises :class:`ReportError` (one clear line, no traceback for CLIs that
    catch it) when the report is missing or holds no records at all.
    """
    try:
        with open(path) as f:
            text = f.read()
    except OSError as e:
        raise ReportError(f"cannot read report {path}: {e.strerror or e}") from None
    if text.lstrip().startswith("["):  # legacy JSON array
        try:
            return None, [_record_from_dict(d) for d in json.loads(text)]
        except (json.JSONDecodeError, TypeError) as e:
            raise ReportError(f"report {path} is not valid JSON: {e}") from None
    meta: RunMetadata | None = None
    records: list[BenchmarkRecord] = []
    lines = [ln for ln in text.splitlines() if ln.strip()]
    if not lines:
        raise ReportError(f"report {path} is empty (no metadata, no records)")
    for i, line in enumerate(lines):
        try:
            obj = json.loads(line)
        except json.JSONDecodeError:
            if i == len(lines) - 1:
                # A run killed mid-write leaves a torn final line; every
                # completed row before it must stay readable.
                break
            raise
        kind = obj.pop("kind", "record")
        if kind == "meta":
            fields = {f.name for f in dataclasses.fields(RunMetadata)}
            meta = RunMetadata(**{k: v for k, v in obj.items() if k in fields})
        else:
            records.append(_record_from_dict(obj))
    return meta, records


def load_records(path: str) -> list[BenchmarkRecord]:
    return load_run(path)[1]
