"""Result records and reports for the suite runner and benchmark drivers."""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Iterable, Sequence

from repro.core.harness import CompiledInfo, TimingResult
from repro.core.metrics import utilization_scale10

__all__ = ["BenchmarkRecord", "to_csv_lines", "write_report", "load_records"]


@dataclasses.dataclass
class BenchmarkRecord:
    """One row of suite output: timing + static characterization."""

    name: str
    level: int
    dwarf: str | None
    domain: str | None
    preset: int
    us_per_call: float
    achieved_gflops: float
    achieved_gbps: float
    compute_util10: int  # paper-style 0..10 bar (roofline fraction of compute)
    memory_util10: int
    dominant: str
    derived: str = ""

    @classmethod
    def from_measurement(
        cls,
        spec,
        preset: int,
        timing: TimingResult,
        compiled: CompiledInfo,
    ) -> "BenchmarkRecord":
        r = compiled.roofline
        bound = r.bound_s if r.bound_s > 0 else 1.0
        return cls(
            name=timing.name,
            level=spec.level,
            dwarf=spec.dwarf,
            domain=spec.domain,
            preset=preset,
            us_per_call=timing.us_per_call,
            achieved_gflops=timing.achieved_gflops,
            achieved_gbps=timing.achieved_gbps,
            compute_util10=utilization_scale10(r.compute_s / bound),
            memory_util10=utilization_scale10(r.memory_s / bound),
            dominant=r.dominant,
            derived=(
                f"flops={r.flops:.3e};bytes={r.hbm_bytes:.3e};"
                f"coll={r.collective_bytes:.3e}"
            ),
        )

    def csv(self) -> str:
        return f"{self.name},{self.us_per_call:.2f},{self.derived}"


def to_csv_lines(records: Iterable[BenchmarkRecord]) -> list[str]:
    return ["name,us_per_call,derived"] + [r.csv() for r in records]


def write_report(records: Sequence[BenchmarkRecord], path: str) -> None:
    """JSON report, one object per record (the artifact EXPERIMENTS.md reads)."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump([dataclasses.asdict(r) for r in records], f, indent=1, sort_keys=True)
    os.replace(tmp, path)


def load_records(path: str) -> list[BenchmarkRecord]:
    with open(path) as f:
        return [BenchmarkRecord(**d) for d in json.load(f)]
