"""Benchmark registry — the suite's Table I as a first-class data structure.

Mirovia/Altis organizes benchmarks into *levels*:

- level 0: device microbenchmarks (bus speed, memory bandwidth, MaxFlops),
- level 1: basic parallel algorithms (GUPS, BFS, GEMM, Pathfinder, Sort),
- level 2: real application kernels (CFD, DWT2D, KMeans, LavaMD, Mandelbrot,
  NW, ParticleFilter, SRAD, Where) **plus the DNN section** (activation,
  pooling, batchnorm, connected, convolution, dropout, rnn, softmax, lrn),

with each benchmark tagged by Berkeley dwarf, application domain, and — where
applicable — the modern-platform feature it exercises. This module stores all
of that metadata and the factory that instantiates a benchmark at a given
problem size, so the suite runner, the preset system, and the report
generators all consume one source of truth.

**The ``batch_dims`` contract (for benchmark authors).** Multi-device runs
are driven by a :class:`~repro.core.plan.Placement`; under ``mode="shard"``
the engine partitions inputs across the data mesh using the workload's
``batch_dims`` declaration:

- ``batch_dims`` is a tuple with one entry per ``make_inputs`` output:
  the input's data-parallel dimension index (almost always ``0``), or
  ``None`` for inputs that must be replicated (weights, scalar state,
  PRNG keys).
- ``batch_dims=None`` (the default) opts the whole workload out of
  sharding: its computation is not data-parallel along any input dim (BFS
  frontier state, bitonic sort networks, DP wavefronts, host-bus
  transfers). Sharded plans fall back to replication for it and the
  result record says ``placement=replicate``.
- Declaring a dim is a *semantic* statement — partitioning it must leave
  the mathematical result unchanged (GSPMD inserts the collectives), so a
  sharded and a replicated execution of the same workload agree
  numerically. Dims that do not divide the device count are replicated
  silently; pick preset sizes that divide common device counts (2, 4, 8).

**The ``impl`` contract (for benchmark authors).** Plans carry an
``impl ∈ {"xla", "pallas"}`` axis selecting which implementation the engine
compiles and times:

- A benchmark opts in by setting ``pallas_kernel`` on its Workload to the
  name of the ``repro.kernels.ops`` entry point its ``fn`` calls (e.g.
  ``"matmul"``; see ``ops.PALLAS_OPS`` for the valid names). The fn itself
  keeps calling the op with the default ``mode="auto"`` — the engine wraps
  tracing in ``ops.force_impl`` so the declared kernel (or the jnp
  reference) is baked into the lowered program.
- ``pallas_kernel=None`` (the default) means the workload has no Pallas
  variant; ``--impl pallas`` plans fall back to XLA for it and the record
  says ``impl=xla`` with ``impl_fallback`` naming the reason.
- The kernel's tune space is the kernel module's exported ``tune_space()``
  (reached via ``ops.tune_space(pallas_kernel)``); ``--tune`` plans sweep
  those candidates in the engine's tune stage and the winning block config
  is persisted next to the executable in the HLO disk cache.
- Like ``batch_dims``, the declaration is semantic: both implementations
  must compute the same function (tests pin pallas-vs-xla agreement
  against the ``kernels/ref.py`` oracles).

**Enforcement.** Both contracts are checked statically by
``python -m repro.check`` (rule ``workload-contract``): every Workload
under the bench levels must pass ``batch_dims`` explicitly (``None`` is
the opt-out, *omitting it* is a finding), and every ``pallas_kernel``
string must name a ``PALLAS_OPS`` entry whose module exports a
well-formed ``tune_space()``. The checker runs in CI's lint job, so a
registration that breaks these rules fails before anything compiles.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Mapping, Sequence

__all__ = [
    "Workload",
    "BenchmarkSpec",
    "register",
    "get_benchmark",
    "all_benchmarks",
    "benchmarks_by_level",
    "DNN_DOMAIN",
]

DNN_DOMAIN = "Deep Learning"


@dataclasses.dataclass
class Workload:
    """A benchmark instantiated at a concrete problem size.

    ``fn`` is a pure JAX function (jit-able); ``make_inputs`` builds the
    concrete input pytree deterministically from a seed. ``flops`` /
    ``bytes_moved`` are *analytic* estimates used to report achieved
    throughput (the compiled HLO numbers come from the harness separately and
    the two are cross-checked in tests). ``validate`` optionally checks
    outputs for correctness (the suite runs it once, outside timing).
    ``batch_dims`` declares the per-input data-parallel dims for sharded
    placements, and ``pallas_kernel`` names the workload's hand-written
    kernel entry point for the ``impl`` axis — see the module docstring for
    both contracts.
    """

    name: str
    fn: Callable[..., Any]
    make_inputs: Callable[[int], tuple]  # seed -> positional args for fn
    flops: float = 0.0
    bytes_moved: float = 0.0
    validate: Callable[[Any, tuple], None] | None = None
    # Differentiable workloads (the DNN section) also expose a backward fn.
    fn_bwd: Callable[..., Any] | None = None
    flops_bwd: float = 0.0
    # Per-input batch dim (None entry = replicate that input); None for the
    # whole field = non-batchable, sharded plans fall back to replicate.
    batch_dims: tuple[int | None, ...] | None = None
    # Name of the repro.kernels.ops entry point fn calls (impl contract);
    # None = no Pallas variant, pallas plans fall back to xla for this row.
    pallas_kernel: str | None = None
    meta: dict = dataclasses.field(default_factory=dict)

    @property
    def batchable(self) -> bool:
        """True when a sharded placement can partition at least one input."""
        return self.batch_dims is not None and any(
            d is not None for d in self.batch_dims
        )


@dataclasses.dataclass(frozen=True)
class BenchmarkSpec:
    """One Table-I row: identity + metadata + preset sizes + factory."""

    name: str
    level: int  # 0, 1, or 2 (DNN benchmarks are level 2, domain "Deep Learning")
    dwarf: str | None
    domain: str | None
    cuda_feature: str | None  # the paper's "New CUDA Feature" column
    tpu_feature: str | None  # our TPU-idiomatic analogue (DESIGN.md §2)
    presets: Mapping[int, Mapping[str, Any]]  # preset id (0..4) -> size kwargs
    build: Callable[..., Workload]  # build(**size_kwargs) -> Workload
    tags: tuple[str, ...] = ()

    def build_preset(self, preset: int, **overrides: Any) -> Workload:
        """Rodinia-style override on top of SHOC-style presets (§III-B)."""
        if preset not in self.presets:
            raise KeyError(
                f"benchmark {self.name!r} has presets {sorted(self.presets)}, "
                f"not {preset}"
            )
        kwargs = dict(self.presets[preset])
        unknown = set(overrides) - set(kwargs)
        if unknown:
            raise TypeError(
                f"benchmark {self.name!r} does not take size parameters {sorted(unknown)}; "
                f"valid: {sorted(kwargs)}"
            )
        kwargs.update(overrides)
        return self.build(**kwargs)


_REGISTRY: dict[str, BenchmarkSpec] = {}


def register(spec: BenchmarkSpec) -> BenchmarkSpec:
    if spec.name in _REGISTRY:
        raise ValueError(f"duplicate benchmark registration: {spec.name!r}")
    if spec.level not in (0, 1, 2):
        raise ValueError(f"benchmark {spec.name!r}: level must be 0/1/2, got {spec.level}")
    if not spec.presets:
        raise ValueError(f"benchmark {spec.name!r}: at least one preset size required")
    _REGISTRY[spec.name] = spec
    return spec


def _ensure_loaded() -> None:
    # Benchmark modules self-register on import; importing the bench package
    # pulls in every level. Kept lazy so `import repro.core` stays light.
    import repro.bench  # noqa: F401


def get_benchmark(name: str) -> BenchmarkSpec:
    _ensure_loaded()
    try:
        return _REGISTRY[name]
    except KeyError:
        known = ", ".join(sorted(_REGISTRY))
        raise KeyError(f"unknown benchmark {name!r}; known: {known}") from None


def all_benchmarks() -> Sequence[BenchmarkSpec]:
    _ensure_loaded()
    return sorted(_REGISTRY.values(), key=lambda s: (s.level, s.name))


def benchmarks_by_level(level: int) -> Sequence[BenchmarkSpec]:
    return [s for s in all_benchmarks() if s.level == level]
