"""repro: a TPU-native Mirovia/Altis benchmarking + training/serving framework.

The package layers (bottom → top):

- ``repro.kernels``    Pallas TPU kernels with pure-jnp oracles.
- ``repro.bench``      The Mirovia/Altis benchmark suite (levels 0/1/2 + DNN).
- ``repro.models``     LM-family model zoo (dense / MoE / SSM / hybrid / audio / VLM).
- ``repro.core``       Benchmark-suite infrastructure: registry, presets, harness,
                       roofline metrics, results, suite runner, feature analogues.
- ``repro.data``       Deterministic synthetic data pipeline with host prefetch.
- ``repro.optim``      AdamW + schedules + ZeRO + gradient compression.
- ``repro.checkpoint`` Async fault-tolerant checkpointing.
- ``repro.runtime``    Sharding rules, elastic re-mesh, straggler monitor, pipeline.
- ``repro.launch``     Production mesh, multi-pod dry-run, train/serve drivers.
"""

__version__ = "1.0.0"
