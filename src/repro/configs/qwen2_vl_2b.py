"""qwen2-vl-2b — VLM backbone with M-RoPE [arXiv:2409.12191].

28L, d_model 1536, 12 heads (GQA kv=2), d_ff 8960, vocab 151936. The vision
frontend (dynamic-resolution ViT) is a STUB per the assignment:
``input_specs`` provides precomputed patch/token embeddings plus 3-component
(t, h, w) M-RoPE position ids. QKV bias and tied embeddings per the
published config.
"""

from repro.models.config import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="qwen2-vl-2b",
        family="vlm",
        n_layers=28,
        d_model=1536,
        n_heads=12,
        n_kv_heads=2,
        head_dim=128,
        d_ff=8960,
        vocab=151936,
        qkv_bias=True,
        tie_embeddings=True,
        rope="mrope",
        rope_theta=1e6,
        mrope_sections=(16, 24, 24),
        input_mode="embeds",
        notes="M-RoPE; patch-embedding frontend stub",
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="qwen2-vl-2b-smoke",
        family="vlm",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab=128,
        qkv_bias=True,
        tie_embeddings=True,
        rope="mrope",
        mrope_sections=(2, 3, 3),
        input_mode="embeds",
    )
