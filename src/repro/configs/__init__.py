"""Architecture configs: one module per assigned architecture.

``get_config(name)`` returns the exact published configuration;
``get_smoke_config(name)`` returns a reduced same-family variant for CPU
smoke tests (small widths/depths/experts, tiny vocab). The full configs are
only ever lowered abstractly (launch/dryrun.py).
"""

from __future__ import annotations

import importlib

from repro.models.config import ArchConfig

ARCHS = (
    "granite-3-8b",
    "qwen1.5-0.5b",
    "granite-8b",
    "deepseek-7b",
    "xlstm-350m",
    "mixtral-8x22b",
    "dbrx-132b",
    "hubert-xlarge",
    "jamba-1.5-large-398b",
    "qwen2-vl-2b",
)

_MODULES = {name: "repro.configs." + name.replace("-", "_").replace(".", "_") for name in ARCHS}


def _module(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {', '.join(ARCHS)}")
    return importlib.import_module(_MODULES[name])


def get_config(name: str) -> ArchConfig:
    cfg = _module(name).config()
    cfg.validate()
    return cfg


def get_smoke_config(name: str) -> ArchConfig:
    cfg = _module(name).smoke_config()
    cfg.validate()
    return cfg
