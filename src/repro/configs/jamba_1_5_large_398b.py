"""jamba-1.5-large-398b — Mamba+attention hybrid MoE [arXiv:2403.19887].

72L, d_model 8192, 64 heads (GQA kv=8), d_ff 24576, vocab 65536, MoE 16
experts top-2. Layout per the Jamba paper: period-8 blocks with ONE
attention layer per 7 Mamba layers (attention at in-period index 4), MoE on
every other layer. Attention carries no positional encoding (Jamba relies
on Mamba for position). Mamba recurrence ⇒ long_500k runs.
"""

from repro.models.config import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="jamba-1.5-large-398b",
        family="hybrid",
        n_layers=72,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        head_dim=128,
        d_ff=24576,
        vocab=65536,
        n_experts=16,
        top_k=2,
        moe_every=2,
        moe_offset=1,
        attn_period=8,
        attn_offset=4,
        rope="none",
        ssm_state=16,
        ssm_expand=2,
        notes="1:7 attn:mamba, MoE every other layer",
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="jamba-1.5-large-398b-smoke",
        family="hybrid",
        n_layers=8,  # one full period
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab=128,
        n_experts=4,
        top_k=2,
        moe_every=2,
        moe_offset=1,
        attn_period=8,
        attn_offset=4,
        rope="none",
        ssm_state=4,
        ssm_expand=2,
        moe_group_size=64,
        capacity_factor=2.0,
    )
