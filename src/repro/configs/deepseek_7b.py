"""deepseek-7b — llama-arch dense transformer [arXiv:2401.02954].

30L, d_model 4096, 32 heads (kv=32 → MHA), d_ff 11008, vocab 102400.
"""

from repro.models.config import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="deepseek-7b",
        family="dense",
        n_layers=30,
        d_model=4096,
        n_heads=32,
        n_kv_heads=32,
        head_dim=128,
        d_ff=11008,
        vocab=102400,
        notes="llama-arch, full MHA KV",
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="deepseek-7b-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab=128,
    )
