"""xlstm-350m — alternating sLSTM + mLSTM blocks [arXiv:2405.04517].

24L, d_model 1024, 4 heads, d_ff 0 (block-internal projections), vocab
50304. Attention-free: the technique-applicability note and the long_500k
eligibility both follow from the O(1)-state recurrence (DESIGN.md §4).
"""

from repro.models.config import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="xlstm-350m",
        family="ssm",
        n_layers=24,
        d_model=1024,
        n_heads=4,
        n_kv_heads=4,
        head_dim=256,
        d_ff=0,
        vocab=50304,
        xlstm_heads=4,
        rope="none",
        notes="sLSTM + mLSTM; attention-free; O(1) decode state",
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="xlstm-350m-smoke",
        family="ssm",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=0,
        vocab=128,
        xlstm_heads=4,
        rope="none",
    )
