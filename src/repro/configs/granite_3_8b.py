"""granite-3-8b — dense GQA transformer [hf:ibm-granite/granite-3.0-2b-base].

40L, d_model 4096, 32 heads (GQA kv=8), d_ff 12800, vocab 49155. The vocab
is not divisible by the 16-way model axis; GSPMD pads the vocab shard
(DESIGN.md §4).
"""

from repro.models.config import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="granite-3-8b",
        family="dense",
        n_layers=40,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=12800,
        vocab=49155,
        notes="GQA; uneven vocab sharding",
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="granite-3-8b-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab=131,  # keep the uneven-vocab property
    )
