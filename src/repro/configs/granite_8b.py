"""granite-8b — llama-arch code model [arXiv:2405.04324].

36L, d_model 4096, 32 heads (GQA kv=8), d_ff 14336, vocab 49152.
"""

from repro.models.config import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="granite-8b",
        family="dense",
        n_layers=36,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab=49152,
        notes="llama-arch, code",
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="granite-8b-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab=128,
    )
