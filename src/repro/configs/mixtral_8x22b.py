"""mixtral-8x22b — sparse MoE with sliding-window attention [arXiv:2401.04088].

56L, d_model 6144, 48 heads (GQA kv=8), d_ff 16384, vocab 32768, 8 experts
top-2, SWA window 4096. SWA makes decode O(window) ⇒ long_500k runs with a
constant-size ring-buffer KV cache (DESIGN.md §4).
"""

from repro.models.config import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="mixtral-8x22b",
        family="moe",
        n_layers=56,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        head_dim=128,
        d_ff=16384,
        vocab=32768,
        n_experts=8,
        top_k=2,
        window=4096,
        rope_theta=1e6,
        notes="8 experts top-2; SWA ring cache",
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="mixtral-8x22b-smoke",
        family="moe",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab=128,
        n_experts=4,
        top_k=2,
        window=16,
        moe_group_size=64,
        capacity_factor=2.0,
    )
