"""dbrx-132b — fine-grained MoE [hf:databricks/dbrx-base].

40L, d_model 6144, 48 heads (GQA kv=8), d_ff 10752, vocab 100352, 16
experts top-4 (fine-grained: more, smaller experts than mixtral).
"""

from repro.models.config import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="dbrx-132b",
        family="moe",
        n_layers=40,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        head_dim=128,
        d_ff=10752,
        vocab=100352,
        n_experts=16,
        top_k=4,
        rope_theta=5e5,
        notes="16 experts top-4, fine-grained",
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="dbrx-132b-smoke",
        family="moe",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=96,
        vocab=128,
        n_experts=8,
        top_k=4,
        moe_group_size=64,
        capacity_factor=2.0,
    )
