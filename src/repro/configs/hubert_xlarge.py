"""hubert-xlarge — encoder-only audio transformer [arXiv:2106.07447].

48L, d_model 1280, 16 heads (kv=16), d_ff 5120, vocab 504 (cluster units).
The conv waveform frontend is a STUB per the assignment: ``input_specs``
provides precomputed frame embeddings (B, T, 1280); training is
masked-unit prediction over the 504 units. Encoder-only ⇒ no decode shapes
(DESIGN.md §4).
"""

from repro.models.config import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="hubert-xlarge",
        family="audio",
        n_layers=48,
        d_model=1280,
        n_heads=16,
        n_kv_heads=16,
        head_dim=80,
        d_ff=5120,
        vocab=504,
        causal=False,
        encoder_only=True,
        input_mode="embeds",
        rope="none",
        notes="encoder-only; frame-embedding frontend stub",
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="hubert-xlarge-smoke",
        family="audio",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab=32,
        causal=False,
        encoder_only=True,
        input_mode="embeds",
        rope="none",
    )
