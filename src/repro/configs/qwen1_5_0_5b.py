"""qwen1.5-0.5b — dense transformer with QKV bias [hf:Qwen/Qwen1.5-0.5B].

24L, d_model 1024, 16 heads (kv=16 → MHA), d_ff 2816, vocab 151936, tied
embeddings.
"""

from repro.models.config import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="qwen1.5-0.5b",
        family="dense",
        n_layers=24,
        d_model=1024,
        n_heads=16,
        n_kv_heads=16,
        head_dim=64,
        d_ff=2816,
        vocab=151936,
        qkv_bias=True,
        tie_embeddings=True,
        rope_theta=1e6,
        notes="QKV bias; tied embeddings",
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="qwen1.5-0.5b-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab=128,
        qkv_bias=True,
        tie_embeddings=True,
    )
