"""Named-axis sharding rules for the model zoo (DESIGN.md §5).

Parameters are matched by leaf name (the trees in models/ use globally
unambiguous names) against an ordered list of *candidate* dimensions to
shard over the ``model`` axis; the first candidate whose size divides the
axis is used, otherwise the leaf replicates (e.g. mixtral's 8 experts don't
divide a 16-way model axis ⇒ its expert FFNs shard the ``d_ff`` dim
instead — rule order encodes that preference). Leaves under ``blocks`` carry
a leading stacked-period dim, handled transparently.

Activations: batch shards over the data axes (("pod","data") multi-pod);
with ``seq_shard=True`` (Megatron-SP analogue) the residual stream also
shards its sequence dim over ``model``, which divides scan-saved activations
by the TP degree — the decisive term for 100B-scale training memory.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:  # newer jax exports shard_map at top level with a `check_vma` kwarg
    shard_map = jax.shard_map
except AttributeError:  # older jax keeps it in experimental as `check_rep`
    from functools import wraps

    from jax.experimental.shard_map import shard_map as _experimental_shard_map

    @wraps(_experimental_shard_map)
    def shard_map(*args, **kwargs):
        if "check_vma" in kwargs:
            kwargs["check_rep"] = kwargs.pop("check_vma")
        return _experimental_shard_map(*args, **kwargs)


# jax.lax.pvary (varying-axis annotation for the vma checker) only exists on
# newer jax; it is semantically an identity, so fall back to one.
pvary = getattr(jax.lax, "pvary", lambda x, axes: x)

__all__ = [
    "ShardingRules",
    "param_pspecs",
    "batch_pspec",
    "make_activation_sharder",
    "data_mesh",
    "init_distributed",
    "host_data_mesh",
    "replicate",
    "workload_pspecs",
    "shard_applies",
    "place_args",
    "shard_map",
    "pvary",
]

# name -> ordered candidate shard dims (on the UNstacked leaf shape).
# dim index -> which dimension to try placing "model" on.
_PARAM_RULES: dict[str, tuple[int, ...]] = {
    "embed": (0,),  # (V, d): vocab-shard
    "unembed": (1,),  # (d, V)
    # attention
    "wq": (1,), "wk": (1,), "wv": (1,), "wo": (0,),
    "bq": (0,), "bk": (0,), "bv": (0,),
    # dense mlp
    "w_gate": (1,), "w_up": (1,), "w_down": (0,),
    # moe (expert-stacked weights): prefer EP on the expert dim, else d_ff
    "moe.w_gate": (0, 2), "moe.w_up": (0, 2), "moe.w_down": (0, 1),
    "router": (),
    # mamba
    "in_proj": (1,), "x_proj": (0,), "dt_w": (1,), "dt_b": (0,),
    "A_log": (0,), "D": (0,), "out_proj": (0,),
    "conv_w": (1,), "conv_b": (0,),
    # mlstm
    "w_gates": (0,), "b_gates": (), "gn": (0,),
    # slstm: block-diagonal recurrent mats shard their output dim (the
    # hidden state all-gathers per step inside the scan — O(d) traffic).
    "w_x": (1,), "r_z": (2,), "r_i": (2,), "r_f": (2,), "r_o": (2,), "b": (),
    "w_ff1": (1,), "w_ff2": (0,),
    # norms
    "ln": (), "ln1": (), "ln2": (), "ln_f": (),
}


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    mesh: Mesh
    model_axis: str = "model"
    data_axes: tuple[str, ...] = ("data",)
    seq_shard: bool = False  # SP: shard residual sequence dim over model
    # Replicate leaves below this element count: tiny per-step weights (e.g.
    # sLSTM recurrent mats) cost more in per-scan-step all-gathers than they
    # save in HBM (§Perf xlstm iteration). 0 disables.
    replicate_below: int = 0
    # Shard decode KV caches over their sequence dim instead of head_dim
    # (§Perf decode iteration): with head_dim sharded, GSPMD all-gathers the
    # whole cache per step (125 GB/step for granite decode_32k); with the
    # sequence sharded, each shard scores its own keys and the softmax
    # combines with scalar-sized reductions — flash-decoding split-K
    # semantics, expressed purely as a sharding choice.
    cache_seq_shard: bool = False
    # Gather the MoE FFN input to data-only sharding before dispatch: the
    # GShard dispatch/combine einsums contract over tokens, and seq-sharded
    # tokens force (G,E,cap,d)-sized partial-sum all-reduces over the model
    # axis (§Perf mixtral iteration — the 3.3 TB/step finding). With the
    # input gathered, the only MoE collective is the dense-MLP-like
    # row-parallel reduce of the expert down-projection.
    moe_gather_tokens: bool = False

    @property
    def model_size(self) -> int:
        return self.mesh.shape[self.model_axis]

    @property
    def data_size(self) -> int:
        out = 1
        for a in self.data_axes:
            out *= self.mesh.shape[a]
        return out


def _leaf_rule_key(path) -> str:
    names = [getattr(p, "key", getattr(p, "name", None)) for p in path]
    names = [n for n in names if isinstance(n, str)]
    leaf = names[-1] if names else ""
    if "ffn" in names and leaf in ("w_gate", "w_up", "w_down") and "router_sibling" not in names:
        # MoE expert weights are distinguished by rank at the call site.
        return leaf
    return leaf


def _pspec_for_leaf(path, leaf, rules: ShardingRules) -> P:
    names = [getattr(p, "key", getattr(p, "name", None)) for p in path]
    names = [n for n in names if isinstance(n, str)]
    name = names[-1] if names else ""
    stacked = "blocks" in names  # leading period dim
    base_rank = leaf.ndim - (1 if stacked else 0)
    key = name
    # Expert-stacked FFN weights have one extra rank vs dense MLP weights.
    if name in ("w_gate", "w_up", "w_down") and base_rank == 3:
        key = "moe." + name
    candidates = _PARAM_RULES.get(key, ())
    spec = [None] * leaf.ndim
    if rules.replicate_below:
        import math

        if math.prod(leaf.shape) < rules.replicate_below:
            return P(*spec)
    offset = 1 if stacked else 0
    for dim in candidates:
        d = dim + offset
        if leaf.shape[d] % rules.model_size == 0 and leaf.shape[d] >= rules.model_size:
            spec[d] = rules.model_axis
            break
    return P(*spec)


def param_pspecs(params: Any, rules: ShardingRules) -> Any:
    """Pytree of PartitionSpecs matching ``params`` (works on
    ShapeDtypeStructs too — the dry-run path)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = [_pspec_for_leaf(path, leaf, rules) for path, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


def batch_pspec(batch: Any, rules: ShardingRules) -> Any:
    """Shard the batch dim over the data axes when divisible (decode at
    batch 1 replicates — latency-bound serving has no batch to shard)."""

    def spec(leaf) -> P:
        b = leaf.shape[0] if leaf.ndim else 1
        if leaf.ndim == 0 or b % max(rules.data_size, 1) != 0:
            return P()
        return P(rules.data_axes, *([None] * (leaf.ndim - 1)))

    return jax.tree.map(spec, batch)


def cache_pspecs(cache: Any, rules: ShardingRules) -> Any:
    """Decode-cache sharding: leading dim is the stacked period axis
    (never sharded), dim 1 is batch (over data axes when divisible), and the
    last dim (head_dim / d_inner / d_model / state width) goes over
    ``model`` when divisible — head_dim sharding keeps GQA caches TP-sharded
    even when kv_heads < TP degree (DESIGN.md §5)."""

    def spec(leaf) -> P:
        if leaf.ndim < 3:
            return P()
        dims: list = [None] * leaf.ndim
        if leaf.shape[1] % max(rules.data_size, 1) == 0 and leaf.shape[1] >= rules.data_size:
            dims[1] = rules.data_axes
        # KV caches are rank 5: (periods, B, S, KV, hd). Prefer the S dim
        # under cache_seq_shard (flash-decoding split-K — see field doc).
        if (
            rules.cache_seq_shard
            and leaf.ndim == 5
            and leaf.shape[2] % rules.model_size == 0
            and leaf.shape[2] >= rules.model_size
        ):
            dims[2] = rules.model_axis
        elif leaf.shape[-1] % rules.model_size == 0 and leaf.shape[-1] >= rules.model_size:
            dims[-1] = rules.model_axis
        return P(*dims)

    return jax.tree.map(spec, cache)


def zero_pspecs(param_specs: Any, params: Any, rules: ShardingRules) -> Any:
    """ZeRO-1: extend each parameter spec with the data axes on the first
    unsharded dim that divides — optimizer moments shard over data *and*
    model, cutting optimizer HBM by the DP degree."""

    def extend(spec: P, leaf) -> P:
        dims = list(spec) + [None] * (leaf.ndim - len(spec))
        for i, d in enumerate(dims):
            if d is None and leaf.shape[i] % max(rules.data_size, 1) == 0 and leaf.shape[i] >= rules.data_size:
                dims[i] = rules.data_axes
                break
        return P(*dims)

    return jax.tree.map(
        extend, param_specs, params, is_leaf=lambda x: isinstance(x, P)
    )


def make_activation_sharder(rules: ShardingRules):
    """The ``shard_activation`` hook Model takes (DESIGN.md §5)."""
    dp = rules.data_axes
    # dp-only binding folds the model axis into data; it is then unavailable
    # for vocab/seq sharding (a spec may use each mesh axis once).
    mdl = rules.model_axis if rules.model_axis not in dp else None

    def shard(x: jax.Array, name: str) -> jax.Array:
        if x.ndim == 3:  # (B, T, d) or (B, T, V)
            b, t, _ = x.shape
            bspec = dp if b % rules.data_size == 0 else None
            if name == "logits":
                s = P(bspec, None, mdl)
            elif name == "moe_in":
                if not rules.moe_gather_tokens:
                    return x
                s = P(bspec, None, None)
            elif rules.seq_shard and t % rules.model_size == 0:
                s = P(bspec, mdl, None)
            else:
                s = P(bspec, None, None)
        elif x.ndim == 2:  # decode: (B, d) or (B, V)
            b = x.shape[0]
            bspec = dp if b % rules.data_size == 0 else None
            s = P(bspec, mdl if name == "logits" else None)
        else:
            return x
        return jax.lax.with_sharding_constraint(x, NamedSharding(rules.mesh, s))

    return shard


def data_mesh(n_devices: int | None = None, axis: str = "data") -> Mesh:
    """A 1-axis mesh over the first ``n_devices`` devices (all by default).

    The benchmark engine's placement stage builds its data mesh here (both
    replicate and shard modes); model code uses the richer meshes in launch/.
    """
    devs = jax.devices()
    n = len(devs) if n_devices is None else n_devices
    if not 1 <= n <= len(devs):
        raise ValueError(f"requested {n} devices but only {len(devs)} available")
    import numpy as np

    return Mesh(np.asarray(devs[:n]), (axis,))


def init_distributed(
    coordinator_address: str | None = None,
    num_processes: int | None = None,
    process_id: int | None = None,
) -> bool:
    """Join a ``jax.distributed`` multi-process topology, gated by backend.

    On real multi-host hardware (TPU/GPU) this wraps
    ``jax.distributed.initialize`` so ``jax.devices()`` becomes the
    *global* device list and :func:`data_mesh` / :func:`host_data_mesh`
    span processes. On the CPU backend XLA cannot execute multi-process
    computations ("Multiprocess computations aren't implemented on the
    CPU backend"), so this returns False without initializing — CI fakes
    the topology instead: one process, ``xla_force_host_platform_
    device_count=N``, and :func:`host_data_mesh` partitioning the forced
    devices into host groups. Returns True when the distributed runtime
    was (or already is) initialized.
    """
    if jax.default_backend() == "cpu":
        return False
    if jax.process_count() > 1:  # already initialized by the launcher/env
        return True
    try:
        jax.distributed.initialize(
            coordinator_address=coordinator_address,
            num_processes=num_processes,
            process_id=process_id,
        )
    except (RuntimeError, ValueError):
        # Already initialized, or a single-process environment with no
        # coordinator: both mean "use what jax already has".
        pass
    return jax.process_count() > 1


def host_data_mesh(
    n_hosts: int,
    devices_per_host: int | None = None,
    axes: tuple[str, str] = ("host", "data"),
) -> Mesh:
    """A 2-axis ``(host, data)`` mesh partitioning the visible devices
    into ``n_hosts`` contiguous groups — the multi-host data-mesh shape.

    Under an initialized ``jax.distributed`` runtime the device list is
    global and the host axis aligns with processes (JAX orders global
    devices by process); on CI the same topology is faked in one process
    by forcing N host devices (``xla_force_host_platform_device_count``)
    and grouping them here — the SNIPPETS idiom the distributed tests and
    the ``--dist`` smoke leg run under. Contiguous grouping means the
    ``data`` axis varies fastest within a host, so collectives over
    ``data`` stay host-local and collectives over ``host`` model the
    cross-host hop.
    """
    if n_hosts < 1:
        raise ValueError(f"n_hosts must be >= 1, got {n_hosts}")
    devs = jax.devices()
    per = devices_per_host
    if per is None:
        if len(devs) % n_hosts:
            raise ValueError(
                f"{len(devs)} devices do not divide into {n_hosts} hosts; "
                "pass devices_per_host explicitly"
            )
        per = len(devs) // n_hosts
    need = n_hosts * per
    if need > len(devs):
        raise ValueError(
            f"requested {n_hosts} hosts x {per} devices = {need}, "
            f"but only {len(devs)} available"
        )
    import numpy as np

    return Mesh(np.asarray(devs[:need]).reshape(n_hosts, per), axes)


def replicate(tree: Any, mesh: Mesh) -> Any:
    """device_put every array leaf fully replicated across ``mesh``."""
    s = NamedSharding(mesh, P())
    return jax.tree.map(lambda x: jax.device_put(x, s), tree)


def workload_pspecs(workload, mesh: Mesh, axis: str = "data") -> tuple:
    """Per-input :class:`NamedSharding` tuple from a workload's
    ``batch_dims`` declaration (the engine's shard-mode placement).

    Each declared dim becomes ``axis`` at that position; ``None`` entries
    (and every input of a non-batchable workload) replicate. Divisibility
    of the actual shapes is checked at placement time (``place_args``),
    not here — this is the pure declaration→sharding mapping.
    """
    dims = workload.batch_dims
    if dims is None:
        raise ValueError(
            f"workload {workload.name!r} declares no batch_dims; "
            "sharded placement must fall back to replicate"
        )

    def sharding(dim: int | None) -> NamedSharding:
        if dim is None:
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, P(*([None] * dim), axis))

    return tuple(sharding(d) for d in dims)


def shard_applies(args: tuple, workload, n_devices: int) -> bool:
    """Shape-only check: would a ``shard`` placement actually partition
    anything? No device transfers — callers (e.g. cache-key resolution)
    can answer this without placing a byte.
    """
    if not getattr(workload, "batchable", False):
        return False
    if len(workload.batch_dims) != len(args):
        raise ValueError(
            f"workload {workload.name!r} declares {len(workload.batch_dims)} "
            f"batch_dims but make_inputs produced {len(args)} inputs"
        )
    for arg, dim in zip(args, workload.batch_dims):
        shape = getattr(arg, "shape", ())
        if dim is not None and len(shape) > dim and shape[dim] % n_devices == 0:
            return True
    return False


def place_args(args: tuple, workload, mesh: Mesh, mode: str) -> tuple[tuple, str]:
    """Place workload inputs on ``mesh`` per the requested placement mode.

    Returns ``(placed_args, effective_mode)``: a ``shard`` request on a
    workload without ``batch_dims`` — or whose declared dims don't divide
    the mesh — degrades to ``replicate``, and the caller records the mode
    that actually happened.
    """
    if mode == "shard" and shard_applies(args, workload, mesh.size):
        shardings = workload_pspecs(workload, mesh)
        n = mesh.size
        placed = []
        for arg, dim, s in zip(args, workload.batch_dims, shardings):
            shape = getattr(arg, "shape", ())
            if dim is not None and len(shape) > dim and shape[dim] % n == 0:
                placed.append(jax.device_put(arg, s))
            else:
                placed.append(jax.device_put(arg, NamedSharding(mesh, P())))
        return tuple(placed), "shard"
    # Non-batchable, or every declared dim failed the divisibility check:
    # this is a plain replicated run and must share its compile-cache entry.
    return replicate(args, mesh), "replicate"


def named(mesh: Mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )
