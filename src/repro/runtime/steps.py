"""Train / eval step factories.

``make_train_step`` wires model loss → grad → clip → schedule → AdamW into
one jit-able function with optional microbatch gradient accumulation
(``accum > 1`` rescans the batch in slices — the activation-memory lever for
the biggest configs). Buffer donation happens at the jit call site
(launch/train.py) so params/opt-state update in place.
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.optim.adamw import AdamW, AdamWState
from repro.optim.clip import clip_by_global_norm

__all__ = ["make_train_step", "make_eval_step"]


def make_train_step(
    model: Model,
    optimizer: AdamW,
    schedule: Callable[[jax.Array], jax.Array],
    *,
    clip_norm: float = 1.0,
    accum: int = 1,
):
    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(model.loss_fn, has_aux=True)(
            params, batch
        )
        return loss, metrics, grads

    def train_step(params, opt_state: AdamWState, batch) -> tuple[Any, AdamWState, dict]:
        if accum == 1:
            loss, metrics, grads = grads_of(params, batch)
        else:
            def slice_mb(i, leaf):
                mb = leaf.shape[0] // accum
                return jax.lax.dynamic_slice_in_dim(leaf, i * mb, mb, axis=0)

            def body(carry, i):
                gacc, lacc = carry
                mb_batch = jax.tree.map(lambda l: slice_mb(i, l), batch)
                loss, _, grads = grads_of(params, mb_batch)
                gacc = jax.tree.map(jnp.add, gacc, grads)
                return (gacc, lacc + loss), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (gsum, lsum), _ = jax.lax.scan(
                body, (zeros, jnp.float32(0)), jnp.arange(accum)
            )
            grads = jax.tree.map(lambda g: (g / accum).astype(jnp.float32), gsum)
            loss = lsum / accum
            metrics = {"loss": loss, "tokens": jnp.float32(0)}
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        lr = schedule(opt_state.step)
        new_params, new_opt = optimizer.update(grads, opt_state, params, lr)
        metrics = dict(metrics)
        metrics.update({"grad_norm": gnorm, "lr": lr})
        return new_params, new_opt, metrics

    return train_step


def make_eval_step(model: Model):
    def eval_step(params, batch) -> dict:
        loss, metrics = model.loss_fn(params, batch)
        return metrics

    return eval_step
