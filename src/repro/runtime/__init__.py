# Distributed runtime: named-axis sharding rules (DP/TP/SP/EP), the train
# step factory, elastic re-meshing, straggler monitoring, and the optional
# pod-axis GPipe pipeline.

from repro.runtime.sharding import (  # noqa: F401
    ShardingRules,
    batch_pspec,
    make_activation_sharder,
    param_pspecs,
)
from repro.runtime.steps import make_eval_step, make_train_step  # noqa: F401
from repro.runtime.elastic import choose_submesh, plan_remesh  # noqa: F401
from repro.runtime.straggler import StragglerMonitor  # noqa: F401
