"""Straggler detection and mitigation policy.

In synchronous SPMD every step runs at the pace of the slowest participant;
a straggler is invisible *inside* the program and shows up as inflated
step wall-time. The monitor keeps a robust baseline (EMA of the step-time
median) and flags sustained deviation; the mitigation ladder is:

1. observe (always) — flag + log, feeds the ops dashboard,
2. checkpoint-now — cut the loss window before a suspected failure,
3. elastic re-mesh (runtime/elastic.py) — evict the slow host and resume.

Eviction is deliberately not automatic-by-default: on real pods transient
HBM ECC scrubs or host GC cause false positives, and a re-mesh costs a
checkpoint restore; ``sustained`` controls how many consecutive slow steps
arm the trigger (DESIGN.md §9).
"""

from __future__ import annotations

import dataclasses

__all__ = ["StragglerMonitor"]


@dataclasses.dataclass
class StragglerMonitor:
    threshold: float = 1.5  # step is "slow" above threshold × baseline
    sustained: int = 5  # consecutive slow steps before triggering
    ema: float = 0.05  # baseline update rate

    _baseline: float | None = None
    _slow_run: int = 0
    triggered: int = 0

    def record(self, step_seconds: float) -> bool:
        """Record one step; returns True when mitigation should trigger."""
        if self._baseline is None:
            self._baseline = step_seconds
            return False
        slow = step_seconds > self.threshold * self._baseline
        if slow:
            self._slow_run += 1
        else:
            self._slow_run = 0
            # Only track the baseline on healthy steps — a straggler must
            # not drag the baseline up and mask itself.
            self._baseline = (1 - self.ema) * self._baseline + self.ema * step_seconds
        if self._slow_run >= self.sustained:
            self._slow_run = 0
            self.triggered += 1
            return True
        return False

    @property
    def baseline(self) -> float | None:
        return self._baseline
