"""GPipe-style pipeline parallelism over the ``pod`` axis.

The pod axis defaults to extra data parallelism; this module is the
alternative binding (DESIGN.md §5): the layer stack is split into P
contiguous stages (params sharded over ``pod`` on their stacked-layer dim by
``shard_map``), microbatches flow stage-to-stage via ``lax.ppermute`` in a
``lax.scan`` over M + P - 1 ticks (the GPipe schedule: P-1 bubble ticks).

This is the *cross-pod traffic shape-changer*: DP-over-pod moves the full
gradient every step over the slow link; PP moves only microbatch activations
(B_mb × T × d per tick). Which wins is quantified in EXPERIMENTS.md §Perf
for jamba (the most collective-bound cell).
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.runtime.sharding import pvary, shard_map

__all__ = ["gpipe_forward"]


def gpipe_forward(
    stage_fn: Callable,  # stage_fn(stage_params, x) -> x
    mesh: Mesh,
    *,
    axis: str = "pod",
):
    """Returns f(stacked_params, x_microbatches) running the pipeline.

    ``stacked_params``: pytree with leading dim = n_stages·layers_per_stage
    (sharded over ``axis``); ``x_microbatches``: (M, mb, ...) replicated in.
    Output: (M, mb, ...) of last-stage results (replicated out).
    """
    n_stages = mesh.shape[axis]

    def pipelined(stage_params, x_mb):
        stage = jax.lax.axis_index(axis)
        M = x_mb.shape[0]
        ticks = M + n_stages - 1

        def tick(carry, t):
            act = carry  # activation entering this stage this tick
            # Stage 0 ingests microbatch t (clamped; bubbles are masked out).
            mb = jax.lax.dynamic_index_in_dim(
                x_mb, jnp.clip(t, 0, M - 1), axis=0, keepdims=False
            )
            inp = jnp.where(stage == 0, mb, act)
            out = stage_fn(stage_params, inp)
            # Results of the final stage for microbatch t-(P-1).
            is_result = (t - (n_stages - 1) >= 0) & (stage == n_stages - 1)
            emitted = jnp.where(is_result, out, jnp.zeros_like(out))
            # Everyone reduces so the result is replicated (cheap at test
            # scale; a real launch would keep results on the last stage).
            emitted = jax.lax.psum(emitted, axis)
            # Hand activations to the next stage.
            act_next = jax.lax.ppermute(
                out, axis, [(i, (i + 1) % n_stages) for i in range(n_stages)]
            )
            return act_next, emitted

        x0 = pvary(jnp.zeros_like(x_mb[0]), (axis,))
        _, results = jax.lax.scan(tick, x0, jnp.arange(ticks))
        return results[n_stages - 1 :]  # (M, mb, ...)

    in_specs = (P(axis), P())  # params stage-sharded; microbatches replicated
    out_specs = P()
    return shard_map(pipelined, mesh=mesh, in_specs=in_specs, out_specs=out_specs)
