"""Elastic scaling: re-mesh after node loss, resume from checkpoint.

SPMD training cannot tolerate a missing participant mid-step; the sound
recovery is (1) detect loss, (2) choose the largest valid submesh over the
surviving devices, (3) restore the latest checkpoint *under the new mesh*
(the per-leaf checkpoint format re-sharders transparently — restore targets
carry the new NamedShardings), (4) rescale the data axis. The TP (model)
degree is pinned — parameters are sharded to it and changing it mid-run
would change per-op numerics and memory layout; elasticity happens on the
data axes, which only changes gradient-batch partitioning (DESIGN.md §9).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
from jax.sharding import Mesh

__all__ = ["choose_submesh", "plan_remesh", "RemeshPlan"]


@dataclasses.dataclass(frozen=True)
class RemeshPlan:
    data: int
    model: int
    devices_used: int
    devices_idle: int
    global_batch_ratio: float  # new_data / old_data


def choose_submesh(n_devices: int, *, model: int, max_data: int | None = None) -> tuple[int, int]:
    """Largest (data, model) with data·model ≤ n_devices, model fixed."""
    if n_devices < model:
        raise ValueError(
            f"cannot keep model axis {model} with only {n_devices} devices; "
            "restore requires at least one full TP group"
        )
    data = n_devices // model
    if max_data is not None:
        data = min(data, max_data)
    # Prefer powers of two on the data axis (collective-friendly rings).
    p = 1
    while p * 2 <= data:
        p *= 2
    return p, model


def plan_remesh(
    old_mesh_shape: tuple[int, int],
    surviving_devices: int,
) -> RemeshPlan:
    old_data, model = old_mesh_shape
    data, model = choose_submesh(surviving_devices, model=model)
    return RemeshPlan(
        data=data,
        model=model,
        devices_used=data * model,
        devices_idle=surviving_devices - data * model,
        global_batch_ratio=data / old_data,
    )


def build_mesh(devices: Sequence[jax.Device] | None, data: int, model: int) -> Mesh:
    devs = list(devices if devices is not None else jax.devices())[: data * model]
    import numpy as np

    return Mesh(np.asarray(devs).reshape(data, model), ("data", "model"))
