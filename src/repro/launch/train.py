"""Fault-tolerant training driver.

End-to-end wiring of every substrate: config → Model → sharding rules →
train step (jit, donated) → synthetic data with prefetch → async atomic
checkpointing → exact resume → straggler monitoring. On this CPU container
it drives the reduced/smoke configs (examples/train_lm.py); on a pod the
same driver binds the production mesh (--mesh pod).

Fault-tolerance contract:
- ``--resume auto`` restores params/optimizer/data-cursor/RNG from the
  latest complete checkpoint; the step sequence is bit-identical to an
  uninterrupted run (tests/test_train_resume.py).
- A straggler trigger forces an immediate checkpoint (the cheap half of the
  mitigation ladder — runtime/straggler.py); re-meshing is the operator's
  call via relaunch with fewer hosts (runtime/elastic.py picks the mesh).
"""

from __future__ import annotations

import argparse
import dataclasses
import functools
import sys
import time

import jax
import numpy as np

from repro.checkpoint import Checkpointer
from repro.configs import ARCHS, get_config, get_smoke_config
from repro.data import Prefetch, SyntheticEmbeds, SyntheticLM
from repro.models import Model
from repro.optim import AdamW
from repro.optim.schedule import warmup_cosine
from repro.runtime.elastic import build_mesh, choose_submesh
from repro.runtime.sharding import (
    ShardingRules,
    batch_pspec,
    make_activation_sharder,
    param_pspecs,
)
from repro.runtime.steps import make_train_step
from repro.runtime.straggler import StragglerMonitor

__all__ = ["main", "train"]


def _make_data(cfg, batch: int, seq: int, seed: int):
    if cfg.input_mode == "embeds":
        return SyntheticEmbeds(
            d_model=cfg.d_model, vocab=cfg.vocab, batch=batch, seq=seq,
            mrope=cfg.rope == "mrope", seed=seed,
        )
    return SyntheticLM(vocab=cfg.vocab, batch=batch, seq=seq, seed=seed)


def train(
    *,
    arch: str,
    smoke: bool = True,
    steps: int = 100,
    stop_after: int | None = None,  # simulate interruption at this step
    batch: int = 8,
    seq: int = 64,
    lr: float = 1e-3,
    accum: int = 1,
    checkpoint_dir: str | None = None,
    save_every: int = 50,
    resume: bool = False,
    use_mesh: bool = False,
    log_every: int = 10,
    seed: int = 0,
    moment_dtype: str = "float32",
) -> dict:
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    if smoke:
        # Keep smoke runs fast but honest: small width, real block structure.
        cfg = dataclasses.replace(cfg, dtype="float32")

    mesh = None
    shard = None
    if use_mesh and len(jax.devices()) > 1:
        data, model_deg = choose_submesh(len(jax.devices()), model=1)
        mesh = build_mesh(jax.devices(), data, model_deg)
        rules = ShardingRules(mesh=mesh, data_axes=("data",))
        shard = make_activation_sharder(rules)

    model = Model(cfg, shard_activation=shard, remat=not smoke)
    opt = AdamW(moment_dtype=moment_dtype)
    sched = functools.partial(
        warmup_cosine, peak_lr=lr, warmup_steps=max(1, steps // 20), total_steps=steps
    )
    step_fn = make_train_step(model, opt, sched, accum=accum)

    params = model.init(jax.random.key(seed))
    opt_state = opt.init(params)
    start_step = 0

    ckpt = Checkpointer(checkpoint_dir) if checkpoint_dir else None
    if resume and ckpt and ckpt.latest_step() is not None:
        payload = {"params": params, "opt": opt_state, "cursor": 0}
        restored_step, payload = ckpt.restore(payload)
        params, opt_state = payload["params"], payload["opt"]
        start_step = int(payload["cursor"])
        print(f"[train] resumed from step {restored_step} (cursor {start_step})")

    if mesh is not None:
        rules = ShardingRules(mesh=mesh, data_axes=("data",))
        p_sh = jax.tree.map(
            lambda s: jax.sharding.NamedSharding(mesh, s),
            param_pspecs(params, rules),
            is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec),
        )
        params = jax.device_put(params, p_sh)
        jit_step = jax.jit(step_fn, donate_argnums=(0, 1))
    else:
        jit_step = jax.jit(step_fn, donate_argnums=(0, 1))

    data = _make_data(cfg, batch, seq, seed)
    batch_sharding = None
    if mesh is not None:
        b_specs = batch_pspec(
            jax.eval_shape(lambda: data.batch_at(0)), rules
        )
        batch_sharding = jax.tree.map(
            lambda s: jax.sharding.NamedSharding(mesh, s),
            b_specs,
            is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec),
        )
    prefetch = Prefetch(data.batch_at, start_step=start_step, sharding=batch_sharding)
    monitor = StragglerMonitor()
    losses: list[float] = []
    t_start = time.time()
    stop_at = min(steps, stop_after) if stop_after is not None else steps
    try:
        for step_idx, batch_data in prefetch:
            if step_idx >= stop_at:
                break
            t0 = time.time()
            params, opt_state, metrics = jit_step(params, opt_state, batch_data)
            jax.block_until_ready(metrics["loss"])
            dt = time.time() - t0
            losses.append(float(metrics["loss"]))
            if monitor.record(dt) and ckpt:
                print(f"[train] straggler trigger at step {step_idx}; checkpointing")
                ckpt.save(step_idx, {"params": params, "opt": opt_state, "cursor": step_idx + 1})
            if ckpt and save_every and (step_idx + 1) % save_every == 0:
                ckpt.save(step_idx + 1, {"params": params, "opt": opt_state, "cursor": step_idx + 1})
            if log_every and step_idx % log_every == 0:
                print(
                    f"[train] step {step_idx} loss {losses[-1]:.4f} "
                    f"({dt * 1e3:.0f} ms/step, lr {float(metrics['lr']):.2e})",
                    flush=True,
                )
    finally:
        prefetch.close()
        if ckpt:
            ckpt.wait()
    wall = time.time() - t_start
    if ckpt:
        ckpt.save(
            stop_at, {"params": params, "opt": opt_state, "cursor": stop_at},
            blocking=True,
        )
    return {
        "first_loss": losses[0] if losses else float("nan"),
        "final_loss": float(np.mean(losses[-5:])) if losses else float("nan"),
        "steps": len(losses),
        "wall_s": wall,
        "params": params,
        "losses": losses,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCHS, default="qwen1.5-0.5b")
    ap.add_argument("--full", action="store_true", help="use the full (non-smoke) config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--mesh", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    out = train(
        arch=args.arch, smoke=not args.full, steps=args.steps, batch=args.batch,
        seq=args.seq, lr=args.lr, accum=args.accum,
        checkpoint_dir=args.checkpoint_dir, save_every=args.save_every,
        resume=args.resume, use_mesh=args.mesh, seed=args.seed,
    )
    print(
        f"[train] done: {out['steps']} steps in {out['wall_s']:.1f}s, "
        f"loss {out['first_loss']:.4f} -> {out['final_loss']:.4f}"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
