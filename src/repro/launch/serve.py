"""Batched serving driver: prefill + decode with continuous batching.

A fixed pool of ``batch`` decode slots runs in lockstep (one jitted
``decode_step`` per tick over the whole pool — the TPU-friendly schedule);
sequences that hit their length budget are retired and their slot is refilled
from the request queue at the next prefill boundary. Greedy decoding;
per-slot position bookkeeping lives host-side, the cache is donated
device-side state.

This is the serving-side example driver ((b) deliverable); the dry-run
lowers the same ``decode_step`` under the production mesh for the
``decode_32k``/``long_500k`` cells.
"""

from __future__ import annotations

import argparse
import dataclasses
import sys
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config, get_smoke_config
from repro.models import Model

__all__ = ["ServeStats", "serve", "main"]


@dataclasses.dataclass
class ServeStats:
    requests: int
    prefill_tokens: int
    decoded_tokens: int
    wall_s: float
    tokens_per_s: float
    outputs: list[list[int]]


def serve(
    *,
    arch: str,
    smoke: bool = True,
    n_requests: int = 8,
    batch: int = 4,
    prompt_len: int = 16,
    gen_len: int = 16,
    max_len: int = 64,
    seed: int = 0,
) -> ServeStats:
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    if cfg.encoder_only:
        raise ValueError(f"{arch} is encoder-only: no decode path")
    if smoke:
        cfg = dataclasses.replace(cfg, dtype="float32")
    model = Model(cfg, remat=False)
    params = model.init(jax.random.key(seed))

    prefill = jax.jit(lambda p, b: model.prefill(p, b, max_len))
    step = jax.jit(model.decode_step, donate_argnums=(1,))

    rng = np.random.default_rng(seed)
    prompts = [
        rng.integers(0, cfg.vocab, prompt_len).astype(np.int32)
        for _ in range(n_requests)
    ]
    pending = list(range(n_requests))
    outputs: list[list[int]] = [[] for _ in range(n_requests)]

    t0 = time.time()
    decoded = 0
    prefilled = 0
    while pending:
        active = pending[:batch]
        pending = pending[len(active) :]
        # Pad the pool to full batch (idle slots decode into a scratch row).
        idx = active + [active[-1]] * (batch - len(active))
        toks = jnp.asarray(np.stack([prompts[i] for i in idx]))
        cache, logits = prefill(params, {"tokens": toks})
        prefilled += prompt_len * len(active)
        last = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        for slot, req in enumerate(active):
            outputs[req].append(int(last[slot]))
        pos = prompt_len
        while pos < prompt_len + gen_len - 1 and pos < max_len - 1:
            logits, cache = step(params, cache, last, jnp.int32(pos))
            last = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            for slot, req in enumerate(active):
                outputs[req].append(int(last[slot]))
            decoded += len(active)
            pos += 1
    wall = time.time() - t0
    return ServeStats(
        requests=n_requests,
        prefill_tokens=prefilled,
        decoded_tokens=decoded,
        wall_s=wall,
        tokens_per_s=(decoded + prefilled) / max(wall, 1e-9),
        outputs=outputs,
    )


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCHS, default="qwen1.5-0.5b")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=16)
    args = ap.parse_args(argv)
    stats = serve(
        arch=args.arch, smoke=not args.full, n_requests=args.requests,
        batch=args.batch, prompt_len=args.prompt_len, gen_len=args.gen_len,
        max_len=args.prompt_len + args.gen_len + 8,
    )
    print(
        f"[serve] {stats.requests} requests, {stats.prefill_tokens} prefill + "
        f"{stats.decoded_tokens} decoded tokens in {stats.wall_s:.2f}s "
        f"({stats.tokens_per_s:.0f} tok/s)"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
