import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ruff: noqa: E402  — the two lines above MUST precede every other import:
# jax locks the device count at first initialization.
"""Multi-pod dry-run: prove every (arch × shape × mesh) cell lowers,
compiles, fits, and extract its roofline terms.

For each cell this driver:

1. builds the production mesh (16×16 single-pod / 2×16×16 multi-pod),
2. derives abstract params / optimizer state / cache via ``jax.eval_shape``
   (no allocation anywhere),
3. lowers + compiles the cell's step —
   ``train_step`` (train_4k), ``prefill`` (prefill_32k), ``serve_step``
   (decode_32k / long_500k) — under explicit in/out shardings,
4. prints ``compiled.memory_analysis()`` (proves the per-device footprint
   fits a 16 GiB v5e) and ``compiled.cost_analysis()`` (FLOPs/bytes for
   §Roofline), parses collective bytes from the optimized HLO,
5. writes one JSON artifact per cell under ``artifacts/dryrun/`` —
   EXPERIMENTS.md §Dry-run/§Roofline and benchmarks/roofline_table.py read
   these.

Usage:
  python -m repro.launch.dryrun --arch granite-3-8b --shape train_4k
  python -m repro.launch.dryrun --all [--mesh single|multi|both] [--force]
"""

import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config
from repro.core.metrics import (
    collective_ops_from_hlo,
    model_flops,
    roofline_terms,
)
from repro.launch.mesh import data_axes, make_production_mesh
from repro.launch.specs import SHAPES, applicability, input_specs
from repro.models import Model
from repro.optim import AdamW
from repro.optim.schedule import warmup_cosine
from repro.runtime.sharding import (
    ShardingRules,
    batch_pspec,
    cache_pspecs,
    make_activation_sharder,
    param_pspecs,
    zero_pspecs,
)
from repro.runtime.steps import make_train_step

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "artifacts", "dryrun")


def _moment_dtype(cfg) -> str:
    # >30B params: fp32 moments alone exceed the HBM share; use bf16 moments
    # (quantified in EXPERIMENTS.md §Dry-run).
    return "bfloat16" if cfg.param_counts()["total"] > 30e9 else "float32"


def _named(mesh, specs):
    from jax.sharding import NamedSharding, PartitionSpec

    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        specs,
        is_leaf=lambda x: isinstance(x, PartitionSpec),
    )


def inner_scan_correction(cfg, batch: int, seq: int, kind: str, chips: int) -> float:
    """Analytic per-device FLOPs of the *time-recurrence* scan bodies.

    ``cost_analysis`` counts a while-loop body once, not × trip count. The
    layer scan is fixed exactly by 1-/2-period extrapolation (run_cell); the
    remaining undercount is the O(T) recurrence inside Mamba/xLSTM blocks,
    whose per-step flops are closed-form (elementwise FMA chains). Decode
    cells run the recurrence once per call → no correction.
    """
    if kind == "decode":
        return 0.0
    per_token = 0.0
    for k in cfg.block_kinds():
        if k.startswith("mamba"):
            # a=exp(ΔA), b=Δ·B·x, h=a·h+b, y=C·h: ≈8 flops per (di, ds) cell
            per_token += 8.0 * cfg.d_inner * cfg.ssm_state
        elif k == "mlstm":
            du = 2 * cfg.d_model
            dh = du // cfg.xlstm_heads
            # C = f·C + i·kvᵀ (4), y = Cq (2), n updates (≈2)
            per_token += 8.0 * cfg.xlstm_heads * dh * dh
        elif k == "slstm":
            dh = cfg.d_model // cfg.xlstm_heads
            per_token += 8.0 * cfg.xlstm_heads * dh * dh + 20.0 * cfg.d_model
    total = per_token * batch * seq
    if kind == "train":
        total *= 4.0  # backward ≈ 2× fwd + remat re-forward ≈ 1×
    return total / chips


def build_cell(arch: str, shape: str, multi_pod: bool, *, zero: bool = False,
               zero3: bool = False,
               seq_shard: bool = True, accum: int = 1, remat: bool = True,
               attn_chunk: int = 0, score_dtype: str = "float32",
               replicate_below: int = 0, moe_group: int = 0,
               capacity_factor: float = 0.0, moe_gather: bool = False,
               dp_only: bool = False, moe_split: int = 0, xlstm_chunk: int = 0,
               cache_seq_shard: bool = False,
               depth_periods: int | None = None):
    """Returns (lower_fn, meta) for one cell; lower_fn() -> lowered.

    ``depth_periods`` truncates the stack to k periods — the analysis pair
    (k=1, 2) from which run_cell extrapolates exact full-depth costs.
    ``attn_chunk``/``score_dtype``/``replicate_below``/``zero``/``accum``
    are the §Perf optimization knobs.
    """
    import dataclasses

    cfg = get_config(arch)
    if attn_chunk or score_dtype != "float32":
        cfg = dataclasses.replace(
            cfg, attn_chunk=attn_chunk, score_dtype=score_dtype
        )
    if moe_group:
        cfg = dataclasses.replace(cfg, moe_group_size=moe_group)
    if capacity_factor:
        cfg = dataclasses.replace(cfg, capacity_factor=capacity_factor)
    if moe_split:
        cfg = dataclasses.replace(cfg, moe_split=moe_split)
    if xlstm_chunk:
        cfg = dataclasses.replace(cfg, xlstm_chunk=xlstm_chunk)
    if depth_periods is not None:
        cfg = dataclasses.replace(
            cfg, n_layers=depth_periods * len(cfg.block_period())
        )
    analysis = depth_periods is not None  # unrolled cost-analysis variant
    if analysis:
        cfg = dataclasses.replace(cfg, unroll_inner=True)
    if dp_only:
        # Small-model binding: both mesh axes act as data parallelism; all
        # weights replicate (§Perf xlstm iteration — a 16-way TP of a 350M
        # model burns ICI for nothing).
        replicate_below = 1 << 62
        seq_shard = False
    mesh = make_production_mesh(multi_pod=multi_pod)
    axes = data_axes(multi_pod) + (("model",) if dp_only else ())
    rules = ShardingRules(
        mesh=mesh,
        data_axes=axes,
        seq_shard=seq_shard and SHAPES[shape].kind == "train",
        replicate_below=replicate_below,
        moe_gather_tokens=moe_gather,
        cache_seq_shard=cache_seq_shard,
    )
    model = Model(
        cfg,
        shard_activation=make_activation_sharder(rules),
        remat=remat,
        scan_unroll=analysis,
    )
    batch_sds = input_specs(cfg, shape)
    params_sds = jax.eval_shape(model.init, jax.random.key(0))
    p_specs = param_pspecs(params_sds, rules)
    if zero3 and SHAPES[shape].kind == "train":
        # ZeRO-3 / FSDP: parameters (and hence grads and the accum buffer)
        # shard over the data axes too; XLA all-gathers one period's weights
        # per scan step (§Perf jamba iteration — 398B params at 16-way TP
        # are 49.8 GiB/device; 2-D sharding is the only way to fit).
        p_specs = zero_pspecs(p_specs, params_sds, rules)
    spec = SHAPES[shape]

    if spec.kind == "train":
        opt = AdamW(moment_dtype=_moment_dtype(cfg))
        opt_sds = jax.eval_shape(opt.init, params_sds)
        # zero3 already data-extended p_specs; extending twice would bind the
        # data axes to two dims of one leaf (DuplicateSpecError).
        m_specs = (
            zero_pspecs(p_specs, params_sds, rules) if (zero and not zero3) else p_specs
        )
        from jax.sharding import PartitionSpec as P

        o_specs = type(opt_sds)(step=P(), m=m_specs, v=m_specs)
        import functools

        sched = functools.partial(
            warmup_cosine, peak_lr=3e-4, warmup_steps=100, total_steps=10000
        )
        step_fn = make_train_step(model, opt, sched, accum=accum)
        b_specs = batch_pspec(batch_sds, rules)
        in_sh = (_named(mesh, p_specs), _named(mesh, o_specs), _named(mesh, b_specs))
        out_sh = (_named(mesh, p_specs), _named(mesh, o_specs), None)

        def lower():
            with mesh:
                return jax.jit(
                    step_fn, in_shardings=in_sh, out_shardings=out_sh,
                    donate_argnums=(0, 1),
                ).lower(params_sds, opt_sds, batch_sds)

        tokens = spec.batch * spec.seq
    elif spec.kind == "prefill":
        b_specs = batch_pspec(batch_sds, rules)

        if cfg.encoder_only:
            def prefill_fn(params, batch):
                return model.forward(params, batch)
        else:
            def prefill_fn(params, batch):
                return model.prefill(params, batch, spec.seq)

        in_sh = (_named(mesh, p_specs), _named(mesh, b_specs))

        def lower():
            with mesh:
                return jax.jit(prefill_fn, in_shardings=in_sh).lower(
                    params_sds, batch_sds
                )

        tokens = spec.batch * spec.seq
    else:  # decode
        cache_sds = jax.eval_shape(
            lambda: model.init_cache(spec.batch, spec.seq)
        )
        c_specs = cache_pspecs(cache_sds, rules)

        def serve_step(params, cache, tokens, pos):
            return model.decode_step(params, cache, tokens, pos)

        in_sh = (_named(mesh, p_specs), _named(mesh, c_specs), None, None)
        out_sh = (None, _named(mesh, c_specs))

        def lower():
            with mesh:
                return jax.jit(
                    serve_step, in_shardings=in_sh, out_shardings=out_sh,
                    donate_argnums=(1,),
                ).lower(
                    params_sds,
                    cache_sds,
                    jax.ShapeDtypeStruct((spec.batch,), jnp.int32),
                    jax.ShapeDtypeStruct((), jnp.int32),
                )

        tokens = spec.batch  # one token per sequence per step
    counts = cfg.param_counts()
    meta = {
        "arch": arch,
        "shape": shape,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": 512 if multi_pod else 256,
        "kind": spec.kind,
        "tokens_per_step": tokens,
        "params_total": counts["total"],
        "params_active": counts["active"],
        "n_periods": cfg.n_periods,
        "batch": spec.batch,
        "seq": spec.seq,
        "zero": zero,
        "zero3": zero3,
        "seq_shard": seq_shard,
        "accum": accum,
        "remat": remat,
        "attn_chunk": attn_chunk,
        "score_dtype": score_dtype,
        "replicate_below": replicate_below,
        "moe_group": moe_group or None,
        "capacity_factor": capacity_factor or None,
        "moe_gather": moe_gather,
        "dp_only": dp_only,
        "moe_split": moe_split or None,
        "xlstm_chunk": xlstm_chunk or None,
        "cache_seq_shard": cache_seq_shard,
    }
    return lower, meta


def run_cell(arch: str, shape: str, multi_pod: bool, out_dir: str, *,
             force: bool = False, variant: str = "baseline", **opts) -> dict:
    tag = f"{arch}__{shape}__{'multi' if multi_pod else 'single'}__{variant}"
    path = os.path.join(out_dir, tag + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    cfg = get_config(arch)
    ok, reason = applicability(cfg, shape)
    if not ok:
        rec = {"tag": tag, "skip": reason, "arch": arch, "shape": shape,
               "mesh": "2x16x16" if multi_pod else "16x16", "variant": variant}
        _write(path, rec)
        print(f"[dryrun] SKIP {tag}: {reason}", flush=True)
        return rec

    lower_fn, meta = build_cell(arch, shape, multi_pod, **opts)
    t0 = time.time()
    lowered = lower_fn()
    t1 = time.time()
    compiled = lowered.compile()  # full-depth proof: memory + shardability
    t2 = time.time()
    mem = compiled.memory_analysis()

    # Cost analysis pair: XLA counts while-loop bodies once, so full-depth
    # costs come from exact linear extrapolation over the period count
    # (cost(k periods) = base + k·delta with the layer scan unrolled), plus
    # the analytic inner-recurrence correction (inner_scan_correction).
    # The roofline table is single-pod (assignment); the multi-pod pass is
    # the shardability/memory proof and reuses the full program's analysis.
    pair: list[dict] = []
    pair_colls: list[dict] = []
    analysis_depths = (1, 2) if not multi_pod else ()
    for k in analysis_depths:
        lk, _ = build_cell(arch, shape, multi_pod, depth_periods=k, **opts)
        ck = lk().compile()
        pair.append(
            {kk: float(v) for kk, v in (ck.cost_analysis() or {}).items()
             if isinstance(v, (int, float))}
        )
        hist: dict[str, dict] = {}
        for op, b in collective_ops_from_hlo(ck.as_text()):
            h = hist.setdefault(op, {"count": 0, "bytes": 0.0})
            h["count"] += 1
            h["bytes"] += b
        pair_colls.append(hist)
    if not pair:  # multi-pod proof: unextrapolated full-program analysis
        pair = [
            {kk: float(v) for kk, v in (compiled.cost_analysis() or {}).items()
             if isinstance(v, (int, float))}
        ] * 2
        hist = {}
        for op, b in collective_ops_from_hlo(compiled.as_text()):
            h = hist.setdefault(op, {"count": 0, "bytes": 0.0})
            h["count"] += 1
            h["bytes"] += b
        pair_colls = [hist, hist]
    P = meta["n_periods"]

    def extrap(a: float, b: float) -> float:
        return max(0.0, a + (P - 1) * (b - a))

    keys = set(pair[0]) | set(pair[1])
    cost = {k: extrap(pair[0].get(k, 0.0), pair[1].get(k, 0.0)) for k in keys}
    scan_fix = inner_scan_correction(
        get_config(arch), meta["batch"], meta["seq"], meta["kind"], meta["chips"]
    )
    cost["flops"] = cost.get("flops", 0.0) + scan_fix
    coll_hist = {}
    for op in set(pair_colls[0]) | set(pair_colls[1]):
        c0 = pair_colls[0].get(op, {"count": 0, "bytes": 0.0})
        c1 = pair_colls[1].get(op, {"count": 0, "bytes": 0.0})
        coll_hist[op] = {
            "count": extrap(c0["count"], c1["count"]),
            "bytes": extrap(c0["bytes"], c1["bytes"]),
        }
    coll_bytes = float(sum(h["bytes"] for h in coll_hist.values()))
    rt = roofline_terms(cost, collective_bytes=coll_bytes)
    # MODEL_FLOPS convention: 6·N·D counts fwd+bwd (training). Inference
    # steps do forward only → 2·N·D.
    mf = model_flops(
        meta["params_total"], meta["tokens_per_step"],
        active_params=meta["params_active"],
    )
    if meta["kind"] != "train":
        mf /= 3.0
    hlo_flops_global = rt.flops * meta["chips"]
    rec = {
        "tag": tag,
        "variant": variant,
        **meta,
        "compile_ok": True,
        "analysis": "extrapolated" if not multi_pod else "full-program-proof",
        "lower_s": round(t1 - t0, 2),
        "compile_s": round(t2 - t1, 2),
        "memory": {
            k: float(getattr(mem, k))
            for k in (
                "argument_size_in_bytes",
                "output_size_in_bytes",
                "temp_size_in_bytes",
                "generated_code_size_in_bytes",
            )
            if hasattr(mem, k)
        },
        "cost": {k: float(v) for k, v in cost.items() if isinstance(v, (int, float))},
        "inner_scan_correction_flops": scan_fix,
        "collectives": coll_hist,
        "roofline": {
            "flops_per_device": rt.flops,
            "hbm_bytes_per_device": rt.hbm_bytes,
            "collective_bytes_per_device": rt.collective_bytes,
            "compute_s": rt.compute_s,
            "memory_s": rt.memory_s,
            "collective_s": rt.collective_s,
            "dominant": rt.dominant,
            "roofline_fraction": rt.roofline_fraction,
            "arithmetic_intensity": rt.arithmetic_intensity(),
        },
        "model_flops": mf,
        "useful_compute_ratio": (mf / hlo_flops_global) if hlo_flops_global else 0.0,
    }
    _write(path, rec)
    hbm_gib = sum(rec["memory"].values()) / 2**30 if rec["memory"] else float("nan")
    print(
        f"[dryrun] OK {tag}: compile {rec['compile_s']}s, "
        f"mem/device ≈ {hbm_gib:.2f} GiB "
        f"(args {rec['memory'].get('argument_size_in_bytes', 0) / 2**30:.2f} + "
        f"temp {rec['memory'].get('temp_size_in_bytes', 0) / 2**30:.2f}), "
        f"dominant={rec['roofline']['dominant']} "
        f"fraction={rec['roofline']['roofline_fraction']:.3f}",
        flush=True,
    )
    print(f"  memory_analysis: {rec['memory']}", flush=True)
    print(
        "  cost_analysis: flops=%.3e bytes=%.3e coll=%.3e"
        % (rt.flops, rt.hbm_bytes, rt.collective_bytes),
        flush=True,
    )
    return rec


def _write(path: str, rec: dict) -> None:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(rec, f, indent=1, sort_keys=True)
    os.replace(tmp, path)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCHS, default=None)
    ap.add_argument("--shape", choices=sorted(SHAPES), default=None)
    ap.add_argument("--mesh", choices=("single", "multi", "both"), default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--zero", action="store_true")
    ap.add_argument("--zero3", action="store_true")
    ap.add_argument("--no-seq-shard", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--attn-chunk", type=int, default=0)
    ap.add_argument("--score-dtype", default="float32")
    ap.add_argument("--replicate-below", type=int, default=0)
    ap.add_argument("--moe-group", type=int, default=0)
    ap.add_argument("--capacity-factor", type=float, default=0.0)
    ap.add_argument("--moe-gather", action="store_true")
    ap.add_argument("--dp-only", action="store_true")
    ap.add_argument("--moe-split", type=int, default=0)
    ap.add_argument("--xlstm-chunk", type=int, default=0)
    ap.add_argument("--cache-seq-shard", action="store_true")
    ap.add_argument("--out", default=os.path.normpath(ARTIFACT_DIR))
    args = ap.parse_args(argv)

    cells: list[tuple[str, str]] = []
    if args.all:
        cells = [(a, s) for a in ARCHS for s in SHAPES]
    else:
        if not (args.arch and args.shape):
            ap.error("--arch and --shape required unless --all")
        cells = [(args.arch, args.shape)]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    failures = []
    for arch, shape in cells:
        for multi in meshes:
            try:
                run_cell(
                    arch, shape, multi, args.out, force=args.force,
                    variant=args.variant, zero=args.zero, zero3=args.zero3,
                    seq_shard=not args.no_seq_shard, accum=args.accum,
                    remat=not args.no_remat, attn_chunk=args.attn_chunk,
                    score_dtype=args.score_dtype,
                    replicate_below=args.replicate_below,
                    moe_group=args.moe_group,
                    capacity_factor=args.capacity_factor,
                    moe_gather=args.moe_gather,
                    dp_only=args.dp_only,
                    moe_split=args.moe_split,
                    xlstm_chunk=args.xlstm_chunk,
                    cache_seq_shard=args.cache_seq_shard,
                )
            except Exception as e:  # noqa: BLE001 — report, keep proving cells
                failures.append((arch, shape, multi, repr(e)))
                print(f"[dryrun] FAIL {arch}/{shape}/multi={multi}: {e!r}", flush=True)
    if failures:
        print(f"[dryrun] {len(failures)} FAILURES", flush=True)
        return 1
    print("[dryrun] all requested cells passed", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
