# Launch layer: production mesh definition (mesh.py), abstract input specs
# (specs.py), the multi-pod dry-run prover + roofline extractor (dryrun.py),
# and the fault-tolerant train/serve drivers (train.py / serve.py).
#
# NOTE: dryrun.py must be executed as a MAIN MODULE (python -m
# repro.launch.dryrun) — it sets XLA_FLAGS before importing jax. Importing
# repro.launch does not touch jax device state.
