"""Production mesh: 16×16 single pod (256 chips), 2×16×16 multi-pod (512).

``make_production_mesh`` is a function, not a module constant — importing
this module never touches jax device state (the dry-run must set
``xla_force_host_platform_device_count`` before the first device query).
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "POD_SHAPE", "MULTI_POD_SHAPE"]

POD_SHAPE = (16, 16)
MULTI_POD_SHAPE = (2, 16, 16)


def make_production_mesh(*, multi_pod: bool = False):
    shape = MULTI_POD_SHAPE if multi_pod else POD_SHAPE
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def data_axes(multi_pod: bool) -> tuple[str, ...]:
    return ("pod", "data") if multi_pod else ("data",)
