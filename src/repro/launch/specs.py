"""Abstract input specs for every (architecture × input shape) cell.

``input_specs`` returns ``jax.ShapeDtypeStruct`` stand-ins — weak-type
correct, shardable, zero allocation — for each of the four assigned shapes:

- ``train_4k``:    seq 4096 × global batch 256  → lowers ``train_step``
- ``prefill_32k``: seq 32768 × global batch 32  → lowers ``prefill``
- ``decode_32k``:  KV len 32768 × batch 128     → lowers ``serve_step``
- ``long_500k``:   KV len 524288 × batch 1      → lowers ``serve_step``

Applicability skips (DESIGN.md §4 / §Arch-applicability): encoder-only
archs have no decode; pure full-attention archs skip ``long_500k`` (needs
sub-quadratic attention); SWA/SSM/hybrid archs run it.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig

__all__ = ["SHAPES", "ShapeSpec", "applicability", "input_specs"]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq: int
    batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def _sub_quadratic(cfg: ArchConfig) -> bool:
    """Can this arch decode a 524k context without O(S) full-attention reads
    growing quadratically in total? SSM/hybrid state is O(1); SWA is
    O(window)."""
    kinds = cfg.block_kinds()
    has_full_attn = any(k.startswith("attn") for k in kinds) and cfg.window is None
    return not has_full_attn or cfg.family in ("ssm",)


def applicability(cfg: ArchConfig, shape: str) -> tuple[bool, str]:
    spec = SHAPES[shape]
    if spec.kind == "decode" and cfg.encoder_only:
        return False, "encoder-only: no autoregressive decode step"
    if shape == "long_500k":
        if cfg.family == "hybrid":
            # Jamba: full attention layers, but 1:7 diluted with O(1) Mamba;
            # runs per the assignment (SSM/hybrid listed as eligible).
            return True, ""
        if not _sub_quadratic(cfg):
            return False, "pure full-attention arch: 524k decode needs sub-quadratic attention"
    return True, ""


def token_dtype():
    return jnp.int32


def input_specs(cfg: ArchConfig, shape: str) -> dict:
    """ShapeDtypeStructs for the *batch* of this cell (params/cache specs are
    derived separately from model.init/init_cache via eval_shape)."""
    spec = SHAPES[shape]
    B, T = spec.batch, spec.seq
    dt = jnp.dtype(cfg.dtype)
    sds = jax.ShapeDtypeStruct
    if spec.kind in ("train", "prefill"):
        if cfg.input_mode == "embeds":
            batch = {"embeds": sds((B, T, cfg.d_model), dt)}
            if cfg.rope == "mrope":
                batch["positions"] = sds((B, T, 3), jnp.int32)
        else:
            batch = {"tokens": sds((B, T), jnp.int32)}
        if spec.kind == "train":
            batch["labels"] = sds((B, T), jnp.int32)
        return batch
    # decode: one new token against a cache of length seq
    return {
        "tokens": sds((B,), jnp.int32),
        "pos": sds((), jnp.int32),
    }
