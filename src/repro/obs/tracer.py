"""The tracing core: spans, retrospective events, counters, Chrome export.

Model
-----

A :class:`Tracer` collects :class:`SpanEvent` rows — named intervals on a
monotonic clock (``time.perf_counter``), zeroed at tracer construction —
two ways:

- ``with tracer.span("compile", bench="gemm_f32_nn"):`` times a live code
  region on whatever thread runs it (the thread ident is recorded, so
  spans from N serving threads land on N Chrome tracks);
- ``tracer.event("request", t_start=c.t_submit, t_end=c.t_done, ...)``
  records an interval *after the fact* from perf_counter timestamps
  something else already measured — how serve completions and batcher
  executions become trace rows without instrumenting their hot loops.

Every event carries a ``track`` (a process-level grouping in the Chrome
model: ``engine``, ``serve``, ``batcher``) and an optional explicit
``tid`` (``"lane 0"``, ``"queue p0/cols=64"``) overriding the thread
ident — which is what renders serve lanes and batcher queues as separate
named tracks. A :class:`Counters` registry rides along for scalar totals
(cache hits, tune trials, batcher flushes, lane submit-block time).

Zero-cost when disabled
-----------------------

:data:`NULL_TRACER` (a :class:`NullTracer`) is falsy, has
``enabled=False``, hands out one shared no-op context manager, and its
counters swallow increments. Call sites on hot paths guard with
``if tracer.enabled:`` so the disabled cost is one attribute read; the
timing hot loop (``harness.time_fn``) is never instrumented at all, so
disabled tracing is *structurally* identical to an uninstrumented build
where it matters (asserted in ``tests/test_obs.py``).

The ambient tracer (:func:`current_tracer` / :func:`use_tracer`) lets the
serve layer reach the engine's tracer without threading a parameter
through every client/lane signature; the default is :data:`NULL_TRACER`.

Everything here is stdlib-only and imports nothing from ``repro``.
"""

from __future__ import annotations

import contextlib
import dataclasses
import json
import os
import threading
import time
from typing import Any, Iterator

__all__ = [
    "SpanEvent",
    "Counters",
    "Tracer",
    "NullTracer",
    "NULL_TRACER",
    "current_tracer",
    "set_tracer",
    "use_tracer",
]


@dataclasses.dataclass(frozen=True)
class SpanEvent:
    """One named interval: microseconds relative to the tracer's origin,
    grouped by ``track`` (Chrome process) and ``tid`` (Chrome thread —
    a real thread ident, or an explicit label like ``"lane 0"``)."""

    name: str
    t_start_us: float
    dur_us: float
    track: str
    tid: int | str
    args: dict


class Counters:
    """Thread-safe named totals. Values are numbers (ints for counts,
    floats for accumulated microseconds); ``snapshot()`` returns a plain
    sorted dict that JSON-serializes into :class:`RunMetadata`."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._values: dict[str, float] = {}

    def inc(self, name: str, value: float = 1) -> None:
        with self._lock:
            self._values[name] = self._values.get(name, 0) + value

    def set(self, name: str, value: float) -> None:
        """Overwrite a total (for folding in externally-accumulated
        counters like the disk cache's, which are cumulative across runs
        — incrementing them again would double-count)."""
        with self._lock:
            self._values[name] = value

    def get(self, name: str, default: float = 0) -> float:
        with self._lock:
            return self._values.get(name, default)

    def snapshot(self) -> dict[str, float]:
        with self._lock:
            return dict(sorted(self._values.items()))


class _NullCounters(Counters):
    """Counters that swallow increments (the disabled path)."""

    def inc(self, name: str, value: float = 1) -> None:
        return None

    def set(self, name: str, value: float) -> None:
        return None


class Tracer:
    """Collects spans/events/counters; exports Chrome trace-event JSON."""

    enabled = True

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._events: list[SpanEvent] = []
        self.counters = Counters()
        self._t0 = time.perf_counter()
        self._main_ident = threading.get_ident()

    def __bool__(self) -> bool:
        return True

    # -- recording ---------------------------------------------------------

    @contextlib.contextmanager
    def span(
        self,
        name: str,
        *,
        track: str = "engine",
        tid: int | str | None = None,
        **attrs: Any,
    ) -> Iterator[None]:
        """Time a live code region; the event is recorded on exit (also on
        exception — a failing stage still shows its time in the trace)."""
        t0 = time.perf_counter()
        try:
            yield
        finally:
            t1 = time.perf_counter()
            self._append(
                SpanEvent(
                    name=name,
                    t_start_us=(t0 - self._t0) * 1e6,
                    dur_us=(t1 - t0) * 1e6,
                    track=track,
                    tid=tid if tid is not None else threading.get_ident(),
                    args=attrs,
                )
            )

    def event(
        self,
        name: str,
        *,
        t_start: float,
        t_end: float,
        track: str = "engine",
        tid: int | str | None = None,
        **attrs: Any,
    ) -> None:
        """Record an interval retrospectively from ``perf_counter``
        timestamps measured elsewhere (serve completions, batch
        executions). ``dur_us`` is exactly ``(t_end - t_start) * 1e6`` —
        callers that also sum the same deltas (the tune stage) get
        sum-of-spans equality by construction."""
        self._append(
            SpanEvent(
                name=name,
                t_start_us=max(0.0, (t_start - self._t0) * 1e6),
                dur_us=(t_end - t_start) * 1e6,
                track=track,
                tid=tid if tid is not None else threading.get_ident(),
                args=attrs,
            )
        )

    def _append(self, event: SpanEvent) -> None:
        with self._lock:
            self._events.append(event)

    def events(self) -> list[SpanEvent]:
        with self._lock:
            return list(self._events)

    # -- export ------------------------------------------------------------

    def chrome_events(self) -> list[dict]:
        """Chrome trace-event list: one ``"X"`` (complete) event per span,
        plus ``"M"`` metadata naming each track (process) and tid (thread).

        Tracks map to pids in order of first appearance; within a track,
        tids map to small sequential numbers — explicit string tids (lane
        and queue labels) keep their label as the thread name, real thread
        idents become ``main`` / ``thread-K``. Events are sorted by
        (pid, tid, start) so the export is stable for a given event set.
        """
        events = self.events()
        pids: dict[str, int] = {}
        tids: dict[tuple[str, int | str], int] = {}
        meta: list[dict] = []
        rows: list[tuple[tuple, dict]] = []
        for ev in events:
            pid = pids.get(ev.track)
            if pid is None:
                pid = pids[ev.track] = len(pids) + 1
                meta.append(
                    {
                        "ph": "M",
                        "pid": pid,
                        "name": "process_name",
                        "args": {"name": ev.track},
                    }
                )
            key = (ev.track, ev.tid)
            tid = tids.get(key)
            if tid is None:
                tid = tids[key] = (
                    sum(1 for t, _ in tids if t == ev.track) + 1
                )
                if isinstance(ev.tid, str):
                    tname = ev.tid
                elif ev.tid == self._main_ident:
                    tname = "main"
                else:
                    tname = f"thread-{tid}"
                meta.append(
                    {
                        "ph": "M",
                        "pid": pid,
                        "tid": tid,
                        "name": "thread_name",
                        "args": {"name": tname},
                    }
                )
            rows.append(
                (
                    (pid, tid, ev.t_start_us),
                    {
                        "ph": "X",
                        "name": ev.name,
                        "cat": ev.track,
                        "pid": pid,
                        "tid": tid,
                        "ts": round(ev.t_start_us, 3),
                        "dur": round(max(ev.dur_us, 0.0), 3),
                        "args": ev.args,
                    },
                )
            )
        rows.sort(key=lambda r: r[0])
        return meta + [row for _, row in rows]

    def export_chrome(self, path: str) -> int:
        """Write ``{"traceEvents": [...]}`` (the Chrome/Perfetto envelope)
        atomically; returns the number of span events exported."""
        events = self.chrome_events()
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(
                {"traceEvents": events, "displayTimeUnit": "ms"},
                f,
                sort_keys=True,
            )
        os.replace(tmp, path)
        return sum(1 for e in events if e.get("ph") == "X")


class NullTracer:
    """The disabled tracer: falsy, no-op spans, counter increments
    swallowed. One shared context manager instance, so the disabled
    ``span()`` cost is a method call returning an existing object."""

    enabled = False
    counters = _NullCounters()
    _span = contextlib.nullcontext()

    def __bool__(self) -> bool:
        return False

    def span(self, name: str, **attrs: Any) -> contextlib.nullcontext:
        return self._span

    def event(self, name: str, **attrs: Any) -> None:
        return None

    def events(self) -> list[SpanEvent]:
        return []


NULL_TRACER = NullTracer()

# The ambient tracer serve modules consult (engine.run installs its own
# for the duration of a run via use_tracer). Module-global, not
# thread-local: lane worker threads are spawned *inside* a run and must
# see the run's tracer.
_CURRENT: Tracer | NullTracer = NULL_TRACER


def current_tracer() -> Tracer | NullTracer:
    return _CURRENT


def set_tracer(tracer: Tracer | NullTracer | None) -> None:
    global _CURRENT
    _CURRENT = tracer if tracer is not None else NULL_TRACER


@contextlib.contextmanager
def use_tracer(tracer: Tracer | NullTracer | None) -> Iterator[None]:
    """Install ``tracer`` as the ambient tracer for a scope (restores the
    previous one on exit, so nested engine runs compose)."""
    global _CURRENT
    prev = _CURRENT
    set_tracer(tracer)
    try:
        yield
    finally:
        _CURRENT = prev
