"""Structured tracing & metrics for the engine and serving stack.

Dependency-free (stdlib only, no ``repro`` imports) so every layer —
engine stages, the disk cache, serve lanes, the batcher — can reach the
ambient tracer without import cycles. See ``obs/tracer.py`` for the
model: spans + retrospective events + a counters registry, exported as
Chrome trace-event JSON (Perfetto / chrome://tracing) and as the
``stage_timings_us`` / ``counters`` blocks stamped into records and run
metadata (schema v8).
"""

from repro.obs.tracer import (
    NULL_TRACER,
    Counters,
    NullTracer,
    SpanEvent,
    Tracer,
    current_tracer,
    set_tracer,
    use_tracer,
)

__all__ = [
    "NULL_TRACER",
    "Counters",
    "NullTracer",
    "SpanEvent",
    "Tracer",
    "current_tracer",
    "set_tracer",
    "use_tracer",
]
