# Optimizer substrate: AdamW with configurable moment dtype (bf16 moments
# for the 398B-class models), warmup-cosine schedules, global-norm clipping,
# ZeRO-1 optimizer-state partitioning rules, and int8 error-feedback
# gradient compression for the cross-pod link tier.

from repro.optim.adamw import AdamW, AdamWState  # noqa: F401
from repro.optim.schedule import warmup_cosine  # noqa: F401
from repro.optim.clip import clip_by_global_norm, global_norm  # noqa: F401
from repro.optim.compression import ErrorFeedbackInt8  # noqa: F401
