"""AdamW with configurable moment dtype and donated in-place update.

For the 100B+ configs, fp32 (m, v) alone exceeds a v5e's HBM share
(EXPERIMENTS.md §Dry-run memory table); ``moment_dtype="bfloat16"`` halves
optimizer state — a distributed-optimization trade the dry-run memory
analysis quantifies. Bias correction runs in fp32 regardless; the update is
computed in fp32 and cast back into the parameter dtype.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["AdamW", "AdamWState"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class AdamWState:
    step: jax.Array  # scalar int32
    m: Any  # pytree like params
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    moment_dtype: str = "float32"

    def init(self, params) -> AdamWState:
        dt = jnp.dtype(self.moment_dtype)
        zeros = lambda p: jnp.zeros(p.shape, dt)
        return AdamWState(
            step=jnp.zeros((), jnp.int32),
            m=jax.tree.map(zeros, params),
            v=jax.tree.map(zeros, params),
        )

    def update(self, grads, state: AdamWState, params, lr) -> tuple[Any, AdamWState]:
        step = state.step + 1
        b1, b2 = self.b1, self.b2
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)
        dt = jnp.dtype(self.moment_dtype)

        def upd(p, g, m, v):
            gf = g.astype(jnp.float32)
            mf = b1 * m.astype(jnp.float32) + (1 - b1) * gf
            vf = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
            mhat = mf / c1
            vhat = vf / c2
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            if p.ndim >= 2:  # decay matrices only (norms/bias exempt)
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
            return new_p, mf.astype(dt), vf.astype(dt)

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state.m)
        flat_v = treedef.flatten_up_to(state.v)
        out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
        new_params = treedef.unflatten([o[0] for o in out])
        new_m = treedef.unflatten([o[1] for o in out])
        new_v = treedef.unflatten([o[2] for o in out])
        return new_params, AdamWState(step=step, m=new_m, v=new_v)
