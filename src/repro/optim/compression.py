"""Int8 error-feedback gradient compression for the slow (cross-pod) link.

XLA gives no control over the wire format of ``psum``, so the compressed
reduction is expressed structurally (DESIGN.md §5): quantize each shard to
int8 against a pod-global scale (one scalar ``psum(max)``), ``all_gather``
the **int8** payload over the pod axis (4× fewer bytes on the slowest link
tier than an fp32 all-reduce leg), and reduce locally in int32. Quantization
residue is carried in an error-feedback accumulator so the compression bias
vanishes over steps (Seide et al.; 1-bit Adam lineage).

Used by the train step only across the ``pod`` axis — intra-pod reductions
stay fp32 (ICI is fast; the compression trade only pays on DCN/cross-pod).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["ErrorFeedbackInt8"]


@dataclasses.dataclass(frozen=True)
class ErrorFeedbackInt8:
    axis: str = "pod"

    def init(self, params) -> Any:
        return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def reduce_mean(self, grads, error):
        """Inside shard_map/pjit with ``self.axis`` in scope: returns
        (approx mean-reduced grads, new error state)."""
        n = jax.lax.psum(1, self.axis)

        def one(g, e):
            gf = g.astype(jnp.float32) + e
            scale = jax.lax.psum(jnp.max(jnp.abs(gf)), self.axis) / n
            scale = jnp.maximum(scale, 1e-12) / 127.0
            q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
            new_e = gf - q.astype(jnp.float32) * scale
            gathered = jax.lax.all_gather(q, self.axis)  # int8 on the wire
            mean = gathered.astype(jnp.int32).sum(axis=0).astype(jnp.float32)
            mean = mean * scale / n
            return mean.astype(g.dtype), new_e

        flat_g, treedef = jax.tree.flatten(grads)
        flat_e = treedef.flatten_up_to(error)
        out = [one(g, e) for g, e in zip(flat_g, flat_e)]
        return (
            treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]),
        )
