"""Learning-rate schedules."""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["warmup_cosine"]


def warmup_cosine(
    step,
    *,
    peak_lr: float,
    warmup_steps: int,
    total_steps: int,
    final_fraction: float = 0.1,
):
    s = jnp.asarray(step, jnp.float32)
    warm = peak_lr * s / jnp.maximum(warmup_steps, 1)
    progress = jnp.clip(
        (s - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0
    )
    cos = peak_lr * (
        final_fraction + (1 - final_fraction) * 0.5 * (1 + jnp.cos(jnp.pi * progress))
    )
    return jnp.where(s < warmup_steps, warm, cos)
