"""Multi-process scale-out: distributed load generation over a local
socket protocol.

One Python process tops out at a host dispatch ceiling long before the
device does — the serving client can only issue so many requests per
second from one interpreter. This package breaks that ceiling the way a
multi-host deployment does: N client *processes*, each replaying a seeded
per-process sub-schedule (``SeedSequence.spawn`` off the plan seed, so
the merged arrival stream is still Poisson at the target QPS and
byte-identical per seed), each compiling through the shared
``HloDiskCache`` (a warm distributed run performs zero XLA compiles in
every process), streaming per-request completion stamps back to the
launcher for merged percentile / goodput accounting.

- :mod:`repro.dist.proto` — the wire format: length-prefixed JSON
  messages (Hello / Assign / Ready / Start / Stamp / Done / Error) over a
  local TCP socket.
- :mod:`repro.dist.client_proc` — the ``python -m repro.dist.client_proc``
  entrypoint one client process runs: connect, receive its assignment,
  build + compile the workload, replay its sub-schedule, stream stamps.
- :mod:`repro.dist.launcher` — spawns and supervises the clients from the
  engine process, synchronizes the start epoch, merges the completion
  streams into one :class:`~repro.serve.latency.LatencyStats` with
  per-process QPS.

Selected via ``ServeSpec.client_procs`` (CLI ``--client-procs N``); the
engine's serve stage routes to :func:`repro.dist.launcher.run_distributed`
when it is nonzero.
"""
