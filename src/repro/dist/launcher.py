"""Spawn, synchronize, and supervise N load-generation client processes;
merge their completion streams into one set of serving statistics.

The launcher runs inside the engine process (the serve stage routes here
when ``ServeSpec.client_procs > 0``). It listens on a loopback TCP port,
spawns ``python -m repro.dist.client_proc`` once per process (inheriting
the environment, ``XLA_FLAGS`` included, so a forced-host-device CI
topology applies to every client), assigns each its workload + seed +
process index, waits for every client to finish compiling (``Ready``),
broadcasts one shared wall-clock start epoch, then collects the
epoch-relative completion stamps each client streams back.

Merged accounting: stamps from process p, local lane l are relabeled to
global lane ``p * lanes + l``, so the merged stream's percentiles are
computed exactly as a single client's would be (``stats_from_completions``
over the concatenation — the identity ``tests/test_dist.py`` pins), while
``proc_qps`` groups the same stamps by process to show whether every
client pulled its weight. Per-client ``HloDiskCache`` counters arrive in
each ``Done`` and are summed into ``client_cache_counters`` — the number
the ``--dist`` smoke leg asserts is zero-compile on a warm run — and
printed per process on stderr next to the engine's own ``# hlocache:``
line.
"""

from __future__ import annotations

import dataclasses
import os
import socket
import subprocess
import sys
import tempfile
import threading
import time

from repro.dist.proto import (
    Assign,
    ConnectionClosed,
    Done,
    Error,
    Hello,
    Ready,
    Stamp,
    Start,
    recv_msg,
    send_msg,
)
from repro.serve.lanes import Completion
from repro.serve.latency import (
    LatencyStats,
    lane_qps_from_completions,
    stats_from_completions,
)

__all__ = ["DistLatencyStats", "run_distributed"]

# How long one client may spend building + compiling before the run is
# declared wedged. Generous: a cold multi-device compile on a loaded CI
# host is tens of seconds, not hundreds.
_READY_TIMEOUT_S = 600.0
# Seconds between the Start broadcast and the shared epoch: long enough
# for every client to receive the frame and wake its sleep loop.
_START_LEAD_S = 0.3


@dataclasses.dataclass(frozen=True)
class DistLatencyStats(LatencyStats):
    """Merged serving statistics of a distributed run: a plain
    :class:`LatencyStats` over the concatenated completion stream, plus
    the per-process accounting the distributed columns report."""

    client_procs: int = 0
    proc_qps: tuple[float, ...] | None = None  # achieved QPS per process
    # Summed HloDiskCache counters across the client processes (None when
    # the run had no cache dir): misses == xla_compiles == 0 here is the
    # "warm distributed run compiled nothing anywhere" assertion.
    client_cache_counters: dict | None = None

    def derived(self) -> str:
        parts = [super().derived(), f"client_procs={self.client_procs}"]
        if self.proc_qps is not None:
            qps = ",".join(f"{q:.1f}" for q in self.proc_qps)
            parts.append(f"proc_qps={qps}")
        return ";".join(parts)


class _StreamCollector:
    """Lock-guarded accumulator the per-client reader threads feed.

    One reader thread per client socket appends stamp rows and records
    the terminal Done/Error; the launcher thread reads everything back
    after joining the readers. All shared-container mutation happens
    under ``self._lock`` (the ``concurrency-locks`` contract).
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._rows: dict[int, list] = {}
        self._done: dict[int, Done] = {}
        self._errors: list[str] = []

    def add_rows(self, proc_id: int, rows: list) -> None:
        with self._lock:
            self._rows.setdefault(proc_id, []).extend(rows)

    def mark_done(self, done: Done) -> None:
        with self._lock:
            self._done[done.proc_id] = done

    def add_error(self, message: str) -> None:
        with self._lock:
            self._errors.append(message)

    def snapshot(self) -> tuple[dict[int, list], dict[int, Done], list[str]]:
        with self._lock:
            return (
                {p: list(rows) for p, rows in self._rows.items()},
                dict(self._done),
                list(self._errors),
            )


def _read_client(sock: socket.socket, proc_id: int, out: _StreamCollector) -> None:
    """Reader-thread body: drain one client until Done/Error/EOF."""
    try:
        while True:
            msg = recv_msg(sock)
            if isinstance(msg, Stamp):
                out.add_rows(msg.proc_id, msg.completions)
            elif isinstance(msg, Done):
                out.mark_done(msg)
                return
            elif isinstance(msg, Error):
                out.add_error(f"proc {msg.proc_id}: {msg.message}")
                return
            else:
                out.add_error(
                    f"proc {proc_id}: unexpected {type(msg).__name__} frame"
                )
                return
    except (ConnectionClosed, OSError, ValueError) as e:
        out.add_error(f"proc {proc_id}: stream died: {e}")


def _src_pythonpath() -> str:
    """PYTHONPATH entry that makes ``import repro`` work in a child."""
    import repro

    pkg_dir = os.path.dirname(os.path.abspath(repro.__file__))
    src_dir = os.path.dirname(pkg_dir)
    existing = os.environ.get("PYTHONPATH")
    return src_dir if not existing else f"{src_dir}{os.pathsep}{existing}"


def _stderr_tail(path: str, limit: int = 2000) -> str:
    try:
        with open(path, encoding="utf-8", errors="replace") as f:
            text = f.read()
        return text[-limit:]
    except OSError:
        return "<stderr unavailable>"


def _sum_counters(dones: dict[int, Done]) -> dict | None:
    total: dict[str, int] = {}
    seen = False
    for done in dones.values():
        if done.cache_counters is None:
            continue
        seen = True
        for k, v in done.cache_counters.items():
            total[k] = total.get(k, 0) + int(v)
    return total if seen else None


def run_distributed(
    *,
    benchmark: str,
    preset: int,
    overrides: dict,
    serve,
    seed: int,
    devices: int,
    placement_mode: str,
    impl: str = "xla",
    cache_dir: str | None = None,
) -> DistLatencyStats:
    """One distributed open-loop serving run of ``benchmark``.

    Blocks until every client process finishes (or fails); raises
    ``RuntimeError`` naming the first failure — the engine's per-benchmark
    fault isolation turns that into an error record like any other stage
    failure.
    """
    n = int(serve.client_procs)
    if n < 1:
        raise ValueError(f"run_distributed needs client_procs >= 1, got {n}")
    serve_fields = {
        f.name: getattr(serve, f.name) for f in dataclasses.fields(type(serve))
    }
    serve_fields["client_procs"] = 0
    # Merged warmup prefix: every process fills its own pipeline, so the
    # single-process fill count scales by the process count.
    warmup = max(serve.concurrency, serve.lanes, 2) * n

    listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    procs: list[subprocess.Popen] = []
    conns: dict[int, socket.socket] = {}
    stderr_paths: list[str] = []
    collector = _StreamCollector()
    try:
        listener.bind(("127.0.0.1", 0))
        listener.listen(n)
        listener.settimeout(_READY_TIMEOUT_S)
        port = listener.getsockname()[1]

        env = dict(os.environ)
        env["PYTHONPATH"] = _src_pythonpath()
        for proc_id in range(n):
            errfile = tempfile.NamedTemporaryFile(
                mode="w", suffix=f".dist{proc_id}.err", delete=False
            )
            stderr_paths.append(errfile.name)
            procs.append(
                subprocess.Popen(
                    [
                        sys.executable,
                        "-m",
                        "repro.dist.client_proc",
                        "--port",
                        str(port),
                        "--proc-id",
                        str(proc_id),
                    ],
                    env=env,
                    stdout=errfile,
                    stderr=errfile,
                )
            )
            errfile.close()

        for _ in range(n):
            conn, _addr = listener.accept()
            conn.settimeout(_READY_TIMEOUT_S)
            hello = recv_msg(conn)
            if not isinstance(hello, Hello):
                raise RuntimeError(
                    f"expected Hello, got {type(hello).__name__}"
                )
            if hello.proc_id in conns:
                raise RuntimeError(f"duplicate proc_id {hello.proc_id}")
            conns[hello.proc_id] = conn
        for proc_id, conn in conns.items():
            send_msg(
                conn,
                Assign(
                    benchmark=benchmark,
                    preset=preset,
                    overrides=dict(overrides),
                    serve=serve_fields,
                    seed=seed,
                    proc_id=proc_id,
                    n_procs=n,
                    warmup=warmup,
                    devices=devices,
                    placement=placement_mode,
                    impl=impl,
                    cache_dir=cache_dir,
                ),
            )

        # Barrier: every client has compiled before any load starts. A
        # client that dies compiling sends Error (or just closes); either
        # way the recv raises or returns the wrong type and we abort with
        # its stderr tail.
        for proc_id, conn in conns.items():
            msg = recv_msg(conn)
            if isinstance(msg, Error):
                raise RuntimeError(
                    f"client {proc_id} failed before Ready: {msg.message}\n"
                    f"--- client stderr ---\n{_stderr_tail(stderr_paths[proc_id])}"
                )
            if not isinstance(msg, Ready):
                raise RuntimeError(
                    f"client {proc_id}: expected Ready, got {type(msg).__name__}"
                )

        epoch = time.time() + _START_LEAD_S
        for conn in conns.values():
            send_msg(conn, Start(epoch=epoch))

        readers = [
            threading.Thread(
                target=_read_client,
                args=(conn, proc_id, collector),
                name=f"dist-reader-{proc_id}",
                daemon=True,
            )
            for proc_id, conn in conns.items()
        ]
        for t in readers:
            t.start()
        deadline = serve.duration_s + _READY_TIMEOUT_S
        for t in readers:
            t.join(timeout=deadline)
            if t.is_alive():
                raise RuntimeError(
                    f"distributed run wedged: {t.name} still reading after "
                    f"{deadline:.0f}s"
                )
        for proc_id, p in enumerate(procs):
            try:
                code = p.wait(timeout=30)
            except subprocess.TimeoutExpired:
                p.kill()
                code = p.wait()
            if code != 0:
                collector.add_error(
                    f"proc {proc_id}: exit code {code}\n"
                    f"--- client stderr ---\n{_stderr_tail(stderr_paths[proc_id])}"
                )
    finally:
        for conn in conns.values():
            try:
                conn.close()
            except OSError:
                pass
        listener.close()
        for p in procs:
            if p.poll() is None:
                p.kill()
        for path in stderr_paths:
            try:
                os.remove(path)
            except OSError:
                pass

    rows_by_proc, dones, errors = collector.snapshot()
    if errors:
        raise RuntimeError("; ".join(errors))
    missing = sorted(set(range(n)) - set(dones))
    if missing:
        raise RuntimeError(f"clients never reported Done: {missing}")

    # Relabel (proc, local lane) -> global lane so the merged stream is
    # statistically identical to one client running n*lanes lanes.
    merged = [
        Completion(
            index=int(index),
            lane=proc_id * serve.lanes + int(lane),
            t_submit=float(t_submit),
            t_done=float(t_done),
            warmup=bool(warm),
        )
        for proc_id, rows in sorted(rows_by_proc.items())
        for index, lane, t_submit, t_done, warm in rows
    ]
    merged.sort(key=lambda c: c.t_done)
    base = stats_from_completions(
        merged,
        offered_qps=serve.qps,
        slo_us=serve.slo_us,
        truncated=any(d.truncated for d in dones.values()),
        n_lanes=n * serve.lanes,
    )
    by_proc = [
        dataclasses.replace(c, lane=c.lane // serve.lanes) for c in merged
    ]
    proc_qps = lane_qps_from_completions(by_proc, n_lanes=n)
    client_counters = _sum_counters(dones)
    if client_counters is not None:
        # Like the engine's "# hlocache:" line: always say what the
        # clients' caches did, so "the warm distributed run compiled
        # nothing anywhere" is assertable from stderr alone.
        line = " ".join(f"{k}={v}" for k, v in sorted(client_counters.items()))
        print(f"# dist-cache[{n} procs]: {line}", file=sys.stderr)
    return DistLatencyStats(
        **{f.name: getattr(base, f.name) for f in dataclasses.fields(LatencyStats)},
        client_procs=n,
        proc_qps=proc_qps,
        client_cache_counters=client_counters,
    )
