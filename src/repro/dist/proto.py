"""Wire protocol for distributed load generation: length-prefixed JSON
messages over a local TCP socket.

Each frame is a 4-byte big-endian length followed by that many bytes of
UTF-8 JSON. The JSON object carries a ``"type"`` tag naming the message
class; the remaining keys are the dataclass fields. Every message type
registered in :data:`MESSAGE_TYPES` round-trips ``decode(encode(msg)) ==
msg`` — enforced statically by the ``dist-proto`` rule of
``python -m repro.check`` (every dataclass here must be registered, with
no duplicate tags) and at runtime by ``tests/test_dist.py``.

Conversation (launcher = server side, client_proc = client side)::

    client                          launcher
      Hello(proc_id) ------------------>
      <------------------------- Assign(workload + serve knobs + seed)
      Ready(proc_id) ------------------>   (after build + compile)
      <------------------------- Start(epoch)   (shared wall-clock start)
      Stamp(completions) -------------->   (batched, epoch-relative)
      Done(summary + cache counters) -->

Timestamps in ``Stamp`` rows are *seconds since the shared epoch*: each
client pairs a ``time.time()`` reading with a ``time.perf_counter()``
reading at its local origin and rebases its perf_counter stamps, so
stamps from different processes land on one comparable axis (same
machine, same wall clock) and the launcher can compute merged windows.
"""

from __future__ import annotations

import dataclasses
import json
import socket
import struct
from typing import Any

__all__ = [
    "ProtocolError",
    "ConnectionClosed",
    "Hello",
    "Assign",
    "Ready",
    "Start",
    "Stamp",
    "Done",
    "Error",
    "MESSAGE_TYPES",
    "encode",
    "decode",
    "send_msg",
    "recv_msg",
]

PROTOCOL_VERSION = 1

_HEADER = struct.Struct(">I")
# Stamp batches are the largest frames (a few hundred rows each); anything
# near this bound is a corrupt header, not a real message.
MAX_FRAME_BYTES = 16 * 1024 * 1024


class ProtocolError(ValueError):
    """A frame or message that cannot be decoded as this protocol."""


class ConnectionClosed(ConnectionError):
    """The peer closed the socket mid-conversation."""


@dataclasses.dataclass(frozen=True)
class Hello:
    """Client → launcher: first message on a fresh connection."""

    proc_id: int
    pid: int
    protocol: int = PROTOCOL_VERSION


@dataclasses.dataclass(frozen=True)
class Assign:
    """Launcher → client: everything one client process needs to rebuild
    the workload and derive its own sub-schedule. ``serve`` is the
    ServeSpec field dict (``client_procs`` forced to 0 so the client runs
    the in-process path); ``overrides`` the flat param-override dict."""

    benchmark: str
    preset: int
    overrides: dict
    serve: dict
    seed: int
    proc_id: int
    n_procs: int
    warmup: int
    devices: int
    placement: str
    impl: str
    cache_dir: str | None = None


@dataclasses.dataclass(frozen=True)
class Ready:
    """Client → launcher: build + compile finished; waiting for Start."""

    proc_id: int
    requests: int  # length of this process's sub-schedule


@dataclasses.dataclass(frozen=True)
class Start:
    """Launcher → client: begin replay at the shared wall-clock epoch
    (``time.time()`` seconds; clients sleep until it passes)."""

    epoch: float


@dataclasses.dataclass(frozen=True)
class Stamp:
    """Client → launcher: a batch of completion rows, each
    ``[index, lane, t_submit, t_done, warmup]`` with epoch-relative
    stamps (seconds since Start.epoch)."""

    proc_id: int
    completions: list


@dataclasses.dataclass(frozen=True)
class Done:
    """Client → launcher: replay finished; per-process summary plus the
    client's own ``HloDiskCache.counter_dict()`` snapshot, so the
    launcher can assert a warm distributed run performed zero XLA
    compiles in *every* process."""

    proc_id: int
    requests: int
    truncated: bool
    cache_counters: dict | None = None


@dataclasses.dataclass(frozen=True)
class Error:
    """Client → launcher: the client failed; ``message`` is the one-line
    reason (full traceback stays on the client's stderr)."""

    proc_id: int
    message: str


# Tag -> message class. A dict *literal* on purpose: the dist-proto check
# rule reads it statically to verify every dataclass above is registered
# exactly once (an unregistered message type would encode but never
# decode).
MESSAGE_TYPES = {
    "hello": Hello,
    "assign": Assign,
    "ready": Ready,
    "start": Start,
    "stamp": Stamp,
    "done": Done,
    "error": Error,
}

_TYPE_TAGS = {cls: tag for tag, cls in MESSAGE_TYPES.items()}


def encode(msg: Any) -> bytes:
    """One message → one wire frame (header + JSON body)."""
    tag = _TYPE_TAGS.get(type(msg))
    if tag is None:
        raise ProtocolError(f"unregistered message type: {type(msg).__name__}")
    body = dict(dataclasses.asdict(msg))
    body["type"] = tag
    payload = json.dumps(body, separators=(",", ":")).encode("utf-8")
    if len(payload) > MAX_FRAME_BYTES:
        raise ProtocolError(f"frame too large: {len(payload)} bytes")
    return _HEADER.pack(len(payload)) + payload


def decode(frame: bytes) -> Any:
    """One frame body (JSON bytes, header already stripped) → message."""
    try:
        body = json.loads(frame.decode("utf-8"))
    except (UnicodeDecodeError, json.JSONDecodeError) as e:
        raise ProtocolError(f"undecodable frame: {e}") from e
    if not isinstance(body, dict):
        raise ProtocolError(f"frame is not an object: {body!r}")
    tag = body.pop("type", None)
    cls = MESSAGE_TYPES.get(tag)
    if cls is None:
        raise ProtocolError(f"unknown message type {tag!r}")
    known = {f.name for f in dataclasses.fields(cls)}
    try:
        return cls(**{k: v for k, v in body.items() if k in known})
    except TypeError as e:  # missing required field
        raise ProtocolError(f"bad {tag!r} message: {e}") from e


def send_msg(sock: socket.socket, msg: Any) -> None:
    """Write one message to a connected socket."""
    sock.sendall(encode(msg))


def _recv_exact(sock: socket.socket, n: int) -> bytes:
    chunks = []
    remaining = n
    while remaining:
        chunk = sock.recv(remaining)
        if not chunk:
            raise ConnectionClosed(
                f"peer closed with {remaining}/{n} bytes outstanding"
            )
        chunks.append(chunk)
        remaining -= len(chunk)
    return b"".join(chunks)


def recv_msg(sock: socket.socket) -> Any:
    """Read one message from a connected socket (blocking)."""
    (length,) = _HEADER.unpack(_recv_exact(sock, _HEADER.size))
    if length > MAX_FRAME_BYTES:
        raise ProtocolError(f"frame header claims {length} bytes")
    return decode(_recv_exact(sock, length))
