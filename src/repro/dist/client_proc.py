"""One distributed load-generation client: ``python -m repro.dist.client_proc``.

Spawned by :mod:`repro.dist.launcher`, one per ``ServeSpec.client_procs``.
The client connects back to the launcher, receives its :class:`Assign`,
rebuilds and compiles the assigned workload through its *own* engine —
against the shared ``--cache-dir``, so a warm distributed run restores
every process's executable with zero XLA compiles — derives its
per-process sub-schedule (``open_loop_lane_schedules`` with
``n_lanes=n_procs``, indexed by ``proc_id``: the same ``SeedSequence.spawn``
split the threaded client uses per lane, so the merged stream is Poisson
at the target QPS and byte-identical per seed), waits for the shared
start epoch, replays the sub-schedule with the in-process open-loop
runner, and streams epoch-relative completion stamps back.

The process inherits the launcher's environment (``XLA_FLAGS`` included),
so a forced-host-device CI topology applies to every client identically.
"""

from __future__ import annotations

import argparse
import os
import socket
import sys
import time
import traceback

from repro.dist.proto import (
    Assign,
    Done,
    Error,
    Hello,
    Ready,
    Stamp,
    Start,
    recv_msg,
    send_msg,
)

# Stamp rows per frame: large enough to amortize framing, small enough
# that one frame never approaches MAX_FRAME_BYTES.
_STAMP_BATCH = 512


def _run_assignment(sock: socket.socket, a: Assign) -> None:
    """Build → compile → sync → replay → stream, for one assignment."""
    from repro.core.engine import Engine
    from repro.core.plan import ExecutionPlan, Placement, ServeSpec
    from repro.core.registry import get_benchmark
    from repro.serve.lanes import run_open_loop
    from repro.serve.loadgen import open_loop_lane_schedules

    serve_fields = dict(a.serve)
    serve_fields["client_procs"] = 0  # this process IS one client
    serve = ServeSpec(**serve_fields)
    spec = get_benchmark(a.benchmark)
    engine = Engine(cache_dir=a.cache_dir)
    plan = ExecutionPlan(
        names=(a.benchmark,),
        preset=a.preset,
        overrides=(
            ((a.benchmark, tuple(sorted(a.overrides.items()))),)
            if a.overrides
            else ()
        ),
        include_backward=False,
        seed=a.seed,
        placement=Placement(devices=a.devices, mode=a.placement),
        impl=a.impl,
        serve=serve,
    )
    workload, args = engine._stage_build(spec, plan, a.preset)
    args, placement = engine._stage_place(
        workload, args, plan.placement_at(a.devices)
    )
    impl, _ = engine._resolve_impl(workload, plan, False)
    entry = engine._stage_compile(
        spec, workload, args, plan, a.preset, False, placement, impl
    )
    call = lambda: entry.executable(*args)  # noqa: E731

    # This process's slice of the merged Poisson stream. Deterministic:
    # every process derives the same n_procs-way split from the shared
    # seed and takes its own index.
    sub = open_loop_lane_schedules(
        qps=serve.qps,
        duration_s=serve.duration_s,
        n_lanes=a.n_procs,
        seed=a.seed,
        warmup=a.warmup,
    )[a.proc_id]

    send_msg(sock, Ready(proc_id=a.proc_id, requests=len(sub)))
    start = recv_msg(sock)
    if not isinstance(start, Start):
        raise RuntimeError(f"expected Start, got {type(start).__name__}")
    # Shared origin: sleep until the wall-clock epoch, then pair a
    # perf_counter reading with a wall reading so stamps rebase onto
    # "seconds since epoch" — one axis across all processes.
    delay = start.epoch - time.time()
    if delay > 0:
        time.sleep(delay)
    pc_ref = time.perf_counter()
    wall_ref = time.time()
    completions = run_open_loop(
        call, sub, n_lanes=serve.lanes, concurrency=serve.concurrency
    )
    shift = (wall_ref - start.epoch) - pc_ref

    rows = [
        [c.index, c.lane, c.t_submit + shift, c.t_done + shift, c.warmup]
        for c in completions
    ]
    for i in range(0, len(rows), _STAMP_BATCH):
        send_msg(
            sock, Stamp(proc_id=a.proc_id, completions=rows[i : i + _STAMP_BATCH])
        )
    counters = (
        engine.disk_cache.counter_dict() if engine.disk_cache is not None else None
    )
    send_msg(
        sock,
        Done(
            proc_id=a.proc_id,
            requests=len(rows),
            truncated=sub.truncated,
            cache_counters=counters,
        ),
    )


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, required=True)
    ap.add_argument("--proc-id", type=int, required=True)
    args = ap.parse_args(argv)

    sock = socket.create_connection((args.host, args.port), timeout=60)
    # The replay phase blocks in recv for Start while the launcher waits
    # for every process to compile; no per-op timeout once connected.
    sock.settimeout(None)
    try:
        send_msg(sock, Hello(proc_id=args.proc_id, pid=os.getpid()))
        assign = recv_msg(sock)
        if not isinstance(assign, Assign):
            raise RuntimeError(f"expected Assign, got {type(assign).__name__}")
        try:
            _run_assignment(sock, assign)
        except Exception as e:  # noqa: BLE001 — report, then die loudly
            traceback.print_exc()
            msg = " ".join(f"{type(e).__name__}: {e}".split())[:500]
            send_msg(sock, Error(proc_id=args.proc_id, message=msg))
            return 1
        return 0
    finally:
        sock.close()


if __name__ == "__main__":
    sys.exit(main())
