"""Implementation-axis figure: XLA vs Pallas side by side, per workload.

The PR-6 analogue of the paper's per-kernel tables: every kernel-backed
benchmark runs twice through the shared engine — once under ``impl=xla``
(the lax/XLA expression) and once under ``impl=pallas`` (the hand-tiled
kernel from ``src/repro/kernels/``, block parameters autotuned when
``tune`` is on) — and the figure reports both times plus the speedup of
the Pallas row over its XLA twin.

Rows are named ``fig_impl.<benchmark>.<requested impl>``; the derived
field carries the *effective* impl (a workload with no Pallas variant
falls back to xla and says so), the interpret flag (Pallas rows timed
off-TPU run in interpreter mode — a correctness row, not a perf claim),
the tuned block parameters, and ``speedup_vs_xla``.

As a section (``benchmarks/run.py --sections fig_impl``) it emits the
standard CSV rows; as a script it prints a per-benchmark pivot table.
"""

from __future__ import annotations

import os
import sys

if __package__ in (None, ""):  # `python benchmarks/fig_impl.py`
    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.common import ERROR_PREFIX, Row, parse_derived
from repro.core import run_suite

# Kernel-backed cross-section: MXU gemm, rowreduce, band-gemm, reduce,
# prefix-scan — one workload per kernel family the tuner has a space for.
DEFAULT_NAMES = (
    "gemm_f32_nn",
    "softmax",
    "lrn",
    "pooling",
    "where",
)
IMPLS = ("xla", "pallas")


class ImplFigureError(ValueError):
    """A sweep that cannot produce the figure (empty selection). main()
    prints the one-line message and exits 2 instead of a traceback."""


def _derive(r, xla_us: dict[str, float]) -> str:
    parts = [f"impl={r.impl}"]
    if r.impl_interpret is not None:
        parts.append(f"interpret={int(r.impl_interpret)}")
    if r.impl_fallback:
        parts.append(f"fallback={r.impl_fallback}")
    if r.tuned_params:
        tuned = "/".join(f"{k}={v}" for k, v in sorted(r.tuned_params.items()))
        parts.append(f"tuned={tuned}")
    if r.tune_trials is not None:
        parts.append(f"tune_trials={r.tune_trials}")
    base = xla_us.get(r.name)
    if r.impl == "pallas" and base:
        parts.append(f"speedup_vs_xla={base / r.us_per_call:.3f}")
    return ";".join(parts)


def rows(
    preset: int = 0,
    names=DEFAULT_NAMES,
    tune: bool = True,
    iters: int = 3,
) -> list[Row]:
    if not names:
        raise ImplFigureError("fig_impl: empty --names selection")
    by_impl = {
        impl: run_suite(
            names=list(names),
            preset=preset,
            iters=iters,
            warmup=1,
            include_backward=False,
            impl=impl,
            tune=tune and impl == "pallas",
            verbose=False,
        )
        for impl in IMPLS
    }
    xla_us = {r.name: r.us_per_call for r in by_impl["xla"] if r.status == "ok"}
    out: list[Row] = []
    for impl in IMPLS:
        for r in by_impl[impl]:
            name = f"fig_impl.{r.name}.{impl}"
            if r.status != "ok":
                out.append((name, 0.0, f"{ERROR_PREFIX}{r.error};{r.derived}"))
            else:
                out.append((name, r.us_per_call, _derive(r, xla_us)))
    return out


def main() -> int:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--preset", type=int, default=0)
    ap.add_argument("--names", nargs="*", default=list(DEFAULT_NAMES))
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--no-tune", action="store_true",
                    help="time Pallas rows at default block sizes")
    args = ap.parse_args()

    try:
        out = rows(
            preset=args.preset, names=tuple(args.names),
            tune=not args.no_tune, iters=args.iters,
        )
    except ImplFigureError as e:
        print(str(e), file=sys.stderr)
        return 2
    except ValueError as e:  # bad selection etc. — configuration, not a crash
        print(f"fig_impl: {e}", file=sys.stderr)
        return 2
    # Pivot into one line per benchmark: xla us, pallas us, speedup, tuning.
    table: dict[str, dict[str, tuple[float, dict[str, str]]]] = {}
    errors = 0
    for name, us, derived in out:
        if derived.startswith(ERROR_PREFIX):
            errors += 1
            print(f"# {name}: {derived}", file=sys.stderr)
            continue
        bench, _, impl = name.removeprefix("fig_impl.").rpartition(".")
        table.setdefault(bench, {})[impl] = (us, parse_derived(derived))
    if not table:
        print(
            f"fig_impl: zero ok records in the sweep "
            f"({errors} error rows, see above) — nothing to tabulate",
            file=sys.stderr,
        )
        return 1
    print(f"{'benchmark':<28}{'xla us':>12}{'pallas us':>12}"
          f"{'speedup':>9}  tuned")
    for bench, per_impl in table.items():
        xla_us, _ = per_impl.get("xla", (0.0, {}))
        pal_us, fields = per_impl.get("pallas", (0.0, {}))
        speedup = fields.get("speedup_vs_xla", "-")
        note = fields.get("tuned", "")
        if fields.get("fallback"):
            note = f"fallback={fields['fallback']}"
        if fields.get("interpret") == "1":
            note = (note + " " if note else "") + "[interpret]"
        print(f"{bench:<28}{xla_us:>12.1f}{pal_us:>12.1f}{speedup:>9}  {note}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
