"""Observability figure: where each workload's engine time actually goes.

The PR-8 companion to the tracing layer: a small selection runs through
its own :class:`~repro.core.engine.Engine` with a live
:class:`~repro.obs.Tracer`, and the figure reports the per-stage wall
breakdown every record now carries (``stage_timings_us``, schema v8) —
build / place / tune / compile / measure / characterize — as a share of
the pass's staged wall time. The span count from the tracer rides along,
so a run whose instrumentation silently stopped recording (zero spans)
shows up in the numbers, not just in a missing trace file.

Rows are named ``fig_trace.<benchmark>.<stage>``; ``us_per_call`` is the
stage's wall microseconds and the derived field carries the share of the
pass total plus the pass's span count. As a script it prints one
breakdown line per benchmark and can also write the Chrome trace
(``--trace-out``) for loading into Perfetto.
"""

from __future__ import annotations

import os
import sys

if __package__ in (None, ""):  # `python benchmarks/fig_trace.py`
    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.common import ERROR_PREFIX, Row
from repro.core import run_suite
from repro.core.engine import Engine
from repro.obs import Tracer

# A small cross-section: a compile-heavy MXU kernel, a bandwidth-bound
# stencil, and a tiny reduction whose fixed stage costs dominate.
DEFAULT_NAMES = ("gemm_f32_nn", "pathfinder", "softmax")

# Stable column order for the figure (matches the engine's stage order).
STAGES = ("build", "place", "tune", "compile", "measure", "characterize")


class TraceFigureError(ValueError):
    """A sweep that cannot produce the figure (empty selection). main()
    prints the one-line message and exits 2 instead of a traceback."""


def rows(
    preset: int = 0,
    names=DEFAULT_NAMES,
    iters: int = 3,
    trace_out: str | None = None,
) -> list[Row]:
    if not names:
        raise TraceFigureError("fig_trace: empty --names selection")
    tracer = Tracer()
    records = run_suite(
        names=list(names),
        preset=preset,
        iters=iters,
        warmup=1,
        include_backward=False,
        verbose=False,
        engine=Engine(tracer=tracer),
    )
    spans = len(tracer.events())
    if trace_out:
        tracer.export_chrome(trace_out)
    out: list[Row] = []
    for r in records:
        if r.status != "ok":
            out.append(
                (f"fig_trace.{r.name}", 0.0, f"{ERROR_PREFIX}{r.error};{r.derived}")
            )
            continue
        timings = r.stage_timings_us or {}
        total = sum(timings.values())
        for stage in STAGES:
            us = timings.get(stage)
            if us is None:
                continue
            share = us / total if total else 0.0
            out.append(
                (
                    f"fig_trace.{r.name}.{stage}",
                    us,
                    f"share={share:.3f};pass_total_us={total:.1f};spans={spans}",
                )
            )
    return out


def main() -> int:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--preset", type=int, default=0)
    ap.add_argument("--names", nargs="*", default=list(DEFAULT_NAMES))
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--trace-out", type=str, default=None, metavar="PATH",
                    help="also write the Chrome trace-event JSON here")
    args = ap.parse_args()

    try:
        out = rows(
            preset=args.preset, names=tuple(args.names),
            iters=args.iters, trace_out=args.trace_out,
        )
    except TraceFigureError as e:
        print(str(e), file=sys.stderr)
        return 2
    except ValueError as e:  # bad selection etc. — configuration, not a crash
        print(f"fig_trace: {e}", file=sys.stderr)
        return 2
    # Pivot into one breakdown line per benchmark.
    table: dict[str, dict[str, float]] = {}
    errors = 0
    for name, us, derived in out:
        if derived.startswith(ERROR_PREFIX):
            errors += 1
            print(f"# {name}: {derived}", file=sys.stderr)
            continue
        bench, _, stage = name.removeprefix("fig_trace.").rpartition(".")
        table.setdefault(bench, {})[stage] = us
    if not table:
        print(
            f"fig_trace: zero ok records in the sweep "
            f"({errors} error rows, see above) — nothing to tabulate",
            file=sys.stderr,
        )
        return 1
    print(f"{'benchmark':<28}{'total ms':>10}  stage shares")
    for bench, timings in table.items():
        total = sum(timings.values())
        shares = "  ".join(
            f"{stage}={timings[stage] / total * 100:.1f}%"
            for stage in STAGES
            if stage in timings and total
        )
        print(f"{bench:<28}{total / 1e3:>10.1f}  {shares}")
    if args.trace_out:
        print(f"# trace written to {args.trace_out}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
