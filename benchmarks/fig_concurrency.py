"""Concurrency figure: dispatch-lane speedup, client architectures, and
co-location interference.

The §V-B HyperQ study, generalized suite-wide through the serving
subsystem (``repro.serve``): any registered workload is served closed-loop
at each lane count in the sweep — under *both* host issue architectures,
side by side — and the dispatch speedup is its achieved QPS over the
single-lane serial baseline (lanes=1, concurrency=1 — one request in
flight, the no-concurrency floor). The paper's curve saturates near the
32 hardware work queues; here saturation lands wherever host dispatch
stops hiding behind device execution — and comparing the ``single``
client (every lane issued from one thread) against the ``threaded``
client (one issuing thread per lane) shows exactly where the
single-threaded client itself was the bottleneck. Threaded rows carry
the measured per-request dispatch overhead.

Both clients serve the *same cached executable*: one compile per
workload feeds the entire sweep (the engine's compile cache is keyed on
the workload, not the serving client), and the script prints the cache
traffic so "no recompile" is visible, not assumed. With ``--cache-dir``
the sweep runs against the two-tier artifact cache: a warm directory
restores serialized executables, so the whole figure — timer, roofline
characterization, and every serving row — costs *zero* XLA compilations
(the disk-cache summary printed at the end is the evidence).

The co-location half serves a workload pair through split lanes
(``ServeSpec.colocate``) and reports both tenants' p50 slowdown vs their
isolated baselines — the §V-B kernel co-location experiment as a table.

As a section (``benchmarks/run.py --sections fig_concurrency``) it emits
the standard CSV rows; as a script it renders the tables. Everything
routes through ``run_suite`` and the shared engine.
"""

from __future__ import annotations

import os
import sys

if __package__ in (None, ""):  # `python benchmarks/fig_concurrency.py`
    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.common import Row, parse_derived, record_rows
from repro.core import run_suite
from repro.core.plan import SERVE_CLIENTS, ServeSpec

DEFAULT_LANES = (1, 2, 4, 8, 16, 32)
DEFAULT_CLIENTS = SERVE_CLIENTS  # ("single", "threaded")
# One wavefront DP kernel (the paper's HyperQ subject) and one MXU kernel,
# so the dispatch curve and the interference pair cover both regimes.
DEFAULT_NAMES = ("pathfinder", "gemm_f32_nn")
FAST = dict(iters=1, warmup=0, include_backward=False, verbose=False)


def _serve_rows(tag: str, records, extra) -> list[Row]:
    return record_rows(
        tag,
        records,
        lambda r: (
            f"{extra(r)}p50_us={r.latency_p50_us:.1f};"
            f"p99_us={r.latency_p99_us:.1f};qps={r.achieved_qps:.1f}"
        ),
    )


def lane_sweep_rows(
    preset: int = 0,
    names=DEFAULT_NAMES,
    lanes_sweep=DEFAULT_LANES,
    duration_s: float = 0.3,
    clients=DEFAULT_CLIENTS,
    engine=None,
) -> list[Row]:
    """One row per (workload, client, lane count): achieved QPS plus the
    dispatch speedup over the same (workload, client)'s narrowest-lane
    baseline (lanes=1 when the sweep includes it — one request in flight,
    the serial floor). Threaded rows add ``dispatch_overhead_us``."""
    out: list[Row] = []
    base_qps: dict[tuple[str, str], float] = {}
    # Ascending order puts the baseline first, so every later row can
    # carry a speedup no matter what subset the caller swept.
    sweep = sorted(set(lanes_sweep))
    for n in sweep:
        # lanes=1 runs one request at a time (the serial-dispatch floor);
        # wider sweeps keep 2 in-flight requests per lane, the paper's
        # N-kernels-on-N-queues shape.
        concurrency = 1 if n == 1 else 2 * n
        for client in clients:
            serve = ServeSpec(
                mode="closed", concurrency=concurrency, lanes=n,
                duration_s=duration_s, client=client,
            )
            records = run_suite(
                names=list(names), preset=preset, serve=serve, engine=engine,
                **FAST,
            )
            for r in records:
                if r.status == "ok" and r.achieved_qps:
                    base_qps.setdefault((r.name, client), r.achieved_qps)

            def extra(r, n=n, concurrency=concurrency, client=client):
                base = base_qps.get((r.name, client))
                speedup = (
                    f"{r.achieved_qps / base:.2f}"
                    if base and r.achieved_qps
                    else "-"
                )
                overhead = (
                    f"{r.dispatch_overhead_us:.1f}"
                    if r.dispatch_overhead_us is not None
                    else "-"
                )
                return (
                    f"client={client};lanes={n};concurrency={concurrency};"
                    f"dispatch_speedup={speedup};"
                    f"dispatch_overhead_us={overhead};"
                )

            out.extend(
                (f"{name}.{client}.l{n}", us, derived)
                for name, us, derived in _serve_rows(
                    "fig_concurrency", records, extra
                )
            )
    return out


def colocation_rows(
    preset: int = 0,
    names=DEFAULT_NAMES,
    duration_s: float = 0.3,
    lanes: int = 2,
    concurrency: int = 4,
    engine=None,
) -> list[Row]:
    """Both tenants' slowdown-vs-isolated for each adjacent pair in
    ``names`` (the interference matrix's off-diagonal samples)."""
    out: list[Row] = []
    pairs = [(names[i], names[i + 1]) for i in range(len(names) - 1)]
    for a, b in pairs:
        serve = ServeSpec(
            mode="closed",
            concurrency=concurrency,
            lanes=lanes,
            duration_s=duration_s,
            colocate=b,
        )
        records = run_suite(
            names=[a], preset=preset, serve=serve, engine=engine, **FAST
        )
        out.extend(
            _serve_rows(
                "fig_concurrency.colocate",
                records,
                lambda r: (
                    f"pair={a}+{b};slowdown="
                    + (
                        f"{r.slowdown_vs_isolated:.2f};"
                        if r.slowdown_vs_isolated is not None
                        else "-;"
                    )
                ),
            )
        )
    return out


def rows(preset: int = 0) -> list[Row]:
    return lane_sweep_rows(preset=preset) + colocation_rows(preset=preset)


def main() -> int:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--preset", type=int, default=0)
    ap.add_argument("--names", nargs="*", default=list(DEFAULT_NAMES))
    ap.add_argument("--lanes", type=int, nargs="*", default=list(DEFAULT_LANES))
    ap.add_argument("--clients", nargs="*", choices=list(SERVE_CLIENTS),
                    default=list(DEFAULT_CLIENTS),
                    help="host issue architectures to sweep side by side")
    ap.add_argument("--duration", type=float, default=0.3)
    ap.add_argument("--cache-dir", type=str, default=None,
                    help="two-tier artifact cache directory: a warm dir "
                         "restores serialized executables, making the "
                         "whole figure a zero-XLA-compile run")
    args = ap.parse_args()

    from repro.core.engine import Engine
    from repro.core.suite import DEFAULT_ENGINE

    engine = Engine(cache_dir=args.cache_dir) if args.cache_dir else DEFAULT_ENGINE
    misses0 = engine.cache.misses
    sweep = lane_sweep_rows(
        preset=args.preset,
        names=tuple(args.names),
        lanes_sweep=tuple(args.lanes),
        duration_s=args.duration,
        clients=tuple(args.clients),
        engine=engine,
    )
    ok = [row for row in sweep if "qps=" in row[2]]
    if not ok:
        print(
            f"fig_concurrency: no ok serve records out of {len(sweep)} rows; "
            "see stderr for per-benchmark errors",
            file=sys.stderr,
        )
        return 1

    # Pivot: (benchmark, client) x lane count -> (qps, speedup).
    table: dict[tuple[str, str], dict[int, tuple[float, str]]] = {}
    counts: list[int] = []
    for name, _us, derived in ok:
        fields = parse_derived(derived)
        n = int(fields["lanes"])
        if n not in counts:
            counts.append(n)
        client = fields.get("client", "single")
        bench = (
            name.removeprefix("fig_concurrency.")
            .rsplit(".l", 1)[0]
            .removesuffix(f".{client}")
        )
        table.setdefault((bench, client), {})[n] = (
            float(fields["qps"]), fields["dispatch_speedup"]
        )
    label_w = 34
    print(f"{'benchmark [client]':<{label_w}}" + "".join(
        f"{f'{n}-lane qps':>14}{'speedup':>10}" for n in counts
    ))
    for (bench, client), per in table.items():
        line = f"{f'{bench} [{client}]':<{label_w}}"
        for n in counts:
            qps, speedup = per.get(n, (0.0, "-"))
            line += f"{qps:>14.1f}{speedup:>10}"
        print(line)
    # One compile per served (workload, pass): both clients and every lane
    # count reuse the cached executable. Print the traffic as evidence —
    # and with a warm --cache-dir even those "misses" were executable
    # restores, not XLA compilations (the hlocache line says which).
    print(
        f"# compile cache: {engine.cache.misses - misses0} misses "
        f"across {len(args.clients)} clients x {len(counts)} lane counts "
        f"({engine.cache.hits} hits total)",
        file=sys.stderr,
    )
    if engine.disk_cache is not None:
        print(f"# {engine.disk_cache.summary()}", file=sys.stderr)

    print()
    if "threaded" in args.clients:
        # Co-location dispatch is single-threaded by construction (tenants
        # alternate submissions — ServeSpec rejects colocate+threaded), so
        # the requested threaded client does NOT apply below. Say so
        # instead of silently dropping the request.
        print(
            "# note: co-location forces the single-threaded client "
            "(tenants alternate submissions); ignoring --clients threaded "
            "for the interference table",
            file=sys.stderr,
        )
    print(f"{'pair (tenant row)':<44}{'p50_us':>10}{'qps':>10}{'slowdown':>10}")
    for name, us, derived in colocation_rows(
        preset=args.preset, names=tuple(args.names), duration_s=args.duration,
        engine=engine,
    ):
        fields = parse_derived(derived)
        label = name.removeprefix("fig_concurrency.colocate.")
        print(
            f"{fields.get('pair', '?') + ' / ' + label:<44}"
            f"{us:>10.1f}{float(fields.get('qps', 0)):>10.1f}"
            f"{fields.get('slowdown', '-'):>10}"
        )
    return 0


if __name__ == "__main__":
    sys.exit(main())
