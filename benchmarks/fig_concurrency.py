"""Concurrency figure: dispatch-lane speedup and co-location interference.

The §V-B HyperQ study, generalized suite-wide through the serving
subsystem (``repro.serve``): any registered workload is served closed-loop
at each lane count in the sweep, and the dispatch speedup is its achieved
QPS over the single-lane serial baseline (lanes=1, concurrency=1 — one
request in flight, the no-concurrency floor). The paper's curve saturates
near the 32 hardware work queues; here saturation lands wherever host
dispatch stops hiding behind device execution.

The co-location half serves a workload pair through split lanes
(``ServeSpec.colocate``) and reports both tenants' p50 slowdown vs their
isolated baselines — the §V-B kernel co-location experiment as a table.

As a section (``benchmarks/run.py --sections fig_concurrency``) it emits
the standard CSV rows; as a script it renders the two tables. Everything
routes through ``run_suite`` and the shared engine, so serving reuses the
executables the measure stage compiled.
"""

from __future__ import annotations

import os
import sys

if __package__ in (None, ""):  # `python benchmarks/fig_concurrency.py`
    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.common import Row, parse_derived, record_rows
from repro.core import run_suite
from repro.core.plan import ServeSpec

DEFAULT_LANES = (1, 2, 4, 8, 16, 32)
# One wavefront DP kernel (the paper's HyperQ subject) and one MXU kernel,
# so the dispatch curve and the interference pair cover both regimes.
DEFAULT_NAMES = ("pathfinder", "gemm_f32_nn")
FAST = dict(iters=1, warmup=0, include_backward=False, verbose=False)


def _serve_rows(tag: str, records, extra) -> list[Row]:
    return record_rows(
        tag,
        records,
        lambda r: (
            f"{extra(r)}p50_us={r.latency_p50_us:.1f};"
            f"p99_us={r.latency_p99_us:.1f};qps={r.achieved_qps:.1f}"
        ),
    )


def lane_sweep_rows(
    preset: int = 0,
    names=DEFAULT_NAMES,
    lanes_sweep=DEFAULT_LANES,
    duration_s: float = 0.3,
) -> list[Row]:
    """One row per (workload, lane count): achieved QPS plus the dispatch
    speedup over the same workload's narrowest-lane baseline (lanes=1 when
    the sweep includes it — one request in flight, the serial floor)."""
    out: list[Row] = []
    base_qps: dict[str, float] = {}
    # Ascending order puts the baseline first, so every later row can
    # carry a speedup no matter what subset the caller swept.
    sweep = sorted(set(lanes_sweep))
    for n in sweep:
        # lanes=1 runs one request at a time (the serial-dispatch floor);
        # wider sweeps keep 2 in-flight requests per lane, the paper's
        # N-kernels-on-N-queues shape.
        concurrency = 1 if n == 1 else 2 * n
        serve = ServeSpec(
            mode="closed", concurrency=concurrency, lanes=n,
            duration_s=duration_s,
        )
        records = run_suite(names=list(names), preset=preset, serve=serve, **FAST)
        for r in records:
            if r.status == "ok" and r.achieved_qps:
                base_qps.setdefault(r.name, r.achieved_qps)

        def extra(r, n=n, concurrency=concurrency):
            base = base_qps.get(r.name)
            speedup = (
                f"{r.achieved_qps / base:.2f}" if base and r.achieved_qps else "-"
            )
            return (
                f"lanes={n};concurrency={concurrency};"
                f"dispatch_speedup={speedup};"
            )

        out.extend(
            (f"{name}.l{n}", us, derived)
            for name, us, derived in _serve_rows("fig_concurrency", records, extra)
        )
    return out


def colocation_rows(
    preset: int = 0,
    names=DEFAULT_NAMES,
    duration_s: float = 0.3,
    lanes: int = 2,
    concurrency: int = 4,
) -> list[Row]:
    """Both tenants' slowdown-vs-isolated for each adjacent pair in
    ``names`` (the interference matrix's off-diagonal samples)."""
    out: list[Row] = []
    pairs = [(names[i], names[i + 1]) for i in range(len(names) - 1)]
    for a, b in pairs:
        serve = ServeSpec(
            mode="closed",
            concurrency=concurrency,
            lanes=lanes,
            duration_s=duration_s,
            colocate=b,
        )
        records = run_suite(names=[a], preset=preset, serve=serve, **FAST)
        out.extend(
            _serve_rows(
                "fig_concurrency.colocate",
                records,
                lambda r: (
                    f"pair={a}+{b};slowdown="
                    + (
                        f"{r.slowdown_vs_isolated:.2f};"
                        if r.slowdown_vs_isolated is not None
                        else "-;"
                    )
                ),
            )
        )
    return out


def rows(preset: int = 0) -> list[Row]:
    return lane_sweep_rows(preset=preset) + colocation_rows(preset=preset)


def main() -> int:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--preset", type=int, default=0)
    ap.add_argument("--names", nargs="*", default=list(DEFAULT_NAMES))
    ap.add_argument("--lanes", type=int, nargs="*", default=list(DEFAULT_LANES))
    ap.add_argument("--duration", type=float, default=0.3)
    args = ap.parse_args()

    sweep = lane_sweep_rows(
        preset=args.preset,
        names=tuple(args.names),
        lanes_sweep=tuple(args.lanes),
        duration_s=args.duration,
    )
    ok = [row for row in sweep if "qps=" in row[2]]
    if not ok:
        print(
            f"fig_concurrency: no ok serve records out of {len(sweep)} rows; "
            "see stderr for per-benchmark errors",
            file=sys.stderr,
        )
        return 1

    # Pivot: benchmark x lane count -> (qps, speedup).
    table: dict[str, dict[int, tuple[float, str]]] = {}
    counts: list[int] = []
    for name, _us, derived in ok:
        fields = parse_derived(derived)
        n = int(fields["lanes"])
        if n not in counts:
            counts.append(n)
        bench = name.removeprefix("fig_concurrency.").rsplit(".l", 1)[0]
        table.setdefault(bench, {})[n] = (
            float(fields["qps"]), fields["dispatch_speedup"]
        )
    print(f"{'benchmark':<28}" + "".join(
        f"{f'{n}-lane qps':>14}{'speedup':>10}" for n in counts
    ))
    for bench, per in table.items():
        line = f"{bench:<28}"
        for n in counts:
            qps, speedup = per.get(n, (0.0, "-"))
            line += f"{qps:>14.1f}{speedup:>10}"
        print(line)

    print()
    print(f"{'pair (tenant row)':<44}{'p50_us':>10}{'qps':>10}{'slowdown':>10}")
    for name, us, derived in colocation_rows(
        preset=args.preset, names=tuple(args.names), duration_s=args.duration
    ):
        fields = parse_derived(derived)
        label = name.removeprefix("fig_concurrency.colocate.")
        print(
            f"{fields.get('pair', '?') + ' / ' + label:<44}"
            f"{us:>10.1f}{float(fields.get('qps', 0)):>10.1f}"
            f"{fields.get('slowdown', '-'):>10}"
        )
    return 0


if __name__ == "__main__":
    sys.exit(main())
