"""Figs. 1–2 analogue: the microbenchmark/basic-algorithm tiers (the SHOC-
like levels 0–1), showing the diverse utilization spread the paper contrasts
against Rodinia's flat profile."""

from __future__ import annotations

from benchmarks.common import Row, record_rows
from repro.core import run_suite


def rows(preset: int = 0) -> list[Row]:
    records = run_suite(
        levels=(0, 1), preset=preset, iters=3, warmup=1,
        include_backward=False, verbose=False,
    )
    return record_rows(
        "fig12",
        records,
        lambda r: (
            f"compute10={r.compute_util10};memory10={r.memory_util10};"
            f"dominant={r.dominant};gbps={r.achieved_gbps:.2f}"
        ),
    )
