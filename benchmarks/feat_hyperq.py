"""§V-B HyperQ analogue: concurrent Pathfinder instances.

The paper launches N Pathfinder kernels on N streams and sees speedup
saturate near the 32 hardware work queues. Both halves of the analogue now
route through the serving subsystem's dispatch modes (``repro.serve``):

- **loop** (``serve.lanes.serve_loop``): N jitted calls synchronized one
  by one — the no-concurrency baseline;
- **windowed loop** (``serve_loop(..., window=N)``): the same N calls
  dispatched back to back with one synchronization on all of them — the
  async-dispatch floor; loop_us − windowed_us is the per-call
  dispatch + sync overhead the serial loop folds into its number;
- **batched** (``serve.lanes.batched_call``): N instances fused into one
  program, filling idle vector lanes the way HyperQ fills idle work
  queues; speedup = loop_us / batched_us — >1 means one instance
  underutilizes the machine, the paper's exact finding.

The lane-count sweep (the *dispatch* half of the story) lives in
``benchmarks/fig_concurrency.py``; this section keeps the paper-shaped
instances-vs-batching table and its historical Row shape.
"""

from __future__ import annotations

import jax

from benchmarks.common import Row
from repro.core.harness import time_fn
from repro.bench.level1.pathfinder import pathfinder_min_path
from repro.serve.lanes import batched_call, serve_loop
from repro.serve.latency import stats_from_completions
from repro.serve.loadgen import closed_loop_schedule


def rows(rows_grid: int = 64, cols: int = 256) -> list[Row]:
    """Reports both HyperQ halves, honestly split by what a 1-core CPU host
    can exhibit: (a) serial-loop of N jitted calls vs (b) one batched
    program. On GPU, (b) fills idle SMs via 32 work queues (the paper's 4×);
    on this host (b) can only amortize dispatch — the *occupancy* half needs
    idle parallel hardware and is a TPU-run measurement (documented in
    EXPERIMENTS.md §Perf-notes)."""
    out: list[Row] = []
    key = jax.random.key(0)
    single = jax.jit(pathfinder_min_path)
    for n in (1, 2, 4, 8, 16, 32):
        grids = jax.random.randint(key, (n, rows_grid, cols), 0, 10)
        jax.block_until_ready(single(grids[0]))  # compile outside timing

        # (a) loop dispatch: one instance per request, synchronized each
        # time; 2n warmup requests then 5 measured sweeps of n instances.
        state = {"i": 0}

        def call() -> jax.Array:
            i = state["i"] = (state["i"] + 1) % n
            return single(grids[i])

        completions = serve_loop(
            call, closed_loop_schedule(7 * n, warmup=2 * n)
        )
        stats = stats_from_completions(completions)
        us_loop = n * 1e6 / stats.achieved_qps  # per N-instance sweep

        # (b) windowed loop: same N calls, one synchronization per sweep —
        # the async-dispatch floor (loop − windowed = dispatch overhead).
        win_completions = serve_loop(
            call, closed_loop_schedule(7 * n, warmup=2 * n), window=n
        )
        win_stats = stats_from_completions(win_completions)
        us_windowed = n * 1e6 / win_stats.achieved_qps

        # (c) batched dispatch: the same N instances as one program.
        fn = jax.jit(batched_call(pathfinder_min_path, n))
        us_batch, _ = time_fn(fn, (grids,), iters=5, warmup=2)
        out.append(
            (
                f"feat_hyperq.n{n}",
                us_batch,
                f"instances={n};loop_us={us_loop:.1f};"
                f"windowed_us={us_windowed:.1f};batched_us={us_batch:.1f};"
                f"batching_speedup={us_loop / max(us_batch, 1e-9):.2f}",
            )
        )
    return out
