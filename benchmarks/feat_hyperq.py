"""§V-B HyperQ analogue: concurrent Pathfinder instances.

The paper launches N Pathfinder kernels on N streams and sees speedup
saturate near the 32 hardware work queues. The TPU analogue fills idle
vector lanes by *batching* N instances into one program
(`core.features.concurrent_instances`); speedup = N·t(1) / t(N) — >1 means
one instance underutilizes the machine, the paper's exact finding.
"""

from __future__ import annotations

import jax

from benchmarks.common import Row
from repro.core.features import concurrent_instances
from repro.core.harness import time_fn
from repro.bench.level1.pathfinder import pathfinder_min_path


def rows(rows_grid: int = 64, cols: int = 256) -> list[Row]:
    """Reports both HyperQ halves, honestly split by what a 1-core CPU host
    can exhibit: (a) serial-loop of N jitted calls vs (b) one batched
    program. On GPU, (b) fills idle SMs via 32 work queues (the paper's 4×);
    on this host (b) can only amortize dispatch — the *occupancy* half needs
    idle parallel hardware and is a TPU-run measurement (documented in
    EXPERIMENTS.md §Perf-notes)."""
    out: list[Row] = []
    key = jax.random.key(0)
    single = jax.jit(pathfinder_min_path)
    for n in (1, 2, 4, 8, 16, 32):
        grids = jax.random.randint(key, (n, rows_grid, cols), 0, 10)

        def loop(grids=grids, n=n):
            return [single(grids[i]) for i in range(n)]

        us_loop, _ = time_fn(lambda: loop(), (), iters=5, warmup=2)
        fn = jax.jit(concurrent_instances(pathfinder_min_path, n))
        us_batch, _ = time_fn(fn, (grids,), iters=5, warmup=2)
        out.append(
            (
                f"feat_hyperq.n{n}",
                us_batch,
                f"instances={n};loop_us={us_loop:.1f};batched_us={us_batch:.1f};"
                f"batching_speedup={us_loop / max(us_batch, 1e-9):.2f}",
            )
        )
    return out
