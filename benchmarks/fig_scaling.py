"""Device-scaling figure: data-parallel throughput across device counts.

The Mirovia/Milabench-style scaling study the paper's successors measure:
run a sample of batchable benchmarks under ``placement=shard`` at each
device count in the sweep and report, per (benchmark, count), the wall
time and the scaling efficiency against the same run's 1-device row
(efficiency = speedup / devices; 1.0 is perfect linear scaling).

Benchmarks that opt out of ``batch_dims`` fall back to replicate and show
efficiency ≈ 1/devices — the redundant-work floor the placement layer
exists to beat.

As a section (``benchmarks/run.py --sections fig_scaling``) it emits the
standard CSV rows; as a script it prints a per-benchmark scaling table.
Counts beyond this host's devices are skipped (force more with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``).
"""

from __future__ import annotations

import os
import sys

if __package__ in (None, ""):  # `python benchmarks/fig_scaling.py`
    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.common import Row, parse_derived, record_rows
from repro.core import run_suite

# A cross-section of batchable workloads: MXU (gemm/connected), VPU
# streaming (devicemem), mixed compute (kmeans), DNN fwd+bwd (softmax),
# plus one opted-out workload (bfs) so the replicate fallback shows up in
# the same table.
DEFAULT_NAMES = (
    "gemm_f32_nn",
    "devicemem_stream",
    "kmeans",
    "softmax",
    "bfs",
)
DEFAULT_COUNTS = (1, 2, 4, 8)


def _usable_counts(counts) -> tuple[int, ...]:
    import jax

    avail = jax.device_count()
    usable = tuple(c for c in counts if c <= avail)
    return usable or (1,)


class ScalingFigureError(ValueError):
    """A sweep that cannot produce the figure (no usable device counts, or
    zero ok records). main() prints the one-line message and exits nonzero
    instead of dumping a traceback or rendering an empty table."""


def rows(
    preset: int = 0,
    counts=DEFAULT_COUNTS,
    names=DEFAULT_NAMES,
    placement: str = "shard",
) -> list[Row]:
    records = run_suite(
        names=list(names),
        preset=preset,
        iters=3,
        warmup=1,
        include_backward=False,
        placement=placement,
        scale_devices=_usable_counts(counts),
        verbose=False,
    )
    return record_rows(
        "fig_scaling",
        records,
        lambda r: (
            f"devices={r.devices};placement={r.placement};eff="
            + (
                f"{r.scaling_efficiency:.3f}"
                if r.scaling_efficiency is not None
                else "baseline"
            )
        ),
    )


def main() -> int:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--preset", type=int, default=0)
    ap.add_argument("--names", nargs="*", default=list(DEFAULT_NAMES))
    ap.add_argument("--counts", type=int, nargs="*", default=list(DEFAULT_COUNTS))
    ap.add_argument("--placement", default="shard",
                    choices=("replicate", "shard"))
    args = ap.parse_args()

    try:
        if not args.counts:
            raise ScalingFigureError("fig_scaling: empty --counts sweep")
        import jax

        if max(args.counts) > 1 and _usable_counts(args.counts) == (1,) and 1 not in args.counts:
            raise ScalingFigureError(
                f"fig_scaling: no requested device count in {args.counts} fits "
                f"this host ({jax.device_count()} devices); force more with "
                "XLA_FLAGS=--xla_force_host_platform_device_count=8"
            )
        out = rows(
            preset=args.preset, counts=tuple(args.counts),
            names=tuple(args.names), placement=args.placement,
        )
    except ScalingFigureError as e:
        print(str(e), file=sys.stderr)
        return 2
    except ValueError as e:  # bad selection etc. — configuration, not a crash
        print(f"fig_scaling: {e}", file=sys.stderr)
        return 2
    # Pivot rows into a per-benchmark scaling table.
    table: dict[str, dict[int, tuple[float, str]]] = {}
    counts: list[int] = []
    errors = 0
    for name, us, derived in out:
        fields = parse_derived(derived)
        if "devices" not in fields:
            errors += 1
            print(f"# {name}: {derived}", file=sys.stderr)
            continue
        n = int(fields["devices"])
        if n not in counts:
            counts.append(n)
        bench = name.removeprefix("fig_scaling.")
        table.setdefault(bench, {})[n] = (us, fields.get("eff", "-"))
    if not table:
        print(
            f"fig_scaling: zero ok records in the sweep "
            f"({errors} error rows, see above) — nothing to tabulate",
            file=sys.stderr,
        )
        return 1
    header = f"{'benchmark':<28}" + "".join(
        f"{f'{n}dev us':>12}{'eff':>10}" for n in counts
    )
    print(header)
    for bench, per_count in table.items():
        line = f"{bench:<28}"
        for n in counts:
            us, eff = per_count.get(n, (0.0, "-"))
            line += f"{us:>12.1f}{eff:>10}"
        print(line)
    return 0


if __name__ == "__main__":
    sys.exit(main())
