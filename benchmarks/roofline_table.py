"""§Roofline table: per (arch × shape) roofline terms from the dry-run
artifacts (artifacts/dryrun/*.json — produced by repro.launch.dryrun)."""

from __future__ import annotations

import glob
import json
import os

from benchmarks.common import DRYRUN_DIR, Row


def load_cells(mesh: str = "single", variant: str = "baseline") -> list[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, f"*__{mesh}__{variant}.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def rows(mesh: str = "single", variant: str = "baseline") -> list[Row]:
    out: list[Row] = []
    for c in load_cells(mesh, variant):
        name = f"roofline.{c['arch']}.{c['shape']}.{mesh}"
        if "skip" in c:
            out.append((name, 0.0, f"skip={c['skip']}"))
            continue
        r = c["roofline"]
        mem_gib = sum(c.get("memory", {}).values()) / 2**30
        out.append(
            (
                name,
                r["compute_s"] * 1e6,  # the compute-term microseconds
                f"dominant={r['dominant']};fraction={r['roofline_fraction']:.3f};"
                f"compute_s={r['compute_s']:.4g};memory_s={r['memory_s']:.4g};"
                f"collective_s={r['collective_s']:.4g};"
                f"useful_ratio={c['useful_compute_ratio']:.3f};"
                f"mem_gib={mem_gib:.2f}",
            )
        )
    return out
