"""§Roofline table: per (arch × shape) roofline terms from the dry-run
artifacts (artifacts/dryrun/*.json — produced by repro.launch.dryrun),
plus a suite-report mode (``rows_from_report``) that renders the same
style of rows from engine records.

The suite-report mode consumes what the engine's characterize stage
attached to each record — which, on a warm ``--cache-dir`` run, was
restored from the two-tier artifact cache without a single XLA
compilation: one cold compile feeds the timer, this table, and the serve
stage; warm runs feed all three with zero. The measured column prefers
``us_per_call_windowed`` (K calls in flight per synchronization) over the
sync number when present, because the roofline bound models kernel
throughput, not host dispatch latency — comparing the bound against
sync-mode time for a small kernel mostly grades the dispatch overhead.
"""

from __future__ import annotations

import glob
import json
import os

from benchmarks.common import DRYRUN_DIR, Row, parse_derived


def load_cells(mesh: str = "single", variant: str = "baseline") -> list[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, f"*__{mesh}__{variant}.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def rows(mesh: str = "single", variant: str = "baseline") -> list[Row]:
    out: list[Row] = []
    for c in load_cells(mesh, variant):
        name = f"roofline.{c['arch']}.{c['shape']}.{mesh}"
        if "skip" in c:
            out.append((name, 0.0, f"skip={c['skip']}"))
            continue
        r = c["roofline"]
        mem_gib = sum(c.get("memory", {}).values()) / 2**30
        out.append(
            (
                name,
                r["compute_s"] * 1e6,  # the compute-term microseconds
                f"dominant={r['dominant']};fraction={r['roofline_fraction']:.3f};"
                f"compute_s={r['compute_s']:.4g};memory_s={r['memory_s']:.4g};"
                f"collective_s={r['collective_s']:.4g};"
                f"useful_ratio={c['useful_compute_ratio']:.3f};"
                f"mem_gib={mem_gib:.2f}",
            )
        )
    return out


def rows_from_records(records) -> list[Row]:
    """Roofline-style rows from engine records (suite or warm-cache runs).

    The measured time is the windowed per-call number when the run carried
    one (schema v5), else the sync number; the derived field keeps both
    plus the record's analytic roofline terms and its implementation axis
    (schema v6: ``impl=xla|pallas``, with the interpret flag on Pallas
    rows timed off-TPU), so the table reads the measured-vs-bound story
    per benchmark and per implementation without recompiling anything.
    """
    out: list[Row] = []
    for r in records:
        if r.status != "ok":
            out.append((f"roofline.{r.name}", 0.0, f"error={r.error}"))
            continue
        terms = parse_derived(r.derived)
        us = (
            r.us_per_call_windowed
            if r.us_per_call_windowed is not None
            else r.us_per_call
        )
        impl = f"impl={r.impl}"
        if r.impl_interpret is not None:
            impl += f";interpret={int(r.impl_interpret)}"
        derived = (
            f"dominant={r.dominant};{impl};sync_us={r.us_per_call:.2f};"
            f"timed={'windowed' if r.us_per_call_windowed is not None else 'sync'};"
            f"flops={terms.get('flops', '0')};bytes={terms.get('bytes', '0')};"
            f"gflops={r.achieved_gflops:.2f};gbps={r.achieved_gbps:.2f}"
        )
        # Pallas rows get a name suffix so a report holding both impls of
        # one workload renders two distinguishable rows.
        suffix = ".pallas" if r.impl == "pallas" else ""
        out.append((f"roofline.{r.name}{suffix}", us, derived))
    return out


def rows_from_report(path: str) -> list[Row]:
    """``rows_from_records`` over a JSON/JSONL suite report on disk."""
    from repro.core.results import load_records

    return rows_from_records(load_records(path))


def rows_from_latest_report() -> list[Row]:
    """The suite-report half of the roofline section: rows from the
    committed suite report artifact when one exists, else nothing (the
    dry-run cells still render)."""
    path = os.path.join(os.path.dirname(DRYRUN_DIR), "suite_report.json")
    if not os.path.exists(path):
        return []
    try:
        return rows_from_report(path)
    except Exception as e:  # noqa: BLE001 — a stale artifact is not fatal
        return [("roofline.suite_report", 0.0, f"error={e}")]
