"""§V-B Dynamic Parallelism analogue: Mandelbrot escape-time vs
Mariani–Silver adaptive tiles.

The paper's cleanest feature win: speedup grows with image size as the
adaptive algorithm skips ever-larger interior swaths. Ours skips whole
tiles whose border lies in the set (bench/level2/mandelbrot.py); both
versions produce identical images (validated there).
"""

from __future__ import annotations

import functools

import jax

from benchmarks.common import Row
from repro.bench.level2.mandelbrot import _pixel_grid, escape_time, mariani_silver
from repro.core.harness import time_fn


def rows(max_iter: int = 256) -> list[Row]:
    out: list[Row] = []
    for n in (128, 256, 512):
        c = _pixel_grid(n)
        flat = jax.jit(functools.partial(escape_time, max_iter=max_iter))
        adap = jax.jit(functools.partial(mariani_silver, max_iter=max_iter))
        us_flat, _ = time_fn(flat, (c,), iters=3, warmup=1)
        us_adap, _ = time_fn(adap, (c,), iters=3, warmup=1)
        out.append(
            (
                f"feat_dp.mandelbrot.{n}px",
                us_adap,
                f"flat_us={us_flat:.1f};adaptive_us={us_adap:.1f};"
                f"speedup={us_flat / max(us_adap, 1e-9):.2f}",
            )
        )
    return out
