"""Distributed load-generation figure: offered-QPS scaling, 1 vs N client
processes, with the single-process dispatch ceiling marked.

One Python process can only issue so many requests per second — past that
ceiling, raising the offered QPS raises p99 but not throughput. This
driver sweeps offered load for a single in-process client (the
``client=threaded`` ceiling-finder) and for N distributed client
processes (``ServeSpec.client_procs``, ``src/repro/dist/``), all replaying
seeded Poisson schedules against the same cached executable, and reports
the achieved-QPS curve per process count next to the marked ceiling.

Honesty note: the merged *schedule* always offers the target QPS (the
``SeedSequence.spawn`` split preserves the Poisson process exactly), so
what scales with processes is what is *achieved* under that offer. On a
multi-core host N processes clear the single-interpreter ceiling; on a
single-core host (some CI runners) the machine itself is the ceiling and
the curve shows that instead — ``cpu_count`` is recorded in the artifact
so the two regimes are never conflated.

As a section (``benchmarks/run.py --sections fig_dist``) it emits the
standard CSV rows; as a script it renders the scaling table, and
``--json PATH`` writes the machine-readable curve (the
``artifacts/BENCH_10.json`` artifact).
"""

from __future__ import annotations

import json
import os
import sys

if __package__ in (None, ""):  # `python benchmarks/fig_dist.py`
    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.common import Row, parse_derived, record_rows
from repro.core import run_suite
from repro.core.plan import ServeSpec

DEFAULT_NAME = "pathfinder"
# procs=1 is the in-process threaded client (the ceiling being broken);
# procs>1 route through repro.dist. The offered points bracket the
# single-process ceiling: one comfortably under, one near, one far past.
DEFAULT_PROCS = (1, 2, 4)
DEFAULT_QPS = (2_000.0, 8_000.0, 20_000.0)
FAST = dict(iters=1, warmup=0, include_backward=False, verbose=False)


def rows(
    preset: int = 0,
    name: str = DEFAULT_NAME,
    procs=DEFAULT_PROCS,
    qps_points=DEFAULT_QPS,
    duration_s: float = 0.75,
    concurrency: int = 16,
    lanes: int = 4,
    seed: int = 0,
    engine=None,
) -> list[Row]:
    """One row per (process count, offered QPS) point. ``procs == 1`` is
    the single-process threaded client; ``procs > 1`` spawns that many
    client processes through the dist launcher."""
    out: list[Row] = []
    for n in procs:
        for qps in qps_points:
            serve = ServeSpec(
                mode="open", qps=qps, duration_s=duration_s,
                concurrency=concurrency, lanes=lanes,
                client="threaded" if n == 1 else "single",
                client_procs=0 if n == 1 else n,
            )
            records = run_suite(
                names=[name], preset=preset, serve=serve, seed=seed,
                engine=engine, **FAST,
            )

            def extra(r, n=n, qps=qps):
                proc_qps = ",".join(f"{q:.0f}" for q in (r.proc_qps or ()))
                return (
                    f"procs={n};offered_qps={qps:.0f};"
                    f"qps={r.achieved_qps:.1f};"
                    f"p50_us={r.latency_p50_us:.1f};"
                    f"p99_us={r.latency_p99_us:.1f};"
                    + (f"proc_qps={proc_qps};" if proc_qps else "")
                )

            out.extend(
                (f"{nm}.procs{n}.q{qps:.0f}", us, derived)
                for nm, us, derived in record_rows("fig_dist", records, extra)
            )
    return out


def main() -> int:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--preset", type=int, default=0)
    ap.add_argument("--name", default=DEFAULT_NAME)
    ap.add_argument("--procs", nargs="*", type=int, default=list(DEFAULT_PROCS),
                    help="client process counts; 1 = in-process threaded "
                         "client (the single-process ceiling)")
    ap.add_argument("--qps", nargs="*", type=float, default=list(DEFAULT_QPS),
                    help="offered-QPS points, identical for every process "
                         "count (bracket the single-process ceiling)")
    ap.add_argument("--duration", type=float, default=0.75)
    ap.add_argument("--concurrency", type=int, default=16)
    ap.add_argument("--lanes", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the scaling curve as JSON (BENCH artifact)")
    ap.add_argument("--cache-dir", type=str, default=None,
                    help="shared two-tier artifact cache: client processes "
                         "restore the executable instead of recompiling "
                         "(a warm dir makes every client zero-XLA-compile)")
    args = ap.parse_args()

    from repro.core.engine import Engine
    from repro.core.suite import DEFAULT_ENGINE

    engine = Engine(cache_dir=args.cache_dir) if args.cache_dir else DEFAULT_ENGINE
    table = rows(
        preset=args.preset, name=args.name, procs=tuple(args.procs),
        qps_points=tuple(args.qps), duration_s=args.duration,
        concurrency=args.concurrency, lanes=args.lanes, seed=args.seed,
        engine=engine,
    )
    points = []
    for _name, _us, derived in table:
        f = parse_derived(derived)
        if "qps" not in f:
            continue
        points.append({
            "procs": int(f["procs"]),
            "offered_qps": float(f["offered_qps"]),
            "achieved_qps": float(f["qps"]),
            "p50_us": float(f["p50_us"]),
            "p99_us": float(f["p99_us"]),
            "proc_qps": [float(q) for q in f["proc_qps"].split(",")]
            if "proc_qps" in f else None,
        })
    if not points:
        print(
            f"fig_dist: no ok serve records out of {len(table)} rows; "
            "see stderr for per-benchmark errors",
            file=sys.stderr,
        )
        return 1

    best = {}
    for p in points:
        best[p["procs"]] = max(best.get(p["procs"], 0.0), p["achieved_qps"])
    ceiling = best.get(1)
    if ceiling:
        print(f"# single-process ceiling: {ceiling:.0f} qps "
              f"(cpu_count={os.cpu_count()})", file=sys.stderr)

    print(f"{'procs':<7}{'offered':>10}{'achieved':>10}{'p50_us':>10}"
          f"{'p99_us':>12}{'vs 1-proc':>11}")
    for p in points:
        ratio = f"{p['achieved_qps'] / ceiling:>10.2f}x" if ceiling else f"{'-':>11}"
        print(
            f"{p['procs']:<7d}{p['offered_qps']:>10.0f}"
            f"{p['achieved_qps']:>10.1f}{p['p50_us']:>10.1f}"
            f"{p['p99_us']:>12.1f}{ratio}"
        )

    if engine.disk_cache is not None:
        print(f"# {engine.disk_cache.summary()}", file=sys.stderr)

    if args.json:
        import jax

        payload = {
            "kind": "fig_dist",
            "backend": jax.default_backend(),
            "jax_version": jax.__version__,
            "cpu_count": os.cpu_count(),
            "name": args.name,
            "duration_s": args.duration,
            "concurrency": args.concurrency,
            "lanes": args.lanes,
            "seed": args.seed,
            "points": points,
            "single_process_ceiling_qps": ceiling,
            "scaling_vs_single_process": {
                str(n): round(q / ceiling, 3) for n, q in sorted(best.items())
            } if ceiling else None,
        }
        os.makedirs(os.path.dirname(os.path.abspath(args.json)), exist_ok=True)
        with open(args.json, "w") as fh:
            json.dump(payload, fh, indent=2, sort_keys=True)
            fh.write("\n")
        print(f"# wrote {args.json}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
