"""Shared helpers for the per-figure benchmark drivers.

Every module exposes ``rows() -> list[(name, us_per_call, derived)]``;
run.py concatenates them into the required ``name,us_per_call,derived`` CSV.
"""

from __future__ import annotations

import os

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "artifacts")
DRYRUN_DIR = os.path.join(ARTIFACT_DIR, "dryrun")

Row = tuple[str, float, str]


def fmt(rows: list[Row]) -> list[str]:
    return [f"{n},{us:.2f},{d}" for n, us, d in rows]
