"""Shared helpers for the per-figure benchmark drivers.

Every module exposes ``rows() -> list[(name, us_per_call, derived)]``;
run.py concatenates them into the required ``name,us_per_call,derived`` CSV.
"""

from __future__ import annotations

import os

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "artifacts")
DRYRUN_DIR = os.path.join(ARTIFACT_DIR, "dryrun")

Row = tuple[str, float, str]

# Derived-field prefix marking a per-benchmark failure row (the engine's
# fault isolation); run.py counts these toward its exit code.
ERROR_PREFIX = "error="


def fmt(rows: list[Row]) -> list[str]:
    return [f"{n},{us:.2f},{d}" for n, us, d in rows]


def parse_derived(derived: str) -> dict[str, str]:
    """Parse a ``k=v;k=v`` derived field back into a dict (the inverse of
    what the figure drivers and serve stats emit); junk fragments without
    '=' are dropped."""
    return dict(kv.split("=", 1) for kv in derived.split(";") if "=" in kv)


def record_rows(tag, records, derive) -> list[Row]:
    """Format suite records as figure rows, surfacing error records.

    ``derive(record) -> str`` builds the derived field for ok records;
    error records become explicit ``error=...`` rows instead of fake zeros.
    """
    out: list[Row] = []
    for r in records:
        if r.status != "ok":
            out.append((f"{tag}.{r.name}", 0.0, f"{ERROR_PREFIX}{r.error};{r.derived}"))
        else:
            out.append((f"{tag}.{r.name}", r.us_per_call, derive(r)))
    return out
