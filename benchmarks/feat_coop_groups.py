"""§V-B Cooperative Groups analogue: SRAD fused vs split phases.

The paper's cooperative kernel fuses SRAD's two phases around a grid sync;
ours fuses them in VMEM (`kernels.srad_stencil`). On the CPU validation
host, the comparison uses the same structure at the XLA level: one jitted
program (phases fused by XLA) vs two jitted programs with a materialized
coefficient array between them (the two-launch HBM round-trip). The static
bytes ratio is reported alongside wall time.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import Row
from repro.core.harness import time_fn
from repro.kernels.ref import _srad_coeff, srad_step_ref


def _split_phase1(img):
    c, _ = _srad_coeff(img, jnp.float32(0.05))
    return c


def _split_phase2(img, c):
    _, (dN, dS, dW, dE) = _srad_coeff(img, jnp.float32(0.05))
    cS = jnp.concatenate([c[1:], c[-1:]], axis=0)
    cE = jnp.concatenate([c[:, 1:], c[:, -1:]], axis=1)
    return img + 0.25 * 0.5 * (c * dN + cS * dS + c * dW + cE * dE)


def rows() -> list[Row]:
    out: list[Row] = []
    fused = jax.jit(srad_step_ref)
    p1 = jax.jit(_split_phase1)
    p2 = jax.jit(_split_phase2)
    for n in (128, 256, 512, 1024):
        img = jnp.exp(0.1 * jax.random.normal(jax.random.key(0), (n, n)))
        us_fused, _ = time_fn(fused, (img,), iters=5, warmup=2)

        def split(img=img):
            return p2(img, p1(img))

        us_split, _ = time_fn(lambda: split(), (), iters=5, warmup=2)
        out.append(
            (
                f"feat_cg.srad.{n}x{n}",
                us_fused,
                f"fused_us={us_fused:.1f};split_us={us_split:.1f};"
                f"fused_speedup={us_split / max(us_fused, 1e-9):.2f}",
            )
        )
    return out
