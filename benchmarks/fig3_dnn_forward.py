"""Fig. 3: DNN forward-kernel utilization (the paper's cuDNN forward set)."""

from __future__ import annotations

from benchmarks.common import Row, record_rows
from repro.core import run_suite

DNN = [
    "activation", "pooling", "batchnorm", "connected", "convolution_xla",
    "convolution_im2col", "dropout", "rnn", "softmax", "lrn",
]


def rows(preset: int = 0, backward: bool = False) -> list[Row]:
    records = run_suite(
        names=DNN, preset=preset, iters=3, warmup=1,
        include_backward=backward, verbose=False,
    )
    tag = "fig4" if backward else "fig3"
    # Keep the pass this figure covers — but always keep error records (a
    # build/compile failure has no .bwd row, and hiding it would fake a
    # clean section).
    records = [
        r
        for r in records
        if backward == r.name.endswith(".bwd") or r.status != "ok"
    ]
    return record_rows(
        tag,
        records,
        lambda r: (
            f"compute10={r.compute_util10};memory10={r.memory_util10};"
            f"dominant={r.dominant};gflops={r.achieved_gflops:.2f}"
        ),
    )
