"""Fig. 5 analogue: utilization characterization of the Mirovia suite.

The paper samples nvprof functional-unit utilization (0–10); we report the
compute/memory roofline split (0–10 bars) from the compiled HLO plus the
measured wall time per benchmark — same plot semantics, deterministic
methodology (DESIGN.md §2).
"""

from __future__ import annotations

from benchmarks.common import Row, record_rows
from repro.core import run_suite

_LEVEL2 = [
    "cfd", "dwt2d_53", "dwt2d_97", "kmeans", "lavamd", "mandelbrot_flat",
    "mandelbrot_ms", "nw", "particlefilter", "srad", "where",
]


def rows(preset: int = 0) -> list[Row]:
    records = run_suite(
        names=_LEVEL2, preset=preset, iters=3, warmup=1,
        include_backward=False, verbose=False,
    )
    return record_rows(
        "fig5",
        records,
        lambda r: (
            f"compute10={r.compute_util10};memory10={r.memory_util10};"
            f"dominant={r.dominant};gflops={r.achieved_gflops:.2f}"
        ),
    )
