"""Continuous-batching figure: loop vs lanes vs dynamic batcher goodput
at the *same* offered mixed-shape load.

The serving subsystem's batcher study: a seeded weighted shape-bucket mix
is sampled once into a request trace, saved to disk, and then *replayed*
for every dispatch policy — so ``loop`` (sync, one program per request),
``lanes`` (async dispatch windows), fixed ``batched``, and the ``dynamic``
coalescing batcher all face byte-identical arrivals at the same offered
QPS. What differs is purely how requests map onto device programs, which
is exactly what the goodput / p99 / occupancy columns compare.

Padding is measured, not hidden: the dynamic batcher pads short batches up
to the next compiled width, and every row carries ``occupancy`` (filled /
dispatched slots) and ``padding_waste`` (1 - occupancy) so wasted device
work is visible next to the latency it bought.

All dispatch modes share one engine: the shape-bucket executables are
compiled once through the ordinary compile cache (width-1 buckets reuse
the measure stage's executable outright) and reused across every mode;
with ``--cache-dir`` the two-tier artifact cache makes warm reruns
zero-XLA-compile across *all* buckets and widths.

As a section (``benchmarks/run.py --sections fig_batching``) it emits the
standard CSV rows; as a script it renders the comparison table, and
``--json PATH`` additionally writes the machine-readable comparison (the
``tools/smoke.sh --bench`` artifact).
"""

from __future__ import annotations

import json
import os
import sys
import tempfile

if __package__ in (None, ""):  # `python benchmarks/fig_batching.py`
    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.common import Row, parse_derived, record_rows
from repro.core import run_suite
from repro.core.plan import SERVE_DISPATCH, ServeSpec, ShapeBucket

DEFAULT_NAME = "pathfinder"
# Two shapes of the same workload, 2:1 — the smallest mix that still
# exercises per-bucket executables, routing, and padding. Narrow cols keep
# the scan overhead-dominated, so a width-8 vmap costs little more than a
# single call — the regime where coalescing buys real throughput.
DEFAULT_MIX = (
    ShapeBucket(preset=0, weight=2.0, overrides=(("cols", 64),)),
    ShapeBucket(preset=0, weight=1.0, overrides=(("cols", 128),)),
)
# loop is the floor, lanes the async middle ground, dynamic the batcher.
DEFAULT_DISPATCHES = ("loop", "lanes", "dynamic")
FAST = dict(iters=1, warmup=0, include_backward=False, verbose=False)


def rows(
    preset: int = 0,
    name: str = DEFAULT_NAME,
    mix=DEFAULT_MIX,
    dispatches=DEFAULT_DISPATCHES,
    qps: float = 45_000.0,
    duration_s: float = 0.7,
    slo_us: float = 20_000.0,
    budget_us: float = 1_000.0,
    max_batch: int = 8,
    concurrency: int = 16,
    lanes: int = 4,
    seed: int = 0,
    trace: str | None = None,
    engine=None,
) -> list[Row]:
    """One row per dispatch policy, all replaying the same mixed-shape
    trace at the same offered QPS. The first policy generates (and saves)
    the trace; every later one replays it, so the comparison is over
    byte-identical arrivals."""
    out: list[Row] = []
    tmp = None
    if trace is None:
        tmp = tempfile.TemporaryDirectory(prefix="fig_batching_")
        trace = os.path.join(tmp.name, "mix_trace.jsonl")
    try:
        for dispatch in dispatches:
            serve = ServeSpec(
                mode="open", qps=qps, duration_s=duration_s,
                concurrency=concurrency, lanes=lanes, slo_us=slo_us,
                dispatch=dispatch, mix=tuple(mix), trace=trace,
                batch_budget_us=budget_us, max_batch=max_batch,
            )
            records = run_suite(
                names=[name], preset=preset, serve=serve, seed=seed,
                engine=engine, **FAST,
            )

            def extra(r, dispatch=dispatch):
                buckets = "/".join(
                    f"{label}:p99={b['p99_us']:.0f}"
                    for label, b in sorted((r.bucket_latency_us or {}).items())
                )
                return (
                    f"dispatch={dispatch};qps={r.achieved_qps:.1f};"
                    f"goodput_qps={r.goodput_qps:.1f};"
                    f"p50_us={r.latency_p50_us:.1f};"
                    f"p99_us={r.latency_p99_us:.1f};"
                    f"occupancy={r.batch_occupancy:.3f};"
                    f"padding_waste={r.padding_waste:.3f};"
                    f"batches={r.serve_batches};buckets={buckets};"
                )

            out.extend(
                (f"{n}.{dispatch}", us, derived)
                for n, us, derived in record_rows("fig_batching", records, extra)
            )
    finally:
        if tmp is not None:
            tmp.cleanup()
    return out


def main() -> int:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--preset", type=int, default=0)
    ap.add_argument("--name", default=DEFAULT_NAME)
    ap.add_argument("--mix", default=None,
                    metavar="P[/K=V...][@W],...",
                    help="weighted shape buckets (suite --serve-mix grammar); "
                         "default: preset twice-weighted vs a cols=256 variant")
    ap.add_argument("--dispatches", nargs="*", default=list(DEFAULT_DISPATCHES),
                    choices=list(SERVE_DISPATCH))
    ap.add_argument("--qps", type=float, default=45_000.0,
                    help="offered load, identical for every dispatch policy "
                         "(default sits past loop saturation but inside the "
                         "batcher's capacity, where coalescing shows)")
    ap.add_argument("--duration", type=float, default=0.7)
    ap.add_argument("--slo-us", type=float, default=20_000.0)
    ap.add_argument("--budget-us", type=float, default=1_000.0,
                    help="dynamic batcher coalescing latency budget")
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace", default=None,
                    help="trace path: generated+saved on first use, replayed "
                         "after (default: a throwaway temp file)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the comparison as JSON (BENCH artifact)")
    ap.add_argument("--cache-dir", type=str, default=None,
                    help="two-tier artifact cache: a warm dir restores every "
                         "bucket/width executable with zero XLA compiles")
    args = ap.parse_args()

    from repro.core.engine import Engine
    from repro.core.suite import DEFAULT_ENGINE, _parse_mix

    mix = _parse_mix(args.mix) if args.mix else DEFAULT_MIX
    engine = Engine(cache_dir=args.cache_dir) if args.cache_dir else DEFAULT_ENGINE
    table = rows(
        preset=args.preset, name=args.name, mix=mix,
        dispatches=tuple(args.dispatches), qps=args.qps,
        duration_s=args.duration, slo_us=args.slo_us,
        budget_us=args.budget_us, max_batch=args.max_batch,
        seed=args.seed, trace=args.trace, engine=engine,
    )
    ok = [row for row in table if "goodput_qps=" in row[2]]
    if not ok:
        print(
            f"fig_batching: no ok serve records out of {len(table)} rows; "
            "see stderr for per-benchmark errors",
            file=sys.stderr,
        )
        return 1

    print(
        f"# offered load: {args.qps:.0f} qps, mix "
        + ",".join(f"{b.label}@{b.weight:g}" for b in mix)
        + f", slo {args.slo_us:.0f}us, budget {args.budget_us:.0f}us",
        file=sys.stderr,
    )
    header = (
        f"{'dispatch':<10}{'qps':>10}{'goodput':>10}{'p50_us':>10}"
        f"{'p99_us':>10}{'occupancy':>11}{'padding':>9}{'batches':>9}"
    )
    print(header)
    modes: dict[str, dict] = {}
    for _name, _us, derived in ok:
        f = parse_derived(derived)
        d = f["dispatch"]
        modes[d] = {
            "achieved_qps": float(f["qps"]),
            "goodput_qps": float(f["goodput_qps"]),
            "p50_us": float(f["p50_us"]),
            "p99_us": float(f["p99_us"]),
            "occupancy": float(f["occupancy"]),
            "padding_waste": float(f["padding_waste"]),
            "batches": int(f["batches"]),
        }
        m = modes[d]
        print(
            f"{d:<10}{m['achieved_qps']:>10.1f}{m['goodput_qps']:>10.1f}"
            f"{m['p50_us']:>10.1f}{m['p99_us']:>10.1f}"
            f"{m['occupancy']:>11.3f}{m['padding_waste']:>9.3f}"
            f"{m['batches']:>9d}"
        )
    if "loop" in modes and "dynamic" in modes and modes["loop"]["goodput_qps"]:
        ratio = modes["dynamic"]["goodput_qps"] / modes["loop"]["goodput_qps"]
        print(f"# dynamic/loop goodput: {ratio:.2f}x", file=sys.stderr)

    if engine.disk_cache is not None:
        print(f"# {engine.disk_cache.summary()}", file=sys.stderr)

    if args.json:
        import jax

        payload = {
            "kind": "fig_batching",
            "backend": jax.default_backend(),
            "jax_version": jax.__version__,
            "name": args.name,
            "mix": ",".join(f"{b.label}@{b.weight:g}" for b in mix),
            "offered_qps": args.qps,
            "duration_s": args.duration,
            "slo_us": args.slo_us,
            "budget_us": args.budget_us,
            "max_batch": args.max_batch,
            "seed": args.seed,
            "modes": modes,
        }
        if "loop" in modes and "dynamic" in modes and modes["loop"]["goodput_qps"]:
            payload["dynamic_over_loop_goodput"] = round(
                modes["dynamic"]["goodput_qps"] / modes["loop"]["goodput_qps"], 3
            )
        os.makedirs(os.path.dirname(os.path.abspath(args.json)), exist_ok=True)
        with open(args.json, "w") as fh:
            json.dump(payload, fh, indent=2, sort_keys=True)
            fh.write("\n")
        print(f"# wrote {args.json}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
