"""Table I: the suite listing — level, dwarf, application domain, modern
feature (CUDA in the paper, TPU analogue here) per benchmark."""

from __future__ import annotations

from benchmarks.common import Row
from repro.core.registry import all_benchmarks


def rows() -> list[Row]:
    out: list[Row] = []
    for s in all_benchmarks():
        derived = (
            f"level={s.level};dwarf={s.dwarf or '-'};domain={s.domain or '-'};"
            f"cuda_feature={s.cuda_feature or '-'};tpu_feature={s.tpu_feature or '-'};"
            f"presets={len(s.presets)}"
        )
        out.append((f"table1.{s.name}", 0.0, derived))
    return out
