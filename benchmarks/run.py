"""Benchmark driver: one section per paper table/figure (DESIGN.md §7).

Prints ``name,us_per_call,derived`` CSV. Sections:
  table1   — suite listing (Table I)
  fig12    — level 0/1 utilization (Figs. 1–2 analogue)
  fig3/4   — DNN forward/backward utilization
  fig5     — application-tier utilization (Fig. 5)
  table2   — per-layer kernel classification (Table II)
  feat_*   — §V-B modern-feature studies (HyperQ / UM / CG / DP analogues)
  roofline — §Roofline table from the multi-pod dry-run artifacts
"""

from __future__ import annotations

import argparse
import sys
import time
import traceback


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--sections", nargs="*", default=None,
                    help="subset of sections to run")
    ap.add_argument("--preset", type=int, default=0)
    args = ap.parse_args(argv)

    from benchmarks import (
        feat_coop_groups,
        feat_dynamic_parallelism,
        feat_hyperq,
        feat_unified_memory,
        fig3_dnn_forward,
        fig4_dnn_backward,
        fig5_suite_utilization,
        fig12_legacy_utilization,
        roofline_table,
        table1_suite,
        table2_dnn_kernels,
    )

    sections = {
        "table1": lambda: table1_suite.rows(),
        "fig12": lambda: fig12_legacy_utilization.rows(preset=args.preset),
        "fig3": lambda: fig3_dnn_forward.rows(preset=args.preset),
        "fig4": lambda: fig4_dnn_backward.rows(preset=args.preset),
        "fig5": lambda: fig5_suite_utilization.rows(preset=args.preset),
        "table2": lambda: table2_dnn_kernels.rows(preset=max(args.preset, 1)),
        "feat_hyperq": feat_hyperq.rows,
        "feat_unified_memory": feat_unified_memory.rows,
        "feat_coop_groups": feat_coop_groups.rows,
        "feat_dynamic_parallelism": feat_dynamic_parallelism.rows,
        "roofline": lambda: roofline_table.rows("single")
        + roofline_table.rows("multi"),
    }
    selected = args.sections or list(sections)
    print("name,us_per_call,derived")
    failures = 0
    for name in selected:
        t0 = time.time()
        try:
            for n, us, d in sections[name]():
                print(f"{n},{us:.2f},{d}", flush=True)
        except Exception:  # noqa: BLE001
            failures += 1
            traceback.print_exc()
            print(f"{name}.FAILED,0.00,error", flush=True)
        print(
            f"# section {name} done in {time.time() - t0:.1f}s",
            file=sys.stderr, flush=True,
        )
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
