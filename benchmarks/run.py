"""Benchmark driver: one section per paper table/figure (DESIGN.md §7).

Prints ``name,us_per_call,derived`` CSV. Sections:
  table1   — suite listing (Table I)
  fig12    — level 0/1 utilization (Figs. 1–2 analogue)
  fig3/4   — DNN forward/backward utilization
  fig5     — application-tier utilization (Fig. 5)
  fig_scaling — device-scaling sweep (sharded data-parallel placement)
  fig_concurrency — dispatch-lane speedup + co-location interference
  fig_batching — continuous batching: loop vs lanes vs dynamic goodput
  fig_dist — distributed load generation: 1 vs N client processes
  fig_impl — XLA vs Pallas implementation axis (autotuned block sizes)
  fig_trace — per-stage engine time breakdown (obs layer, schema v8)
  table2   — per-layer kernel classification (Table II)
  feat_*   — §V-B modern-feature studies (HyperQ / UM / CG / DP analogues)
  roofline — §Roofline table from the multi-pod dry-run artifacts

Suite-backed sections (fig12/3/4/5) run through the staged engine via
``run_suite``: one shared compile cache across sections (fig4 reuses fig3's
builds) and per-benchmark fault isolation inside each section. The
try/except here is only a last-resort guard for the non-suite sections.
"""

from __future__ import annotations

import argparse
import os
import sys
import time
import traceback

if __package__ in (None, ""):  # `python benchmarks/run.py` (vs -m benchmarks.run)
    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

SECTION_NAMES = (
    "table1",
    "fig12",
    "fig3",
    "fig4",
    "fig5",
    "fig_scaling",
    "fig_concurrency",
    "fig_batching",
    "fig_dist",
    "fig_impl",
    "fig_trace",
    "table2",
    "feat_hyperq",
    "feat_unified_memory",
    "feat_coop_groups",
    "feat_dynamic_parallelism",
    "roofline",
)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--sections", nargs="*", default=None,
                    help=f"subset of sections to run; valid: {', '.join(SECTION_NAMES)}")
    ap.add_argument("--preset", type=int, default=0)
    args = ap.parse_args(argv)

    selected = args.sections or list(SECTION_NAMES)
    unknown = [s for s in selected if s not in SECTION_NAMES]
    if unknown:
        print(f"unknown section(s): {', '.join(unknown)}", file=sys.stderr)
        print(f"valid sections: {', '.join(SECTION_NAMES)}", file=sys.stderr)
        return 2

    # Imported after validation so a bad --sections fails fast, before jax.
    from benchmarks import (
        feat_coop_groups,
        feat_dynamic_parallelism,
        feat_hyperq,
        feat_unified_memory,
        fig3_dnn_forward,
        fig4_dnn_backward,
        fig5_suite_utilization,
        fig12_legacy_utilization,
        fig_batching,
        fig_concurrency,
        fig_dist,
        fig_impl,
        fig_scaling,
        fig_trace,
        roofline_table,
        table1_suite,
        table2_dnn_kernels,
    )

    sections = {
        "table1": lambda: table1_suite.rows(),
        "fig12": lambda: fig12_legacy_utilization.rows(preset=args.preset),
        "fig3": lambda: fig3_dnn_forward.rows(preset=args.preset),
        "fig4": lambda: fig4_dnn_backward.rows(preset=args.preset),
        "fig5": lambda: fig5_suite_utilization.rows(preset=args.preset),
        "fig_scaling": lambda: fig_scaling.rows(preset=args.preset),
        "fig_concurrency": lambda: fig_concurrency.rows(preset=args.preset),
        "fig_batching": lambda: fig_batching.rows(preset=args.preset),
        "fig_dist": lambda: fig_dist.rows(preset=args.preset),
        "fig_impl": lambda: fig_impl.rows(preset=args.preset),
        "fig_trace": lambda: fig_trace.rows(preset=args.preset),
        "table2": lambda: table2_dnn_kernels.rows(preset=max(args.preset, 1)),
        "feat_hyperq": feat_hyperq.rows,
        "feat_unified_memory": feat_unified_memory.rows,
        "feat_coop_groups": feat_coop_groups.rows,
        "feat_dynamic_parallelism": feat_dynamic_parallelism.rows,
        "roofline": lambda: roofline_table.rows("single")
        + roofline_table.rows("multi")
        + roofline_table.rows_from_latest_report(),
    }
    # SECTION_NAMES exists so --sections validates before the jax imports
    # above; keep the two in sync.
    assert set(sections) == set(SECTION_NAMES), "update SECTION_NAMES"
    from benchmarks.common import ERROR_PREFIX

    print("name,us_per_call,derived")
    failures = 0
    for name in selected:
        t0 = time.time()
        try:
            for n, us, d in sections[name]():
                if d.startswith(ERROR_PREFIX):  # engine fault-isolated row
                    failures += 1
                    print(f"# ERROR {n}: {d}", file=sys.stderr, flush=True)
                print(f"{n},{us:.2f},{d}", flush=True)
        except Exception:  # noqa: BLE001
            failures += 1
            traceback.print_exc()
            print(f"{name}.FAILED,0.00,error", flush=True)
        print(
            f"# section {name} done in {time.time() - t0:.1f}s",
            file=sys.stderr, flush=True,
        )
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
