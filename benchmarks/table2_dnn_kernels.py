"""Table II analogue: per-DNN-layer kernel classification.

The paper maps each layer to its cuDNN kernel and classifies convolution as
compute-bound vs batch-norm as memory-bound from IPC/eligible-warp metrics
(§V-A). Here each layer maps to its TPU kernel (Pallas or XLA op) and the
classification falls out of the roofline terms — the reproduction check is
that convolution lands compute-dominant and batchnorm memory-dominant.
"""

from __future__ import annotations

from benchmarks.common import Row
from repro.core import ExecutionPlan
from repro.core.registry import get_benchmark
from repro.core.suite import DEFAULT_ENGINE

_KERNEL_MAP = {
    "activation": ("xla:relu-fusion", "elementwise"),
    "pooling": ("pallas:avgpool reshape-reduce", "reduce"),
    "batchnorm": ("xla:bn-fusion", "stats+scale"),
    "connected": ("pallas:matmul (MXU)", "gemm"),
    "convolution_xla": ("xla:conv (MXU)", "conv"),
    "convolution_im2col": ("pallas:matmul via im2col", "gemm"),
    "dropout": ("xla:threefry fusion", "prng+mask"),
    "rnn": ("xla:while(fused-gate gemm)", "scan-gemm"),
    "softmax": ("pallas:online-softmax", "rowreduce"),
    "lrn": ("pallas:banded-matmul (MXU)", "band-gemm"),
}


def rows(preset: int = 1) -> list[Row]:
    # Characterize-only flow through the shared engine: compiled executables
    # are cached alongside the fig3/fig4 runs of the same preset.
    plan = ExecutionPlan(preset=preset)
    out: list[Row] = []
    for name, (kernel, kind) in _KERNEL_MAP.items():
        spec = get_benchmark(name)
        w = spec.build_preset(plan.resolve_preset(spec))
        for backward in (False, True):
            if backward and w.fn_bwd is None:
                continue
            info = DEFAULT_ENGINE.characterize(spec, plan, backward=backward, workload=w)
            r = info.roofline
            out.append(
                (
                    f"table2.{name}{'.bwd' if backward else ''}",
                    0.0,
                    f"kernel={kernel};class={kind};dominant={r.dominant};"
                    f"ai={r.arithmetic_intensity():.2f};"
                    f"flops={r.flops:.3e};bytes={r.hbm_bytes:.3e}",
                )
            )
    return out
