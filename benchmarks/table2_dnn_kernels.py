"""Table II analogue: per-DNN-layer kernel classification.

The paper maps each layer to its cuDNN kernel and classifies convolution as
compute-bound vs batch-norm as memory-bound from IPC/eligible-warp metrics
(§V-A). Here each layer maps to its TPU kernel and the classification falls
out of the roofline terms — the reproduction check is that convolution
lands compute-dominant and batchnorm memory-dominant.

Since PR 6 the kernel column is the engine's ``impl`` axis, not a static
label: layers with a Pallas variant get one row per implementation (the
XLA/reference lowering and the hand-tiled kernel), both characterized
through ``DEFAULT_ENGINE`` so the compiled executables are cached
alongside the fig3/fig4 runs of the same preset. Pallas backward rows are
skipped — the engine falls back to xla for backward passes, so the row
would duplicate its xla twin.
"""

from __future__ import annotations

from benchmarks.common import Row
from repro.core import ExecutionPlan
from repro.core.registry import get_benchmark
from repro.core.suite import DEFAULT_ENGINE

# name -> (xla kernel label, pallas kernel label or None, classification).
_KERNEL_MAP = {
    "activation": ("xla:relu-fusion", None, "elementwise"),
    "pooling": ("xla:reshape-mean", "pallas:avgpool reshape-reduce", "reduce"),
    "batchnorm": ("xla:bn-fusion", None, "stats+scale"),
    "connected": ("xla:dot (MXU)", "pallas:matmul (MXU)", "gemm"),
    "convolution_xla": ("xla:conv (MXU)", None, "conv"),
    "convolution_im2col": ("xla:dot via im2col", "pallas:matmul via im2col", "gemm"),
    "dropout": ("xla:threefry fusion", None, "prng+mask"),
    "rnn": ("xla:while(fused-gate gemm)", None, "scan-gemm"),
    "softmax": ("xla:rowreduce fusion", "pallas:online-softmax", "rowreduce"),
    "lrn": ("xla:banded-matmul fusion", "pallas:banded-matmul (MXU)", "band-gemm"),
}


def rows(preset: int = 1) -> list[Row]:
    out: list[Row] = []
    for name, (xla_kernel, pallas_kernel, kind) in _KERNEL_MAP.items():
        spec = get_benchmark(name)
        impls = ("xla",) if pallas_kernel is None else ("xla", "pallas")
        for impl in impls:
            plan = ExecutionPlan(preset=preset, impl=impl)
            w = spec.build_preset(plan.resolve_preset(spec))
            kernel = pallas_kernel if impl == "pallas" else xla_kernel
            for backward in (False, True):
                if backward and (w.fn_bwd is None or impl == "pallas"):
                    continue
                info = DEFAULT_ENGINE.characterize(
                    spec, plan, backward=backward, workload=w
                )
                r = info.roofline
                suffix = ".pallas" if impl == "pallas" else ""
                out.append(
                    (
                        f"table2.{name}{suffix}{'.bwd' if backward else ''}",
                        0.0,
                        f"kernel={kernel};class={kind};impl={impl};"
                        f"dominant={r.dominant};"
                        f"ai={r.arithmetic_intensity():.2f};"
                        f"flops={r.flops:.3e};bytes={r.hbm_bytes:.3e}",
                    )
                )
    return out
