"""Fig. 4: DNN backward-kernel utilization (gradients w.r.t. inputs+weights)."""

from __future__ import annotations

from benchmarks.common import Row
from benchmarks.fig3_dnn_forward import rows as _fwd_rows


def rows(preset: int = 0) -> list[Row]:
    return _fwd_rows(preset=preset, backward=True)
