"""§V-B Unified Memory analogue: BFS with staged vs prefetched graphs.

The paper compares BFS with explicit copies vs unified memory (± advice,
± prefetch) and finds demand paging only wins once prefetch is added. The
JAX analogue: per-call ``device_put`` of a host-resident graph (demand
staging) vs ahead-of-time prefetch (`core.features.Prefetcher`, transfer
overlapped with the previous iteration's compute) vs device-resident.
"""

from __future__ import annotations

import numpy as np

import jax

from benchmarks.common import Row
from repro.bench.level1.bfs import bfs_depths, make_random_graph
from repro.core.features import Prefetcher
from repro.core.harness import time_fn


def rows() -> list[Row]:
    out: list[Row] = []
    for n_nodes, n_edges in ((1 << 10, 1 << 13), (1 << 13, 1 << 16), (1 << 15, 1 << 18)):
        src_h, dst_h = make_random_graph(n_nodes, n_edges, seed=0)
        fn = jax.jit(lambda s, d, n=n_nodes: bfs_depths(n, s, d, 0))

        # demand staging: H2D on every call
        def demand():
            return fn(jax.device_put(src_h), jax.device_put(dst_h))

        us_demand, _ = time_fn(lambda: demand(), (), iters=5, warmup=2)

        # prefetched: next graph staged while current runs
        pf = Prefetcher()
        pf.prefetch("g", (src_h, dst_h))

        def prefetched():
            s, d = pf.get("g")
            res = fn(s, d)
            pf.prefetch("g", (src_h, dst_h))
            return res

        us_prefetch, _ = time_fn(lambda: prefetched(), (), iters=5, warmup=2)

        # device-resident baseline (explicit-copy-once, the paper's baseline)
        src_d, dst_d = jax.device_put(src_h), jax.device_put(dst_h)
        us_resident, _ = time_fn(fn, (src_d, dst_d), iters=5, warmup=2)

        out.append(
            (
                f"feat_um.bfs.n{n_nodes}",
                us_resident,
                f"demand_us={us_demand:.1f};prefetch_us={us_prefetch:.1f};"
                f"resident_us={us_resident:.1f};"
                f"prefetch_speedup_vs_demand={us_demand / max(us_prefetch, 1e-9):.2f}",
            )
        )
    return out
