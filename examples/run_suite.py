"""Run the full Mirovia/Altis suite — the paper's headline artifact.

Level 0 microbenchmarks through the DNN section (forward + backward), with
SHOC-style presets and Rodinia-style overrides, producing the utilization
table + a JSON report.

Usage:
  PYTHONPATH=src python examples/run_suite.py [--preset 0..4] [--levels 0 1 2]
  PYTHONPATH=src python examples/run_suite.py --names kmeans srad --preset 2
"""

import argparse

from repro.core import run_suite
from repro.core.results import to_csv_lines


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--preset", type=int, default=0)
    ap.add_argument("--levels", type=int, nargs="*", default=[0, 1, 2])
    ap.add_argument("--names", nargs="*", default=None)
    ap.add_argument("--report", default="artifacts/suite_report.json")
    ap.add_argument("--iters", type=int, default=5)
    args = ap.parse_args()
    records = run_suite(
        levels=tuple(args.levels), names=args.names, preset=args.preset,
        iters=args.iters, warmup=2, report_path=args.report, verbose=False,
    )
    print(f"{'benchmark':<34}{'us/call':>12}  {'compute':<12}{'memory':<12}dominant")
    for r in records:
        print(
            f"{r.name:<34}{r.us_per_call:>12.1f}  "
            f"|{'#' * r.compute_util10:<10}| |{'#' * r.memory_util10:<10}| {r.dominant}"
        )
    print(f"\n{len(records)} rows; report: {args.report}")
    for line in to_csv_lines(records)[:5]:
        print(line)


if __name__ == "__main__":
    main()
