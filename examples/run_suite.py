"""Run the full Mirovia/Altis suite — the paper's headline artifact.

Level 0 microbenchmarks through the DNN section (forward + backward), with
SHOC-style presets and Rodinia-style overrides, producing the utilization
table + a JSON report. Runs through the staged engine (build → compile →
measure → characterize → report): each workload is compiled exactly once
per pass, failures are isolated per benchmark, and ``--jsonl`` streams
records (with run metadata) as they finish.

Usage:
  PYTHONPATH=src python examples/run_suite.py [--preset 0..4] [--levels 0 1 2]
  PYTHONPATH=src python examples/run_suite.py --names kmeans srad --preset 2
  PYTHONPATH=src python examples/run_suite.py --jsonl artifacts/suite.jsonl
"""

import argparse

from repro.core import Engine, ExecutionPlan, Placement
from repro.core.plan import PLACEMENT_MODES
from repro.core.results import to_csv_lines


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--preset", type=int, default=0)
    ap.add_argument("--levels", type=int, nargs="*", default=[0, 1, 2])
    ap.add_argument("--names", nargs="*", default=None)
    ap.add_argument("--report", default="artifacts/suite_report.json")
    ap.add_argument("--jsonl", default=None, help="streaming JSONL report path")
    ap.add_argument("--iters", type=int, default=5)
    ap.add_argument("--devices", type=int, default=1,
                    help="run on the first N devices")
    ap.add_argument("--placement", choices=PLACEMENT_MODES, default="replicate",
                    help="replicate inputs or shard declared batch dims")
    args = ap.parse_args()
    plan = ExecutionPlan(
        levels=tuple(args.levels),
        names=tuple(args.names) if args.names else None,
        preset=args.preset,
        iters=args.iters,
        warmup=2,
        placement=Placement(devices=args.devices, mode=args.placement),
    )
    engine = Engine()
    result = engine.run(plan, report_path=args.report, jsonl_path=args.jsonl)
    print(f"{'benchmark':<34}{'us/call':>12}  {'compute':<12}{'memory':<12}dominant")
    for r in result.records:
        if r.status != "ok":
            print(f"{r.name:<34}{'ERROR':>12}  {r.error[:60]}")
            continue
        print(
            f"{r.name:<34}{r.us_per_call:>12.1f}  "
            f"|{'#' * r.compute_util10:<10}| |{'#' * r.memory_util10:<10}| {r.dominant}"
        )
    meta = result.metadata
    print(
        f"\n{len(result.records)} rows ({len(result.error_records)} errors); "
        f"backend={meta.backend} devices={meta.devices}/{meta.device_count} "
        f"compiles={engine.cache.misses} cache_hits={engine.cache.hits}; "
        f"report: {args.report}" + (f" jsonl: {args.jsonl}" if args.jsonl else "")
    )
    for line in to_csv_lines(result.records)[:5]:
        print(line)


if __name__ == "__main__":
    main()
