"""Reproduce the paper's §V-B modern-feature studies in one command.

Runs all four feature analogues (HyperQ, Unified Memory, Cooperative
Groups, Dynamic Parallelism — DESIGN.md §2 explains each mapping) and
prints the speedup curves the paper plots.

Usage: PYTHONPATH=src python examples/feature_analysis.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))  # benchmarks/

from benchmarks import (
    feat_coop_groups,
    feat_dynamic_parallelism,
    feat_hyperq,
    feat_unified_memory,
)

SECTIONS = [
    ("HyperQ → batched occupancy (Pathfinder)", feat_hyperq.rows),
    ("Unified Memory → staging vs prefetch (BFS)", feat_unified_memory.rows),
    ("Cooperative Groups → fused stencil (SRAD)", feat_coop_groups.rows),
    ("Dynamic Parallelism → adaptive tiles (Mandelbrot)", feat_dynamic_parallelism.rows),
]


def main() -> None:
    for title, fn in SECTIONS:
        print(f"\n=== {title} ===")
        for name, us, derived in fn():
            print(f"  {name:<28} {us:>12.1f} us   {derived}")


if __name__ == "__main__":
    main()
