"""Quickstart: the three things the framework does, in 60 seconds on a CPU.

1. Run a slice of the Mirovia/Altis suite and print the Fig-5-style table.
2. Train a tiny LM for a few steps (loss goes down).
3. Serve it with batched greedy decoding.

Usage: PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import run_suite
from repro.launch.serve import serve
from repro.launch.train import train


def main() -> None:
    print("=== 1. Mirovia suite slice (preset 0) ===")
    records = run_suite(
        names=["gemm_bf16_nn", "srad", "where", "softmax"],
        preset=0, iters=3, warmup=1, verbose=False,
    )
    for r in records:
        if r.status != "ok":
            print(f"  {r.name:<28} ERROR: {r.error}")
            continue
        print(
            f"  {r.name:<28} {r.us_per_call:>10.1f} us  "
            f"compute|{'#' * r.compute_util10:<10}| memory|{'#' * r.memory_util10:<10}|"
        )

    print("=== 2. Train a small qwen-family LM ===")
    out = train(arch="qwen1.5-0.5b", smoke=True, steps=40, batch=8, seq=32,
                lr=2e-3, log_every=10)
    print(f"  loss {out['first_loss']:.3f} -> {out['final_loss']:.3f} "
          f"in {out['wall_s']:.1f}s")

    print("=== 3. Serve it (batched greedy decode) ===")
    stats = serve(arch="qwen1.5-0.5b", smoke=True, n_requests=4, batch=2,
                  prompt_len=8, gen_len=8, max_len=24)
    print(f"  {stats.tokens_per_s:.0f} tok/s over {stats.requests} requests")
    print(f"  sample output tokens: {stats.outputs[0]}")


if __name__ == "__main__":
    main()
