"""Batched-serving example: prefill + ring-cache decode on a MoE+SWA arch.

Serves the mixtral-family smoke config (sliding-window attention exercises
the ring-buffer KV cache; MoE exercises expert dispatch at batch size 1 per
token). Reports prefill/decode token throughput.

Usage: PYTHONPATH=src python examples/serve_lm.py [--arch <id>] [--requests N]
"""

import argparse

from repro.configs import ARCHS
from repro.launch.serve import serve


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCHS, default="mixtral-8x22b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen-len", type=int, default=24)
    args = ap.parse_args()
    stats = serve(
        arch=args.arch, smoke=True, n_requests=args.requests, batch=args.batch,
        prompt_len=args.prompt_len, gen_len=args.gen_len,
        max_len=args.prompt_len + args.gen_len + 8,
    )
    print(
        f"[serve_lm] {args.arch}: {stats.requests} requests | "
        f"{stats.prefill_tokens} prefill + {stats.decoded_tokens} decode tokens | "
        f"{stats.wall_s:.2f}s | {stats.tokens_per_s:.0f} tok/s"
    )
    for i, toks in enumerate(stats.outputs[:3]):
        print(f"  request {i}: {toks[:12]}...")


if __name__ == "__main__":
    main()
