"""End-to-end training driver example ((b) deliverable).

Default: a ~5M-param qwen-family model for 200 steps on synthetic data —
finishes in minutes on one CPU core, with checkpoints and exact resume.
``--size 100m --steps 300`` is the assignment-scale run (~110M params,
a few hundred steps) for real hardware; the driver is identical.

Usage:
  PYTHONPATH=src python examples/train_lm.py [--size 5m|25m|100m] [--steps N]
  PYTHONPATH=src python examples/train_lm.py --resume   # continue last run
"""

import argparse
import dataclasses
import functools
import time

import jax

from repro.checkpoint import Checkpointer
from repro.data import Prefetch, SyntheticLM
from repro.models import Model
from repro.models.config import ArchConfig
from repro.optim import AdamW
from repro.optim.schedule import warmup_cosine
from repro.runtime.steps import make_train_step
from repro.runtime.straggler import StragglerMonitor

SIZES = {
    # name: (layers, d_model, heads, kv, d_ff, vocab) — ~5M / ~25M / ~110M
    "5m": (4, 256, 4, 2, 704, 4096),
    "25m": (8, 512, 8, 4, 1408, 8192),
    "100m": (12, 768, 12, 4, 2048, 32768),
}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--size", choices=SIZES, default="5m")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    L, d, h, kv, ff, v = SIZES[args.size]
    cfg = ArchConfig(
        name=f"train-lm-{args.size}", family="dense", n_layers=L, d_model=d,
        n_heads=h, n_kv_heads=kv, head_dim=d // h, d_ff=ff, vocab=v,
        dtype="float32",
    )
    model = Model(cfg, remat=False)
    n_params = cfg.param_counts()["total"]
    print(f"[train_lm] {cfg.name}: ~{n_params / 1e6:.1f}M params")

    opt = AdamW()
    sched = functools.partial(
        warmup_cosine, peak_lr=args.lr,
        warmup_steps=max(10, args.steps // 20), total_steps=args.steps,
    )
    step_fn = jax.jit(make_train_step(model, opt, sched), donate_argnums=(0, 1))

    params = model.init(jax.random.key(0))
    opt_state = opt.init(params)
    start = 0
    ckpt = Checkpointer(args.ckpt, keep=2)
    if args.resume and ckpt.latest_step() is not None:
        s, payload = ckpt.restore({"params": params, "opt": opt_state, "cursor": 0})
        params, opt_state, start = payload["params"], payload["opt"], int(payload["cursor"])
        print(f"[train_lm] resumed at step {start}")

    data = SyntheticLM(vocab=cfg.vocab, batch=args.batch, seq=args.seq)
    prefetch = Prefetch(data.batch_at, start_step=start)
    monitor = StragglerMonitor()
    t0 = time.time()
    tokens = 0
    try:
        for i, batch in prefetch:
            if i >= args.steps:
                break
            ts = time.time()
            params, opt_state, m = step_fn(params, opt_state, batch)
            loss = float(m["loss"])
            dt = time.time() - ts
            monitor.record(dt)
            tokens += args.batch * args.seq
            if i % 20 == 0:
                print(
                    f"[train_lm] step {i:>4} loss {loss:.4f} "
                    f"{args.batch * args.seq / dt:,.0f} tok/s", flush=True,
                )
            if (i + 1) % 100 == 0:
                ckpt.save(i + 1, {"params": params, "opt": opt_state, "cursor": i + 1})
    finally:
        prefetch.close()
        ckpt.wait()
    ckpt.save(args.steps, {"params": params, "opt": opt_state, "cursor": args.steps},
              blocking=True)
    wall = time.time() - t0
    print(f"[train_lm] {tokens:,} tokens in {wall:.1f}s ({tokens / wall:,.0f} tok/s); "
          f"final loss {loss:.4f}; checkpoint at {args.ckpt}")


if __name__ == "__main__":
    main()
