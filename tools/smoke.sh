#!/usr/bin/env bash
# CI smoke: run a preset-0 suite slice through the staged engine with a
# streaming JSONL report, verify the report loads back, then tier-1 pytest.
#
# With --multi-device, instead run the placement smoke: force 8 host
# devices and drive a sharded device-scaling sweep, asserting zero
# status=error records and populated scaling_efficiency columns.
#
# With --serve [CLIENT], instead run the serving smoke on forced host
# devices with that serving client (single|threaded, default single): a
# tiny closed-loop serve (2 lanes, ~2 s) asserting schema-v4 latency/QPS
# columns (threaded runs additionally assert the dispatch-overhead and
# per-lane QPS accounting), plus — for the single client — one
# co-location pair asserting slowdown-vs-isolated on both tenants' rows.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

out="$(mktemp -d)"
trap 'rm -rf "$out"' EXIT

if [[ "${1:-}" == "--multi-device" ]]; then
  export XLA_FLAGS="--xla_force_host_platform_device_count=8${XLA_FLAGS:+ $XLA_FLAGS}"

  python -m repro.core.suite \
    --levels 1 --preset 0 --iters 1 --warmup 0 --no-backward \
    --placement shard --scale-devices 1,2,4 \
    --jsonl "$out/scaling.jsonl"

  python - "$out/scaling.jsonl" <<'PY'
import sys

from repro.core.results import load_run

meta, records = load_run(sys.argv[1])
assert meta is not None and meta.placement == "shard", meta
assert meta.device_sweep == (1, 2, 4), meta
bad = [r for r in records if r.status == "error"]
for r in bad:
    print(f"ERROR {r.name}: {r.error}", file=sys.stderr)
assert not bad, f"{len(bad)} error records in the scaling sweep"
counts = sorted({r.devices for r in records})
assert counts == [1, 2, 4], counts
multi = [r for r in records if r.devices > 1]
assert multi and all(r.scaling_efficiency is not None for r in multi), (
    "multi-device rows missing scaling_efficiency")
sharded = [r for r in multi if r.placement == "shard"]
assert sharded, "no workload actually sharded in the sweep"
print(f"multi-device smoke: {len(records)} records over counts {counts}, "
      f"{len(sharded)} sharded rows, 0 errors")
PY
  exit 0
fi

if [[ "${1:-}" == "--serve" ]]; then
  client="${2:-single}"
  export XLA_FLAGS="--xla_force_host_platform_device_count=8${XLA_FLAGS:+ $XLA_FLAGS}"

  python -m repro.core.suite \
    --names pathfinder --preset 0 --iters 1 --warmup 0 --no-backward \
    --serve closed --concurrency 4 --lanes 2 --serve-duration 2 \
    --serve-client "$client" --jsonl "$out/serve.jsonl"

  python - "$out/serve.jsonl" "$client" <<'PY'
import sys

from repro.core.results import load_run

meta, records = load_run(sys.argv[1])
client = sys.argv[2]
assert meta is not None and meta.schema_version >= 4, meta
assert meta.serve is not None and meta.serve.mode == "closed", meta.serve
assert meta.serve.client == client, meta.serve
bad = [r for r in records if r.status != "ok"]
for r in bad:
    print(f"ERROR {r.name}: {r.error}", file=sys.stderr)
assert not bad, f"{len(bad)} error records in the serve smoke"
(rec,) = records
assert rec.serve_mode == "closed" and rec.serve_lanes == 2, rec
assert rec.serve_client == client, rec.serve_client
assert rec.latency_p50_us and rec.latency_p95_us and rec.latency_p99_us
assert rec.latency_p50_us <= rec.latency_p99_us <= rec.latency_max_us
assert rec.achieved_qps and rec.achieved_qps > 0, rec
assert rec.serve_truncated is False, rec.serve_truncated
assert rec.lane_qps and len(rec.lane_qps) == 2, rec.lane_qps
if client == "threaded":
    assert rec.dispatch_overhead_us and rec.dispatch_overhead_us > 0, rec
print(f"serve smoke [{client}]: {rec.name} p50={rec.latency_p50_us:.0f}us "
      f"p99={rec.latency_p99_us:.0f}us qps={rec.achieved_qps:.0f} "
      f"lane_qps={[round(q) for q in rec.lane_qps]}")
PY

  # Co-location rides the single-threaded dispatch path by design.
  if [[ "$client" == "single" ]]; then
    python -m repro.core.suite \
      --names pathfinder --preset 0 --iters 1 --warmup 0 --no-backward \
      --serve closed --concurrency 4 --lanes 2 --serve-duration 1 \
      --colocate gemm_f32_nn --jsonl "$out/colocate.jsonl"

    python - "$out/colocate.jsonl" <<'PY'
import sys

from repro.core.results import load_run

meta, records = load_run(sys.argv[1])
assert meta.serve is not None and meta.serve.colocate == "gemm_f32_nn"
bad = [r for r in records if r.status != "ok"]
for r in bad:
    print(f"ERROR {r.name}: {r.error}", file=sys.stderr)
assert not bad, f"{len(bad)} error records in the co-location smoke"
assert len(records) == 2, [r.name for r in records]
primary, partner = records
assert primary.serve_colocate == "gemm_f32_nn", primary
assert partner.name == "gemm_f32_nn@pathfinder", partner.name
for r in records:
    assert r.slowdown_vs_isolated is not None and r.slowdown_vs_isolated > 0, r
print("co-location smoke: slowdowns "
      + ", ".join(f"{r.name}={r.slowdown_vs_isolated:.2f}" for r in records))
PY
  fi
  exit 0
fi

python -m repro.core.suite \
  --levels 0 1 --preset 0 --iters 1 --warmup 0 --no-backward \
  --jsonl "$out/smoke.jsonl"

python - "$out/smoke.jsonl" <<'PY'
import sys

from repro.core.results import load_run

meta, records = load_run(sys.argv[1])
assert meta is not None and meta.backend and meta.jax_version, meta
ok = [r for r in records if r.status == "ok"]
bad = [r for r in records if r.status != "ok"]
assert ok, "smoke suite produced no ok records"
for r in bad:
    print(f"ERROR {r.name}: {r.error}", file=sys.stderr)
print(f"smoke: {len(ok)} ok / {len(bad)} error records "
      f"(backend={meta.backend}, jax={meta.jax_version})")
sys.exit(1 if bad else 0)
PY

python -m pytest -x -q
