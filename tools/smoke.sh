#!/usr/bin/env bash
# CI smoke: run a preset-0 suite slice through the staged engine with a
# streaming JSONL report, verify the report loads back, then tier-1 pytest.
#
# With --multi-device, instead run the placement smoke: force 8 host
# devices and drive a sharded device-scaling sweep, asserting zero
# status=error records and populated scaling_efficiency columns.
#
# With --serve [CLIENT], instead run the serving smoke on forced host
# devices with that serving client (single|threaded, default single): a
# tiny closed-loop serve (2 lanes, ~2 s) asserting schema-v4 latency/QPS
# columns (threaded runs additionally assert the dispatch-overhead and
# per-lane QPS accounting), plus — for the single client — one
# co-location pair asserting slowdown-vs-isolated on both tenants' rows.
#
# With --warm-cache, instead run the zero-compile smoke: the same suite
# slice twice against one --cache-dir, asserting the warm run restored
# every entry from the serialized-executable tier — zero retraces, zero
# XLA compilations, zero fallbacks (the printed hlocache counters are
# parsed and checked) — and produced only ok records.
#
# With --impl [IMPL], instead run the implementation-axis smoke (default
# pallas; interpret mode off-TPU): a kernel-backed slice under
# --impl/--tune/--cache-dir twice, asserting cold rows carry
# impl/tuned_params/tune_trials>0 and the warm run restored every tuned
# winner AND every executable — zero XLA compiles, zero tune trials.
#
# With --batching, instead run the continuous-batching smoke: a dynamic
# mixed-shape serve under --cache-dir twice (cold stores one executable
# per (shape bucket, batch width); warm restores every one of them with
# zero retraces and zero XLA compiles), then a loop-dispatch run
# replaying the *same* saved trace, asserting the dynamic batcher's
# goodput strictly beats the sync loop's at identical offered load.
#
# With --trace, instead run the observability smoke: a small suite slice
# served through 2 lanes with --trace-out, asserting the trace parses as
# Chrome trace-event JSON with >=1 span per engine stage and named
# serve-lane tracks, that every record carries stage_timings_us summing
# within 10% of the run's wall time, that the final metadata line holds
# the counter snapshot, and that tools/trace_report.py reads the file.
#
# With --dist, instead run the distributed load-generation smoke on a
# forced-8-host-device topology: 2 client processes replay seeded
# sub-schedules against a shared --cache-dir (cold run stores, warm run
# must restore the executable in *every* client — the summed
# `# dist-cache` counters must show zero misses and zero XLA compiles),
# with merged percentiles, per-process QPS summing to the merged
# throughput, and a deterministic request count across runs. On hosts
# with >=2 cores it additionally asserts 2 client processes sustain
# >= 1.5x the single-process threaded client's achieved QPS at the same
# saturating offered load (on a single core the processes serialize at
# the hardware, so the scaling assertion is skipped with a note).
#
# With --check, instead run the static lint leg: the repro.check contract
# checker (AST-only, needs no JAX) must exit clean, and ruff (F/E9/B
# scope, see ruff.toml) runs when installed. This is the only leg that
# works on a bare Python install.
#
# With --bench [PATH], instead write the perf-trajectory artifact
# (default artifacts/BENCH_7.json): loop vs lanes vs dynamic-batcher
# latency/goodput over one fixed seeded mixed-shape trace (the
# fig_batching comparison), asserting dynamic goodput strictly beats
# loop goodput, so future PRs have a baseline.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

out="$(mktemp -d)"
trap 'rm -rf "$out"' EXIT

if [[ "${1:-}" == "--check" ]]; then
  python -m repro.check
  if command -v ruff >/dev/null 2>&1; then
    ruff check .
  else
    echo "# ruff not installed; skipping lint (repro.check still enforced)" >&2
  fi
  exit 0
fi

if [[ "${1:-}" == "--multi-device" ]]; then
  export XLA_FLAGS="--xla_force_host_platform_device_count=8${XLA_FLAGS:+ $XLA_FLAGS}"

  python -m repro.core.suite \
    --levels 1 --preset 0 --iters 1 --warmup 0 --no-backward \
    --placement shard --scale-devices 1,2,4 \
    --jsonl "$out/scaling.jsonl"

  python - "$out/scaling.jsonl" <<'PY'
import sys

from repro.core.results import load_run

meta, records = load_run(sys.argv[1])
assert meta is not None and meta.placement == "shard", meta
assert meta.device_sweep == (1, 2, 4), meta
bad = [r for r in records if r.status == "error"]
for r in bad:
    print(f"ERROR {r.name}: {r.error}", file=sys.stderr)
assert not bad, f"{len(bad)} error records in the scaling sweep"
counts = sorted({r.devices for r in records})
assert counts == [1, 2, 4], counts
multi = [r for r in records if r.devices > 1]
assert multi and all(r.scaling_efficiency is not None for r in multi), (
    "multi-device rows missing scaling_efficiency")
sharded = [r for r in multi if r.placement == "shard"]
assert sharded, "no workload actually sharded in the sweep"
print(f"multi-device smoke: {len(records)} records over counts {counts}, "
      f"{len(sharded)} sharded rows, 0 errors")
PY
  exit 0
fi

if [[ "${1:-}" == "--serve" ]]; then
  client="${2:-single}"
  export XLA_FLAGS="--xla_force_host_platform_device_count=8${XLA_FLAGS:+ $XLA_FLAGS}"

  python -m repro.core.suite \
    --names pathfinder --preset 0 --iters 1 --warmup 0 --no-backward \
    --serve closed --concurrency 4 --lanes 2 --serve-duration 2 \
    --serve-client "$client" --jsonl "$out/serve.jsonl"

  python - "$out/serve.jsonl" "$client" <<'PY'
import sys

from repro.core.results import load_run

meta, records = load_run(sys.argv[1])
client = sys.argv[2]
assert meta is not None and meta.schema_version >= 4, meta
assert meta.serve is not None and meta.serve.mode == "closed", meta.serve
assert meta.serve.client == client, meta.serve
bad = [r for r in records if r.status != "ok"]
for r in bad:
    print(f"ERROR {r.name}: {r.error}", file=sys.stderr)
assert not bad, f"{len(bad)} error records in the serve smoke"
(rec,) = records
assert rec.serve_mode == "closed" and rec.serve_lanes == 2, rec
assert rec.serve_client == client, rec.serve_client
assert rec.latency_p50_us and rec.latency_p95_us and rec.latency_p99_us
assert rec.latency_p50_us <= rec.latency_p99_us <= rec.latency_max_us
assert rec.achieved_qps and rec.achieved_qps > 0, rec
assert rec.serve_truncated is False, rec.serve_truncated
assert rec.lane_qps and len(rec.lane_qps) == 2, rec.lane_qps
if client == "threaded":
    assert rec.dispatch_overhead_us and rec.dispatch_overhead_us > 0, rec
print(f"serve smoke [{client}]: {rec.name} p50={rec.latency_p50_us:.0f}us "
      f"p99={rec.latency_p99_us:.0f}us qps={rec.achieved_qps:.0f} "
      f"lane_qps={[round(q) for q in rec.lane_qps]}")
PY

  # Co-location rides the single-threaded dispatch path by design.
  if [[ "$client" == "single" ]]; then
    python -m repro.core.suite \
      --names pathfinder --preset 0 --iters 1 --warmup 0 --no-backward \
      --serve closed --concurrency 4 --lanes 2 --serve-duration 1 \
      --colocate gemm_f32_nn --jsonl "$out/colocate.jsonl"

    python - "$out/colocate.jsonl" <<'PY'
import sys

from repro.core.results import load_run

meta, records = load_run(sys.argv[1])
assert meta.serve is not None and meta.serve.colocate == "gemm_f32_nn"
bad = [r for r in records if r.status != "ok"]
for r in bad:
    print(f"ERROR {r.name}: {r.error}", file=sys.stderr)
assert not bad, f"{len(bad)} error records in the co-location smoke"
assert len(records) == 2, [r.name for r in records]
primary, partner = records
assert primary.serve_colocate == "gemm_f32_nn", primary
assert partner.name == "gemm_f32_nn@pathfinder", partner.name
for r in records:
    assert r.slowdown_vs_isolated is not None and r.slowdown_vs_isolated > 0, r
print("co-location smoke: slowdowns "
      + ", ".join(f"{r.name}={r.slowdown_vs_isolated:.2f}" for r in records))
PY
  fi
  exit 0
fi

if [[ "${1:-}" == "--warm-cache" ]]; then
  cache="$out/cache"

  python -m repro.core.suite \
    --levels 0 1 --preset 0 --iters 1 --warmup 0 --no-backward \
    --cache-dir "$cache" --jsonl "$out/cold.jsonl" 2> "$out/cold.err" \
    || { cat "$out/cold.err" >&2; exit 1; }
  grep '^# hlocache:' "$out/cold.err"
  python -m repro.core.suite \
    --levels 0 1 --preset 0 --iters 1 --warmup 0 --no-backward \
    --cache-dir "$cache" --jsonl "$out/warm.jsonl" 2> "$out/warm.err" \
    || { cat "$out/warm.err" >&2; exit 1; }
  grep '^# hlocache:' "$out/warm.err"

  python - "$out/cold.err" "$out/warm.err" "$out/warm.jsonl" <<'PY'
import re
import sys

from repro.core.results import load_run


def counters(path):
    with open(path) as f:
        (line,) = [l for l in f if l.startswith("# hlocache:")]
    return {k: int(v) for k, v in re.findall(r"(\w+)=(\d+)", line)}, line

cold, cold_line = counters(sys.argv[1])
warm, warm_line = counters(sys.argv[2])
assert cold["stores"] > 0, f"cold run stored nothing: {cold_line}"
# The zero-compile warm start: every lookup restored a serialized
# executable — no retrace (misses=0), no tier-2 compile (hlo=0,
# xla_compiles=0), no silent degradation (fallbacks=0).
assert warm["exe_hits"] == cold["stores"], (cold_line, warm_line)
assert warm["hits"] == warm["exe_hits"], warm_line
assert warm["misses"] == 0, warm_line
assert warm["xla_compiles"] == 0, warm_line
assert warm["fallbacks"] == 0 and warm["exe_fallbacks"] == 0, warm_line
meta, records = load_run(sys.argv[3])
bad = [r for r in records if r.status != "ok"]
for r in bad:
    print(f"ERROR {r.name}: {r.error}", file=sys.stderr)
assert not bad, f"{len(bad)} error records in the warm run"
# Warm rows still carry both timing modes (schema v5).
assert meta is not None and meta.schema_version >= 5, meta
windowed = [r for r in records if r.us_per_call_windowed is not None]
assert windowed, "warm run produced no windowed timings"
print(f"warm-cache smoke: {warm['exe_hits']} executables restored, "
      f"0 XLA compiles, {len(records)} ok records "
      f"({len(windowed)} with windowed timings)")
PY
  exit 0
fi

if [[ "${1:-}" == "--impl" ]]; then
  impl="${2:-pallas}"
  cache="$out/cache"

  python -m repro.core.suite \
    --names gemm_f32_nn softmax where --preset 0 --iters 1 --warmup 0 \
    --no-backward --impl "$impl" --tune --cache-dir "$cache" \
    --jsonl "$out/impl_cold.jsonl" 2> "$out/impl_cold.err" \
    || { cat "$out/impl_cold.err" >&2; exit 1; }
  grep '^# hlocache:' "$out/impl_cold.err"
  python -m repro.core.suite \
    --names gemm_f32_nn softmax where --preset 0 --iters 1 --warmup 0 \
    --no-backward --impl "$impl" --tune --cache-dir "$cache" \
    --jsonl "$out/impl_warm.jsonl" 2> "$out/impl_warm.err" \
    || { cat "$out/impl_warm.err" >&2; exit 1; }
  grep '^# hlocache:' "$out/impl_warm.err"

  python - "$out/impl_cold.jsonl" "$out/impl_warm.jsonl" "$out/impl_warm.err" "$impl" <<'PY'
import re
import sys

from repro.core.results import load_run

cold_meta, cold = load_run(sys.argv[1])
warm_meta, warm = load_run(sys.argv[2])
impl = sys.argv[4]
with open(sys.argv[3]) as f:
    (line,) = [l for l in f if l.startswith("# hlocache:")]
counters = {k: int(v) for k, v in re.findall(r"(\w+)=(\d+)", line)}

for meta in (cold_meta, warm_meta):
    assert meta is not None and meta.schema_version >= 6, meta
    assert meta.impl == impl and meta.tune is True, (meta.impl, meta.tune)
for tag, records in (("cold", cold), ("warm", warm)):
    bad = [r for r in records if r.status != "ok"]
    for r in bad:
        print(f"ERROR {r.name}: {r.error}", file=sys.stderr)
    assert not bad, f"{len(bad)} error records in the {tag} impl run"
    for r in records:
        assert r.impl == impl and r.impl_fallback is None, (r.name, r.impl)
        if impl == "pallas":
            assert r.impl_interpret is not None, r.name
            assert r.tuned_params, (r.name, "no tuned_params")
# Cold run actually swept the tune space; warm run restored every winner
# from the .tune.json sidecar (zero trials) and every executable from the
# serialized tier (zero XLA compiles).
assert sum(r.tune_trials or 0 for r in cold) > 0, "cold run swept nothing"
assert all((r.tune_trials or 0) == 0 for r in warm), "warm run re-tuned"
assert counters["misses"] == 0 and counters["xla_compiles"] == 0, line
assert counters["tune_hits"] == len(warm), line
won = {r.name: r.tuned_params for r in warm}
assert won == {r.name: r.tuned_params for r in cold}, "winners drifted"
trials = sum(r.tune_trials or 0 for r in cold)
print(f"impl smoke [{impl}]: {len(warm)} records, cold swept {trials} "
      f"trials, warm restored {counters['tune_hits']} winners with "
      "0 XLA compiles and 0 tune trials")
PY
  exit 0
fi

if [[ "${1:-}" == "--batching" ]]; then
  cache="$out/cache"
  trace="$out/mix_trace.jsonl"
  mix="0/cols=64@2,0/cols=128@1"
  common=(--names pathfinder --preset 0 --iters 1 --warmup 0 --no-backward
    --serve open --qps 45000 --serve-duration 0.5 --concurrency 16
    --serve-mix "$mix" --serve-trace "$trace" --slo-us 20000
    --max-batch 8 --batch-latency-budget 1000)

  # Cold: the dynamic batcher compiles one executable per (bucket, width)
  # through the two-tier cache — and saves the generated trace.
  python -m repro.core.suite "${common[@]}" --serve-dispatch dynamic \
    --cache-dir "$cache" --jsonl "$out/dyn_cold.jsonl" 2> "$out/dyn_cold.err" \
    || { cat "$out/dyn_cold.err" >&2; exit 1; }
  grep '^# hlocache:' "$out/dyn_cold.err"
  # Warm: the same run (now replaying the trace) restores every bucket.
  python -m repro.core.suite "${common[@]}" --serve-dispatch dynamic \
    --cache-dir "$cache" --jsonl "$out/dyn_warm.jsonl" 2> "$out/dyn_warm.err" \
    || { cat "$out/dyn_warm.err" >&2; exit 1; }
  grep '^# hlocache:' "$out/dyn_warm.err"
  # The sync-loop floor, replaying the identical trace (same offered load).
  python -m repro.core.suite "${common[@]}" --serve-dispatch loop \
    --jsonl "$out/loop.jsonl"

  python - "$out/dyn_cold.err" "$out/dyn_warm.err" \
    "$out/dyn_warm.jsonl" "$out/loop.jsonl" <<'PY'
import re
import sys

from repro.core.results import load_run


def counters(path):
    with open(path) as f:
        (line,) = [l for l in f if l.startswith("# hlocache:")]
    return {k: int(v) for k, v in re.findall(r"(\w+)=(\d+)", line)}, line

cold, cold_line = counters(sys.argv[1])
warm, warm_line = counters(sys.argv[2])
# Cold compiles: the measure-stage executable plus 2 buckets x 4 dynamic
# widths (1, 2, 4, 8) = 9 distinct programs, every one stored.
assert cold["stores"] == 9, cold_line
# Warm restores the whole bucket table from the serialized-executable
# tier: zero retraces, zero XLA compiles, zero fallbacks.
assert warm["exe_hits"] == cold["stores"], (cold_line, warm_line)
assert warm["misses"] == 0 and warm["xla_compiles"] == 0, warm_line
assert warm["fallbacks"] == 0 and warm["exe_fallbacks"] == 0, warm_line

_, dyn_records = load_run(sys.argv[3])
_, loop_records = load_run(sys.argv[4])
(dyn,) = dyn_records
(loop,) = loop_records
for tag, rec in (("dynamic", dyn), ("loop", loop)):
    assert rec.status == "ok", (tag, rec.error)
    assert rec.serve_dispatch == tag, rec.serve_dispatch
    assert rec.serve_mix == "p0/cols=64@2,p0/cols=128@1", rec.serve_mix
    assert rec.batch_occupancy and 0 < rec.batch_occupancy <= 1.0, rec
    assert rec.serve_batches and rec.goodput_qps, rec
    assert rec.bucket_latency_us and set(rec.bucket_latency_us) == {
        "p0/cols=64", "p0/cols=128"}, rec.bucket_latency_us
# Identical replayed trace -> identical offered load and request count.
assert dyn.serve_requests == loop.serve_requests, (dyn, loop)
assert dyn.offered_qps == loop.offered_qps, (dyn, loop)
# Coalescing is the point: far fewer device programs than requests, and
# strictly more goodput than the sync loop under the same SLO.
assert dyn.serve_batches < loop.serve_batches, (dyn.serve_batches,
                                                loop.serve_batches)
assert dyn.goodput_qps > loop.goodput_qps, (dyn.goodput_qps,
                                            loop.goodput_qps)
print(f"batching smoke: {warm['exe_hits']} bucket executables restored "
      f"warm with 0 XLA compiles; dynamic goodput {dyn.goodput_qps:.0f} "
      f"qps > loop {loop.goodput_qps:.0f} qps over {dyn.serve_requests} "
      f"replayed requests ({dyn.serve_batches} vs {loop.serve_batches} "
      "device programs)")
PY
  exit 0
fi

if [[ "${1:-}" == "--trace" ]]; then
  export XLA_FLAGS="--xla_force_host_platform_device_count=8${XLA_FLAGS:+ $XLA_FLAGS}"

  start_ns=$(date +%s%N)
  python -m repro.core.suite \
    --levels 0 --preset 0 --iters 1 --warmup 0 --no-backward \
    --serve closed --concurrency 4 --lanes 2 --serve-duration 0.5 \
    --serve-client threaded \
    --trace-out "$out/run.trace.json" --jsonl "$out/trace.jsonl" \
    2> "$out/trace.err" || { cat "$out/trace.err" >&2; exit 1; }
  wall_us=$(( ($(date +%s%N) - start_ns) / 1000 ))
  grep '^# trace:' "$out/trace.err"

  python - "$out/run.trace.json" "$out/trace.jsonl" "$wall_us" <<'PY'
import json
import sys

from repro.core.results import load_run

with open(sys.argv[1]) as f:
    doc = json.load(f)
events = doc["traceEvents"]
spans = [e for e in events if e["ph"] == "X"]
meta_events = [e for e in events if e["ph"] == "M"]
assert spans and meta_events, "trace missing span or metadata events"
for ev in spans:
    assert {"name", "cat", "pid", "tid", "ts", "dur"} <= set(ev), ev

# One track per engine stage: every stage appears at least once.
stages = {"build", "place", "tune", "compile", "measure",
          "characterize", "serve"}
engine_spans = {e["name"] for e in spans if e["cat"] == "engine"}
missing = stages - engine_spans
assert not missing, f"engine stages missing from trace: {sorted(missing)}"

# Serve lanes render as named thread tracks carrying request events.
lane_names = {
    e["args"]["name"] for e in meta_events if e["name"] == "thread_name"
}
assert {"lane 0", "lane 1"} <= lane_names, sorted(lane_names)
requests = [e for e in spans if e["name"] == "request"]
assert requests, "no per-request serve events in the trace"

meta, records = load_run(sys.argv[2])
bad = [r for r in records if r.status != "ok"]
for r in bad:
    print(f"ERROR {r.name}: {r.error}", file=sys.stderr)
assert not bad, f"{len(bad)} error records in the trace smoke"
assert meta is not None and meta.schema_version >= 8, meta
assert meta.counters and meta.counters.get("serve.requests", 0) > 0, (
    meta.counters)

# Every record carries the per-stage breakdown; the stages run back to
# back inside the run, so their total can only undershoot the run's
# wall clock — within 10% accounts for selection + report bookkeeping.
wall_us = int(sys.argv[3])
total = 0.0
for r in records:
    assert r.stage_timings_us, f"{r.name} missing stage_timings_us"
    assert set(r.stage_timings_us) >= {"build", "compile", "measure"}, r
    total += sum(r.stage_timings_us.values())
assert total <= wall_us * 1.10, (total, wall_us)
print(f"trace smoke: {len(spans)} spans over stages "
      f"{sorted(engine_spans)}, {len(requests)} request events on "
      f"{len(lane_names & {'lane 0', 'lane 1'})} lane tracks; stage "
      f"timings {total/1e6:.2f}s within run wall {wall_us/1e6:.2f}s")
PY

  python tools/trace_report.py "$out/run.trace.json"
  exit 0
fi

if [[ "${1:-}" == "--dist" ]]; then
  export XLA_FLAGS="--xla_force_host_platform_device_count=8${XLA_FLAGS:+ $XLA_FLAGS}"
  cache="$out/cache"
  common=(--names pathfinder --preset 0 --iters 1 --warmup 0 --no-backward
    --serve open --serve-duration 1 --concurrency 16 --lanes 4)

  # Cold distributed run: 2 client processes derive their sub-schedules
  # from the shared seed, compile through the shared cache, and stream
  # completion stamps back for merged accounting.
  python -m repro.core.suite "${common[@]}" --qps 4000 --client-procs 2 \
    --cache-dir "$cache" --jsonl "$out/dist_cold.jsonl" 2> "$out/dist_cold.err" \
    || { cat "$out/dist_cold.err" >&2; exit 1; }
  grep '^# dist-cache' "$out/dist_cold.err"
  # Warm: same spec; every client process must restore its executable.
  python -m repro.core.suite "${common[@]}" --qps 4000 --client-procs 2 \
    --cache-dir "$cache" --jsonl "$out/dist_warm.jsonl" 2> "$out/dist_warm.err" \
    || { cat "$out/dist_warm.err" >&2; exit 1; }
  grep '^# dist-cache' "$out/dist_warm.err"

  python - "$out/dist_cold.jsonl" "$out/dist_warm.jsonl" "$out/dist_warm.err" <<'PY'
import re
import sys

from repro.core.results import load_run

cold_meta, cold_records = load_run(sys.argv[1])
warm_meta, warm_records = load_run(sys.argv[2])
with open(sys.argv[3]) as f:
    (line,) = [l for l in f if l.startswith("# dist-cache")]
counters = {k: int(v) for k, v in re.findall(r"(\w+)=(\d+)", line)}

for meta in (cold_meta, warm_meta):
    assert meta is not None and meta.schema_version >= 9, meta
    assert meta.serve is not None and meta.serve.client_procs == 2, meta.serve
for tag, records in (("cold", cold_records), ("warm", warm_records)):
    (rec,) = records
    assert rec.status == "ok", (tag, rec.error)
    assert rec.client_procs == 2, rec.client_procs
    assert rec.proc_qps and len(rec.proc_qps) == 2, rec.proc_qps
    assert rec.latency_p50_us and rec.latency_p99_us and rec.achieved_qps, rec
    # Per-process accounting must sum back to the merged throughput.
    assert abs(sum(rec.proc_qps) - rec.achieved_qps) < 0.1 * rec.achieved_qps, (
        rec.proc_qps, rec.achieved_qps)
(cold_rec,) = cold_records
(warm_rec,) = warm_records
# Same seed -> same SeedSequence split -> same merged request count.
assert cold_rec.serve_requests == warm_rec.serve_requests, (
    cold_rec.serve_requests, warm_rec.serve_requests)
# The zero-compile warm distributed run: the summed client counters show
# every process restored its executable from the shared cache.
assert counters["misses"] == 0, line
assert counters["xla_compiles"] == 0, line
assert counters["exe_hits"] == 2, line
print(f"dist smoke: 2 client procs, {warm_rec.serve_requests} merged "
      f"requests, proc_qps={[round(q) for q in warm_rec.proc_qps]}, "
      "warm run 0 XLA compiles in every client")
PY

  # Scaling: 2 client processes must clear the single-interpreter
  # dispatch ceiling. Only meaningful with >=2 cores — a single-core
  # host serializes the processes at the hardware level, so there the
  # leg stops at the accounting + zero-compile assertions above.
  if [[ "$(python -c 'import os; print(os.cpu_count() or 1)')" -ge 2 ]]; then
    for attempt in 1 2; do
      python -m repro.core.suite "${common[@]}" --qps 25000 \
        --serve-client threaded --cache-dir "$cache" \
        --jsonl "$out/ceil_single.jsonl"
      python -m repro.core.suite "${common[@]}" --qps 25000 --client-procs 2 \
        --cache-dir "$cache" --jsonl "$out/ceil_dist.jsonl"
      if python - "$out/ceil_single.jsonl" "$out/ceil_dist.jsonl" <<'PY'
import sys

from repro.core.results import load_run

_, (single,) = load_run(sys.argv[1])
_, (dist,) = load_run(sys.argv[2])
assert single.status == "ok", single.error
assert dist.status == "ok", dist.error
ratio = dist.achieved_qps / single.achieved_qps
print(f"dist scaling: 2 procs {dist.achieved_qps:.0f} qps vs single "
      f"{single.achieved_qps:.0f} qps ({ratio:.2f}x)")
assert ratio >= 1.5, f"2-process scaling only {ratio:.2f}x (< 1.5x)"
PY
      then
        exit 0
      fi
      echo "dist scaling attempt $attempt below 1.5x; retrying" >&2
    done
    echo "dist smoke: 2 procs failed to reach 1.5x single-process QPS" >&2
    exit 1
  else
    echo "# dist smoke: single-core host, scaling assertion skipped" >&2
  fi
  exit 0
fi

if [[ "${1:-}" == "--bench" ]]; then
  bench_path="${2:-artifacts/BENCH_7.json}"
  cache="$out/cache"

  # The fig_batching comparison: one fixed seeded mixed-shape trace
  # (generated by the first policy, replayed by the rest), loop vs lanes
  # vs dynamic at the same offered load. Two attempts: the acceptance
  # inequality (dynamic goodput > loop goodput) has a 3-5x margin at
  # these knobs, so one retry covers a pathological scheduling hiccup.
  for attempt in 1 2; do
    if python benchmarks/fig_batching.py \
        --trace "$out/bench_trace_$attempt.jsonl" \
        --json "$bench_path"; then
      if python - "$bench_path" <<'PY'
import json
import sys

with open(sys.argv[1]) as f:
    bench = json.load(f)
modes = bench["modes"]
assert set(modes) >= {"loop", "lanes", "dynamic"}, sorted(modes)
dyn, loop = modes["dynamic"], modes["loop"]
for mode, m in modes.items():
    assert m["goodput_qps"] >= 0 and m["batches"] > 0, (mode, m)
# The acceptance inequality: the continuous batcher strictly beats the
# sync loop at identical offered mixed-shape load, under the same SLO.
assert dyn["goodput_qps"] > loop["goodput_qps"], (dyn, loop)
assert dyn["batches"] < loop["batches"], (dyn, loop)
print(f"BENCH_7: dynamic goodput {dyn['goodput_qps']:.0f} qps > loop "
      f"{loop['goodput_qps']:.0f} qps "
      f"({bench['dynamic_over_loop_goodput']}x) at "
      f"{bench['offered_qps']:.0f} offered qps, mix {bench['mix']} "
      f"-> {sys.argv[1]}")
PY
      then
        exit 0
      fi
    fi
    echo "BENCH_7 attempt $attempt failed; retrying" >&2
  done
  echo "BENCH_7: dynamic goodput did not beat loop in 2 attempts" >&2
  exit 1
fi

python -m repro.core.suite \
  --levels 0 1 --preset 0 --iters 1 --warmup 0 --no-backward \
  --jsonl "$out/smoke.jsonl"

python - "$out/smoke.jsonl" <<'PY'
import sys

from repro.core.results import load_run

meta, records = load_run(sys.argv[1])
assert meta is not None and meta.backend and meta.jax_version, meta
ok = [r for r in records if r.status == "ok"]
bad = [r for r in records if r.status != "ok"]
assert ok, "smoke suite produced no ok records"
for r in bad:
    print(f"ERROR {r.name}: {r.error}", file=sys.stderr)
print(f"smoke: {len(ok)} ok / {len(bad)} error records "
      f"(backend={meta.backend}, jax={meta.jax_version})")
sys.exit(1 if bad else 0)
PY

python -m pytest -x -q
