#!/usr/bin/env bash
# CI smoke: run a preset-0 suite slice through the staged engine with a
# streaming JSONL report, verify the report loads back, then tier-1 pytest.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

out="$(mktemp -d)"
trap 'rm -rf "$out"' EXIT

python -m repro.core.suite \
  --levels 0 1 --preset 0 --iters 1 --warmup 0 --no-backward \
  --jsonl "$out/smoke.jsonl"

python - "$out/smoke.jsonl" <<'PY'
import sys

from repro.core.results import load_run

meta, records = load_run(sys.argv[1])
assert meta is not None and meta.backend and meta.jax_version, meta
ok = [r for r in records if r.status == "ok"]
bad = [r for r in records if r.status != "ok"]
assert ok, "smoke suite produced no ok records"
for r in bad:
    print(f"ERROR {r.name}: {r.error}", file=sys.stderr)
print(f"smoke: {len(ok)} ok / {len(bad)} error records "
      f"(backend={meta.backend}, jax={meta.jax_version})")
sys.exit(1 if bad else 0)
PY

python -m pytest -x -q
