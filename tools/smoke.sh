#!/usr/bin/env bash
# CI smoke: run a preset-0 suite slice through the staged engine with a
# streaming JSONL report, verify the report loads back, then tier-1 pytest.
#
# With --multi-device, instead run the placement smoke: force 8 host
# devices and drive a sharded device-scaling sweep, asserting zero
# status=error records and populated scaling_efficiency columns.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

out="$(mktemp -d)"
trap 'rm -rf "$out"' EXIT

if [[ "${1:-}" == "--multi-device" ]]; then
  export XLA_FLAGS="--xla_force_host_platform_device_count=8${XLA_FLAGS:+ $XLA_FLAGS}"

  python -m repro.core.suite \
    --levels 1 --preset 0 --iters 1 --warmup 0 --no-backward \
    --placement shard --scale-devices 1,2,4 \
    --jsonl "$out/scaling.jsonl"

  python - "$out/scaling.jsonl" <<'PY'
import sys

from repro.core.results import load_run

meta, records = load_run(sys.argv[1])
assert meta is not None and meta.placement == "shard", meta
assert meta.device_sweep == (1, 2, 4), meta
bad = [r for r in records if r.status == "error"]
for r in bad:
    print(f"ERROR {r.name}: {r.error}", file=sys.stderr)
assert not bad, f"{len(bad)} error records in the scaling sweep"
counts = sorted({r.devices for r in records})
assert counts == [1, 2, 4], counts
multi = [r for r in records if r.devices > 1]
assert multi and all(r.scaling_efficiency is not None for r in multi), (
    "multi-device rows missing scaling_efficiency")
sharded = [r for r in multi if r.placement == "shard"]
assert sharded, "no workload actually sharded in the sweep"
print(f"multi-device smoke: {len(records)} records over counts {counts}, "
      f"{len(sharded)} sharded rows, 0 errors")
PY
  exit 0
fi

python -m repro.core.suite \
  --levels 0 1 --preset 0 --iters 1 --warmup 0 --no-backward \
  --jsonl "$out/smoke.jsonl"

python - "$out/smoke.jsonl" <<'PY'
import sys

from repro.core.results import load_run

meta, records = load_run(sys.argv[1])
assert meta is not None and meta.backend and meta.jax_version, meta
ok = [r for r in records if r.status == "ok"]
bad = [r for r in records if r.status != "ok"]
assert ok, "smoke suite produced no ok records"
for r in bad:
    print(f"ERROR {r.name}: {r.error}", file=sys.stderr)
print(f"smoke: {len(ok)} ok / {len(bad)} error records "
      f"(backend={meta.backend}, jax={meta.jax_version})")
sys.exit(1 if bad else 0)
PY

python -m pytest -x -q
