"""Render EXPERIMENTS.md tables from dry-run artifacts.

Usage: PYTHONPATH=src python tools/render_experiments.py
Writes artifacts/tables/{dryrun,roofline,perf}.md for inclusion in
EXPERIMENTS.md (the narrative around them is hand-written).
"""

from __future__ import annotations

import glob
import json
import os

ROOT = os.path.join(os.path.dirname(__file__), "..")
DRY = os.path.join(ROOT, "artifacts", "dryrun")
OUT = os.path.join(ROOT, "artifacts", "tables")

HBM_GIB = 16.0

ARCH_ORDER = [
    "granite-3-8b", "qwen1.5-0.5b", "granite-8b", "deepseek-7b", "xlstm-350m",
    "mixtral-8x22b", "dbrx-132b", "hubert-xlarge", "jamba-1.5-large-398b",
    "qwen2-vl-2b",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(mesh: str, variant: str) -> dict[tuple[str, str], dict]:
    out = {}
    for p in glob.glob(os.path.join(DRY, f"*__{mesh}__{variant}.json")):
        c = json.load(open(p))
        out[(c["arch"], c["shape"])] = c
    return out


def _mem_gib(c: dict) -> float:
    m = c.get("memory", {})
    # donated outputs alias arguments; args+temp is the live footprint
    return (m.get("argument_size_in_bytes", 0) + m.get("temp_size_in_bytes", 0)) / 2**30


def _coll_summary(c: dict) -> str:
    hist = c.get("collectives", {})
    parts = [
        f"{k}:{int(v['count'])}×/{v['bytes'] / 1e9:.1f}GB"
        for k, v in sorted(hist.items(), key=lambda kv: -kv[1]["bytes"])
    ]
    return " ".join(parts[:3]) if parts else "-"


def render_dryrun() -> str:
    single = load("single", "baseline")
    multi = load("multi", "baseline")
    lines = [
        "| arch | shape | 16×16 compile | 2×16×16 compile | mem/dev (args+temp) | fits 16 GiB | top collectives (per step, per device) |",
        "|---|---|---|---|---|---|---|",
    ]
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            cs, cm = single.get((a, s)), multi.get((a, s))
            if cs is None:
                continue
            if "skip" in cs:
                lines.append(f"| {a} | {s} | — | — | — | — | *skip: {cs['skip']}* |")
                continue
            gib = _mem_gib(cs)
            fits = "✅" if gib <= HBM_GIB else f"❌ ({gib / HBM_GIB:.1f}×)"
            mc = f"{cm['compile_s']}s ✓" if cm and "skip" not in cm else "—"
            lines.append(
                f"| {a} | {s} | {cs['compile_s']}s ✓ | {mc} | {gib:.1f} GiB | {fits} | {_coll_summary(cs)} |"
            )
    return "\n".join(lines)


_MOVE_HINT = {
    "compute": "already MXU-bound; gains need better matmul shapes/fusion",
    "memory": "cut HBM traffic: avoid f32 score materialization (chunked/online attention, bf16 scores), tighter remat",
    "collective": "cut ICI bytes: re-shard (replicate small weights / EP where divisible), reduce dispatch traffic, overlap",
}


def render_roofline() -> str:
    single = load("single", "baseline")
    lines = [
        "| arch | shape | compute_s | memory_s | collective_s | dominant | roofline fraction | 6·N·D / HLO | next lever |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            c = single.get((a, s))
            if c is None:
                continue
            if "skip" in c:
                lines.append(f"| {a} | {s} | — | — | — | — | — | — | *skip: {c['skip']}* |")
                continue
            r = c["roofline"]
            lines.append(
                f"| {a} | {s} | {r['compute_s']:.3g} | {r['memory_s']:.3g} | "
                f"{r['collective_s']:.3g} | **{r['dominant']}** | "
                f"{r['roofline_fraction']:.3f} | {c['useful_compute_ratio']:.2f} | "
                f"{_MOVE_HINT[r['dominant']]} |"
            )
    return "\n".join(lines)


def render_variants() -> str:
    """All non-baseline variants vs their baselines."""
    base = load("single", "baseline")
    rows = []
    for p in sorted(glob.glob(os.path.join(DRY, "*__single__*.json"))):
        c = json.load(open(p))
        if c.get("variant", "baseline") == "baseline" or "skip" in c:
            continue
        b = base.get((c["arch"], c["shape"]))
        if b is None or "skip" in b:
            continue
        rb, rv = b["roofline"], c["roofline"]
        rows.append(
            f"| {c['arch']} | {c['shape']} | {c['variant']} | "
            f"{rb['compute_s']:.3g}→{rv['compute_s']:.3g} | "
            f"{rb['memory_s']:.3g}→{rv['memory_s']:.3g} | "
            f"{rb['collective_s']:.3g}→{rv['collective_s']:.3g} | "
            f"{rb['roofline_fraction']:.3f}→{rv['roofline_fraction']:.3f} | "
            f"{_mem_gib(b):.1f}→{_mem_gib(c):.1f} GiB |"
        )
    return "\n".join(
        [
            "| arch | shape | variant | compute_s | memory_s | collective_s | fraction | mem/dev |",
            "|---|---|---|---|---|---|---|---|",
        ]
        + rows
    )


def main() -> None:
    os.makedirs(OUT, exist_ok=True)
    for name, text in (
        ("dryrun", render_dryrun()),
        ("roofline", render_roofline()),
        ("variants", render_variants()),
    ):
        with open(os.path.join(OUT, name + ".md"), "w") as f:
            f.write(text + "\n")
        print(f"wrote artifacts/tables/{name}.md ({len(text.splitlines())} rows)")


if __name__ == "__main__":
    main()
