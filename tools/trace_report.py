#!/usr/bin/env python
"""Summarize a --trace-out Chrome trace-event file in the terminal.

Perfetto is the right tool for *looking* at a trace; this is the right
tool for a CI log or a quick skim: top spans by duration, the engine's
per-stage time share, and per-lane serve utilization (busy time within
the lane's active window). Stdlib-only — it reads the JSON the tracer
wrote and nothing else.

Usage:
    python tools/trace_report.py run.trace.json [--top N]

Exit codes: 0 ok, 2 the file is not a Chrome trace-event JSON object.
"""

from __future__ import annotations

import argparse
import json
import sys
from collections import defaultdict


class TraceError(ValueError):
    """The input is not a readable Chrome trace-event file."""


def load_trace(path: str) -> list[dict]:
    """The trace's event list, validated just enough to report on.

    Accepts the object envelope (``{"traceEvents": [...]}``, what the
    tracer writes) or the bare-array form Chrome also loads.
    """
    try:
        with open(path) as f:
            doc = json.load(f)
    except OSError as e:
        raise TraceError(f"cannot read {path}: {e}")
    except json.JSONDecodeError as e:
        raise TraceError(f"{path} is not JSON: {e}")
    events = doc.get("traceEvents") if isinstance(doc, dict) else doc
    if not isinstance(events, list):
        raise TraceError(
            f"{path}: expected a trace-event array or a "
            '{"traceEvents": [...]} object'
        )
    for ev in events:
        if not isinstance(ev, dict) or "ph" not in ev:
            raise TraceError(f"{path}: malformed trace event: {ev!r}")
    return events


def _names(events: list[dict]) -> tuple[dict[int, str], dict[tuple, str]]:
    """(pid -> process name, (pid, tid) -> thread name) from "M" events."""
    procs: dict[int, str] = {}
    threads: dict[tuple, str] = {}
    for ev in events:
        if ev.get("ph") != "M":
            continue
        if ev.get("name") == "process_name":
            procs[ev["pid"]] = ev.get("args", {}).get("name", str(ev["pid"]))
        elif ev.get("name") == "thread_name":
            threads[(ev["pid"], ev.get("tid"))] = ev.get("args", {}).get(
                "name", str(ev.get("tid"))
            )
    return procs, threads


def _spans(events: list[dict]) -> list[dict]:
    return [ev for ev in events if ev.get("ph") == "X"]


def _fmt_us(us: float) -> str:
    if us >= 1e6:
        return f"{us / 1e6:.2f}s"
    if us >= 1e3:
        return f"{us / 1e3:.1f}ms"
    return f"{us:.0f}us"


def report(events: list[dict], top: int = 10) -> list[str]:
    """The report lines for one trace (printing is the caller's job)."""
    procs, threads = _names(events)
    spans = _spans(events)
    lines: list[str] = []
    if not spans:
        return ["trace has no span events"]

    def track_of(ev: dict) -> str:
        return procs.get(ev.get("pid"), str(ev.get("pid")))

    def thread_of(ev: dict) -> str:
        return threads.get(
            (ev.get("pid"), ev.get("tid")), str(ev.get("tid"))
        )

    # -- top spans ---------------------------------------------------------
    lines.append(f"top {min(top, len(spans))} spans by duration:")
    for ev in sorted(spans, key=lambda e: -e.get("dur", 0))[:top]:
        attrs = ev.get("args", {})
        bench = attrs.get("bench")
        suffix = f"  bench={bench}" if bench else ""
        lines.append(
            f"  {_fmt_us(ev.get('dur', 0)):>8}  "
            f"{track_of(ev)}/{thread_of(ev)}  {ev.get('name')}{suffix}"
        )

    # -- engine per-stage share -------------------------------------------
    stage_us: dict[str, float] = defaultdict(float)
    for ev in spans:
        if track_of(ev) == "engine":
            stage_us[ev.get("name", "?")] += ev.get("dur", 0)
    if stage_us:
        total = sum(stage_us.values())
        lines.append("")
        lines.append(f"engine stages ({_fmt_us(total)} total):")
        for name, us in sorted(stage_us.items(), key=lambda kv: -kv[1]):
            share = us / total * 100 if total else 0.0
            lines.append(f"  {share:5.1f}%  {_fmt_us(us):>8}  {name}")

    # -- serve lane utilization -------------------------------------------
    # Busy time = sum of request durations on the lane's track; window =
    # the lane's first start to last end. Overlap within a lane (depth>1
    # windows) can push utilization past 100% — that is pipelining, and
    # worth seeing.
    lanes: dict[str, list[tuple[float, float]]] = defaultdict(list)
    for ev in spans:
        if track_of(ev) == "serve" and ev.get("name") == "request":
            lanes[thread_of(ev)].append(
                (ev.get("ts", 0.0), ev.get("dur", 0.0))
            )
    if lanes:
        lines.append("")
        lines.append("serve lanes:")
        for lane, rows in sorted(lanes.items()):
            busy = sum(dur for _, dur in rows)
            start = min(ts for ts, _ in rows)
            end = max(ts + dur for ts, dur in rows)
            window = end - start
            util = busy / window * 100 if window > 0 else 0.0
            lines.append(
                f"  {lane}: {len(rows)} requests, busy {_fmt_us(busy)} "
                f"over {_fmt_us(window)} ({util:.0f}% util)"
            )

    # -- batcher summary ---------------------------------------------------
    batches: dict[str, list[dict]] = defaultdict(list)
    for ev in spans:
        if track_of(ev) == "batcher":
            batches[thread_of(ev)].append(ev)
    if batches:
        lines.append("")
        lines.append("batcher queues:")
        for queue, rows in sorted(batches.items()):
            slots = sum(ev.get("args", {}).get("width", 0) for ev in rows)
            filled = sum(ev.get("args", {}).get("filled", 0) for ev in rows)
            causes: dict[str, int] = defaultdict(int)
            for ev in rows:
                causes[ev.get("args", {}).get("cause", "?")] += 1
            cause_s = ", ".join(
                f"{k}={v}" for k, v in sorted(causes.items())
            )
            occ = filled / slots * 100 if slots else 0.0
            lines.append(
                f"  {queue}: {len(rows)} batches, occupancy {occ:.0f}% "
                f"({filled}/{slots} slots), causes: {cause_s}"
            )
    return lines


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        description="Summarize a --trace-out Chrome trace-event file"
    )
    ap.add_argument("trace", help="path to the trace JSON")
    ap.add_argument("--top", type=int, default=10,
                    help="how many top spans to list (default 10)")
    args = ap.parse_args(argv)
    try:
        events = load_trace(args.trace)
    except TraceError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2
    for line in report(events, top=args.top):
        print(line)
    return 0


if __name__ == "__main__":
    sys.exit(main())
