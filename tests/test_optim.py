"""Optimizer substrate: AdamW reference check, schedules, clipping."""

import numpy as np

import jax
import jax.numpy as jnp

from repro.optim import AdamW, clip_by_global_norm, global_norm, warmup_cosine


def test_adamw_matches_reference_implementation():
    """One step against a hand-computed Adam update."""
    opt = AdamW(b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0)
    p = {"w": jnp.asarray([1.0, -2.0])}
    g = {"w": jnp.asarray([0.5, 0.25])}
    s = opt.init(p)
    new_p, new_s = opt.update(g, s, p, lr=0.1)
    # step 1: m=0.1g/0.1=g (bias-corrected), v=g² corrected → update = g/|g|
    want = np.array([1.0, -2.0]) - 0.1 * np.array([0.5, 0.25]) / (
        np.sqrt(np.array([0.25, 0.0625])) + 1e-8
    )
    np.testing.assert_allclose(np.asarray(new_p["w"]), want, rtol=1e-5)
    assert int(new_s.step) == 1


def test_adamw_weight_decay_only_on_matrices():
    opt = AdamW(weight_decay=0.1)
    p = {"mat": jnp.ones((2, 2)), "vec": jnp.ones((2,))}
    g = jax.tree.map(jnp.zeros_like, p)
    s = opt.init(p)
    new_p, _ = opt.update(g, s, p, lr=0.1)
    assert float(new_p["mat"][0, 0]) < 1.0  # decayed
    assert float(new_p["vec"][0]) == 1.0  # exempt


def test_adamw_bf16_moments_track_fp32():
    opt32 = AdamW(moment_dtype="float32", weight_decay=0.0)
    opt16 = AdamW(moment_dtype="bfloat16", weight_decay=0.0)
    p = {"w": jnp.ones((16,))}
    s32, s16 = opt32.init(p), opt16.init(p)
    assert jax.tree.leaves(s16.m)[0].dtype == jnp.bfloat16
    p32, p16 = dict(p), dict(p)
    for i in range(10):
        g = {"w": jnp.full((16,), 0.1 * (i + 1))}
        p32, s32 = opt32.update(g, s32, p32, lr=0.01)
        p16, s16 = opt16.update(g, s16, p16, lr=0.01)
    np.testing.assert_allclose(
        np.asarray(p32["w"]), np.asarray(p16["w"]), rtol=0.05
    )


def test_warmup_cosine_shape():
    lrs = [float(warmup_cosine(s, peak_lr=1.0, warmup_steps=10, total_steps=100))
           for s in range(101)]
    assert lrs[0] == 0.0
    assert abs(lrs[10] - 1.0) < 1e-6
    assert max(lrs) <= 1.0 + 1e-6
    assert lrs[100] < 0.2  # decays to final_fraction
    assert all(b <= a + 1e-9 for a, b in zip(lrs[10:], lrs[11:]))  # monotone decay


def test_clip_by_global_norm():
    tree = {"a": jnp.ones((4,)) * 3, "b": jnp.ones((4,)) * 4}
    norm = float(global_norm(tree))
    assert abs(norm - 10.0) < 1e-5
    clipped, n = clip_by_global_norm(tree, 5.0)
    assert abs(float(global_norm(clipped)) - 5.0) < 1e-4
    # No-op below the threshold
    same, _ = clip_by_global_norm(tree, 100.0)
    np.testing.assert_allclose(np.asarray(same["a"]), 3.0, rtol=1e-6)


def test_train_step_reduces_loss_and_accum_matches():
    import functools

    from repro.configs import get_smoke_config
    from repro.data import SyntheticLM
    from repro.models import Model
    from repro.runtime.steps import make_train_step
    import dataclasses

    cfg = dataclasses.replace(get_smoke_config("qwen1.5-0.5b"), dtype="float32")
    model = Model(cfg, remat=False)
    params = model.init(jax.random.key(0))
    opt = AdamW()
    opt_state = opt.init(params)
    sched = functools.partial(warmup_cosine, peak_lr=5e-3, warmup_steps=2, total_steps=40)
    step = jax.jit(make_train_step(model, opt, sched))
    data = SyntheticLM(vocab=cfg.vocab, batch=8, seq=16)
    losses = []
    for i in range(25):
        params, opt_state, m = step(params, opt_state, data.batch_at(i))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.2, losses

    # microbatch accumulation ≈ full batch gradient step
    step2 = jax.jit(make_train_step(model, opt, sched, accum=2))
    b = data.batch_at(100)
    p1, _, _ = step(params, opt_state, b)
    p2, _, _ = step2(params, opt_state, b)
    for a, c in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c), rtol=1e-3, atol=1e-5)
