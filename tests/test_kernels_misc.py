"""Softmax / LRN / avgpool / SRAD / prefix-scan / bitonic-sort kernels vs oracles."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.avgpool import avgpool_pallas
from repro.kernels.bitonic_sort import bitonic_sort_pallas
from repro.kernels.lrn import lrn_pallas
from repro.kernels.prefix_scan import prefix_scan_pallas
from repro.kernels.softmax import softmax_pallas
from repro.kernels.srad_stencil import srad_step_fused, srad_step_split


@pytest.mark.parametrize("rows,cols", [(1, 8), (33, 257), (64, 64), (7, 1031)])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_softmax(rng, rows, cols, dtype):
    x = (5 * jnp.asarray(rng.normal(size=(rows, cols)).astype(np.float32))).astype(dtype)
    out = softmax_pallas(x, block_rows=16, block_cols=64, interpret=True)
    want = ref.softmax_ref(x)
    tol = 1e-5 if dtype == np.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32), rtol=tol, atol=tol
    )


@pytest.mark.parametrize("n,c,h,w", [(1, 5, 4, 4), (2, 13, 9, 11), (3, 64, 8, 8)])
@pytest.mark.parametrize("size", [3, 5])
def test_lrn(rng, n, c, h, w, size):
    x = jnp.asarray(rng.normal(size=(n, c, h, w)).astype(np.float32))
    out = lrn_pallas(x, size=size, block_s=16, interpret=True)
    want = ref.lrn_ref(x, size=size)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("shape,ks", [((1, 3, 4, 4), 2), ((2, 5, 8, 12), 2), ((1, 8, 9, 9), 3)])
def test_avgpool(rng, shape, ks):
    x = jnp.asarray(rng.normal(size=shape).astype(np.float32))
    out = avgpool_pallas(x, ksize=ks, block_c=4, interpret=True)
    want = ref.avgpool_ref(x, ksize=ks)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("h,w", [(8, 8), (32, 48), (65, 33)])
def test_srad_fused_and_split(rng, h, w):
    img = jnp.asarray(rng.uniform(0.2, 1.0, size=(h, w)).astype(np.float32))
    want = ref.srad_step_ref(img)
    for fn in (srad_step_fused, srad_step_split):
        out = fn(img, interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("n,bn", [(8, 8), (1000, 128), (4096, 512), (5, 3)])
def test_prefix_scan(rng, n, bn):
    x = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
    out = prefix_scan_pallas(x, block_n=bn, interpret=True)
    want = ref.prefix_scan_ref(x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("n", [2, 8, 64, 1024])
def test_bitonic_sort(rng, n):
    keys = jnp.asarray(rng.integers(0, 1 << 20, n).astype(np.int32))
    vals = jnp.arange(n, dtype=jnp.int32)
    ko, vo = bitonic_sort_pallas(keys, vals, interpret=True)
    rk, rv = ref.sort_kv_ref(keys, vals)
    np.testing.assert_array_equal(np.asarray(ko), np.asarray(rk))
    # Same pairing: keys[vo] == ko
    np.testing.assert_array_equal(np.asarray(keys)[np.asarray(vo)], np.asarray(ko))


def test_bitonic_sort_floats(rng):
    keys = jnp.asarray(rng.normal(size=(256,)).astype(np.float32))
    vals = jnp.arange(256, dtype=jnp.int32)
    ko, vo = bitonic_sort_pallas(keys, vals, interpret=True)
    assert np.all(np.diff(np.asarray(ko)) >= 0)
