"""Tests for the static contract checker (``repro.check``).

Each rule gets a minimal fixture tree with a seeded violation and an
assertion that ``python -m repro.check`` would exit nonzero on it; the
final test asserts the live repo is check-clean, which is the invariant
CI enforces.
"""

from __future__ import annotations

import json
import textwrap
from pathlib import Path

import pytest

from repro.check import run_checks
from repro.check.__main__ import main
from repro.check.schema import update_fingerprint

REPO_ROOT = Path(__file__).resolve().parents[1]


def write_tree(root: Path, files: dict[str, str]) -> Path:
    for rel, content in files.items():
        path = root / rel
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(textwrap.dedent(content))
    return root


def rule_lines(findings, rule):
    return [(f.file, f.line) for f in findings if f.rule == rule]


def messages(findings):
    return "\n".join(f.message for f in findings)


# --- workload-contract ---------------------------------------------------


def workload_fixture(tmp_path: Path) -> Path:
    return write_tree(
        tmp_path,
        {
            "src/repro/kernels/ops.py": """\
                from repro.kernels import badkern as _bad_mod

                PALLAS_OPS = {"badkern": _bad_mod}
            """,
            "src/repro/kernels/badkern.py": """\
                def tune_space():
                    return ({"block": 0},)
            """,
            "src/repro/bench/level0/foo.py": """\
                def register():
                    return Workload(name="foo", pallas_kernel="nope")
            """,
        },
    )


def test_workload_contract_fires(tmp_path):
    root = workload_fixture(tmp_path)
    findings = run_checks(root, rules=["workload-contract"])
    msgs = messages(findings)
    assert "positive int literals" in msgs  # block: 0 in tune_space
    assert "batch_dims" in msgs  # Workload() without batch_dims
    assert "'nope' is not a key" in msgs  # unknown pallas_kernel
    assert main(["--root", str(root), "--rules", "workload-contract"]) == 1


def test_workload_contract_checks_kernel_passed_through_helpers(tmp_path):
    root = workload_fixture(tmp_path)
    (root / "src/repro/bench/level0/foo.py").write_text(
        textwrap.dedent("""\
            def register():
                # Not a Workload() call: kernel rides a construction helper.
                return make_workload(name="foo", pallas_kernel="bogus")
        """)
    )
    findings = run_checks(root, rules=["workload-contract"])
    assert "'bogus' is not a key" in messages(findings)


def test_workload_contract_ignores_strings_in_conditional_test(tmp_path):
    root = workload_fixture(tmp_path)
    (root / "src/repro/kernels/badkern.py").write_text(
        "def tune_space():\n    return ({},)\n"
    )
    (root / "src/repro/bench/level0/foo.py").write_text(
        textwrap.dedent("""\
            def register(impl):
                return Workload(
                    name="foo",
                    batch_dims=(0,),
                    # "other" sits in the test position, not a kernel name.
                    pallas_kernel="badkern" if impl == "other" else None,
                )
        """)
    )
    assert run_checks(root, rules=["workload-contract"]) == []


def test_workload_contract_accepts_optout_and_known_kernel(tmp_path):
    root = workload_fixture(tmp_path)
    (root / "src/repro/bench/level0/foo.py").write_text(
        textwrap.dedent("""\
            def register():
                return Workload(
                    name="foo", batch_dims=None, pallas_kernel="badkern"
                )
        """)
    )
    (root / "src/repro/kernels/badkern.py").write_text(
        "def tune_space():\n    return ({},)\n"
    )
    assert run_checks(root, rules=["workload-contract"]) == []


# --- cache-key -----------------------------------------------------------


def cachekey_fixture(tmp_path: Path) -> Path:
    return write_tree(
        tmp_path,
        {
            "src/repro/core/plan.py": """\
                import dataclasses

                @dataclasses.dataclass(frozen=True)
                class Placement:
                    devices: int
                    mode: str
            """,
            "src/repro/core/engine.py": """\
                class Engine:
                    def _cache_key(self, spec, preset, placement, impl):
                        return (spec, preset, placement.devices)

                    def _bucket_key(self, spec, preset, placement):
                        return (spec, preset, placement.devices, placement.mode)

                    def load(self, spec):
                        return self.disk_cache.load((spec, "adhoc"), None)
            """,
            "src/repro/core/hlocache.py": """\
                import hashlib

                class HloDiskCache:
                    def _path(self, key):
                        return hashlib.sha256(repr(key[0]).encode()).hexdigest()
            """,
        },
    )


def test_cache_key_fires(tmp_path):
    root = cachekey_fixture(tmp_path)
    findings = run_checks(root, rules=["cache-key"])
    msgs = messages(findings)
    assert "'impl' never reaches the key" in msgs
    assert "omits Placement.mode" in msgs
    assert "axis joined only one of them" in msgs  # 3- vs 4-arity key tuples
    assert "not built ad hoc" in msgs
    assert "must not subscript the key" in msgs
    assert main(["--root", str(root), "--rules", "cache-key"]) == 1


def test_cache_key_accepts_builder_bound_keys(tmp_path):
    root = cachekey_fixture(tmp_path)
    (root / "src/repro/core/engine.py").write_text(
        textwrap.dedent("""\
            class Engine:
                def _cache_key(self, spec, preset, placement, impl):
                    return (spec, preset, placement.devices, placement.mode, impl)

                def _bucket_key(self, spec, preset, placement, impl, width):
                    base = (spec, preset, placement.devices, placement.mode, impl)
                    return base if width == 1 else base + ("vmap", width)

                def load(self, spec, preset, placement, impl):
                    key = self._cache_key(spec, preset, placement, impl)

                    def build():
                        # Closure capture of `key` is a legal binding.
                        return self.disk_cache.load(key, None)

                    return build()
        """)
    )
    (root / "src/repro/core/hlocache.py").write_text(
        textwrap.dedent("""\
            import hashlib

            class HloDiskCache:
                def _path(self, key):
                    return hashlib.sha256(repr(key).encode()).hexdigest()
        """)
    )
    assert run_checks(root, rules=["cache-key"]) == []


# --- stage-discipline ----------------------------------------------------


def stage_fixture(tmp_path: Path) -> Path:
    return write_tree(
        tmp_path,
        {
            "src/repro/core/engine.py": """\
                class Engine:
                    def run_one(self, spec):
                        entry = self._stage_measure(spec)
                        return entry
            """,
            "src/repro/core/harness.py": """\
                def time_fn(fn, tracer):
                    tracer.counters.inc("samples", 1)
                    if tracer.enabled:
                        tracer.counters.inc("guarded", 1)
                    return fn()
            """,
        },
    )


def test_stage_discipline_fires(tmp_path):
    root = stage_fixture(tmp_path)
    findings = run_checks(root, rules=["stage-discipline"])
    msgs = messages(findings)
    assert "_stage_measure() called outside a _timed_stage span" in msgs
    assert "without an `if tracer.enabled:` guard" in msgs
    # The guarded inc() on the next line must NOT be flagged.
    hot = [f for f in findings if f.file == "src/repro/core/harness.py"]
    assert len(hot) == 1 and hot[0].line == 2
    assert main(["--root", str(root), "--rules", "stage-discipline"]) == 1


def test_stage_discipline_accepts_timed_calls(tmp_path):
    root = stage_fixture(tmp_path)
    (root / "src/repro/core/engine.py").write_text(
        textwrap.dedent("""\
            class Engine:
                def run_one(self, spec):
                    timings = {}
                    with self._timed_stage("measure", timings):
                        entry = self._stage_measure(spec)
                    return entry

                def _stage_tune(self, spec):
                    # Nested stage calls run inside the caller's span.
                    return self._stage_compile(spec)
        """)
    )
    (root / "src/repro/core/harness.py").write_text(
        "def time_fn(fn):\n    return fn()\n"
    )
    assert run_checks(root, rules=["stage-discipline"]) == []


# --- schema-drift --------------------------------------------------------


RESULTS_V3 = """\
    SCHEMA_VERSION = 3

    class BenchmarkRecord:
        name: str
        us_per_call: float

    class RunMetadata:
        backend: str

    def csv_header():
        return "name,us_per_call"
"""


def test_schema_drift_missing_fingerprint_fires(tmp_path):
    root = write_tree(tmp_path, {"src/repro/core/results.py": RESULTS_V3})
    findings = run_checks(root, rules=["schema-drift"])
    assert "fingerprint is missing" in messages(findings)
    assert main(["--root", str(root), "--rules", "schema-drift"]) == 1


def test_schema_drift_shape_change_without_bump_fires(tmp_path):
    root = write_tree(tmp_path, {"src/repro/core/results.py": RESULTS_V3})
    update_fingerprint(root)
    assert run_checks(root, rules=["schema-drift"]) == []
    # Grow the record without touching SCHEMA_VERSION.
    (root / "src/repro/core/results.py").write_text(
        textwrap.dedent(RESULTS_V3).replace(
            "us_per_call: float", "us_per_call: float\n    extra: int"
        )
    )
    findings = run_checks(root, rules=["schema-drift"])
    assert "without a SCHEMA_VERSION bump" in messages(findings)


def test_schema_drift_bump_requires_regenerated_fingerprint(tmp_path):
    root = write_tree(tmp_path, {"src/repro/core/results.py": RESULTS_V3})
    update_fingerprint(root)
    (root / "src/repro/core/results.py").write_text(
        textwrap.dedent(RESULTS_V3).replace(
            "SCHEMA_VERSION = 3", "SCHEMA_VERSION = 4"
        )
    )
    findings = run_checks(root, rules=["schema-drift"])
    assert "regenerate" in messages(findings)
    update_fingerprint(root)
    assert run_checks(root, rules=["schema-drift"]) == []


def test_schema_drift_csv_header_must_name_record_fields(tmp_path):
    root = write_tree(
        tmp_path,
        {
            "src/repro/core/results.py": textwrap.dedent(RESULTS_V3).replace(
                '"name,us_per_call"', '"name,bogus_column"'
            )
        },
    )
    update_fingerprint(root)
    findings = run_checks(root, rules=["schema-drift"])
    assert "'bogus_column'" in messages(findings)


# --- concurrency ---------------------------------------------------------


SINK_UNLOCKED = """\
    import threading

    class Sink:
        def __init__(self):
            self._lock = threading.Lock()
            self._items = []

        def add(self, item):
            self._items.append(item)

        def harvest(self):
            with self._lock:
                out = list(self._items)
                self._items.clear()
            return out
"""


def test_concurrency_fires_on_unlocked_mutation(tmp_path):
    root = write_tree(tmp_path, {"src/repro/serve/sink.py": SINK_UNLOCKED})
    findings = run_checks(root, rules=["concurrency"])
    assert rule_lines(findings, "concurrency") == [
        ("src/repro/serve/sink.py", 9)
    ]
    assert "outside `with self._lock:`" in messages(findings)
    assert main(["--root", str(root), "--rules", "concurrency"]) == 1


def test_concurrency_skips_lockfree_and_locked_classes(tmp_path):
    root = write_tree(
        tmp_path,
        {
            "src/repro/serve/sink.py": textwrap.dedent(SINK_UNLOCKED).replace(
                "        self._items.append(item)",
                "        with self._lock:\n            self._items.append(item)",
            ),
            # No lock attribute: single-owner by design, out of scope.
            "src/repro/serve/tally.py": """\
                class Tally:
                    def __init__(self):
                        self.counts = {}

                    def bump(self, k):
                        self.counts[k] = self.counts.get(k, 0) + 1
            """,
        },
    )
    assert run_checks(root, rules=["concurrency"]) == []


def test_concurrency_covers_dist_scope(tmp_path):
    root = write_tree(tmp_path, {"src/repro/dist/sink.py": SINK_UNLOCKED})
    findings = run_checks(root, rules=["concurrency"])
    assert rule_lines(findings, "concurrency") == [
        ("src/repro/dist/sink.py", 9)
    ]


# --- dist-proto ----------------------------------------------------------


PROTO_UNREGISTERED = """\
    import dataclasses

    @dataclasses.dataclass(frozen=True)
    class Hello:
        proc_id: int

    @dataclasses.dataclass(frozen=True)
    class Rogue:
        payload: str

    MESSAGE_TYPES = {"hello": Hello}
"""


def test_dist_proto_fires_on_unregistered_dataclass(tmp_path):
    root = write_tree(tmp_path, {"src/repro/dist/proto.py": PROTO_UNREGISTERED})
    findings = run_checks(root, rules=["dist-proto"])
    assert rule_lines(findings, "dist-proto") == [
        ("src/repro/dist/proto.py", 8)
    ]
    assert "would encode but never decode" in messages(findings)
    assert main(["--root", str(root), "--rules", "dist-proto"]) == 1


def test_dist_proto_fires_on_computed_registry(tmp_path):
    root = write_tree(
        tmp_path,
        {
            "src/repro/dist/proto.py": """\
                import dataclasses

                @dataclasses.dataclass(frozen=True)
                class Hello:
                    proc_id: int

                MESSAGE_TYPES = dict(hello=Hello)
            """
        },
    )
    findings = run_checks(root, rules=["dist-proto"])
    assert "dict literal" in messages(findings)


def test_dist_proto_fires_on_non_stdlib_import(tmp_path):
    root = write_tree(
        tmp_path,
        {
            "src/repro/dist/proto.py": PROTO_UNREGISTERED.replace(
                "import dataclasses",
                "import dataclasses\n    import jax",
            )
        },
    )
    findings = run_checks(root, rules=["dist-proto"])
    assert "pure-stdlib" in messages(findings)


# --- suppression ---------------------------------------------------------


def test_suppression_comment_silences_one_rule(tmp_path):
    root = write_tree(
        tmp_path,
        {
            "src/repro/serve/sink.py": textwrap.dedent(SINK_UNLOCKED).replace(
                "self._items.append(item)",
                "self._items.append(item)  # repro-check: ignore[concurrency]",
            )
        },
    )
    assert run_checks(root, rules=["concurrency"]) == []


def test_suppression_on_preceding_line_and_star(tmp_path):
    root = write_tree(
        tmp_path,
        {
            "src/repro/serve/sink.py": textwrap.dedent(SINK_UNLOCKED).replace(
                "        self._items.append(item)",
                "        # repro-check: ignore[*]\n"
                "        self._items.append(item)",
            )
        },
    )
    assert run_checks(root, rules=["concurrency"]) == []


def test_suppression_for_other_rule_does_not_silence(tmp_path):
    root = write_tree(
        tmp_path,
        {
            "src/repro/serve/sink.py": textwrap.dedent(SINK_UNLOCKED).replace(
                "self._items.append(item)",
                "self._items.append(item)  # repro-check: ignore[cache-key]",
            )
        },
    )
    assert len(run_checks(root, rules=["concurrency"])) == 1


# --- CLI -----------------------------------------------------------------


def test_cli_json_output(tmp_path, capsys):
    root = write_tree(tmp_path, {"src/repro/serve/sink.py": SINK_UNLOCKED})
    code = main(["--root", str(root), "--json"])
    assert code == 1
    payload = json.loads(capsys.readouterr().out)
    assert payload["count"] == len(payload["findings"]) == 1
    finding = payload["findings"][0]
    assert finding["rule"] == "concurrency"
    assert finding["file"] == "src/repro/serve/sink.py"
    assert finding["severity"] == "error"


def test_cli_unknown_rule_exits_2(tmp_path, capsys):
    assert main(["--root", str(tmp_path), "--rules", "no-such-rule"]) == 2
    assert "unknown rule" in capsys.readouterr().err


def test_cli_empty_tree_is_green(tmp_path, capsys):
    # Checkers skip when their target files are absent.
    assert main(["--root", str(tmp_path)]) == 0
    assert "0 findings" in capsys.readouterr().out


def test_cli_update_fingerprint_roundtrip(tmp_path, capsys):
    root = write_tree(tmp_path, {"src/repro/core/results.py": RESULTS_V3})
    assert main(["--root", str(root), "--update-schema-fingerprint"]) == 0
    capsys.readouterr()
    fp = root / "src/repro/check/schema_fingerprint.json"
    committed = json.loads(fp.read_text())
    assert committed["schema_version"] == 3
    assert committed["record_fields"] == ["name", "us_per_call"]
    assert committed["csv_header"] == "name,us_per_call"


# --- the live repo -------------------------------------------------------


def test_live_repo_is_check_clean():
    findings = run_checks(REPO_ROOT)
    assert findings == [], "\n" + "\n".join(f.format() for f in findings)


def test_live_repo_fingerprint_is_current():
    # The committed fingerprint must match what --update-schema-fingerprint
    # would write today, byte for byte.
    from repro.check.core import Context
    from repro.check.schema import FINGERPRINT_FILE, compute_schema

    committed = json.loads((REPO_ROOT / FINGERPRINT_FILE).read_text())
    assert committed == compute_schema(Context(REPO_ROOT))


@pytest.mark.parametrize(
    "rule",
    [
        "workload-contract",
        "cache-key",
        "stage-discipline",
        "schema-drift",
        "concurrency",
        "dist-proto",
    ],
)
def test_every_rule_is_registered(rule):
    from repro.check import all_checkers

    assert rule in {c.rule for c in all_checkers()}
