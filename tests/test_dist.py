"""Distributed load generation: wire-protocol round-trips, seeded
sub-schedule determinism (byte-identical merged traces), merged-stream
accounting identity, plan validation, and the launcher end-to-end (real
client subprocesses against a shared executable cache)."""

import dataclasses
import socket

import pytest

from repro.core.plan import PlanError, ServeSpec
from repro.dist import proto
from repro.serve.latency import stats_from_completions
from repro.serve.lanes import Completion
from repro.serve.loadgen import (
    merge_schedules,
    open_loop_lane_schedules,
    open_loop_schedule,
    save_trace,
)

_SAMPLES = {
    "hello": proto.Hello(proc_id=3, pid=4242),
    "assign": proto.Assign(
        benchmark="pathfinder", preset=0, overrides={"rows": 64},
        serve={"mode": "open", "qps": 100.0}, seed=7, proc_id=1, n_procs=4,
        warmup=8, devices=1, placement="replicate", impl="xla",
        cache_dir="/tmp/c",
    ),
    "ready": proto.Ready(proc_id=1, requests=97),
    "start": proto.Start(epoch=1723.25),
    "stamp": proto.Stamp(
        proc_id=1, completions=[[0, 0, 0.001, 0.002, True], [1, 0, 0.01, 0.02, False]]
    ),
    "done": proto.Done(
        proc_id=1, requests=97, truncated=False,
        cache_counters={"xla_compiles": 0, "exe_hits": 1},
    ),
    "error": proto.Error(proc_id=2, message="boom"),
}


def test_every_registered_message_type_roundtrips():
    assert set(_SAMPLES) == set(proto.MESSAGE_TYPES)
    for tag, msg in _SAMPLES.items():
        frame = proto.encode(msg)
        assert proto.decode(frame[proto._HEADER.size:]) == msg


def test_socket_framing_preserves_message_order():
    a, b = socket.socketpair()
    try:
        for msg in _SAMPLES.values():
            proto.send_msg(a, msg)
        for msg in _SAMPLES.values():
            assert proto.recv_msg(b) == msg
        a.close()
        with pytest.raises(proto.ConnectionClosed):
            proto.recv_msg(b)
    finally:
        a.close()
        b.close()


def test_decode_rejects_garbage_and_unknown_types():
    with pytest.raises(proto.ProtocolError):
        proto.decode(b"not json")
    with pytest.raises(proto.ProtocolError):
        proto.decode(b'{"type":"warp-drive"}')
    with pytest.raises(proto.ProtocolError):
        proto.decode(b'{"type":"ready"}')  # missing required fields
    with pytest.raises(proto.ProtocolError):
        proto.encode(object())  # unregistered type


def test_subschedules_deterministic_and_merged_trace_byte_identical(tmp_path):
    kw = dict(qps=400.0, duration_s=2.0, n_lanes=4, seed=123, warmup=6)
    subs_a = open_loop_lane_schedules(**kw)
    subs_b = open_loop_lane_schedules(**kw)
    assert [s.requests for s in subs_a] == [s.requests for s in subs_b]
    # Each sub-stream carries its share of the target; the merged stream
    # is the full offered load in arrival order with dense global indices.
    merged_a = merge_schedules(subs_a)
    merged_b = merge_schedules(subs_b)
    assert merged_a.offered_qps == pytest.approx(400.0)
    assert [r.index for r in merged_a.requests] == list(range(len(merged_a.requests)))
    pa, pb = tmp_path / "a.jsonl", tmp_path / "b.jsonl"
    save_trace(merged_a, str(pa))
    save_trace(merged_b, str(pb))
    assert pa.read_bytes() == pb.read_bytes()
    # A different seed is a different stream — the traces must not collide.
    other = merge_schedules(open_loop_lane_schedules(**{**kw, "seed": 124}))
    save_trace(other, str(pb))
    assert pa.read_bytes() != pb.read_bytes()


def _synthetic_completions(n_procs: int, lanes: int, per_lane: int):
    """Per-process completion lists with distinct latencies everywhere."""
    streams = []
    k = 0
    for _ in range(n_procs):
        rows = []
        for lane in range(lanes):
            for i in range(per_lane):
                t = 0.01 * k
                rows.append(Completion(
                    index=k, lane=lane, t_submit=t, t_done=t + 0.001 * (k % 17 + 1),
                    warmup=k < 3,
                ))
                k += 1
        streams.append(rows)
    return streams


def test_merged_stream_percentiles_equal_concatenated_stream():
    lanes = 2
    streams = _synthetic_completions(n_procs=3, lanes=lanes, per_lane=40)
    # The launcher's merge: relabel to global lanes, order by t_done.
    merged = sorted(
        (
            dataclasses.replace(c, lane=proc_id * lanes + c.lane)
            for proc_id, rows in enumerate(streams)
            for c in rows
        ),
        key=lambda c: c.t_done,
    )
    concat = [c for rows in streams for c in rows]
    a = stats_from_completions(merged, offered_qps=300.0, n_lanes=3 * lanes)
    b = stats_from_completions(concat, offered_qps=300.0)
    assert (a.p50_us, a.p95_us, a.p99_us) == (b.p50_us, b.p95_us, b.p99_us)
    assert a.requests == b.requests
    assert a.achieved_qps == pytest.approx(b.achieved_qps)
    assert a.lane_qps is not None and len(a.lane_qps) == 3 * lanes


def test_too_short_duration_yields_explicit_empty_schedule():
    sched = open_loop_schedule(qps=0.5, duration_s=1e-9, seed=0)
    assert len(sched) == 0
    assert sched.truncated is False
    assert sched.offered_qps == 0.5
    with pytest.raises(ValueError, match="schedule was empty"):
        stats_from_completions(list(sched), offered_qps=0.5)


def test_servespec_client_procs_validation():
    ok = ServeSpec(mode="open", qps=10.0, duration_s=1.0, client_procs=2)
    assert ok.client_procs == 2
    with pytest.raises(PlanError):
        ServeSpec(mode="open", qps=10.0, duration_s=1.0, client_procs=-1)
    with pytest.raises(PlanError):
        ServeSpec(mode="closed", client_procs=2)
    with pytest.raises(PlanError):
        ServeSpec(mode="open", qps=10.0, duration_s=1.0, client_procs=2,
                  dispatch="batched")
    with pytest.raises(PlanError):
        ServeSpec(mode="open", qps=10.0, duration_s=1.0, client_procs=2,
                  client="threaded")


def test_launcher_two_procs_merged_accounting_and_warm_zero_compiles(tmp_path):
    from repro.dist.launcher import DistLatencyStats, run_distributed

    serve = ServeSpec(mode="open", qps=120.0, duration_s=0.75,
                      concurrency=8, lanes=1, client_procs=2)
    kw = dict(benchmark="pathfinder", preset=0, overrides={}, serve=serve,
              seed=11, devices=1, placement_mode="replicate", impl="xla",
              cache_dir=str(tmp_path / "hlo"))
    cold = run_distributed(**kw)
    assert isinstance(cold, DistLatencyStats)
    assert cold.client_procs == 2
    assert cold.proc_qps is not None and len(cold.proc_qps) == 2
    assert cold.requests > 0
    assert "client_procs=2" in cold.derived()
    warm = run_distributed(**kw)
    # Determinism: same seed, same sub-schedules, same request count.
    assert warm.requests == cold.requests
    # Shared-cache contract: a warm distributed run restores executables
    # in every client — zero misses, zero XLA compiles across processes.
    assert warm.client_cache_counters is not None
    assert warm.client_cache_counters["misses"] == 0
    assert warm.client_cache_counters["xla_compiles"] == 0
    assert warm.client_cache_counters["exe_hits"] == 2


def test_engine_routes_client_procs_and_record_carries_dist_fields(tmp_path):
    from repro.core.engine import Engine
    from repro.core.plan import ExecutionPlan

    serve = ServeSpec(mode="open", qps=120.0, duration_s=0.75,
                      concurrency=8, lanes=1, client_procs=2)
    eng = Engine(cache_dir=str(tmp_path / "hlo"))
    res = eng.run(ExecutionPlan(
        names=("pathfinder",), preset=0, iters=1, warmup=0,
        include_backward=False, serve=serve, seed=5,
    ))
    rec = res.records[0]
    assert rec.status == "ok", rec.error
    assert rec.client_procs == 2
    assert rec.proc_qps is not None and len(rec.proc_qps) == 2
    assert "client_procs=2" in rec.csv()
    assert rec.achieved_qps is not None and rec.achieved_qps > 0
