"""Placement layer: batch_dims declarations, shard-vs-replicate numerics,
device-scaling sweeps, and the suite CLI's placement surface.

Multi-device cases run in subprocesses with forced host devices
(``XLA_FLAGS=--xla_force_host_platform_device_count=8``) so the parent
pytest process keeps the real single-CPU device view — the same pattern as
test_distributed.py. Plan/record-shape cases run in-process on one device.
"""

import os
import subprocess
import sys
import textwrap

import pytest

from repro.core.plan import ExecutionPlan, Placement, PlanError
from repro.core.registry import Workload, all_benchmarks, get_benchmark
from repro.core.results import SCHEMA_VERSION, BenchmarkRecord

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(script: str, devices: int = 8, timeout: int = 420) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = _SRC
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(script)],
        env=env, capture_output=True, text=True, timeout=timeout,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


# -- plan / placement value objects (single device, in-process) ------------


def test_placement_validation():
    with pytest.raises(PlanError, match="mode"):
        Placement(devices=2, mode="bogus")
    with pytest.raises(PlanError, match="devices"):
        Placement(devices=0)


def test_plan_devices_backcompat_builds_replicate_placement():
    plan = ExecutionPlan(devices=2)
    assert plan.placement == Placement(devices=2, mode="replicate")
    assert plan.devices == 2
    assert plan.device_sweep == (2,)


def test_plan_placement_conflicts_with_devices():
    with pytest.raises(PlanError, match="conflicting"):
        ExecutionPlan(devices=2, placement=Placement(devices=4))


def test_plan_device_sweep_normalizes_sorted_unique():
    plan = ExecutionPlan(device_sweep=(4, 1, 2, 2))
    assert plan.device_sweep == (1, 2, 4)
    with pytest.raises(PlanError, match="device_sweep"):
        ExecutionPlan(device_sweep=())
    with pytest.raises(PlanError, match="device_sweep"):
        ExecutionPlan(device_sweep=(0,))


def test_placement_at_degenerates_to_replicate_on_one_device():
    plan = ExecutionPlan(placement=Placement(devices=1, mode="shard"),
                         device_sweep=(1, 4))
    assert plan.placement_at(1).mode == "replicate"
    assert plan.placement_at(4).mode == "shard"


def test_batch_dims_declarations_match_input_arity():
    """Every declared batch_dims tuple lines up with make_inputs' arity and
    points at a real dimension of the corresponding input."""
    checked = 0
    for spec in all_benchmarks():
        w = spec.build_preset(0)
        if w.batch_dims is None:
            continue
        args = w.make_inputs(0)
        assert len(w.batch_dims) == len(args), spec.name
        for dim, arg in zip(w.batch_dims, args):
            if dim is None:
                continue
            assert hasattr(arg, "shape") and len(arg.shape) > dim, spec.name
        checked += 1
    assert checked >= 5  # the batchable sample exists


def test_expected_batchability_split():
    batchable = {"gemm_f32_nn", "kmeans", "maxflops_bf16", "devicemem_stream",
                 "softmax", "connected", "activation", "mandelbrot_flat"}
    non_batchable = {"bfs", "sort", "gups", "nw", "busspeeddownload",
                     "mandelbrot_ms", "gemm_f32_tn"}
    for name in batchable:
        assert get_benchmark(name).build_preset(0).batchable, name
    for name in non_batchable:
        assert not get_benchmark(name).build_preset(0).batchable, name


def test_record_schema_carries_placement_columns():
    assert SCHEMA_VERSION >= 2
    fields = {f.name for f in __import__("dataclasses").fields(BenchmarkRecord)}
    assert {"devices", "placement", "scaling_efficiency"} <= fields
    assert BenchmarkRecord.csv_header().startswith("name,us_per_call,")


def test_verbose_run_emits_csv_header_once_before_rows(capsys):
    from repro.core.engine import Engine

    Engine().run(
        ExecutionPlan(names=("devicemem_stream",), preset=0, iters=1,
                      warmup=0, include_backward=False),
        verbose=True,
    )
    lines = [ln for ln in capsys.readouterr().out.splitlines() if ln]
    assert lines[0] == BenchmarkRecord.csv_header()
    assert len(lines) == 2  # header + one row, header not repeated
    assert lines[1].startswith("devicemem.stream")


def test_suite_cli_exits_2_with_device_count_on_bad_placement(capsys):
    from repro.core.suite import main

    rc = main(["--names", "gemm_f32_nn", "--devices", "4096"])
    assert rc == 2
    err = capsys.readouterr().err
    assert "4096" in err
    assert "available devices:" in err

    rc = main(["--names", "gemm_f32_nn", "--scale-devices", "1,4096"])
    assert rc == 2


def test_workload_pspecs_requires_declaration():
    from repro.runtime.sharding import data_mesh, workload_pspecs

    w = Workload(name="opted_out", fn=lambda x: x,
                 make_inputs=lambda seed: (1.0,))
    with pytest.raises(ValueError, match="batch_dims"):
        workload_pspecs(w, data_mesh(1))


def test_batch_dims_arity_mismatch_fails_at_placement_boundary():
    import jax.numpy as jnp

    from repro.runtime.sharding import data_mesh, place_args

    w = Workload(name="bad_arity", fn=lambda x, y: x + y,
                 make_inputs=lambda seed: (jnp.zeros(4), jnp.zeros(4)),
                 batch_dims=(0,))  # declares 1 dim for 2 inputs
    with pytest.raises(ValueError, match="declares 1 batch_dims"):
        place_args(w.make_inputs(0), w, data_mesh(1), "shard")


# -- multi-device behaviour (forced-8-device subprocesses) -----------------


def test_sharded_matches_replicated_outputs():
    """Sharding a declared batch dim is placement, not semantics: sharded
    and replicated executions of batchable benchmarks agree numerically."""
    _run("""
        import numpy as np, jax
        from repro.core.registry import get_benchmark
        from repro.runtime.sharding import data_mesh, place_args

        mesh = data_mesh(8)
        # bf16 chains re-tile per shard shape, shifting accumulation order
        # by ~1 ulp; f32 elementwise/row-parallel cases stay tight.
        tols = {"maxflops_bf16": dict(rtol=2e-2, atol=5e-3)}
        for name in ("gemm_f32_nn", "devicemem_stream", "activation",
                     "connected", "kmeans", "maxflops_bf16"):
            w = get_benchmark(name).build_preset(0)
            args = w.make_inputs(0)
            sharded_args, mode = place_args(args, w, mesh, "shard")
            assert mode == "shard", (name, mode)
            replicated_args, rmode = place_args(args, w, mesh, "replicate")
            assert rmode == "replicate", (name, rmode)
            out_s = jax.jit(w.fn).lower(*sharded_args).compile()(*sharded_args)
            out_r = jax.jit(w.fn).lower(*replicated_args).compile()(*replicated_args)
            tol = tols.get(name, dict(rtol=2e-5, atol=2e-5))
            for a, b in zip(jax.tree.leaves(out_s), jax.tree.leaves(out_r)):
                np.testing.assert_allclose(
                    np.asarray(a, dtype=np.float64),
                    np.asarray(b, dtype=np.float64),
                    err_msg=name, **tol,
                )
        print("OK")
    """)


def test_sweep_records_devices_placement_and_efficiency():
    """A shard-mode sweep yields one record per (benchmark, pass, count)
    with correct devices/placement columns, populated scaling_efficiency on
    multi-device rows, replicate fallback for opted-out workloads, and
    monotone non-increasing compile-cache misses across the sweep."""
    _run("""
        from repro.core.engine import Engine
        from repro.core.plan import ExecutionPlan, Placement

        eng = Engine()
        plan = ExecutionPlan(
            names=("gemm_f32_nn", "bfs", "softmax"), preset=0, iters=1,
            warmup=0, include_backward=True,
            placement=Placement(devices=1, mode="shard"),
            device_sweep=(1, 2, 4),
        )
        res = eng.run(plan)
        assert not res.error_records, [(r.name, r.error) for r in res.error_records]
        # one record per (benchmark, pass, device count): 4 rows x 3 counts
        assert len(res.records) == 12, [r.name for r in res.records]
        for r in res.records:
            assert r.devices in (1, 2, 4), r
            base = r.name.split(".")[0]
            if r.devices == 1 or base == "bfs":
                assert r.placement == "replicate", r
            else:
                assert r.placement == "shard", r
            if r.devices > 1:
                assert r.scaling_efficiency is not None and r.scaling_efficiency > 0, r
            else:
                assert r.scaling_efficiency is None, r
        misses = [s.misses for s in res.sweep_stats]
        assert [s.devices for s in res.sweep_stats] == [1, 2, 4]
        assert all(m2 <= m1 for m1, m2 in zip(misses, misses[1:])), misses
        print("OK")
    """)


def test_jsonl_sweep_report_roundtrips_placement():
    _run("""
        import tempfile, os
        from repro.core.engine import Engine
        from repro.core.plan import ExecutionPlan, Placement
        from repro.core.results import load_run

        path = os.path.join(tempfile.mkdtemp(), "sweep.jsonl")
        plan = ExecutionPlan(
            names=("kmeans",), preset=0, iters=1, warmup=0,
            include_backward=False,
            placement=Placement(devices=1, mode="shard"), device_sweep=(1, 2),
        )
        res = Engine().run(plan, jsonl_path=path)
        meta, recs = load_run(path)
        assert meta.placement == "shard" and meta.device_sweep == (1, 2), meta
        assert recs == res.records
        assert [r.devices for r in recs] == [1, 2]
        assert recs[1].scaling_efficiency is not None
        print("OK")
    """)


def test_no_jit_sweep_rows_stay_single_device():
    """Host-bus transfers never run on more than one device: their sweep
    rows must say devices=1 with no fabricated scaling_efficiency (and
    share one compile-cache entry across the sweep)."""
    _run("""
        from repro.core.engine import Engine
        from repro.core.plan import ExecutionPlan, Placement

        eng = Engine()
        res = eng.run(ExecutionPlan(
            names=("busspeeddownload",), preset=0, iters=1, warmup=0,
            include_backward=False,
            placement=Placement(devices=1, mode="shard"), device_sweep=(1, 2, 4),
        ))
        assert not res.error_records, res.error_records
        assert [r.devices for r in res.records] == [1, 1, 1], res.records
        assert all(r.placement == "replicate" for r in res.records)
        assert all(r.scaling_efficiency is None for r in res.records)
        assert eng.cache.misses == 1, eng.cache.misses
        print("OK")
    """)


def test_replicated_sweep_still_measures_redundant_work():
    """Back-compat: replicate mode replicates every workload at every
    count — no shard placements appear anywhere."""
    _run("""
        from repro.core.engine import Engine
        from repro.core.plan import ExecutionPlan

        res = Engine().run(ExecutionPlan(
            names=("gemm_f32_nn",), preset=0, iters=1, warmup=0,
            include_backward=False, device_sweep=(1, 2),
        ))
        assert not res.error_records
        assert [r.placement for r in res.records] == ["replicate", "replicate"]
        print("OK")
    """)
