"""Hypothesis properties on the application benchmarks' invariants."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

import jax.numpy as jnp

_settings = settings(max_examples=15, deadline=None)


@_settings
@given(st.sampled_from(["53", "97"]), st.integers(3, 6), st.integers(0, 100))
def test_dwt_perfect_reconstruction(mode, log_n, seed):
    """inverse(forward(x)) == x for both wavelets, any even size."""
    from repro.bench.level2.dwt2d import dwt2d

    n = 2**log_n
    rng = np.random.default_rng(seed)
    img = jnp.asarray(rng.uniform(0, 255, (n, n)).astype(np.float32))
    rec = dwt2d(dwt2d(img, mode=mode), mode=mode, inverse=True)
    np.testing.assert_allclose(np.asarray(rec), np.asarray(img), rtol=1e-4, atol=1e-2)


@_settings
@given(st.floats(0.5, 2.0), st.floats(0.5, 2.0))
def test_cfd_free_stream_preservation(rho, pressure):
    """A uniform state is a fixed point of the Euler update (the standard
    finite-volume sanity property)."""
    from repro.bench.level2.cfd import GAMMA, euler_step

    n = 8
    u = jnp.concatenate(
        [
            jnp.full((1, n, n, n), rho),
            jnp.zeros((3, n, n, n)),
            jnp.full((1, n, n, n), pressure / (GAMMA - 1.0)),
        ]
    )
    u2 = euler_step(u)
    np.testing.assert_allclose(np.asarray(u2), np.asarray(u), rtol=1e-6, atol=1e-6)


@_settings
@given(st.integers(1, 200), st.floats(0.0, 0.4), st.floats(0.6, 1.0), st.integers(0, 99))
def test_where_equals_boolean_filter(n, lo, hi, seed):
    from repro.bench.level2.where import where_select

    rng = np.random.default_rng(seed)
    recs = jnp.asarray(rng.uniform(0, 1, (n, 3)).astype(np.float32))
    out, count = where_select(recs, lo, hi)
    r = np.asarray(recs)
    want = r[(r[:, 0] > lo) & (r[:, 0] < hi)]
    assert int(count) == want.shape[0]
    np.testing.assert_allclose(np.asarray(out)[: int(count)], want, rtol=1e-6)
    assert np.all(np.asarray(out)[int(count):] == 0.0)


@_settings
@given(st.integers(2, 64), st.integers(0, 50))
def test_pathfinder_never_exceeds_straight_path(cols, seed):
    """The min path is ≤ any single column's sum (a valid path)."""
    from repro.bench.level1.pathfinder import pathfinder_min_path

    rng = np.random.default_rng(seed)
    grid = jnp.asarray(rng.integers(0, 10, (8, cols)).astype(np.int32))
    dist = np.asarray(pathfinder_min_path(grid))
    straight = np.asarray(grid).sum(axis=0)
    assert np.all(dist <= straight)


@_settings
@given(st.integers(0, 30))
def test_srad_preserves_positivity(seed):
    """Diffusion of a positive image stays positive and finite."""
    from repro.kernels.ref import srad_step_ref

    rng = np.random.default_rng(seed)
    img = jnp.asarray(np.exp(0.2 * rng.standard_normal((32, 32))).astype(np.float32))
    out = img
    for _ in range(5):
        out = srad_step_ref(out)
    o = np.asarray(out)
    assert np.all(np.isfinite(o)) and np.all(o > 0)
