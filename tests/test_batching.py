"""Continuous batching: shape-bucket mixes, replayable traces, the
dynamic batcher's coalescing/padding policy, and the engine's bucketed
serve path through the compile caches.

Pure-policy tests drive the batcher with counting Python closures (no
device work), so dispatch decisions — full-width, budget expiry,
end-of-stream flush, padding — assert exactly. Engine tests serve a real
workload for a fraction of a second; throughput *comparisons* (dynamic
beats loop) live in tools/smoke.sh --bench, not here, per the
flaky-timing policy.
"""

import dataclasses
import json
import os

import pytest

from repro.core.plan import ExecutionPlan, PlanError, ServeSpec, ShapeBucket
from repro.serve.batcher import (
    BatchExecution,
    BatchReport,
    bucket_widths,
    serve_dynamic,
    serve_fixed_batched,
    serve_mixed_lanes,
    serve_mixed_loop,
)
from repro.serve.loadgen import (
    Request,
    Schedule,
    load_trace,
    merge_schedules,
    open_loop_schedule,
    sample_mix,
    save_trace,
)

FAST = dict(preset=0, iters=1, warmup=0, include_backward=False)
# Narrow-cols pathfinder variants: cheap to compile, cheap to serve.
TINY_MIX = (
    ShapeBucket(preset=0, weight=2.0, overrides=(("cols", 64),)),
    ShapeBucket(preset=0, weight=1.0, overrides=(("cols", 128),)),
)


def _mixed_serve(**kw) -> ServeSpec:
    base = dict(
        mode="open", qps=300.0, duration_s=0.25, concurrency=8,
        dispatch="dynamic", mix=TINY_MIX, batch_budget_us=500.0, max_batch=2,
    )
    base.update(kw)
    return ServeSpec(**base)


# -- merge_schedules edge cases (lane sub-schedules) -----------------------


def test_merge_schedules_tolerates_empty_sublanes():
    """A starved lane contributes an empty sub-schedule; the merge must
    keep its offered share and stay a well-formed stream, not choke or
    drop the lane's rate from the target."""
    busy = Schedule(
        requests=tuple(Request(index=i, arrival_s=0.01 * (i + 1)) for i in range(4)),
        offered_qps=100.0,
    )
    empty = Schedule(requests=(), offered_qps=100.0)
    merged = merge_schedules([busy, empty, empty])
    assert len(merged) == 4
    assert merged.offered_qps == pytest.approx(300.0)  # empty lanes still offer
    assert [r.arrival_s for r in merged] == sorted(r.arrival_s for r in merged)
    assert not merged.truncated
    # All-empty is still a valid (empty) stream at the summed rate.
    all_empty = merge_schedules([empty, empty])
    assert len(all_empty) == 0 and all_empty.offered_qps == pytest.approx(200.0)


def test_merge_schedules_truncation_sticky_through_empty_sublanes():
    """One truncated sub-schedule poisons the merge — even when other
    lanes are empty (an empty truncated lane means its stream was cut
    before its first arrival, which is still under-offering)."""
    busy = Schedule(
        requests=(Request(index=0, arrival_s=0.01),), offered_qps=50.0
    )
    cut = Schedule(requests=(), offered_qps=50.0, truncated=True)
    assert merge_schedules([busy, cut]).truncated
    assert merge_schedules([cut]).truncated
    assert not merge_schedules([busy]).truncated
    with pytest.raises(ValueError, match="at least one"):
        merge_schedules([])


# -- shape-mix sampling ----------------------------------------------------


def test_sample_mix_deterministic_per_seed_and_arrival_preserving():
    sched = open_loop_schedule(qps=800.0, duration_s=0.5, seed=11, warmup=3)
    mix = {"a": 2.0, "b": 1.0}
    one = sample_mix(sched, mix, seed=5)
    two = sample_mix(sched, mix, seed=5)
    assert one == two  # bit-identical bucket assignment
    other = sample_mix(sched, mix, seed=6)
    assert [r.bucket for r in one] != [r.bucket for r in other]
    # The arrival process is untouched: only the bucket field changes.
    for before, after in zip(sched, one):
        assert dataclasses.replace(after, bucket=None) == before
    # Every request got a label from the mix, both labels actually drawn.
    assert {r.bucket for r in one} == {"a", "b"}
    # Mapping and (label, weight) sequence agree when the sequence is in
    # sorted-label order (the mapping is normalized to exactly that).
    assert sample_mix(sched, [("a", 2.0), ("b", 1.0)], seed=5) == one


def test_sample_mix_validation():
    sched = open_loop_schedule(qps=100.0, duration_s=0.1, seed=0)
    with pytest.raises(ValueError, match="at least one"):
        sample_mix(sched, {}, seed=0)
    with pytest.raises(ValueError, match="weights"):
        sample_mix(sched, {"a": 0.0}, seed=0)


# -- trace save / load -----------------------------------------------------


def test_trace_roundtrip_exact(tmp_path):
    sched = sample_mix(
        open_loop_schedule(qps=500.0, duration_s=0.3, seed=2, warmup=2),
        {"p0": 3.0, "p0/cols=64": 1.0},
        seed=2,
    )
    path = str(tmp_path / "trace.jsonl")
    save_trace(sched, path)
    assert load_trace(path) == sched  # buckets, warmup flags, qps, all of it


def test_load_trace_rejects_foreign_and_truncated_files(tmp_path):
    notatrace = tmp_path / "report.jsonl"
    notatrace.write_text(json.dumps({"kind": "run-report"}) + "\n")
    with pytest.raises(ValueError, match="kind='run-report'"):
        load_trace(str(notatrace))
    sched = open_loop_schedule(qps=200.0, duration_s=0.2, seed=0)
    path = tmp_path / "cut.jsonl"
    save_trace(sched, str(path))
    lines = path.read_text().splitlines()
    path.write_text("\n".join(lines[:-1]) + "\n")  # drop the last request
    with pytest.raises(ValueError, match="truncated on disk"):
        load_trace(str(path))


# -- batcher policy (pure Python calls, exact assertions) ------------------


def test_bucket_widths_per_dispatch_policy():
    assert bucket_widths("dynamic", 8) == (1, 2, 4, 8)
    assert bucket_widths("dynamic", 6) == (1, 2, 4, 6)  # non-pow2 reachable
    assert bucket_widths("dynamic", 1) == (1,)
    assert bucket_widths("batched", 4) == (4,)
    assert bucket_widths("loop", 8) == (1,)
    assert bucket_widths("lanes", 8) == (1,)


def test_batch_report_occupancy_math():
    mk = lambda w, f: BatchExecution(  # noqa: E731
        bucket="b", width=w, filled=f, t_dispatch=0.0, t_done=1.0
    )
    report = BatchReport(completions=(), batches=(mk(4, 4), mk(4, 2), mk(2, 2)))
    assert report.total_slots == 10
    assert report.filled_slots == 8
    assert report.occupancy == pytest.approx(0.8)
    assert report.padding_waste == pytest.approx(0.2)
    assert report.mean_width == pytest.approx(10 / 3)
    empty = BatchReport(completions=(), batches=())
    assert empty.occupancy == 0.0 and empty.mean_width == 0.0
    with pytest.raises(ValueError, match="fill"):
        mk(4, 5)
    with pytest.raises(ValueError, match="fill"):
        mk(4, 0)


def _counting_calls(buckets, widths):
    """calls[bucket][width] -> closure counting dispatches per (b, w)."""
    dispatched = []

    def make(b, w):
        return lambda: dispatched.append((b, w))

    return {b: {w: make(b, w) for w in widths} for b in buckets}, dispatched


def _instant(reqs) -> Schedule:
    return Schedule(requests=tuple(reqs), offered_qps=1000.0)


def test_serve_mixed_loop_is_width1_and_fully_occupied():
    calls, dispatched = _counting_calls(["a", "b"], [1])
    sched = _instant(
        Request(index=i, arrival_s=0.0, bucket="ab"[i % 2]) for i in range(6)
    )
    report = serve_mixed_loop(calls, sched)
    assert len(report.completions) == 6
    assert [c.bucket for c in report.completions] == ["a", "b"] * 3
    assert dispatched == [("a", 1), ("b", 1)] * 3
    assert report.occupancy == 1.0 and report.padding_waste == 0.0
    assert all(b.width == 1 for b in report.batches)


def test_serve_mixed_lanes_routes_by_bucket():
    calls, dispatched = _counting_calls(["a", "b"], [1])
    sched = _instant(
        Request(index=i, arrival_s=0.0, bucket="ab"[i % 2]) for i in range(8)
    )
    report = serve_mixed_lanes(calls, sched, n_lanes=2, concurrency=4)
    assert len(report.completions) == 8
    assert sorted(c.index for c in report.completions) == list(range(8))
    assert {c.bucket for c in report.completions} == {"a", "b"}
    assert dispatched.count(("a", 1)) == 4 and dispatched.count(("b", 1)) == 4
    assert report.occupancy == 1.0


def test_dynamic_coalesces_full_width_then_pads_the_flush():
    """7 simultaneous requests, widths (1, 2, 4): a full 4-batch goes out
    first; the end-of-stream flush takes the remaining 3 padded into a
    4-slot program. Occupancy accounts for the one padded slot."""
    calls, dispatched = _counting_calls(["a"], [1, 2, 4])
    sched = _instant(
        Request(index=i, arrival_s=0.0, bucket="a") for i in range(7)
    )
    report = serve_dynamic(calls, sched, budget_s=10.0, concurrency=32)
    assert len(report.completions) == 7
    assert [(b.width, b.filled) for b in report.batches] == [(4, 4), (4, 3)]
    assert dispatched == [("a", 4), ("a", 4)]
    assert report.occupancy == pytest.approx(7 / 8)
    assert report.padding_waste == pytest.approx(1 / 8)


def test_dynamic_budget_expiry_dispatches_partial_batch():
    """Two early requests can't fill max width; with a later arrival still
    pending, only the latency budget can release them — as a width-2
    batch, long before the straggler arrives."""
    calls, _ = _counting_calls(["a"], [1, 2, 4])
    sched = _instant([
        Request(index=0, arrival_s=0.0, bucket="a"),
        Request(index=1, arrival_s=0.0, bucket="a"),
        Request(index=2, arrival_s=0.25, bucket="a"),
    ])
    report = serve_dynamic(calls, sched, budget_s=0.02, concurrency=32)
    first = report.batches[0]
    assert (first.width, first.filled) == (2, 2)
    # Released by the budget (~20ms), not the straggler's arrival (250ms).
    assert first.t_dispatch - report.completions[0].t_submit < 0.15
    assert len(report.completions) == 3


def test_fixed_batched_waits_for_full_width_and_pads_only_the_flush():
    calls, dispatched = _counting_calls(["a"], [4])
    sched = _instant(
        Request(index=i, arrival_s=0.0, bucket="a") for i in range(6)
    )
    report = serve_fixed_batched(calls, sched, batch=4, concurrency=32)
    assert [(b.width, b.filled) for b in report.batches] == [(4, 4), (4, 2)]
    assert dispatched == [("a", 4), ("a", 4)]
    assert report.occupancy == pytest.approx(6 / 8)
    with pytest.raises(ValueError, match="batch"):
        serve_fixed_batched(calls, sched, batch=0)


def test_unknown_bucket_and_missing_width_are_loud():
    calls, _ = _counting_calls(["a"], [1])
    stray = _instant([Request(index=0, arrival_s=0.0, bucket="zz")])
    with pytest.raises(KeyError, match="no compiled executables"):
        serve_dynamic(calls, stray, budget_s=0.01)
    with pytest.raises(KeyError, match="width=1"):
        serve_mixed_loop({"a": {}}, _instant([Request(index=0, bucket="a")]))
    with pytest.raises(ValueError, match="budget_s"):
        serve_dynamic(calls, stray, budget_s=-1.0)


def test_dynamic_inflight_cap_still_serves_every_request():
    """concurrency=2 caps in-flight *requests* at 2: width-2 batches must
    retire one at a time, but every request still completes exactly once
    and the batch accounting stays exact."""
    calls, dispatched = _counting_calls(["a"], [1, 2])
    sched = _instant(
        Request(index=i, arrival_s=0.0, bucket="a") for i in range(8)
    )
    report = serve_dynamic(calls, sched, budget_s=10.0, concurrency=2)
    assert sorted(c.index for c in report.completions) == list(range(8))
    assert dispatched == [("a", 2)] * 4
    assert report.occupancy == 1.0


def test_dynamic_batch_wider_than_inflight_cap_dispatches_alone():
    """max width > concurrency must not deadlock the cap-wait loop: the
    oversized batch goes out alone once the window drains."""
    calls, dispatched = _counting_calls(["a"], [1, 2, 4])
    sched = _instant(
        Request(index=i, arrival_s=0.0, bucket="a") for i in range(8)
    )
    report = serve_dynamic(calls, sched, budget_s=10.0, concurrency=1)
    assert sorted(c.index for c in report.completions) == list(range(8))
    assert dispatched == [("a", 4)] * 2
    assert report.occupancy == 1.0


# -- ServeSpec mixed validation --------------------------------------------


def test_shapebucket_labels_and_validation():
    assert ShapeBucket(preset=1).label == "p1"
    b = ShapeBucket(preset=0, overrides=(("cols", 64), ("rows", 32)))
    assert b.label == "p0/cols=64/rows=32"  # sorted params, stable label
    # JSON round-trip shape: list-of-lists overrides normalize to tuples.
    assert ShapeBucket(preset=0, overrides=[["cols", 64]]) == ShapeBucket(
        preset=0, overrides=(("cols", 64),)
    )
    with pytest.raises(PlanError, match="weight"):
        ShapeBucket(weight=0.0)
    with pytest.raises(PlanError, match="preset"):
        ShapeBucket(preset=-1)


def test_servespec_mixed_validation():
    spec = _mixed_serve()
    assert spec.is_mixed
    assert not ServeSpec(mode="closed").is_mixed
    with pytest.raises(PlanError, match="dispatch"):
        _mixed_serve(dispatch="bogus")
    with pytest.raises(PlanError, match="mode='open'"):
        ServeSpec(mode="closed", dispatch="dynamic")
    with pytest.raises(PlanError, match="client='single'"):
        _mixed_serve(client="threaded")
    with pytest.raises(PlanError, match="colocate"):
        ServeSpec(
            mode="open", qps=10.0, dispatch="dynamic", colocate="kmeans"
        )
    with pytest.raises(PlanError, match="duplicate"):
        _mixed_serve(mix=(ShapeBucket(preset=0), ShapeBucket(preset=0)))
    with pytest.raises(PlanError, match="at least one"):
        _mixed_serve(mix=())
    with pytest.raises(PlanError, match="batch_budget_us"):
        _mixed_serve(batch_budget_us=0.0)
    with pytest.raises(PlanError, match="max_batch"):
        _mixed_serve(max_batch=0)
    with pytest.raises(PlanError, match="ShapeBucket"):
        _mixed_serve(mix=("p0",))
    # Dict entries (the RunMetadata JSON round-trip) normalize in place.
    from_json = _mixed_serve(
        mix=[{"preset": 0, "weight": 2.0, "overrides": [["cols", 64]]},
             {"preset": 0, "weight": 1.0, "overrides": [["cols", 128]]}]
    )
    assert from_json.mix == TINY_MIX
    # A trace alone selects the mixed path with a single default bucket.
    traced = ServeSpec(mode="open", qps=10.0, trace="/tmp/t.jsonl")
    assert traced.is_mixed
    assert [b.label for b in traced.buckets(2)] == ["p2"]
    assert spec.buckets(2) == TINY_MIX  # an explicit mix wins


# -- engine: bucketed serve through the caches -----------------------------


def test_engine_mixed_dynamic_end_to_end_records_batching_columns():
    from repro.core.engine import Engine

    plan = ExecutionPlan(names=("pathfinder",), serve=_mixed_serve(), **FAST)
    res = Engine().run(plan)
    (rec,) = res.records
    assert rec.status == "ok", rec.error
    assert rec.serve_dispatch == "dynamic"
    assert rec.serve_mix == "p0/cols=64@2,p0/cols=128@1"
    assert rec.serve_batches is not None and rec.serve_batches >= 1
    assert rec.batch_occupancy is not None and 0 < rec.batch_occupancy <= 1.0
    assert rec.padding_waste == pytest.approx(1.0 - rec.batch_occupancy)
    assert rec.latency_p50_us > 0 and rec.achieved_qps > 0
    # Coalescing means strictly fewer device programs than requests is
    # *possible* but not guaranteed on a sparse schedule; what IS
    # guaranteed is that every request landed in some batch slot.
    assert rec.serve_requests >= 1
    labels = {b.label for b in TINY_MIX}
    assert rec.bucket_latency_us is not None
    assert set(rec.bucket_latency_us) <= labels
    for stats in rec.bucket_latency_us.values():
        assert stats["requests"] >= 1
        assert stats["p50_us"] <= stats["p95_us"] <= stats["p99_us"]
    csv = rec.csv()
    assert "dispatch=dynamic" in csv and "occupancy=" in csv


def test_engine_mixed_serve_precompiles_every_bucket_width():
    """dynamic with max_batch=2 over a 2-bucket mix needs 4 executables
    (2 buckets x widths {1, 2}); the measure stage's own executable is a
    5th distinct compile (plan preset != either bucket's overrides), and
    re-running the same plan compiles nothing new."""
    from repro.core.engine import Engine

    eng = Engine()
    plan = ExecutionPlan(names=("pathfinder",), serve=_mixed_serve(), **FAST)
    res = eng.run(plan)
    assert res.records[0].status == "ok", res.records[0].error
    assert eng.cache.misses == 5
    eng.run(plan)
    assert eng.cache.misses == 5  # warm in-process rerun: all hits


def test_engine_mixed_trace_replay_pins_the_load(tmp_path):
    """Run 1 (loop) generates and saves the trace; run 2 (dynamic) replays
    it — identical request stream, identical offered load, whatever the
    dispatch policy."""
    from repro.core.engine import Engine

    trace = str(tmp_path / "mix.jsonl")
    base = _mixed_serve(trace=trace, dispatch="loop")
    plan = ExecutionPlan(names=("pathfinder",), serve=base, **FAST)
    res1 = Engine().run(plan)
    assert res1.records[0].status == "ok", res1.records[0].error
    assert os.path.exists(trace)
    saved = load_trace(trace)

    replay = dataclasses.replace(plan, serve=dataclasses.replace(base, dispatch="dynamic"))
    res2 = Engine().run(replay)
    (rec2,) = res2.records
    assert rec2.status == "ok", rec2.error
    assert load_trace(trace) == saved  # replay never rewrites the trace
    assert rec2.serve_requests == res1.records[0].serve_requests
    assert rec2.offered_qps == res1.records[0].offered_qps
    assert rec2.serve_dispatch == "dynamic"


def test_engine_mixed_rejects_no_jit_and_unknown_trace_bucket(tmp_path):
    from repro.core.engine import Engine

    # Host-transfer (no_jit) workloads have no device program to batch.
    plan = ExecutionPlan(
        names=("busspeeddownload",),
        serve=_mixed_serve(mix=(ShapeBucket(preset=0),)),
        **FAST,
    )
    (rec,) = Engine().run(plan).records
    assert rec.status == "error"
    assert "no_jit" in rec.error

    # A trace naming a bucket the mix never compiled is a loud error.
    sched = sample_mix(
        open_loop_schedule(qps=200.0, duration_s=0.2, seed=0),
        {"p9/zz=1": 1.0},
        seed=0,
    )
    trace = str(tmp_path / "alien.jsonl")
    save_trace(sched, trace)
    bad = ExecutionPlan(
        names=("pathfinder",), serve=_mixed_serve(trace=trace), **FAST
    )
    (rec,) = Engine().run(bad).records
    assert rec.status == "error"
    assert "p9/zz=1" in rec.error


def test_jsonl_roundtrips_mixed_serve_metadata(tmp_path):
    from repro.core.engine import Engine
    from repro.core.results import SCHEMA_VERSION, load_run

    path = str(tmp_path / "mixed.jsonl")
    spec = _mixed_serve()
    plan = ExecutionPlan(names=("pathfinder",), serve=spec, **FAST)
    res = Engine().run(plan, jsonl_path=path)
    meta, recs = load_run(path)
    assert meta.schema_version == SCHEMA_VERSION >= 7
    assert meta.serve == spec  # dict mix entries -> ShapeBucket round-trip
    assert recs == res.records
    assert recs[0].bucket_latency_us == res.records[0].bucket_latency_us


# -- suite CLI surface -----------------------------------------------------


def test_parse_mix_grammar():
    from repro.core.suite import _parse_mix

    mix = _parse_mix("0@2,0/cols=64@1,1/rows=32/cols=2.5")
    assert mix == (
        ShapeBucket(preset=0, weight=2.0),
        ShapeBucket(preset=0, weight=1.0, overrides=(("cols", 64),)),
        ShapeBucket(
            preset=1, weight=1.0, overrides=(("rows", 32), ("cols", 2.5))
        ),
    )
    for bad in ("", "x@1", "0@zero", "0/cols@1", "0@"):
        with pytest.raises(SystemExit):
            _parse_mix(bad)


def test_suite_cli_dynamic_mix_end_to_end(capsys):
    from repro.core.suite import main

    rc = main([
        "--names", "pathfinder", "--serve", "open", "--qps", "300",
        "--serve-duration", "0.25", "--serve-mix", "0/cols=64@2,0/cols=128@1",
        "--serve-dispatch", "dynamic", "--max-batch", "2",
        "--batch-latency-budget", "500", "--iters", "1", "--warmup", "0",
        "--no-backward",
    ])
    assert rc == 0
    out = capsys.readouterr().out
    assert "dispatch=dynamic" in out
    assert "occupancy=" in out and "padding_waste=" in out
    assert "buckets=" in out and "p0/cols=64" in out


def test_suite_cli_stray_batching_flags_are_config_errors(capsys):
    from repro.core.suite import main

    rc = main(["--names", "pathfinder", "--serve-mix", "0@1"])
    assert rc == 2
    assert "--serve-mix" in capsys.readouterr().err
    rc = main(["--names", "pathfinder", "--serve-dispatch", "dynamic"])
    assert rc == 2
    assert "--serve-dispatch" in capsys.readouterr().err


def test_suite_help_epilog_shows_batching_examples(capsys):
    from repro.core.suite import main

    with pytest.raises(SystemExit) as exc:
        main(["--help"])
    assert exc.value.code == 0
    out = capsys.readouterr().out
    assert "--serve-mix" in out and "--serve-trace" in out
    assert "--batch-latency-budget" in out
    assert "padding" in out and "occupancy" in out
