"""Windowed vs sync timing modes: agreement on compute-bound work,
strict reduction on dispatch-bound work, pre-committed inputs, and the
schema-v5 columns that carry both numbers.

The two perf-comparison tests run in a subprocess on a forced host
device (the test_placement/test_hlocache pattern) with deliberately
generous tolerances and best-of-N sampling: QPS/timing comparisons on
shared CI hosts are known to flake under concurrent load, so each mode
takes the *minimum of several medians* — the least-contended sample —
before the modes are compared.
"""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax

from repro.core.engine import Engine
from repro.core.harness import commit_args, time_fn, time_workload
from repro.core.plan import ExecutionPlan
from repro.core.registry import get_benchmark

FAST = dict(preset=0, iters=1, warmup=0, include_backward=False)


def _run_forced_host(script: str, timeout: int = 420) -> None:
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=1"
    env["PYTHONPATH"] = src
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(script)],
        env=env, capture_output=True, text=True, timeout=timeout,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"


def test_windowed_strictly_reduces_dispatch_bound_kernel_time():
    """For a tiny kernel, sync mode measures host dispatch + sync latency
    as much as kernel time; windowed mode amortizes the synchronization
    across the window and must come out strictly lower."""
    _run_forced_host("""
        import jax, jax.numpy as jnp
        from repro.core.harness import time_fn

        f = jax.jit(lambda x, y: x @ y)  # dispatch-bound at this size
        args = (jnp.ones((64, 64)), jnp.ones((64, 64)))
        jax.block_until_ready(f(*args))
        # Best of 3 medians per mode (the least-contended sample), and the
        # whole comparison retried: a CPU load spike during any one
        # attempt must not fail the invariant.
        last = None
        for attempt in range(3):
            sync = min(time_fn(f, args, iters=15, warmup=3)[0] for _ in range(3))
            win = min(
                time_fn(f, args, iters=8, warmup=1, window=8)[0]
                for _ in range(3)
            )
            last = (win, sync)
            if win < sync:
                break
        else:
            raise AssertionError(f"windowed never beat sync: {last}")
        print(f"OK sync={sync:.1f}us windowed={win:.1f}us")
    """)


def test_windowed_and_sync_agree_on_compute_bound_workload():
    """For a large, compute-dominated workload the two modes measure the
    same thing; tolerances are generous (shared-host noise)."""
    _run_forced_host("""
        import jax, jax.numpy as jnp
        from repro.core.harness import time_fn

        f = jax.jit(lambda x: jnp.cumsum(x))  # sequential: no overlap win
        args = (jnp.ones((262144,)),)
        jax.block_until_ready(f(*args))
        sync = min(time_fn(f, args, iters=10, warmup=2)[0] for _ in range(3))
        win = min(
            time_fn(f, args, iters=5, warmup=1, window=4)[0] for _ in range(3)
        )
        ratio = win / sync
        assert 0.25 <= ratio <= 2.5, (sync, win, ratio)
        print(f"OK sync={sync:.1f}us windowed={win:.1f}us ratio={ratio:.2f}")
    """)


def test_time_fn_rejects_bad_window():
    with pytest.raises(ValueError, match="window"):
        time_fn(lambda: None, (), window=0)


def test_commit_args_moves_host_leaves_and_passes_device_leaves():
    host = np.ones((4, 4), dtype=np.float32)
    dev = jax.device_put(np.zeros((2,), dtype=np.float32))
    committed = commit_args((host, dev, 3.0))
    assert isinstance(committed[0], jax.Array)
    assert committed[1] is dev  # already-placed arrays are untouched
    assert isinstance(committed[2], jax.Array)  # scalars commit too
    np.testing.assert_array_equal(np.asarray(committed[0]), host)


def test_commit_args_passes_abstract_leaves_through():
    sds = jax.ShapeDtypeStruct((3,), np.float32)
    (out,) = commit_args((sds,))
    assert out is sds


def test_records_carry_both_timing_modes():
    res = Engine().run(ExecutionPlan(names=("pathfinder",), **FAST))
    (r,) = res.records
    assert r.status == "ok"
    assert r.timing_window == 4  # the plan default
    assert r.us_per_call_windowed is not None and r.us_per_call_windowed > 0
    # The derived overhead follows the documented clamping convention.
    assert r.timer_dispatch_us == pytest.approx(
        max(r.us_per_call - r.us_per_call_windowed, 0.0)
    )
    assert res.metadata.timing_window == 4
    assert f"win_us={r.us_per_call_windowed:.2f}" in r.csv()


def test_timing_window_one_is_sync_only():
    res = Engine().run(
        ExecutionPlan(names=("pathfinder",), timing_window=1, **FAST)
    )
    (r,) = res.records
    assert r.status == "ok"
    assert r.us_per_call_windowed is None
    assert r.timing_window is None and r.timer_dispatch_us is None
    assert "win_us" not in r.csv()


def test_no_jit_workloads_skip_windowed_mode():
    """Host-transfer benchmarks run synchronously by construction: a
    windowed number would be the sync number with extra noise."""
    res = Engine().run(ExecutionPlan(names=("busspeeddownload",), **FAST))
    (r,) = res.records
    assert r.status == "ok"
    assert r.us_per_call_windowed is None and r.timing_window is None


def test_plan_rejects_bad_timing_window():
    with pytest.raises(ValueError, match="timing_window"):
        ExecutionPlan(timing_window=0)


def test_time_workload_one_shot_windowed():
    workload = get_benchmark("softmax").build_preset(0)
    timing = time_workload(workload, iters=2, warmup=1, window=4)
    assert timing.us_per_call > 0
    assert timing.us_per_call_windowed is not None
    assert timing.timing_window == 4
    assert timing.timer_dispatch_us is not None
    # window=1 keeps the pre-v5 sync-only shape.
    sync_only = time_workload(workload, iters=2, warmup=1)
    assert sync_only.us_per_call_windowed is None
    assert sync_only.timing_window is None


def test_serve_loop_windowed_floor():
    from repro.serve.lanes import serve_loop
    from repro.serve.loadgen import Request

    calls = 0

    def call():
        nonlocal calls
        calls += 1
        return jax.numpy.ones((4,)) * calls

    reqs = [Request(index=i, arrival_s=0.0, warmup=i < 2) for i in range(10)]
    done = serve_loop(call, reqs, window=4)
    assert calls == 10
    assert sorted(c.index for c in done) == list(range(10))
    assert sum(c.warmup for c in done) == 2
    # Requests in one window share the window's completion stamp; the
    # 10 requests span ceil(10/4)=3 windows.
    assert len({c.t_done for c in done}) == 3
    for c in done:
        assert c.t_done >= c.t_submit
    with pytest.raises(ValueError, match="window"):
        serve_loop(call, reqs, window=0)


def test_roofline_rows_from_records_prefer_windowed_time():
    import sys

    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from benchmarks.roofline_table import rows_from_records

    res = Engine().run(
        ExecutionPlan(names=("pathfinder", "busspeeddownload"), **FAST)
    )
    rows = {name: (us, derived) for name, us, derived in
            rows_from_records(res.records)}
    rec = next(r for r in res.ok_records if r.us_per_call_windowed is not None)
    path_row = rows[f"roofline.{rec.name}"]
    # The measured column is the windowed number when the record has one,
    # else the sync number (busspeeddownload is no_jit: sync only).
    assert path_row[0] == rec.us_per_call_windowed
    assert "timed=windowed" in path_row[1]
    assert f"sync_us={rec.us_per_call:.2f}" in path_row[1]
    bus = next(r for r in res.ok_records if r.us_per_call_windowed is None)
    bus_row = rows[f"roofline.{bus.name}"]
    assert bus_row[0] == bus.us_per_call
    assert "timed=sync" in bus_row[1]


def test_suite_cli_timing_window_flag(capsys):
    from repro.core.suite import main

    rc = main([
        "--names", "pathfinder", "--iters", "1", "--warmup", "0",
        "--no-backward", "--timing-window", "2",
    ])
    assert rc == 0
    out = capsys.readouterr().out
    assert "win_us=" in out and "timer_dispatch_us=" in out

    rc = main([
        "--names", "pathfinder", "--iters", "1", "--warmup", "0",
        "--no-backward", "--timing-window", "1",
    ])
    assert rc == 0
    assert "win_us=" not in capsys.readouterr().out
