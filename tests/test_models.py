"""Per-architecture smoke tests + family-specific correctness properties."""

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config, get_smoke_config
from repro.models import Model
from repro.models.moe import apply_moe, init_moe, moe_oracle


def _batch_for(cfg, B=2, T=16, seed=1):
    if cfg.input_mode == "embeds":
        batch = {
            "embeds": jax.random.normal(jax.random.key(seed), (B, T, cfg.d_model), jnp.float32),
            "labels": jax.random.randint(jax.random.key(seed + 1), (B, T), 0, cfg.vocab),
        }
        if cfg.rope == "mrope":
            batch["positions"] = jnp.broadcast_to(
                jnp.arange(T)[None, :, None], (B, T, 3)
            )
        return batch
    return {
        "tokens": jax.random.randint(jax.random.key(seed), (B, T), 0, cfg.vocab),
        "labels": jax.random.randint(jax.random.key(seed + 1), (B, T), 0, cfg.vocab),
    }


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    """Assignment requirement: reduced config, one forward/train step on CPU,
    output shapes + no NaNs."""
    cfg = get_smoke_config(arch)
    model = Model(cfg, remat=False)
    params = model.init(jax.random.key(0))
    B, T = 2, 16
    batch = _batch_for(cfg, B, T)
    logits = jax.jit(model.forward)(params, batch)
    assert logits.shape == (B, T, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits)))
    loss, grads = jax.jit(
        lambda p, b: jax.value_and_grad(lambda q: model.loss_fn(q, b)[0])(p)
    )(params, batch)
    assert np.isfinite(float(loss))
    gn = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32)))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    """The exact published numbers from the assignment block."""
    expected = {
        "granite-3-8b": (40, 4096, 32, 8, 12800, 49155),
        "qwen1.5-0.5b": (24, 1024, 16, 16, 2816, 151936),
        "granite-8b": (36, 4096, 32, 8, 14336, 49152),
        "deepseek-7b": (30, 4096, 32, 32, 11008, 102400),
        "xlstm-350m": (24, 1024, 4, 4, 0, 50304),
        "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
        "dbrx-132b": (40, 6144, 48, 8, 10752, 100352),
        "hubert-xlarge": (48, 1280, 16, 16, 5120, 504),
        "jamba-1.5-large-398b": (72, 8192, 64, 8, 24576, 65536),
        "qwen2-vl-2b": (28, 1536, 12, 2, 8960, 151936),
    }[arch]
    cfg = get_config(arch)
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_ff, cfg.vocab)
    assert got == expected
    # MoE structure
    if arch == "mixtral-8x22b":
        assert (cfg.n_experts, cfg.top_k, cfg.window) == (8, 2, 4096)
    if arch == "dbrx-132b":
        assert (cfg.n_experts, cfg.top_k) == (16, 4)
    if arch == "jamba-1.5-large-398b":
        kinds = cfg.block_kinds()
        assert sum(k.startswith("attn") for k in kinds) == 9  # 1:7 attn:mamba
        assert sum(k.endswith("_moe") for k in kinds) == 36  # every other layer
    if arch == "hubert-xlarge":
        assert cfg.encoder_only and not cfg.causal
    if arch == "qwen1.5-0.5b":
        assert cfg.qkv_bias and cfg.tie_embeddings
    if arch == "qwen2-vl-2b":
        assert cfg.rope == "mrope"


_DECODABLE = [a for a in ARCHS if a not in ("hubert-xlarge", "qwen2-vl-2b")]


@pytest.mark.parametrize("arch", _DECODABLE)
def test_decode_matches_teacher_forcing(arch):
    """prefill+decode logits == full forward logits (every family's cache)."""
    cfg = get_smoke_config(arch)
    cfg = dataclasses.replace(cfg, dtype="float32")
    model = Model(cfg, remat=False)
    params = model.init(jax.random.key(0))
    B, T, T0 = 2, 16, 8
    tokens = jax.random.randint(jax.random.key(1), (B, T), 0, cfg.vocab)
    full = jax.jit(model.forward)(params, {"tokens": tokens})
    cache, pl_logits = jax.jit(lambda p, b: model.prefill(p, b, 32))(
        params, {"tokens": tokens[:, :T0]}
    )
    np.testing.assert_allclose(
        np.asarray(pl_logits), np.asarray(full[:, :T0]), rtol=2e-3, atol=2e-3
    )
    step = jax.jit(model.decode_step)
    for t in range(T0, T):
        logits_t, cache = step(params, cache, tokens[:, t], jnp.int32(t))
    np.testing.assert_allclose(
        np.asarray(logits_t), np.asarray(full[:, T - 1]), rtol=5e-3, atol=5e-3
    )


def test_swa_ring_cache_beyond_window():
    """Mixtral-style SWA: decoding past the window with a ring cache matches
    teacher forcing (the cache holds only the last W tokens)."""
    cfg = dataclasses.replace(get_smoke_config("mixtral-8x22b"), window=8, dtype="float32")
    model = Model(cfg, remat=False)
    params = model.init(jax.random.key(0))
    B, T, T0 = 1, 24, 4  # decode well past window=8
    tokens = jax.random.randint(jax.random.key(1), (B, T), 0, cfg.vocab)
    full = jax.jit(model.forward)(params, {"tokens": tokens})
    cache, _ = jax.jit(lambda p, b: model.prefill(p, b, T))(params, {"tokens": tokens[:, :T0]})
    # ring cache is window-sized regardless of max_len
    k_leaf = jax.tree.leaves(cache)[0]
    step = jax.jit(model.decode_step)
    for t in range(T0, T):
        logits_t, cache = step(params, cache, tokens[:, t], jnp.int32(t))
    np.testing.assert_allclose(
        np.asarray(logits_t), np.asarray(full[:, T - 1]), rtol=5e-3, atol=5e-3
    )


def test_moe_dispatch_matches_oracle():
    """GShard dispatch == per-token dense oracle at full capacity."""
    cfg = dataclasses.replace(
        get_smoke_config("dbrx-132b"),
        dtype="float32",
        capacity_factor=float(8 / 4),  # E/top_k → capacity can hold everything
        moe_group_size=16,
    )
    p = init_moe(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model), jnp.float32)
    got = apply_moe(p, cfg, x)
    want = moe_oracle(p, cfg, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_moe_capacity_drops_are_bounded():
    """With cf < E/k some tokens drop; outputs stay finite and norm-bounded."""
    cfg = dataclasses.replace(
        get_smoke_config("mixtral-8x22b"), dtype="float32",
        capacity_factor=0.5, moe_group_size=16,
    )
    p = init_moe(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model), jnp.float32)
    got = np.asarray(apply_moe(p, cfg, x))
    assert np.all(np.isfinite(got))
    want = np.asarray(moe_oracle(p, cfg, x))
    assert np.linalg.norm(got) <= np.linalg.norm(want) * 1.5 + 1e-3


def test_hubert_is_bidirectional():
    """Encoder attends to future frames: perturbing frame t+k changes
    output at t (it wouldn't under a causal mask)."""
    cfg = dataclasses.replace(get_smoke_config("hubert-xlarge"), dtype="float32")
    model = Model(cfg, remat=False)
    params = model.init(jax.random.key(0))
    B, T = 1, 12
    e = jax.random.normal(jax.random.key(1), (B, T, cfg.d_model), jnp.float32)
    out1 = model.forward(params, {"embeds": e})
    e2 = e.at[:, -1].add(1.0)
    out2 = model.forward(params, {"embeds": e2})
    assert not np.allclose(np.asarray(out1[:, 0]), np.asarray(out2[:, 0]))


def test_causal_model_ignores_future():
    cfg = dataclasses.replace(get_smoke_config("granite-3-8b"), dtype="float32")
    model = Model(cfg, remat=False)
    params = model.init(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (1, 12), 0, cfg.vocab)
    out1 = model.forward(params, {"tokens": toks})
    toks2 = toks.at[0, -1].set((toks[0, -1] + 1) % cfg.vocab)
    out2 = model.forward(params, {"tokens": toks2})
    np.testing.assert_allclose(
        np.asarray(out1[:, :-1]), np.asarray(out2[:, :-1]), rtol=1e-5, atol=1e-5
    )


def test_remat_does_not_change_values():
    cfg = dataclasses.replace(get_smoke_config("granite-8b"), dtype="float32")
    batch = _batch_for(cfg)
    m1 = Model(cfg, remat=False)
    m2 = Model(cfg, remat=True)
    params = m1.init(jax.random.key(0))
    l1 = float(m1.loss_fn(params, batch)[0])
    l2 = float(m2.loss_fn(params, batch)[0])
    assert abs(l1 - l2) < 1e-5
    g1 = jax.grad(lambda p: m1.loss_fn(p, batch)[0])(params)
    g2 = jax.grad(lambda p: m2.loss_fn(p, batch)[0])(params)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), rtol=1e-4, atol=1e-5
        )


def test_scan_unroll_does_not_change_values():
    cfg = dataclasses.replace(get_smoke_config("jamba-1.5-large-398b"), dtype="float32")
    batch = _batch_for(cfg)
    m1 = Model(cfg, remat=False, scan_unroll=False)
    m2 = Model(cfg, remat=False, scan_unroll=True)
    params = m1.init(jax.random.key(0))
    np.testing.assert_allclose(
        np.asarray(m1.forward(params, batch)),
        np.asarray(m2.forward(params, batch)),
        rtol=1e-5, atol=1e-5,
    )


def test_param_counts_match_actual():
    """Analytic param_counts (drives MODEL_FLOPS) ≈ actual init sizes."""
    for arch in ("granite-3-8b", "mixtral-8x22b", "jamba-1.5-large-398b"):
        cfg = get_smoke_config(arch)
        model = Model(cfg, remat=False)
        params_sds = jax.eval_shape(model.init, jax.random.key(0))
        actual = sum(np.prod(l.shape) for l in jax.tree.leaves(params_sds))
        est = cfg.param_counts()["total"]
        assert abs(est - actual) / actual < 0.15, (arch, est, actual)
