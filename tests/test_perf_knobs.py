"""Correctness of the §Perf optimization knobs: every speed/memory lever
must be a semantic no-op."""

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.models import Model
from repro.models.layers import sdpa
from repro.models.moe import apply_moe, init_moe, moe_oracle, split_moe_params


@pytest.mark.parametrize("causal,window", [(False, None), (True, None), (True, 7)])
@pytest.mark.parametrize("chunk", [8, 16])
def test_chunked_sdpa_equals_dense(rng, causal, window, chunk):
    B, T, H, KV, hd, S = 2, 16, 4, 2, 8, 48
    q = jnp.asarray(rng.normal(size=(B, T, H, hd)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, S, KV, hd)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, S, KV, hd)).astype(np.float32))
    dense = sdpa(q, k, v, causal=causal, window=window)
    chunked = sdpa(q, k, v, causal=causal, window=window, chunk=chunk)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(chunked),
                               rtol=2e-4, atol=2e-4)
    # unroll_inner is analysis-only sugar: same values
    unrolled = sdpa(q, k, v, causal=causal, window=window, chunk=chunk,
                    unroll_inner=True)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(unrolled),
                               rtol=1e-6, atol=1e-6)


def test_chunked_sdpa_respects_kv_len(rng):
    B, H, KV, hd, S = 1, 2, 2, 8, 32
    q = jnp.asarray(rng.normal(size=(B, 1, H, hd)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, S, KV, hd)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, S, KV, hd)).astype(np.float32))
    kvl = jnp.int32(19)
    dense = sdpa(q, k, v, causal=False, window=None, kv_len=kvl)
    chunked = sdpa(q, k, v, causal=False, window=None, kv_len=kvl, chunk=8)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(chunked),
                               rtol=2e-4, atol=2e-4)


def test_score_bf16_is_close(rng):
    B, T, H, hd = 1, 8, 2, 16
    q = jnp.asarray(rng.normal(size=(B, T, H, hd)).astype(np.float32))
    f32 = sdpa(q, q, q, causal=True, window=None)
    bf16 = sdpa(q, q, q, causal=True, window=None, score_dtype=jnp.bfloat16)
    np.testing.assert_allclose(np.asarray(f32), np.asarray(bf16), rtol=5e-2, atol=5e-2)


def test_expert_slicing_equals_unsplit(rng):
    cfg = dataclasses.replace(
        get_smoke_config("mixtral-8x22b"), dtype="float32",
        capacity_factor=2.0, moe_group_size=16,
    )
    p = init_moe(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model), jnp.float32)
    want = moe_oracle(p, cfg, x)
    for split in (2, 4):
        cfg_s = dataclasses.replace(cfg, moe_split=split)
        got = apply_moe(split_moe_params(p, split), cfg_s, x)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=3e-4, atol=3e-4)


def test_split_init_shards_over_16():
    """The point of slicing: 8 experts × split 2 = 16 virtual experts divide
    the 16-way model axis → EP rule engages."""
    from jax.sharding import PartitionSpec as P

    from repro.configs import get_config
    from repro.runtime.sharding import ShardingRules, param_pspecs

    class FakeMesh:
        def __init__(self, shape):
            self.shape = shape

    cfg = dataclasses.replace(get_config("mixtral-8x22b"), moe_split=2)
    model = Model(cfg, remat=False)
    params = jax.eval_shape(model.init, jax.random.key(0))
    rules = ShardingRules(mesh=FakeMesh({"data": 16, "model": 16}))
    specs = param_pspecs(params, rules)
    assert tuple(specs["blocks"][0]["ffn"]["w_gate"]) == (None, "model", None, None)


def test_chunked_attention_model_forward_matches(rng):
    cfg = dataclasses.replace(get_smoke_config("granite-3-8b"), dtype="float32")
    cfg_c = dataclasses.replace(cfg, attn_chunk=8)
    m1, m2 = Model(cfg, remat=False), Model(cfg_c, remat=False)
    params = m1.init(jax.random.key(0))
    batch = {"tokens": jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab)}
    np.testing.assert_allclose(
        np.asarray(m1.forward(params, batch)),
        np.asarray(m2.forward(params, batch)),
        rtol=2e-3, atol=2e-3,
    )


def test_chunkwise_mlstm_equals_sequential(rng):
    """The chunkwise-parallel stabilized mLSTM (EXPERIMENTS.md §Perf
    derivation) is bit-for-bit the same recurrence, state included."""
    from repro.models.ssm import apply_mlstm, init_mlstm

    cfg = dataclasses.replace(get_smoke_config("xlstm-350m"), dtype="float32")
    p = init_mlstm(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 32, cfg.d_model), jnp.float32)
    out_seq, st_seq = apply_mlstm(p, cfg, x)
    for L in (4, 16):
        cfg_c = dataclasses.replace(cfg, xlstm_chunk=L)
        out_ch, st_ch = apply_mlstm(p, cfg_c, x)
        np.testing.assert_allclose(
            np.asarray(out_seq), np.asarray(out_ch), rtol=2e-4, atol=2e-4
        )
        np.testing.assert_allclose(
            np.asarray(st_seq["C"]), np.asarray(st_ch["C"]), rtol=2e-3, atol=2e-4
        )
        np.testing.assert_allclose(
            np.asarray(st_seq["m"]), np.asarray(st_ch["m"]), rtol=1e-4, atol=1e-5
        )


def test_dp_only_sharder_never_reuses_axes():
    """Regression: with the model axis folded into data, logits/seq specs
    must not reference it again (DuplicateSpecError in iteration 2)."""
    from repro.runtime.sharding import ShardingRules, make_activation_sharder

    rules = ShardingRules(
        mesh=jax.make_mesh((1, 1), ("data", "model")),
        data_axes=("data", "model"),
        seq_shard=True,
    )
    shard = make_activation_sharder(rules)
    # No mesh context here: with_sharding_constraint would fail on a bad
    # spec at trace time inside jit; build the specs via a traced fn.
    x = jnp.zeros((4, 8, 16))

    def f(x):
        return shard(x, "logits") + shard(x, "residual")

    jax.eval_shape(f, x)  # must not raise DuplicateSpecError
