"""End-to-end behaviour tests for the whole system."""

import numpy as np

import jax


def test_suite_end_to_end(tmp_path):
    """The paper's workflow: run a suite slice, get the Fig-5-style table."""
    from repro.core import run_suite
    from repro.core.results import BenchmarkRecord, load_records, to_csv_lines

    records = run_suite(
        names=["gemm_bf16_nn", "srad", "softmax"],
        preset=0, iters=2, warmup=1, verbose=False,
        report_path=str(tmp_path / "suite.json"),
    )
    assert len(records) >= 3  # softmax contributes fwd+bwd
    lines = to_csv_lines(records)
    assert lines[0] == BenchmarkRecord.csv_header()
    assert lines[0] == "name,us_per_call,devices,placement,derived"
    assert all("," in ln for ln in lines[1:])
    assert load_records(str(tmp_path / "suite.json"))


def test_train_then_serve_round_trip(tmp_path):
    """Train a small model, checkpoint it, reload, serve greedy decode."""
    from repro.checkpoint import Checkpointer
    from repro.configs import get_smoke_config
    from repro.launch.serve import serve
    from repro.launch.train import train

    out = train(
        arch="qwen1.5-0.5b", smoke=True, steps=15, batch=4, seq=16,
        lr=1e-3, checkpoint_dir=str(tmp_path), save_every=10, log_every=0,
    )
    assert out["steps"] == 15
    ck = Checkpointer(str(tmp_path))
    assert ck.latest_step() == 15

    stats = serve(arch="qwen1.5-0.5b", smoke=True, n_requests=4, batch=2,
                  prompt_len=8, gen_len=4, max_len=16)
    assert stats.decoded_tokens > 0
    assert all(len(o) >= 4 for o in stats.outputs)


def test_feature_analogues_behave():
    """The §V-B feature analogues produce their expected signatures."""
    import jax.numpy as jnp

    from repro.core.features import adaptive_refine, async_launch, concurrent_instances

    # HyperQ: vmapped instances == loop of single instances
    from repro.bench.level1.pathfinder import pathfinder_min_path

    grids = jax.random.randint(jax.random.key(0), (4, 16, 64), 0, 10)
    batched = jax.jit(concurrent_instances(pathfinder_min_path, 4))(grids)
    singles = [pathfinder_min_path(grids[i]) for i in range(4)]
    for i in range(4):
        np.testing.assert_array_equal(np.asarray(batched[i]), np.asarray(singles[i]))

    outs = async_launch(jax.jit(lambda x: x * 2), [(jnp.ones(4),), (jnp.ones(4) * 2,)])
    assert len(outs) == 2

    # Dynamic parallelism combinator: refined only where needed
    run = adaptive_refine(
        coarse_fn=lambda x: jnp.round(x),
        fine_fn=lambda x: x * 10,
        needs_refine=lambda c: c > 0,
    )
    got = run(jnp.asarray([-1.0, 2.0]))
    np.testing.assert_allclose(np.asarray(got), [-1.0, 20.0])


def test_srad_fused_vs_split_same_result_different_traffic():
    """The cooperative-groups analogue: same numerics, fewer HBM round
    trips (two pallas_calls vs one)."""
    import jax.numpy as jnp

    from repro.kernels.srad_stencil import srad_step_fused, srad_step_split

    img = jnp.exp(0.1 * jax.random.normal(jax.random.key(0), (64, 64)))
    a = srad_step_fused(img, interpret=True)
    b = srad_step_split(img, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)


def test_decode_cache_memory_is_constant_for_ssm():
    """xLSTM decode state does not grow with context (the long_500k case)."""
    from repro.configs import get_smoke_config
    from repro.models import Model

    cfg = get_smoke_config("xlstm-350m")
    model = Model(cfg, remat=False)
    c1 = jax.eval_shape(lambda: model.init_cache(1, 1024))
    c2 = jax.eval_shape(lambda: model.init_cache(1, 524288))
    s1 = sum(np.prod(l.shape) for l in jax.tree.leaves(c1))
    s2 = sum(np.prod(l.shape) for l in jax.tree.leaves(c2))
    assert s1 == s2
