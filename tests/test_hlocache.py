"""Two-tier persistent compile cache: warm runs restore serialized
executables (zero retrace, zero XLA compile), degrade one tier at a time
(executable → HLO text → retrace) with counted, explained fallbacks, and
entries are versioned by toolchain + topology."""

import json
import os

import jax

from repro.core.engine import Engine
from repro.core.plan import ExecutionPlan

FAST = dict(preset=0, iters=1, warmup=0, include_backward=False)


def _version_dir(root: str) -> str:
    # Exactly one toolchain dir for this process; "jax-persistent" is
    # jax's own compilation cache, colocated but not ours.
    (sub,) = [d for d in os.listdir(root) if d != "jax-persistent"]
    return os.path.join(root, sub)


def test_cold_run_populates_cache_dir_with_versioned_entries(tmp_path):
    root = str(tmp_path / "hlo")
    eng = Engine(cache_dir=root)
    res = eng.run(ExecutionPlan(names=("pathfinder", "softmax"), **FAST))
    assert [r.status for r in res.records] == ["ok", "ok"]
    assert eng.disk_cache.stores == 2
    assert eng.disk_cache.exe_stores == 2  # tier-1 sidecars written too
    assert eng.disk_cache.hits == 0
    version_dir = _version_dir(root)
    # Versioned by toolchain (jax + jaxlib + backend), topology (device
    # kind x count x process count — serialized executables are compiled
    # *for* a device topology), AND a content hash of the repro package,
    # so an edited kernel misses instead of replaying its old artifacts.
    base = os.path.basename(version_dir)
    assert base.startswith(f"jax-{jax.__version__}-jaxlib-")
    assert f"-{jax.default_backend()}-" in base
    assert f"x{jax.device_count()}p{jax.process_count()}-" in base
    entries = sorted(os.listdir(version_dir))
    # One .json payload + one .exe serialized-executable sidecar per entry.
    assert len(entries) == 4
    assert [e for e in entries if e.endswith(".json")] != []
    assert len([e for e in entries if e.endswith(".exe")]) == 2
    payload_path = next(e for e in entries if e.endswith(".json"))
    payload = json.load(open(os.path.join(version_dir, payload_path)))
    assert payload["hlo"].lstrip().startswith("module")
    assert "cost" in payload and "memory" in payload


def test_warm_run_hits_exe_tier_and_matches_cold_records(tmp_path):
    root = str(tmp_path / "hlo")
    plan = ExecutionPlan(names=("pathfinder",), **FAST)
    cold = Engine(cache_dir=root).run(plan)

    warm_engine = Engine(cache_dir=root)
    warm = warm_engine.run(plan)
    assert warm_engine.disk_cache.hits == 1
    assert warm_engine.disk_cache.exe_hits == 1  # tier 1: no compilation
    assert warm_engine.disk_cache.hlo_hits == 0
    assert warm_engine.disk_cache.misses == 0
    (c,), (w,) = cold.records, warm.records
    assert w.status == "ok"
    assert w.name == c.name
    # The stored characterization reproduces the roofline analysis.
    assert w.dominant == c.dominant
    assert w.derived == c.derived
    assert w.us_per_call > 0


def test_warm_suite_run_performs_zero_xla_compiles(tmp_path):
    """The zero-compile warm start, asserted on counters: every warm
    lookup restores a serialized executable — no retrace (misses=0), no
    tier-2 compile (hlo_hits=0, xla_compiles=0), no silent degradation
    (fallbacks=0) — across a multi-benchmark slice including forward and
    backward passes."""
    root = str(tmp_path / "hlo")
    plan = ExecutionPlan(
        names=("pathfinder", "softmax", "gemm_f32_nn"),
        preset=0, iters=1, warmup=0, include_backward=True,
    )
    cold_engine = Engine(cache_dir=root)
    cold = cold_engine.run(plan)
    n_entries = cold_engine.disk_cache.stores
    assert n_entries == len(cold.ok_records) >= 4  # fwd rows + some bwd

    warm_engine = Engine(cache_dir=root)
    warm = warm_engine.run(plan)
    dc = warm_engine.disk_cache
    assert [r.status for r in warm.records] == ["ok"] * len(cold.records)
    assert dc.exe_hits == n_entries, dc.summary()
    assert dc.hlo_hits == 0, dc.summary()
    assert dc.misses == 0, dc.summary()
    assert dc.xla_compiles == 0, dc.summary()
    assert dc.fallback_count == 0 and dc.exe_fallbacks == 0, dc.summary()
    # Warm rows still carry both timing modes (schema v5).
    assert all(r.us_per_call_windowed is not None for r in warm.ok_records)


def test_corrupt_cache_entry_falls_back_to_retrace(tmp_path):
    root = str(tmp_path / "hlo")
    plan = ExecutionPlan(names=("pathfinder",), **FAST)
    Engine(cache_dir=root).run(plan)
    version_dir = _version_dir(root)
    for entry in os.listdir(version_dir):
        with open(os.path.join(version_dir, entry), "w") as f:
            f.write("{not json")

    eng = Engine(cache_dir=root)
    res = eng.run(plan)
    assert [r.status for r in res.records] == ["ok"]
    assert eng.disk_cache.hits == 0
    assert eng.disk_cache.misses == 1
    assert eng.disk_cache.stores == 1  # the retrace re-stored a good entry


def test_corrupt_exe_sidecar_degrades_to_hlo_tier_not_retrace(tmp_path):
    """Tier degradation is one step at a time: a blown executable blob
    still leaves the run with the stored lowering (one compile, no
    retrace), and the degradation is counted and named."""
    root = str(tmp_path / "hlo")
    plan = ExecutionPlan(names=("pathfinder",), **FAST)
    Engine(cache_dir=root).run(plan)
    version_dir = _version_dir(root)
    for entry in os.listdir(version_dir):
        if entry.endswith(".exe"):
            with open(os.path.join(version_dir, entry), "wb") as f:
                f.write(b"not an executable")

    eng = Engine(cache_dir=root)
    res = eng.run(plan)
    dc = eng.disk_cache
    assert [r.status for r in res.records] == ["ok"]
    assert dc.hits == 1 and dc.hlo_hits == 1 and dc.exe_hits == 0
    assert dc.xla_compiles == 1  # tier 2 paid exactly one compile
    assert dc.exe_fallbacks == 1
    assert dc.last_exe_fallback is not None and "pathfinder" in dc.last_exe_fallback
    assert dc.fallback_count == 0  # never fell all the way back
    assert dc.misses == 0


def test_fallbacks_are_counted_and_explained_not_silent(tmp_path, capsys):
    """A present-but-unusable entry is a diagnosable *fallback* (counter +
    reason, printed by verbose engine runs); a simply-absent entry is an
    ordinary cold miss and records no reason."""
    root = str(tmp_path / "hlo")
    plan = ExecutionPlan(names=("pathfinder",), **FAST)

    cold = Engine(cache_dir=root)
    cold.run(plan)
    assert cold.disk_cache.fallback_count == 0  # cold miss, no fallback
    assert cold.disk_cache.last_fallback is None

    version_dir = _version_dir(root)
    for entry in os.listdir(version_dir):
        with open(os.path.join(version_dir, entry), "w") as f:
            f.write("{not json")

    eng = Engine(cache_dir=root)
    eng.run(plan, verbose=True)
    dc = eng.disk_cache
    assert dc.fallback_count == 1
    assert dc.last_fallback is not None
    assert "pathfinder" in dc.last_fallback  # which key fell back...
    assert "JSONDecodeError" in dc.last_fallback  # ...and why
    assert dc.fallback_reasons == [dc.last_fallback]
    out = capsys.readouterr().out
    assert "hlocache:" in out and "fallbacks=1" in out
    assert "JSONDecodeError" in out


def test_suite_cli_prints_cache_summary_with_cache_dir(tmp_path, capsys):
    from repro.core.suite import main

    rc = main([
        "--names", "pathfinder", "--cache-dir", str(tmp_path / "hlo"),
        "--iters", "1", "--warmup", "0", "--no-backward",
    ])
    assert rc == 0
    err = capsys.readouterr().err
    assert "hlocache:" in err and "stores=1" in err


def test_disk_cache_persists_and_restores_sharded_executables(tmp_path):
    """Multi-device executables used to be a recorded cache *skip*; they
    are now a first-class sharded tier (topology-keyed, serialized via
    jax.experimental.serialize_executable). Cold run stores; a warm run
    in a fresh process restores with zero XLA compiles."""
    import subprocess
    import sys
    import textwrap

    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = src
    script = textwrap.dedent(f"""
        from repro.core.engine import Engine
        from repro.core.plan import ExecutionPlan, Placement

        eng = Engine(cache_dir={str(tmp_path / 'hlo')!r})
        res = eng.run(ExecutionPlan(
            names=("gemm_f32_nn",), preset=0, iters=1, warmup=0,
            include_backward=False,
            placement=Placement(devices=4, mode="shard"),
        ))
        assert res.records[0].status == "ok", res.records[0].error
        dc = eng.disk_cache
        assert dc.skips == 0, dc.last_skip
        assert dc.stores == 1, dc.stores
        assert dc.exe_stores == 1, dc.exe_stores
        print("COLD-OK")
    """)
    out = subprocess.run(
        [sys.executable, "-c", script], env=env, capture_output=True,
        text=True, timeout=420,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"

    warm = textwrap.dedent(f"""
        from repro.core.engine import Engine
        from repro.core.plan import ExecutionPlan, Placement

        eng = Engine(cache_dir={str(tmp_path / 'hlo')!r})
        res = eng.run(ExecutionPlan(
            names=("gemm_f32_nn",), preset=0, iters=1, warmup=0,
            include_backward=False,
            placement=Placement(devices=4, mode="shard"),
        ))
        assert res.records[0].status == "ok", res.records[0].error
        dc = eng.disk_cache
        assert dc.hits == 1, dc.counter_dict()
        assert dc.exe_hits == 1, dc.counter_dict()
        assert dc.misses == 0, dc.counter_dict()
        # The whole point: restoring a sharded executable performs no
        # XLA compilation at all.
        assert dc.xla_compiles == 0, dc.counter_dict()
        print("WARM-OK")
    """)
    out = subprocess.run(
        [sys.executable, "-c", warm], env=env, capture_output=True,
        text=True, timeout=420,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
