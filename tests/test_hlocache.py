"""Persistent HLO-text compile cache: warm runs skip retracing, failures
fall back to the normal trace-and-compile path, and entries are versioned
by toolchain."""

import json
import os

import jax

from repro.core.engine import Engine
from repro.core.plan import ExecutionPlan

FAST = dict(preset=0, iters=1, warmup=0, include_backward=False)


def _version_dir(root: str) -> str:
    (sub,) = os.listdir(root)  # exactly one toolchain dir for this process
    return os.path.join(root, sub)


def test_cold_run_populates_cache_dir_with_versioned_entries(tmp_path):
    root = str(tmp_path / "hlo")
    eng = Engine(cache_dir=root)
    res = eng.run(ExecutionPlan(names=("pathfinder", "softmax"), **FAST))
    assert [r.status for r in res.records] == ["ok", "ok"]
    assert eng.disk_cache.stores == 2
    assert eng.disk_cache.hits == 0
    version_dir = _version_dir(root)
    # Versioned by toolchain AND a content hash of the repro package, so
    # an edited kernel misses instead of replaying its old lowering.
    assert os.path.basename(version_dir).startswith(
        f"jax-{jax.__version__}-{jax.default_backend()}-"
    )
    entries = os.listdir(version_dir)
    assert len(entries) == 2 and all(e.endswith(".json") for e in entries)
    payload = json.load(open(os.path.join(version_dir, entries[0])))
    assert payload["hlo"].lstrip().startswith("module")
    assert "cost" in payload and "memory" in payload


def test_warm_run_hits_disk_and_matches_cold_records(tmp_path):
    root = str(tmp_path / "hlo")
    plan = ExecutionPlan(names=("pathfinder",), **FAST)
    cold = Engine(cache_dir=root).run(plan)

    warm_engine = Engine(cache_dir=root)
    warm = warm_engine.run(plan)
    assert warm_engine.disk_cache.hits == 1
    assert warm_engine.disk_cache.misses == 0
    (c,), (w,) = cold.records, warm.records
    assert w.status == "ok"
    assert w.name == c.name
    # The stored characterization reproduces the roofline analysis.
    assert w.dominant == c.dominant
    assert w.derived == c.derived
    assert w.us_per_call > 0


def test_corrupt_cache_entry_falls_back_to_retrace(tmp_path):
    root = str(tmp_path / "hlo")
    plan = ExecutionPlan(names=("pathfinder",), **FAST)
    Engine(cache_dir=root).run(plan)
    version_dir = _version_dir(root)
    for entry in os.listdir(version_dir):
        with open(os.path.join(version_dir, entry), "w") as f:
            f.write("{not json")

    eng = Engine(cache_dir=root)
    res = eng.run(plan)
    assert [r.status for r in res.records] == ["ok"]
    assert eng.disk_cache.hits == 0
    assert eng.disk_cache.misses == 1
    assert eng.disk_cache.stores == 1  # the retrace re-stored a good entry


def test_fallbacks_are_counted_and_explained_not_silent(tmp_path, capsys):
    """A present-but-unusable entry is a diagnosable *fallback* (counter +
    reason, printed by verbose engine runs); a simply-absent entry is an
    ordinary cold miss and records no reason."""
    root = str(tmp_path / "hlo")
    plan = ExecutionPlan(names=("pathfinder",), **FAST)

    cold = Engine(cache_dir=root)
    cold.run(plan)
    assert cold.disk_cache.fallback_count == 0  # cold miss, no fallback
    assert cold.disk_cache.last_fallback is None

    version_dir = _version_dir(root)
    for entry in os.listdir(version_dir):
        with open(os.path.join(version_dir, entry), "w") as f:
            f.write("{not json")

    eng = Engine(cache_dir=root)
    eng.run(plan, verbose=True)
    dc = eng.disk_cache
    assert dc.fallback_count == 1
    assert dc.last_fallback is not None
    assert "pathfinder" in dc.last_fallback  # which key fell back...
    assert "JSONDecodeError" in dc.last_fallback  # ...and why
    assert dc.fallback_reasons == [dc.last_fallback]
    out = capsys.readouterr().out
    assert "hlocache:" in out and "fallbacks=1" in out
    assert "JSONDecodeError" in out


def test_suite_cli_prints_cache_summary_with_cache_dir(tmp_path, capsys):
    from repro.core.suite import main

    rc = main([
        "--names", "pathfinder", "--cache-dir", str(tmp_path / "hlo"),
        "--iters", "1", "--warmup", "0", "--no-backward",
    ])
    assert rc == 0
    err = capsys.readouterr().err
    assert "hlocache:" in err and "stores=1" in err


def test_disk_cache_skips_multi_device_entries(tmp_path):
    import subprocess
    import sys
    import textwrap

    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = src
    script = textwrap.dedent(f"""
        from repro.core.engine import Engine
        from repro.core.plan import ExecutionPlan, Placement

        eng = Engine(cache_dir={str(tmp_path / 'hlo')!r})
        res = eng.run(ExecutionPlan(
            names=("gemm_f32_nn",), preset=0, iters=1, warmup=0,
            include_backward=False,
            placement=Placement(devices=4, mode="shard"),
        ))
        assert res.records[0].status == "ok", res.records[0].error
        assert eng.disk_cache.stores == 0, eng.disk_cache.stores
        print("OK")
    """)
    out = subprocess.run(
        [sys.executable, "-c", script], env=env, capture_output=True,
        text=True, timeout=420,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
