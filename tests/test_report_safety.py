"""Report crash-safety and loader error contracts.

A run that dies mid-suite must still leave a parseable JSONL report
(metadata header + every completed record); the loaders must tolerate a
torn final line and turn missing/empty reports into one-line
:class:`ReportError` messages rather than tracebacks.
"""

import pytest

from repro.core.engine import Engine
from repro.core.plan import ExecutionPlan
from repro.core.registry import BenchmarkSpec, get_benchmark
from repro.core.results import (
    BenchmarkRecord,
    JsonlReportWriter,
    ReportError,
    RunMetadata,
    load_records,
    load_run,
)

FAST = dict(preset=0, iters=1, warmup=0, include_backward=False)


def _exit_bomb(**_kw):
    # BaseException-adjacent: escapes the engine's per-benchmark Exception
    # isolation, like a Ctrl-C or a watchdog kill would.
    raise SystemExit("suite killed mid-run")


_EXIT_BOMB = BenchmarkSpec(
    name="zz_exit_bomb", level=0, dwarf=None, domain=None,
    cuda_feature=None, tpu_feature=None, presets={0: {}}, build=_exit_bomb,
)


def test_crash_mid_suite_leaves_parseable_jsonl(tmp_path):
    """SystemExit after one completed benchmark: the JSONL file still
    carries the metadata header and the completed record."""
    path = str(tmp_path / "crash.jsonl")
    plan = ExecutionPlan(
        specs=(get_benchmark("maxflops_bf16"), _EXIT_BOMB), **FAST
    )
    with pytest.raises(SystemExit, match="mid-run"):
        Engine().run(plan, jsonl_path=path)
    meta, recs = load_run(path)
    assert meta is not None and meta.backend
    assert len(recs) == 1
    assert recs[0].status == "ok" and recs[0].name.startswith("maxflops")


def test_abandoned_writer_plus_torn_line_still_loads(tmp_path):
    """Records are flushed as written: a writer that is never closed (hard
    crash) plus a torn final line still yields every complete record."""
    path = str(tmp_path / "torn.jsonl")
    meta = RunMetadata.capture(preset=0)
    writer = JsonlReportWriter(path, meta)
    recs = Engine().run(ExecutionPlan(names=("pathfinder",), **FAST)).records
    for r in recs:
        writer.write(r)
    # No writer.close(): simulate the process dying, then a torn write.
    with open(path, "a") as f:
        f.write('{"kind": "record", "name": "half-writ')
    loaded_meta, loaded = load_run(path)
    assert loaded_meta == meta
    assert loaded == recs


def test_torn_line_mid_file_still_raises(tmp_path):
    """Only the *final* line may be torn (crash residue); corruption
    elsewhere in the file is a real error and must surface."""
    import dataclasses
    import json

    path = tmp_path / "midtorn.jsonl"
    rec = json.dumps(
        {"kind": "record", **dataclasses.asdict(BenchmarkRecord(
            name="x", level=0, dwarf=None, domain=None, preset=0,
            us_per_call=1.0, achieved_gflops=0.0, achieved_gbps=0.0,
            compute_util10=0, memory_util10=0, dominant="memory",
        ))}
    )
    # A lone torn line is also the final line -> tolerated, zero records.
    path.write_text('{"kind": "meta", "torn')
    meta, recs = load_run(str(path))
    assert meta is None and recs == []
    # A torn first line with records after it is corruption, not residue.
    path.write_text('{"kind": "meta", "torn\n' + rec + "\n")
    with pytest.raises(json.JSONDecodeError):
        load_run(str(path))


def test_load_run_missing_file_is_one_line_report_error(tmp_path):
    missing = str(tmp_path / "nope.jsonl")
    with pytest.raises(ReportError) as exc:
        load_run(missing)
    msg = str(exc.value)
    assert "nope.jsonl" in msg and "\n" not in msg
    with pytest.raises(ReportError):
        load_records(missing)


def test_load_run_empty_file_is_report_error(tmp_path):
    path = tmp_path / "empty.jsonl"
    path.write_text("")
    with pytest.raises(ReportError, match="empty"):
        load_run(str(path))
    path.write_text("   \n\n")
    with pytest.raises(ReportError, match="empty"):
        load_run(str(path))


def test_load_run_bad_legacy_json_is_report_error(tmp_path):
    path = tmp_path / "bad.json"
    path.write_text("[{broken")
    with pytest.raises(ReportError, match="not valid JSON"):
        load_run(str(path))


def test_report_error_is_a_value_error():
    # CLI catch sites use `except (PlanError, ValueError)`; ReportError
    # must flow through them.
    assert issubclass(ReportError, ValueError)
