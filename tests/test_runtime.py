"""Runtime: sharding rules, elastic planning, straggler policy."""

import numpy as np
import pytest

import jax
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, get_config, get_smoke_config
from repro.models import Model
from repro.runtime.elastic import choose_submesh, plan_remesh
from repro.runtime.sharding import ShardingRules, param_pspecs, zero_pspecs
from repro.runtime.straggler import StragglerMonitor


class _FakeMesh:
    """Shape-only stand-in so sharding rules are testable on 1 device."""

    def __init__(self, shape: dict):
        self.shape = shape


def _rules(data=16, model=16):
    return ShardingRules(mesh=_FakeMesh({"data": data, "model": model}))


@pytest.mark.parametrize("arch", ARCHS)
def test_param_specs_are_valid_for_full_configs(arch):
    """Every full-config param leaf gets a spec whose sharded dims divide."""
    cfg = get_config(arch)
    model = Model(cfg, remat=False)
    params = jax.eval_shape(model.init, jax.random.key(0))
    rules = _rules()
    specs = param_pspecs(params, rules)
    flat_p = jax.tree.leaves(params)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_p) == len(flat_s)
    n_sharded = 0
    for leaf, spec in zip(flat_p, flat_s):
        for i, axis in enumerate(spec):
            if axis == "model":
                assert leaf.shape[i] % 16 == 0, (leaf.shape, spec)
                n_sharded += 1
    # The big tensors must actually shard: >50% of parameter BYTES.
    sharded_bytes = sum(
        np.prod(l.shape) for l, s in zip(flat_p, flat_s) if any(a == "model" for a in s)
    )
    total = sum(np.prod(l.shape) for l in flat_p)
    assert sharded_bytes / total > 0.95, f"{arch}: only {sharded_bytes/total:.2%} sharded"


def test_mixtral_experts_fall_back_to_ff_sharding():
    """8 experts don't divide the 16-way model axis → d_ff sharding."""
    cfg = get_config("mixtral-8x22b")
    model = Model(cfg, remat=False)
    params = jax.eval_shape(model.init, jax.random.key(0))
    specs = param_pspecs(params, _rules())
    moe_spec = specs["blocks"][0]["ffn"]["w_gate"]
    # stacked leaf: (periods, E=8, d, ff) → model axis on ff (dim 3)
    assert tuple(moe_spec) == (None, None, None, "model")


def test_dbrx_experts_use_expert_parallelism():
    cfg = get_config("dbrx-132b")
    model = Model(cfg, remat=False)
    params = jax.eval_shape(model.init, jax.random.key(0))
    specs = param_pspecs(params, _rules())
    moe_spec = specs["blocks"][0]["ffn"]["w_gate"]
    # 16 experts divide 16 → EP on the expert dim
    assert tuple(moe_spec) == (None, "model", None, None)


def test_zero_pspecs_add_data_axis():
    cfg = get_smoke_config("granite-8b")
    model = Model(cfg, remat=False)
    params = jax.eval_shape(model.init, jax.random.key(0))
    rules = ShardingRules(mesh=_FakeMesh({"data": 2, "model": 2}))
    base = param_pspecs(params, rules)
    z = zero_pspecs(base, params, rules)
    flat_b = jax.tree.leaves(base, is_leaf=lambda x: isinstance(x, P))
    flat_z = jax.tree.leaves(z, is_leaf=lambda x: isinstance(x, P))
    extended = sum(
        1 for b, zz in zip(flat_b, flat_z)
        if sum(a is not None for a in zz) > sum(a is not None for a in b)
    )
    assert extended > 0


def test_choose_submesh():
    assert choose_submesh(256, model=16) == (16, 16)
    assert choose_submesh(255, model=16) == (8, 16)  # lost one chip → 2^k data
    assert choose_submesh(17, model=16) == (1, 16)
    with pytest.raises(ValueError):
        choose_submesh(15, model=16)


def test_plan_remesh_reports_ratio():
    plan = plan_remesh((16, 16), 240)
    assert plan.model == 16 and plan.data == 8
    assert plan.global_batch_ratio == 0.5
    assert plan.devices_idle == 240 - 128


def test_straggler_monitor_flags_sustained_only():
    mon = StragglerMonitor(threshold=1.5, sustained=3)
    for _ in range(20):
        assert not mon.record(1.0)
    assert not mon.record(3.0)  # one-off spike
    assert not mon.record(3.0)
    assert mon.record(3.0)  # third consecutive → trigger
    assert mon.triggered == 1
    # baseline must not have drifted up from slow steps
    assert mon.baseline < 1.1
