"""Flash-attention kernel vs dense oracle: GQA / causal / SWA / decode sweep."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_pallas


def _qkv(rng, B, Hq, Hkv, T, S, D, dtype=np.float32):
    q = jnp.asarray(rng.normal(size=(B, Hq, T, D)).astype(dtype))
    k = jnp.asarray(rng.normal(size=(B, Hkv, S, D)).astype(dtype))
    v = jnp.asarray(rng.normal(size=(B, Hkv, S, D)).astype(dtype))
    return q, k, v


CASES = [
    # B, Hq, Hkv, T, S, D, causal, window
    (1, 2, 2, 32, 32, 16, False, None),
    (2, 4, 2, 32, 32, 16, True, None),  # GQA causal
    (1, 8, 1, 17, 17, 8, True, None),  # MQA, ragged T
    (2, 4, 4, 33, 33, 16, True, 9),  # SWA
    (1, 4, 2, 1, 64, 16, True, None),  # decode: 1 query vs cache
    (1, 4, 2, 1, 64, 16, True, 17),  # SWA decode
    (2, 2, 2, 16, 48, 8, True, None),  # chunked prefill (kv_len > q_len)
]


@pytest.mark.parametrize("B,Hq,Hkv,T,S,D,causal,window", CASES)
def test_flash_matches_ref(rng, B, Hq, Hkv, T, S, D, causal, window):
    q, k, v = _qkv(rng, B, Hq, Hkv, T, S, D)
    out = flash_attention_pallas(
        q, k, v, causal=causal, window=window, block_q=16, block_k=16, interpret=True
    )
    want = ref.attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_flash_bf16(rng):
    q, k, v = _qkv(rng, 1, 2, 2, 32, 32, 16)
    qb, kb, vb = (x.astype(jnp.bfloat16) for x in (q, k, v))
    out = flash_attention_pallas(qb, kb, vb, causal=True, block_q=16, block_k=16, interpret=True)
    want = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want), rtol=2e-2, atol=2e-2
    )


def test_flash_swa_equals_model_sdpa(rng):
    """The kernel and the model-layer sdpa agree (two independent impls)."""
    from repro.models.layers import sdpa

    q, k, v = _qkv(rng, 2, 4, 2, 24, 24, 16)
    out = flash_attention_pallas(q, k, v, causal=True, window=7, block_q=8,
                                 block_k=8, interpret=True)
    got2 = sdpa(
        jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2),
        causal=True, window=7,
    )
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(jnp.swapaxes(got2, 1, 2)), rtol=2e-4, atol=2e-4
    )


def test_flash_grad_via_ref_path():
    """Training path (ops.attention mode=ref) is differentiable and finite."""
    from repro.kernels import ops

    key = jax.random.key(0)
    q = jax.random.normal(key, (1, 2, 8, 4))

    def f(q):
        return jnp.sum(ops.attention(q, q, q, causal=True, mode="ref"))

    g = jax.grad(f)(q)
    assert np.all(np.isfinite(np.asarray(g)))
