# Shared fixtures. NOTE: no XLA_FLAGS here — tests must see the real single
# CPU device; multi-device tests spawn subprocesses (test_distributed.py).

import numpy as np
import pytest

import jax


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture
def key():
    return jax.random.key(0)
