"""Pallas matmul kernel vs pure-jnp oracle: shape/dtype sweep."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.matmul import matmul_pallas

SHAPES = [
    (8, 8, 8),
    (128, 128, 128),
    (130, 70, 50),  # padding in all dims
    (1, 256, 33),
    (257, 1, 128),
]


@pytest.mark.parametrize("m,k,n", SHAPES)
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_matmul_matches_ref(rng, m, k, n, dtype):
    a = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32)).astype(dtype)
    b = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32)).astype(dtype)
    out = matmul_pallas(a, b, block_m=64, block_n=64, block_k=32, interpret=True)
    want = ref.matmul_ref(a, b)
    tol = 1e-5 if dtype == np.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32), rtol=tol, atol=tol
    )


def test_matmul_block_shapes_invariance(rng):
    """Result is independent of BlockSpec tiling."""
    a = jnp.asarray(rng.normal(size=(96, 64)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(64, 80)).astype(np.float32))
    outs = [
        matmul_pallas(a, b, block_m=bm, block_n=bn, block_k=bk, interpret=True)
        for bm, bn, bk in [(32, 16, 16), (96, 80, 64), (48, 40, 8)]
    ]
    for o in outs[1:]:
        # fp32 accumulation order differs across tilings — tolerance only.
        np.testing.assert_allclose(
            np.asarray(outs[0]), np.asarray(o), rtol=1e-3, atol=1e-5
        )
