"""Hypothesis property tests on system invariants."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

import jax.numpy as jnp

from repro.kernels.bitonic_sort import bitonic_sort_pallas
from repro.kernels.prefix_scan import prefix_scan_pallas
from repro.kernels.softmax import softmax_pallas

_settings = settings(max_examples=20, deadline=None)

floats = st.floats(-100, 100, allow_nan=False, width=32)


@_settings
@given(st.lists(floats, min_size=1, max_size=200), st.integers(1, 64))
def test_prefix_scan_equals_cumsum(xs, bn):
    x = jnp.asarray(np.array(xs, np.float32))
    out = prefix_scan_pallas(x, block_n=bn, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.cumsum(xs, dtype=np.float32),
                               rtol=1e-4, atol=1e-3)


@_settings
@given(st.integers(0, 6).flatmap(
    lambda p: st.lists(st.integers(-(1 << 20), 1 << 20), min_size=2**p, max_size=2**p)
))
def test_bitonic_sort_is_sorted_permutation(keys):
    k = jnp.asarray(np.array(keys, np.int32))
    v = jnp.arange(len(keys), dtype=jnp.int32)
    ko, vo = bitonic_sort_pallas(k, v, interpret=True)
    ko, vo = np.asarray(ko), np.asarray(vo)
    assert np.all(np.diff(ko) >= 0)
    assert sorted(vo.tolist()) == list(range(len(keys)))  # permutation
    np.testing.assert_array_equal(np.array(keys)[vo], ko)  # pairing


@_settings
@given(
    st.integers(1, 8), st.integers(1, 40),
    st.floats(-5, 5, allow_nan=False, width=32),
)
def test_softmax_simplex_and_shift_invariance(rows, cols, shift):
    rng = np.random.default_rng(42)
    x = jnp.asarray(rng.normal(size=(rows, cols)).astype(np.float32) * 3)
    out = softmax_pallas(x, block_rows=8, block_cols=16, interpret=True)
    o = np.asarray(out)
    np.testing.assert_allclose(o.sum(-1), 1.0, rtol=1e-5)
    assert np.all(o >= 0)
    out2 = softmax_pallas(x + shift, block_rows=8, block_cols=16, interpret=True)
    np.testing.assert_allclose(o, np.asarray(out2), rtol=1e-4, atol=1e-5)


@_settings
@given(st.integers(1, 4), st.integers(2, 16), st.integers(8, 32))
def test_rope_preserves_norm(b, t, half_pairs):
    """Rotary embedding is a rotation: per-pair norms are invariant."""
    from repro.models.config import ArchConfig
    from repro.models.layers import apply_rope, rope_angles

    hd = 2 * half_pairs
    cfg = ArchConfig(
        name="t", family="dense", n_layers=2, d_model=hd, n_heads=1,
        n_kv_heads=1, head_dim=hd, d_ff=8, vocab=16,
    )
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.normal(size=(b, t, 1, hd)).astype(np.float32))
    pos = jnp.broadcast_to(jnp.arange(t)[None], (b, t))
    cos, sin = rope_angles(cfg, pos)
    y = apply_rope(x, cos, sin)
    # pairwise (i, i+half) norms preserved
    nx = np.asarray(x[..., :half_pairs] ** 2 + x[..., half_pairs:] ** 2)
    ny = np.asarray(y[..., :half_pairs] ** 2 + y[..., half_pairs:] ** 2)
    np.testing.assert_allclose(nx, ny, rtol=1e-4, atol=1e-4)


@_settings
@given(st.integers(2, 6), st.integers(1, 3), st.integers(1, 4))
def test_moe_routing_mass_conservation(n_experts, top_k, groups):
    """Router combine weights sum to 1 per token (before capacity drops)."""
    from repro.models.moe import _route

    top_k = min(top_k, n_experts)
    rng = np.random.default_rng(3)
    logits = jnp.asarray(rng.normal(size=(groups * 8, n_experts)).astype(np.float32))
    w = _route(logits, top_k)
    wn = np.asarray(w)
    np.testing.assert_allclose(wn.sum(-1), 1.0, rtol=1e-5)
    assert np.all((wn > 0).sum(-1) <= top_k)


@_settings
@given(st.integers(1, 30), st.integers(1, 30))
def test_nw_score_vs_oracle(n_prefix, seed):
    from repro.bench.level2.nw import nw_oracle, nw_score

    rng = np.random.default_rng(seed)
    n = max(2, n_prefix)
    a = rng.integers(0, 4, n).astype(np.int32)
    b = rng.integers(0, 4, n).astype(np.int32)
    got = int(nw_score(jnp.asarray(a), jnp.asarray(b)))
    assert got == nw_oracle(a, b)


@_settings
@given(st.integers(0, 1000))
def test_synthetic_data_deterministic(step):
    from repro.data import SyntheticLM

    d = SyntheticLM(vocab=64, batch=2, seq=8, seed=1)
    b1, b2 = d.batch_at(step), d.batch_at(step)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
    # labels are next-token shifted
    d2 = SyntheticLM(vocab=64, batch=2, seq=8, seed=2)
    assert not np.array_equal(
        np.asarray(d.batch_at(step)["tokens"]), np.asarray(d2.batch_at(step)["tokens"])
    ) or step < 0


@_settings
@given(st.floats(0.1, 10, allow_nan=False), st.integers(1, 50))
def test_adamw_converges_on_quadratic(scale, steps):
    from repro.optim import AdamW

    opt = AdamW(weight_decay=0.0)
    p = {"w": jnp.asarray([float(scale)])}
    s = opt.init(p)
    for _ in range(steps):
        g = {"w": 2 * p["w"]}  # d/dw w²
        p, s = opt.update(g, s, p, lr=0.1)
    assert abs(float(p["w"][0])) <= scale + 1e-6
